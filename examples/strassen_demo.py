"""The paper's own configuration (section 3): a 4x4 matrix multiplier built from
2x2-PE Strassen recursion with the run-time-reconfigurable multiplier inside.

    PYTHONPATH=src python examples/strassen_demo.py
"""
import numpy as np
import jax.numpy as jnp

from repro.configs import paper_4x4
from repro.core import Mode, mp_matmul
from repro.core.strassen import leaf_products, strassen_matmul

rng = np.random.default_rng(0)
A = jnp.asarray(rng.standard_normal((4, 4)).astype(np.float32))
B = jnp.asarray(rng.standard_normal((4, 4)).astype(np.float32))
exact = np.asarray(A, np.float64) @ np.asarray(B, np.float64)

print(f"paper config: {paper_4x4.MATRIX_SIZE}x{paper_4x4.MATRIX_SIZE} matrix, "
      f"{paper_4x4.PE_SIZE}x{paper_4x4.PE_SIZE} PEs, Strassen depth {paper_4x4.STRASSEN_DEPTH}")
print(f"leaf products: {leaf_products(paper_4x4.STRASSEN_DEPTH)} (classical would use 8)")

for mode in (Mode.M8, Mode.M16, Mode.M24):
    def leaf(x, y, m=mode):
        return mp_matmul(x, y, m)

    out = strassen_matmul(A, B, depth=paper_4x4.STRASSEN_DEPTH, leaf_fn=leaf, align=2)
    err = np.abs(np.asarray(out, np.float64) - exact).max()
    print(f"  PE mode {mode.name}: max abs err = {err:.2e}")

# the parallel-PE claim (section 3): all 7 sub-products are data-independent ->
# on TPU they lower to independent dots XLA schedules in parallel
print("All 7 PE products are independent block dots (XLA schedules them concurrently)")
