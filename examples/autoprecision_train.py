"""Beyond-paper demo: per-layer precision policies during training.

Trains the same tiny LM under three RMPM policies and compares loss curves —
the paper's power/accuracy dial, realized as a training-quality/cost dial.

    PYTHONPATH=src python examples/autoprecision_train.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Mode
from repro.core.policy import PrecisionPolicy
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.optim import adamw
from repro.train.step import TrainConfig, init_train_state, make_train_step

POLICIES = {
    "paper_baseline_M24(6 passes)": PrecisionPolicy(default=Mode.M24),
    "fast_M8(1 pass)": PrecisionPolicy(default=Mode.M8),
    "mixed(M8 bulk,M16 attn/logits)": PrecisionPolicy(
        default=Mode.M8, overrides=(("attn_qk", Mode.M16), ("logits", Mode.M16))
    ),
}
STEPS = 60


def run(policy):
    cfg = get_config("qwen1.5-0.5b")
    cfg = dataclasses.replace(
        cfg, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=512, remat=False, attn_chunk=64,
    ).with_policy(policy)
    model = build_model(cfg)
    tcfg = TrainConfig(optimizer=adamw.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=STEPS))
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=0)
    state = init_train_state(model, jax.random.key(0), tcfg)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, batch=8, seed=0)
    losses = []
    for _ in range(STEPS):
        state, m = step(state, data.next_batch())
        losses.append(float(m["loss"]))
    return losses


def main():
    print(f"training the same model under {len(POLICIES)} precision policies, {STEPS} steps")
    results = {}
    for name, pol in POLICIES.items():
        losses = run(pol)
        results[name] = losses
        print(f"  {name:34s} loss {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f}")
    base = np.mean(results["paper_baseline_M24(6 passes)"][-5:])
    for name, losses in results.items():
        gap = np.mean(losses[-5:]) - base
        print(f"  final-loss gap vs baseline: {name:34s} {gap:+.4f}")


if __name__ == "__main__":
    main()
