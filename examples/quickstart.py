"""Quickstart: the run-time-reconfigurable multi-precision matmul engine.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the paper's core ideas in 60 lines:
  * one executable, precision selected at RUN TIME (mode bits / lax.switch)
  * auto-mode (paper mode 1): operand probe picks the cheapest precision
  * the precision/cost ladder (paper Tables 2/7/9)
  * Strassen block matmul with 7 leaf products (paper section 3.1)
  * the planner (repro.plan): shape+accuracy -> (mode, depth, impl)
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    MODE_PASSES, Mode, auto_mode, mp_matmul, mp_matmul_runtime, strassen_matmul,
)

rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((256, 256)).astype(np.float32))
b = jnp.asarray(rng.standard_normal((256, 256)).astype(np.float32))
exact = np.asarray(a, np.float64) @ np.asarray(b, np.float64)


def rel_err(out):
    return np.abs(np.asarray(out, np.float64) - exact).max() / np.abs(exact).max()


print("=== precision ladder (static modes) ===")
for mode in (Mode.M8, Mode.M16, Mode.M24):
    out = mp_matmul(a, b, mode)
    print(f"  {mode.name}: {MODE_PASSES[mode]} MXU pass(es), rel_err={rel_err(out):.2e}")

print("=== run-time reconfiguration: ONE compiled executable ===")
fn = jax.jit(mp_matmul_runtime)  # mode is a traced scalar — no recompile
for mode_bits in (1, 2, 3):
    out = fn(a, b, jnp.int32(mode_bits))
    print(f"  mode bits={mode_bits:03b}: rel_err={rel_err(out):.2e}")
print(f"  executables compiled: {fn._cache_size()} (the paper's 'no re-synthesis')")

print("=== auto-mode (paper mode 1 / Fig 7) ===")
ai = jnp.asarray(rng.integers(0, 100, (256, 256)).astype(np.float32))
print(f"  float operands  -> mode {Mode(int(auto_mode(a, b))).name}")
print(f"  integer operands-> mode {Mode(int(auto_mode(ai, ai))).name}")
out = fn(ai, ai, jnp.int32(0))  # AUTO
exact_int = np.asarray(ai, np.float64) @ np.asarray(ai, np.float64)
print(f"  integer product exact: {np.array_equal(np.asarray(out, np.float64), exact_int)}")

print("=== Strassen (7 multiplications per 2x2 level) ===")
out = strassen_matmul(a, b, depth=1, align=64)
print(f"  depth=1: rel_err={rel_err(out):.2e}, leaf matmuls=7 (classical: 8)")
out = mp_matmul(a, b, Mode.M16, strassen_depth=1)
print(f"  Strassen OUTSIDE x RMPM M16 INSIDE (the paper's full stack): rel_err={rel_err(out):.2e}")

print("=== the planner: shape + accuracy -> (mode, depth, impl) ===")
from repro.plan import matmul as planned_matmul, plan_matmul  # noqa: E402

for n, acc in ((256, 2**-4), (4096, 2**-12), (16384, 2**-20)):
    p = plan_matmul((n, n), (n, n), accuracy=acc, backend="tpu")
    print(f"  ({n}x{n}) @ acc 2^{int(np.log2(acc))}: {p.mode.name}/"
          f"{p.impl}/depth={p.strassen_depth} ({p.cost.dominant}-bound)")
out = planned_matmul(a, b, accuracy=2**-12)  # plans for THIS backend, executes
print(f"  planned execution on {jax.default_backend()}: rel_err={rel_err(out):.2e}")
