"""Continuous-batching serving: the streaming submit/step/drain API, staggered
arrivals joining slots mid-flight, runtime precision policy, and int8
KV-cache quantization.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.policy import PRESETS
from repro.models import build_model
from repro.serve import Request, ServeEngine


def build(kv_dtype: str):
    cfg = get_config("qwen1.5-0.5b")
    cfg = dataclasses.replace(
        cfg, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab=512, remat=False, attn_chunk=64, kv_cache_dtype=kv_dtype,
    ).with_policy(PRESETS["native_f32"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def main():
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 512, rng.integers(4, 12)).astype(np.int32) for _ in range(6)]
    reqs = [Request(prompt=p, max_new=12, rid=i) for i, p in enumerate(prompts)]

    # streaming API: 6 ragged requests through 3 slots, two joining late —
    # they take over slots freed by earlier completions (mid-flight join)
    model, params = build("bfloat16")
    eng = ServeEngine(model, params, batch_slots=3, max_len=64)
    for r in reqs[:4]:
        eng.submit(r)
    for _ in range(4):
        for rid, tok in eng.step():
            print(f"  step event: req {rid} -> {tok}")
    for r in reqs[4:]:
        eng.submit(r)  # arrive while the first wave is still decoding
    outs = {"bfloat16": eng.drain()}
    print(eng.metrics.format_summary())

    # same workload, int8 KV cache (offline batch API on the same engine)
    model, params = build("int8")
    eng8 = ServeEngine(model, params, batch_slots=3, max_len=64)
    outs["int8"] = eng8.generate_batch(reqs)
    for kv_dtype in outs:
        print(f"kv_cache={kv_dtype}:")
        for rid in sorted(outs[kv_dtype]):
            print(f"  req {rid}: {outs[kv_dtype][rid]}")

    agree = sum(
        outs["bfloat16"][r.rid] == outs["int8"][r.rid] for r in reqs
    )
    print(f"int8-KV agrees with bf16-KV on {agree}/{len(reqs)} requests "
          f"(greedy decode; small divergence is the quantization trade)")


if __name__ == "__main__":
    main()
