"""End-to-end training driver: a ~100M-param qwen-family model trained for a
few hundred steps on synthetic data, with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 300 --resume   # restart

The model runs with the RMPM engine policy given by --policy (default
native_f32 for CPU speed; use fast_m8 / paper_baseline to execute the limb
engine end to end)."""
import argparse
import shutil

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.core.policy import PRESETS
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.optim import adamw
from repro.train.loop import LoopConfig, resume_or_init, train_loop
from repro.train.step import TrainConfig, init_train_state, make_train_step
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--policy", default="native_f32", choices=tuple(PRESETS))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()

    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    # ~100M params: qwen1.5-0.5b topology, trimmed vocab/width for CPU wall-time
    cfg = get_config("qwen1.5-0.5b")
    cfg = dataclasses.replace(
        cfg, n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, d_ff=1024,
        vocab=2048, remat=False, attn_chunk=128,
    ).with_policy(PRESETS[args.policy])
    model = build_model(cfg)
    n_params = None

    tcfg = TrainConfig(
        optimizer=adamw.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps),
        accum_steps=1,
    )
    train_step = jax.jit(make_train_step(model, tcfg), donate_argnums=0)

    data = SyntheticLM(vocab=cfg.vocab, seq_len=128, batch=8, seed=0)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    start, state = resume_or_init(
        mgr if args.resume else None, lambda: init_train_state(model, jax.random.key(0), tcfg)
    )
    if start:
        print(f"resumed from step {start} (elastic restore; data skip-ahead)")
        data.skip_to(start)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"model: {n_params/1e6:.1f}M params, policy={cfg.policy.describe()}")

    state, history = train_loop(
        train_step, state, data,
        LoopConfig(total_steps=args.steps, checkpoint_every=100, log_every=20),
        ckpt_manager=mgr, start_step=start,
        on_metrics=lambda r: print(
            f"  step {r['step']:4d} loss={r['loss']:.4f} gnorm={r['grad_norm']:.2f} "
            f"dt={r['dt']*1e3:.0f}ms{' STRAGGLER' if r['straggler'] else ''}"
        ),
    )
    first = np.mean([h["loss"] for h in history[:10]])
    last = np.mean([h["loss"] for h in history[-10:]])
    stragglers = [h["step"] for h in history if h["straggler"]]
    print(f"loss: {first:.3f} -> {last:.3f}  (improved: {last < first})")
    print(f"straggler steps flagged: {stragglers[:5]}{'...' if len(stragglers)>5 else ''}")
    print(f"checkpoints: {mgr.all_steps()}")
    assert last < first, "training must reduce loss on the synthetic chain"


if __name__ == "__main__":
    main()
