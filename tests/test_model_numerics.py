"""Numerical correctness of the model building blocks against naive
references: flash attention vs dense softmax, SSD chunked vs sequential
recurrence, RG-LRU associative scan vs step loop, MoE routing invariants."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.policy import NATIVE_F32
from repro.models.layers import flash_attention
from repro.models import griffin as griffin_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib

POLICY = NATIVE_F32


def _naive_attention(q, k, v, causal=True, window=0):
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * (hd**-0.5)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    valid = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        valid &= kp <= qp
    if window:
        valid &= kp > qp - window
    s = jnp.where(valid[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, hd)


class TestFlashAttention:
    @pytest.mark.parametrize("sq,skv,chunk", [(32, 32, 8), (17, 17, 16), (64, 64, 64)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_naive(self, rng, sq, skv, chunk, causal):
        q = jnp.asarray(rng.standard_normal((2, sq, 4, 16)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((2, skv, 2, 16)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((2, skv, 2, 16)).astype(np.float32))
        out = flash_attention(q, k, v, POLICY, causal=causal, chunk=chunk)
        ref = _naive_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)

    def test_sliding_window(self, rng):
        q = jnp.asarray(rng.standard_normal((1, 48, 2, 8)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 48, 2, 8)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((1, 48, 2, 8)).astype(np.float32))
        out = flash_attention(q, k, v, POLICY, causal=True, window=8, chunk=16)
        ref = _naive_attention(q, k, v, causal=True, window=8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)

    def test_decode_position_mask(self, rng):
        # q at offset: only kv positions <= offset attend
        k = jnp.asarray(rng.standard_normal((1, 16, 1, 8)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((1, 16, 1, 8)).astype(np.float32))
        q = jnp.asarray(rng.standard_normal((1, 1, 1, 8)).astype(np.float32))
        out = flash_attention(q, k, v, POLICY, causal=True, q_offset=7, kv_len=16, chunk=4)
        ref = _naive_attention(
            jnp.pad(q, ((0, 0), (7, 8), (0, 0), (0, 0))), k, v, causal=True
        )[:, 7:8]
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


class TestSSD:
    def test_chunked_matches_sequential(self, rng):
        cfg = get_smoke_config("mamba2-2.7b").with_policy(POLICY)
        b, s, h, p, n = 2, 32, 4, 8, 16
        xh = jnp.asarray(rng.standard_normal((b, s, h, p)).astype(np.float32))
        dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, h)).astype(np.float32)))
        a = -jnp.exp(jnp.asarray(rng.standard_normal(h).astype(np.float32)))
        bm = jnp.asarray(rng.standard_normal((b, s, n)).astype(np.float32))
        cm = jnp.asarray(rng.standard_normal((b, s, n)).astype(np.float32))
        import dataclasses

        cfg = dataclasses.replace(cfg, ssm_chunk=8)
        y_chunk, final = ssm_lib._ssd_chunked(xh, dt, a, bm, cm, cfg)
        # sequential recurrence reference
        hstate = np.zeros((b, h, p, n))
        ys = np.zeros((b, s, h, p))
        for t in range(s):
            decay = np.exp(np.asarray(dt[:, t]) * np.asarray(a)[None, :])
            hstate = hstate * decay[..., None, None] + (
                np.asarray(dt[:, t])[..., None] * np.asarray(xh[:, t])
            )[..., None] * np.asarray(bm[:, t])[:, None, None, :]
            ys[:, t] = np.einsum("bhpn,bn->bhp", hstate, np.asarray(cm[:, t]))
        np.testing.assert_allclose(np.asarray(y_chunk), ys, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(final), hstate, rtol=2e-3, atol=2e-3)

    def test_train_decode_agree_end_to_end(self, rng):
        cfg = get_smoke_config("mamba2-2.7b").with_policy(POLICY)
        p = ssm_lib.mamba2_init(jax.random.key(0), cfg)
        x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)).astype(np.float32) * 0.2)
        y_train, _ = ssm_lib.mamba2_apply(p, x, cfg, state=None)
        st = ssm_lib.ssm_state_init(cfg, 1)
        y_dec, _ = ssm_lib.mamba2_apply(p, x, cfg, state=st)
        np.testing.assert_allclose(
            np.asarray(y_train), np.asarray(y_dec), rtol=5e-3, atol=5e-4
        )


class TestRGLRU:
    def test_scan_matches_step_loop(self, rng):
        b, s, w = 2, 24, 8
        x = jnp.asarray(rng.standard_normal((b, s, w)).astype(np.float32))
        r = jax.nn.sigmoid(jnp.asarray(rng.standard_normal((b, s, w)).astype(np.float32)))
        i = jax.nn.sigmoid(jnp.asarray(rng.standard_normal((b, s, w)).astype(np.float32)))
        lam = jnp.asarray(rng.standard_normal(w).astype(np.float32))
        h_seq, h_last = griffin_lib._rglru_scan(x, r, i, lam, None)
        log_a = griffin_lib._C * np.asarray(r) * np.log(
            1 / (1 + np.exp(-np.asarray(lam)))
        )[None, None, :]
        a = np.exp(log_a)
        href = np.zeros((b, w))
        out = np.zeros((b, s, w))
        for t in range(s):
            href = a[:, t] * href + np.sqrt(1 - a[:, t] ** 2) * (
                np.asarray(i[:, t]) * np.asarray(x[:, t])
            )
            out[:, t] = href
        np.testing.assert_allclose(np.asarray(h_seq), out, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_last), href, rtol=1e-4, atol=1e-5)

    def test_state_carrying_decode(self, rng):
        # split the sequence: scan(all) == scan(first half) then scan(second, h0)
        b, s, w = 1, 16, 4
        x = jnp.asarray(rng.standard_normal((b, s, w)).astype(np.float32))
        r = jax.nn.sigmoid(x * 0.3)
        i = jax.nn.sigmoid(-x * 0.2)
        lam = jnp.ones(w)
        full, _ = griffin_lib._rglru_scan(x, r, i, lam, None)
        h1, last1 = griffin_lib._rglru_scan(x[:, :8], r[:, :8], i[:, :8], lam, None)
        h2, _ = griffin_lib._rglru_scan(x[:, 8:], r[:, 8:], i[:, 8:], lam, last1)
        np.testing.assert_allclose(
            np.asarray(full), np.concatenate([h1, h2], axis=1), rtol=1e-5, atol=1e-6
        )


class TestMoE:
    def _cfg(self):
        return get_smoke_config("phi3.5-moe-42b-a6.6b").with_policy(POLICY)

    def test_output_shape_and_aux(self, rng):
        cfg = self._cfg()
        p = moe_lib.moe_init(jax.random.key(0), cfg)
        x = jnp.asarray(rng.standard_normal((2, 64, cfg.d_model)).astype(np.float32))
        out, aux = moe_lib.moe_apply(p, x, cfg)
        assert out.shape == x.shape
        assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound is 1

    def test_dispatch_respects_capacity(self, rng):
        ids = jnp.asarray(rng.integers(0, 4, (1, 32, 2)), jnp.int32)
        w = jnp.ones((1, 32, 2), jnp.float32) * 0.5
        dispatch, combine = moe_lib._dispatch_combine(ids, w, e=4, capacity=3)
        # each (expert, slot) holds at most one token
        per_slot = np.asarray(dispatch, np.float32).sum(axis=1)  # (G, E, C)
        assert per_slot.max() <= 1.0 + 1e-6
        # combine weight mass never exceeds dispatch mass
        assert float(combine.sum()) <= float(dispatch.sum()) + 1e-6

    def test_identical_tokens_get_identical_outputs(self, rng):
        cfg = self._cfg()
        p = moe_lib.moe_init(jax.random.key(0), cfg)
        x0 = rng.standard_normal((1, 1, cfg.d_model)).astype(np.float32)
        x = jnp.asarray(np.repeat(x0, 8, axis=1))
        out, _ = moe_lib.moe_apply(p, x, cfg)
        out = np.asarray(out)
        # first token (guaranteed within capacity) defines the reference;
        # tokens beyond capacity may be dropped (zero) — allowed by GShard
        ref = out[0, 0]
        for t in range(1, 8):
            ok_same = np.allclose(out[0, t], ref, rtol=1e-4, atol=1e-5)
            ok_dropped = np.allclose(out[0, t], 0, atol=1e-6) or (
                "shared" in p and True
            )
            assert ok_same or ok_dropped

    def test_decode_batch_grouping(self, rng):
        cfg = self._cfg()
        p = moe_lib.moe_init(jax.random.key(0), cfg)
        x = jnp.asarray(rng.standard_normal((8, 1, cfg.d_model)).astype(np.float32))
        out, _ = moe_lib.moe_apply(p, x, cfg)
        assert out.shape == (8, 1, cfg.d_model)
        assert bool(jnp.isfinite(out).all())
