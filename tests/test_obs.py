"""repro.obs: tracer ring, exporters, timeline, profiler, and the
engine-integration contracts — zero jit-visible cost when off, schema-valid
Chrome traces, and event streams that replay through the scheduler
invariant harness (tests/scheduler_model.py consumer mode)."""
import dataclasses
import json

import numpy as np
import pytest
import jax

from repro.configs import get_smoke_config
from repro.core.policy import NATIVE_F32
from repro.models import build_model
from repro.obs import (
    NULL_TRACER,
    Event,
    PhaseProfiler,
    TraceConfig,
    Tracer,
    precision_timeline,
    span_violations,
    to_chrome,
    to_prometheus,
    validate_chrome,
)
from repro.serve import (
    CacheConfig,
    Request,
    RequestClass,
    SchedulingConfig,
    ServeConfig,
    ServeEngine,
    Tenant,
)

from scheduler_model import FINISH, SUBMIT, check_replay, log_from_trace


def _tiny(arch="qwen1.5-0.5b", **over):
    cfg = get_smoke_config(arch).with_policy(NATIVE_F32)
    cfg = dataclasses.replace(cfg, **{"n_layers": 2, **over})
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _reqs(cfg, n, *, prompt_len=6, max_new=5, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab, prompt_len)
                    .astype(np.int32), max_new=max_new, rid=i, **kw)
            for i in range(n)]


class TestTracer:
    def test_ring_capacity_and_dropped(self):
        tr = Tracer(TraceConfig(capacity=4), clock=lambda: 0.0)
        for i in range(10):
            tr.emit("token", rid=i)
        assert tr.emitted == 10
        assert len(tr.events) == 4
        assert tr.dropped == 6
        assert [e.rid for e in tr.events] == [6, 7, 8, 9]  # oldest dropped

    def test_counters_gauges_and_step_stamp(self):
        tr = Tracer(clock=lambda: 1.5)
        tr.inc("x")
        tr.inc("x", 2)
        tr.set_gauge("g", 7)
        tr.step = 3
        tr.emit("decode_step")
        tr.emit("submit", step=9)  # explicit step overrides
        assert tr.counters["x"] == 3
        assert tr.gauges["g"] == 7.0
        assert [e.step for e in tr.events] == [3, 9]
        assert tr.events[0].ts == 1.5

    def test_invalid_capacity(self):
        with pytest.raises(ValueError, match="capacity"):
            TraceConfig(capacity=0)

    def test_null_tracer_is_inert_and_refuses_export(self):
        NULL_TRACER.emit("token", rid=1)
        NULL_TRACER.inc("x")
        NULL_TRACER.set_gauge("g", 1)
        assert not NULL_TRACER.enabled
        assert NULL_TRACER.events == () and NULL_TRACER.dropped == 0
        assert NULL_TRACER.describe() == "tracing off"
        for call in (NULL_TRACER.chrome, NULL_TRACER.prometheus,
                     NULL_TRACER.precision_timeline):
            with pytest.raises(RuntimeError, match="tracing is off"):
                call()


class TestExport:
    def _lifecycle(self):
        t = iter(float(i) for i in range(100))
        return [
            Event(next(t), 0, "submit", rid=0),
            Event(next(t), 1, "admit", rid=0, slot=0),
            Event(next(t), 1, "token", rid=0, slot=0),
            Event(next(t), 2, "preempt", rid=0, slot=0, cause="priority"),
            Event(next(t), 3, "resume", rid=0, slot=1),
            Event(next(t), 3, "token", rid=0, slot=1),
            Event(next(t), 3, "done", rid=0, slot=1, cause="budget"),
        ]

    def test_chrome_valid_and_spans_cover_lifecycle(self):
        doc = to_chrome(self._lifecycle(), {"tokens_out": 2}, {"g": 1.0})
        assert validate_chrome(doc) == []
        spans = [e for e in doc["traceEvents"]
                 if e["ph"] == "X" and e["pid"] == 2]
        # queued -> running -> preempted -> running: four lifecycle spans
        assert [s["name"] for s in spans] == [
            "queued", "running", "preempted", "running"]
        counters = {e["name"] for e in doc["traceEvents"] if e["ph"] == "C"}
        assert {"tokens_out", "g"} <= counters
        json.dumps(doc)  # must be serializable as-is

    def test_chrome_inflight_spans_closed_at_ring_end(self):
        events = self._lifecycle()[:2]  # submit + admit, never done
        doc = to_chrome(events)
        assert validate_chrome(doc) == []
        spans = [e for e in doc["traceEvents"]
                 if e["ph"] == "X" and e["pid"] == 2]
        assert [s["name"] for s in spans] == ["queued", "running"]

    def test_validate_catches_malformed(self):
        assert validate_chrome({}) == ["traceEvents missing or not a list"]
        bad_dur = {"traceEvents": [
            {"ph": "X", "pid": 1, "tid": 0, "name": "a", "ts": 0, "dur": -1}]}
        assert any("bad dur" in p for p in validate_chrome(bad_dur))
        overlap = {"traceEvents": [
            {"ph": "X", "pid": 1, "tid": 0, "name": "a", "ts": 0, "dur": 10},
            {"ph": "X", "pid": 1, "tid": 0, "name": "b", "ts": 5, "dur": 10},
        ]}
        assert any("partially overlaps" in p for p in validate_chrome(overlap))
        missing = {"traceEvents": [{"ph": "i", "pid": 1}]}
        assert any("missing keys" in p for p in validate_chrome(missing))

    def test_span_violations(self):
        assert span_violations(self._lifecycle()) == []
        bad = [Event(0.0, 0, "admit", rid=1, slot=0)]  # admit before submit
        assert span_violations(bad)
        twice = self._lifecycle() + [Event(99.0, 4, "admit", rid=0, slot=1)]
        assert any("after done" in p for p in span_violations(twice))
        resume_running = [
            Event(0.0, 0, "submit", rid=2),
            Event(1.0, 1, "admit", rid=2, slot=0),
            Event(2.0, 2, "resume", rid=2, slot=0),
        ]
        assert span_violations(resume_running)

    def test_prometheus_text(self):
        text = to_prometheus({"tokens_out": 5, "a.b": 1}, {"occ": 0.5})
        assert "# TYPE repro_obs_tokens_out counter\n" in text
        assert "repro_obs_tokens_out 5\n" in text
        assert "repro_obs_a_b 1" in text  # sanitized name
        assert "# TYPE repro_obs_occ gauge\nrepro_obs_occ 0.5" in text
        assert to_prometheus({}, {}) == ""


class TestTimeline:
    def test_merges_three_precision_axes(self):
        rows = precision_timeline([
            Event(0.0, 1, "decode_step", data={"mode": "M16", "n_active": 2}),
            Event(1.0, 4, "mode_switch", data={"mode": "M24",
                                               "sites": {"mlp": "M24"}}),
            Event(2.0, 6, "draft_shift", data={"shift": 1}),
            Event(3.0, 8, "tier_tick", data={"keep": 5, "depth": 1}),
            Event(4.0, 8, "mode_switch", data={"mode": "M16"}),
        ])
        assert [r["step"] for r in rows] == [1, 4, 6, 8]
        assert rows[0]["mode"] == "M16" and rows[0]["draft_shift"] is None
        assert rows[1]["mode"] == "M24"
        assert rows[2]["draft_shift"] == 1 and rows[2]["mode"] == "M24"
        # step 8: tier tick and a second mode switch merge into one row
        assert rows[3]["tier_keep"] == 5 and rows[3]["mode"] == "M16"
        assert rows[3]["draft_shift"] == 1  # carried forward

    def test_empty(self):
        assert precision_timeline([]) == []


class TestProfiler:
    def test_phase_accounting_and_recompile_detection(self):
        tr = Tracer(clock=lambda: 0.0)
        p = PhaseProfiler(tr)
        p.record("decode", 0.5, tokens=10)
        p.record("decode", 0.5, tokens=10)
        p.observe_cache("decode_step", 1)
        p.observe_cache("decode_step", 1)  # stable: no recompile
        assert p.recompiles == 0
        p.observe_cache("decode_step", 3)  # grew by 2
        assert p.recompiles == 2
        snap = p.snapshot()
        assert snap["phases"]["decode"] == {
            "calls": 2, "wall_s": 1.0, "tokens": 20, "tok_s": 20.0}
        assert tr.counters["recompiles"] == 2
        assert [e.kind for e in tr.events] == ["recompile"]
        p.observe_cache("prefill", None)  # unavailable cache: no-op
        assert p.recompiles == 2


class TestEngineTracing:
    def test_zero_overhead_pin_tokens_and_compiles(self):
        # THE tentpole contract: tracing must be invisible to jit — same
        # tokens, same compile counts, traced vs untraced
        cfg, model, params = _tiny()
        reqs = _reqs(cfg, 4)
        base = ServeConfig(batch_slots=2, max_len=24)
        e_off = ServeEngine(model, params, config=base)
        e_on = ServeEngine(model, params, config=dataclasses.replace(
            base, trace=TraceConfig()))
        assert e_off.tracer is NULL_TRACER and e_on.tracer.enabled
        out_off = e_off.generate_batch(reqs)
        out_on = e_on.generate_batch(reqs)
        assert out_off == out_on
        assert e_off.decode_compile_count == e_on.decode_compile_count == 1

    def test_trace_true_means_default_config(self):
        cfg, model, params = _tiny()
        eng = ServeEngine(
            model, params,
            config=ServeConfig(batch_slots=1, max_len=16, trace=True))
        assert eng.tracer.enabled
        assert eng.tracer.config.capacity == TraceConfig().capacity

    def test_plain_run_replays_and_exports(self, tmp_path):
        cfg, model, params = _tiny()
        eng = ServeEngine(model, params, config=ServeConfig(
            batch_slots=2, max_len=24, trace=TraceConfig()))
        eng.generate_batch(_reqs(cfg, 4))
        log = check_replay(eng)
        assert sum(1 for _, k, _, _ in log if k == SUBMIT) == 4
        assert sum(1 for _, k, _, _ in log if k == FINISH) == 4
        path = tmp_path / "trace.json"
        doc = eng.tracer.export_chrome(str(path))
        assert validate_chrome(doc) == []
        assert validate_chrome(json.loads(path.read_text())) == []
        # counters reached the registry and the text exposition
        assert eng.tracer.counters["tokens_out"] == 20
        assert "repro_obs_tokens_out 20" in eng.tracer.prometheus()

    def test_zero_budget_request_traces_done_without_admit(self):
        cfg, model, params = _tiny()
        eng = ServeEngine(model, params, config=ServeConfig(
            batch_slots=1, max_len=16, trace=TraceConfig()))
        rng = np.random.default_rng(0)
        eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 5)
                           .astype(np.int32), max_new=0, rid=0))
        eng.drain()
        kinds = [e.kind for e in eng.tracer.events if e.rid == 0]
        assert kinds == ["submit", "done"]
        done = [e for e in eng.tracer.events if e.kind == "done"][0]
        assert done.cause == "zero_budget" and done.slot == -1
        check_replay(eng)

    def test_multi_tenant_preemption_replay_and_causes(self):
        cfg, model, params = _tiny()
        sched = SchedulingConfig(
            tenants=[Tenant("hot", priority=0), Tenant("bulk", priority=2)],
            classes=[RequestClass("c", prompt_len=5, max_new=6)],
            min_quantum=1)
        eng = ServeEngine(model, params, config=ServeConfig(
            batch_slots=1, max_len=24, scheduling=sched,
            trace=TraceConfig()))
        rng = np.random.default_rng(0)
        eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 5)
                           .astype(np.int32), max_new=6, rid=0,
                           tenant="bulk", rclass="c"))
        eng.step()
        eng.step()
        eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 5)
                           .astype(np.int32), max_new=3, rid=1,
                           tenant="hot", rclass="c"))
        eng.drain()
        events = list(eng.tracer.events)
        pre = [e for e in events if e.kind == "preempt"]
        assert pre and all(e.cause == "priority" for e in pre)
        assert any(e.kind == "preempt_plan" for e in events)  # scheduler emits
        assert any(e.kind == "resume" and e.rid == 0 for e in events)
        check_replay(eng)

    def test_spec_round_events(self):
        from repro.spec import SpecConfig

        cfg, model, params = _tiny()
        eng = ServeEngine(model, params, config=ServeConfig(
            batch_slots=2, max_len=32,
            spec=SpecConfig(k=2, draft_shift=1), trace=TraceConfig()))
        eng.generate_batch(_reqs(cfg, 3, max_new=6))
        events = list(eng.tracer.events)
        rounds = [e for e in events if e.kind == "spec_round"]
        assert rounds
        for e in rounds:
            d = e.data
            assert d["drafted"] == eng.spec.k * d["n_active"]
            assert 0 <= d["agreed"] <= d["drafted"]
        assert eng.tracer.counters["spec_rounds"] == len(rounds)
        check_replay(eng)

    def test_paged_run_traces_cache_events_and_replays(self):
        # the hybrid local-window arch is where ring wrap writes back into
        # shared prompt pages mid-decode, so COW forks actually fire
        cfg, model, params = _tiny("recurrentgemma-9b", n_layers=3)
        eng = ServeEngine(model, params, config=ServeConfig(
            batch_slots=3, max_len=48,
            cache=CacheConfig(layout="paged", page_size=4),
            trace=TraceConfig()))
        prompt = np.asarray([7] * 8, np.int32)  # shared prefix -> shared pages
        for i in range(3):
            eng.submit(Request(prompt=np.append(prompt, i).astype(np.int32),
                               max_new=30, rid=i))
        eng.drain()
        kinds = {e.kind for e in eng.tracer.events}
        assert "prefix_share" in kinds
        assert "cow_fork" in kinds
        for e in eng.tracer.events:
            if e.kind == "cow_fork":
                assert e.cause == "shared_page_write"
        check_replay(eng)

    def test_adapt_run_emits_decisions_and_timeline(self):
        from repro.adapt import SLO
        from repro.serve import AdaptConfig

        cfg, model, params = _tiny()
        eng = ServeEngine(model, params, config=ServeConfig(
            batch_slots=2, max_len=32,
            adapt=AdaptConfig(slo=SLO(max_err=0.5), adapt_every=2),
            trace=TraceConfig()))
        eng.generate_batch(_reqs(cfg, 3, max_new=8))
        events = list(eng.tracer.events)
        decisions = [e for e in events if e.kind == "adapt_decision"]
        assert decisions
        assert all(e.cause in ("hold", "cooldown", "err_violation",
                               "latency_pressure", "clean_streak")
                   for e in decisions)
        switches = [e for e in events if e.kind == "mode_switch"]
        assert len(switches) == eng.metrics.mode_switches
        for e in switches:
            assert e.cause in ("err_violation", "latency_pressure",
                               "clean_streak")
            assert set(e.data["sites"]) == set(eng.mode_table.modes())
        rows = eng.tracer.precision_timeline()
        assert rows and rows[0]["mode"] is not None
        if switches:
            assert any(r["sites"] is not None for r in rows)
        check_replay(eng)

    def test_describe_consolidation(self):
        cfg, model, params = _tiny()
        eng = ServeEngine(model, params, config=ServeConfig(
            batch_slots=1, max_len=16, trace=TraceConfig()))
        eng.generate_batch(_reqs(cfg, 1))
        d = eng.describe()
        assert {"plans", "adaptation", "speculation", "tenancy",
                "cache", "trace", "profile"} <= set(d)
        # thin-wrapper contract: the legacy helpers read the same source
        assert eng.describe_plans() == d["plans"]
        assert eng.describe_cache() == d["cache"]
        assert eng.describe_adaptation() == d["adaptation"]
        assert eng.describe_speculation() == d["speculation"]
        assert eng.describe_tenancy() == d["tenancy"]
        block = eng.format_describe()
        for key in d:
            assert f"-- {key} --" in block
        # untraced engines don't grow the extra keys
        e2 = ServeEngine(model, params,
                         config=ServeConfig(batch_slots=1, max_len=16))
        assert set(e2.describe()) == {"plans", "adaptation", "speculation",
                                      "tenancy", "cache"}

    def test_log_from_trace_skip_causes(self):
        evs = [
            Event(0.0, 1, "preempt", rid=0, slot=0, cause="page_pressure"),
            Event(1.0, 1, "preempt", rid=1, slot=1, cause="priority"),
            Event(2.0, 1, "decode_step", data={"dur_ms": 1.0}),  # dropped
        ]
        full = log_from_trace(evs)
        assert len(full) == 2
        filtered = log_from_trace(evs, skip_causes=("page_pressure",))
        assert [rid for _, _, rid, _ in filtered] == [1]
