"""Serving engine + KV-cache behaviour: continuous batching, int8 cache,
ring-buffer sliding window."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.policy import NATIVE_F32
from repro.models import build_model
from repro.models.layers import (
    kv_cache_append,
    kv_cache_append_slots,
    kv_cache_init,
)
from repro.serve.engine import Request, ServeEngine


def _tiny(arch="qwen1.5-0.5b", **over):
    cfg = get_smoke_config(arch).with_policy(NATIVE_F32)
    cfg = dataclasses.replace(cfg, n_layers=2, **over)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


class TestKVCache:
    def test_append_tracks_positions(self):
        c = kv_cache_init(2, 8, 1, 4, "bfloat16")
        k = jnp.ones((2, 3, 1, 4))
        c = kv_cache_append(c, k, k)
        assert int(c.length) == 3
        np.testing.assert_array_equal(np.asarray(c.pos), [0, 1, 2, -1, -1, -1, -1, -1])

    def test_ring_buffer_wrap_single_token(self):
        c = kv_cache_init(1, 4, 1, 2, "bfloat16")
        for t in range(6):
            c = kv_cache_append(c, jnp.full((1, 1, 1, 2), t, jnp.float32), jnp.zeros((1, 1, 1, 2)))
        # slots hold positions 4,5,2,3 (ring) — oldest evicted
        assert sorted(np.asarray(c.pos).tolist()) == [2, 3, 4, 5]
        assert int(c.length) == 6

    def test_long_prefill_keeps_tail(self):
        c = kv_cache_init(1, 4, 1, 2, "bfloat16")
        k = jnp.arange(10, dtype=jnp.float32).reshape(1, 10, 1, 1) * jnp.ones((1, 10, 1, 2))
        c = kv_cache_append(c, k, k)
        assert int(c.length) == 10
        np.testing.assert_array_equal(np.asarray(c.pos), [6, 7, 8, 9])
        np.testing.assert_allclose(np.asarray(c.k[0, :, 0, 0], np.float32), [6, 7, 8, 9])

    def test_per_slot_append_independent_offsets(self):
        # continuous-batching layout: rows at different depths append at
        # their own ring offsets in one call
        c = kv_cache_init(2, 4, 1, 2, "bfloat16", per_slot=True)
        assert c.pos.shape == (2, 4) and c.length.shape == (2,)
        # advance row 1 by two tokens first (mask row 0 by re-selecting it)
        for t in range(2):
            nxt = kv_cache_append_slots(
                c, jnp.full((2, 1, 1, 2), t, jnp.float32), jnp.zeros((2, 1, 1, 2))
            )
            c = jax.tree.map(  # freeze row 0, keep row 1 — the engine's mask
                lambda n, o: jnp.concatenate([o[:1], n[1:]]), nxt, c)
        np.testing.assert_array_equal(np.asarray(c.length), [0, 2])
        c = kv_cache_append_slots(
            c, jnp.full((2, 1, 1, 2), 9, jnp.float32), jnp.zeros((2, 1, 1, 2))
        )
        np.testing.assert_array_equal(np.asarray(c.length), [1, 3])
        np.testing.assert_array_equal(np.asarray(c.pos),
                                      [[0, -1, -1, -1], [0, 1, 2, -1]])
        np.testing.assert_allclose(np.asarray(c.k[0, 0, 0, 0], np.float32), 9)
        np.testing.assert_allclose(np.asarray(c.k[1, 2, 0, 0], np.float32), 9)

    def test_per_slot_ring_wrap(self):
        c = kv_cache_init(1, 4, 1, 2, "bfloat16", per_slot=True)
        for t in range(6):
            c = kv_cache_append_slots(
                c, jnp.full((1, 1, 1, 2), t, jnp.float32), jnp.zeros((1, 1, 1, 2))
            )
        assert sorted(np.asarray(c.pos[0]).tolist()) == [2, 3, 4, 5]
        assert int(c.length[0]) == 6

    def test_int8_roundtrip_error(self, rng):
        c = kv_cache_init(1, 8, 2, 16, "int8")
        k = jnp.asarray(rng.standard_normal((1, 8, 2, 16)).astype(np.float32))
        c = kv_cache_append(c, k, k)
        deq = np.asarray(c.k, np.float32) * np.asarray(c.k_scale)
        rel = np.abs(deq - np.asarray(k)).max() / np.abs(np.asarray(k)).max()
        assert rel < 1.5 / 127


class TestServeEngine:
    def test_generate_batch_deterministic(self):
        cfg, model, params = _tiny()
        eng = ServeEngine(model, params, batch_slots=4, max_len=48)
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32), max_new=5, rid=i) for i in range(3)]
        out1 = eng.generate_batch(reqs)
        eng2 = ServeEngine(model, params, batch_slots=4, max_len=48)
        out2 = eng2.generate_batch(reqs)
        assert out1 == out2
        assert all(len(v) == 5 for v in out1.values())

    def test_non_greedy_raises(self):
        # satellite regression: greedy=False used to be silently ignored
        # (the masked step and the prefill hard-code argmax) — the contract
        # is now explicit at construction time
        cfg, model, params = _tiny()
        with pytest.raises(NotImplementedError, match="greedy"):
            ServeEngine(model, params, batch_slots=1, max_len=16, greedy=False)

    def test_zero_budget_request_reaches_metrics(self):
        # satellite regression: budget-0 requests (max_new=0, or a prompt
        # filling the whole cache) used to complete inside Scheduler.admit()
        # without ever reaching ServeMetrics.on_done, so summary()
        # ["completed"] undercounted vs drain()/scheduler.completed
        cfg, model, params = _tiny()
        eng = ServeEngine(model, params, batch_slots=2, max_len=16)
        rng = np.random.default_rng(0)
        eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                           max_new=0, rid=0))
        eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                           max_new=3, rid=1))
        done = eng.drain()
        assert sorted(done) == [0, 1] and done[0] == []
        s = eng.metrics.summary()
        assert s["completed"] == len(eng.scheduler.completed) == 2
        assert eng.metrics.latency(0) is not None

    def test_metrics_unknown_rid_returns_none(self):
        # satellite regression: ttft()/latency() raised KeyError for rids
        # never submitted instead of the documented None
        from repro.serve.metrics import ServeMetrics

        m = ServeMetrics(2)
        assert m.ttft(12345) is None
        assert m.latency(12345) is None
        m.on_submit(7)
        assert m.ttft(7) is None and m.latency(7) is None  # mid-flight

    def test_greedy_matches_stepwise_apply(self):
        # engine's cached decode must agree with re-running apply() each step
        cfg, model, params = _tiny()
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        eng = ServeEngine(model, params, batch_slots=1, max_len=32)
        out = eng.generate_batch([Request(prompt=prompt, max_new=4, rid=0)])[0]
        toks = list(prompt)
        ref = []
        for _ in range(4):
            logits, _ = model.apply(params, {"tokens": jnp.asarray([toks], jnp.int32)})
            nxt = int(jnp.argmax(logits[0, -1]))
            ref.append(nxt)
            toks.append(nxt)
        assert out == ref


class TestServeMetricsEdges:
    """ServeMetrics edge cases (obs PR satellites): monotonic clock,
    percentile corner ranks, empty-tenant and no-round spec summaries."""

    def test_backwards_clock_never_negative_latency(self):
        # satellite regression: latency/TTFT stamps come from one _mark()
        # point on a monotonic clock, and even a clock that steps BACKWARDS
        # (a broken injected clock, a platform perf_counter regression)
        # must be clamped — a negative latency would poison every
        # percentile downstream
        from repro.serve.metrics import ServeMetrics

        ticks = iter([100.0, 90.0, 80.0, 70.0])
        m = ServeMetrics(1, clock=lambda: next(ticks))
        m.on_submit(0)
        m.on_first_token(0)
        m.on_done(0, step=1)
        assert m.ttft(0) == 0.0
        assert m.latency(0) == 0.0
        s = m.summary()
        assert s["ttft_mean_s"] >= 0.0 and s["latency_mean_s"] >= 0.0

    def test_percentile_nearest_rank_corners(self):
        from repro.serve.metrics import percentile

        assert percentile([], 50) is None
        # single element: every q maps to it
        assert percentile([3.0], 0) == 3.0
        assert percentile([3.0], 50) == 3.0
        assert percentile([3.0], 100) == 3.0
        vals = [5.0, 1.0, 3.0, 2.0, 4.0]
        assert percentile(vals, 0) == 1.0  # q=0 -> min, never index -1
        assert percentile(vals, 100) == 5.0  # q=100 -> max, never OOB
        assert percentile(vals, 50) == 3.0

    def test_tenant_summary_zero_budget_only_tenant(self):
        # a tenant whose every request was zero-budget (completed straight
        # from the queue: no first token, no decode slots) must still get a
        # coherent row — completed counts, None percentiles, zero share
        from repro.serve.metrics import ServeMetrics

        m = ServeMetrics(2)
        m.set_tenant_shares({"z": 1.0, "busy": 1.0})
        m.on_submit(0, tenant="z", step=0)
        m.on_done(0, step=1)
        m.on_submit(1, tenant="busy", step=0)
        m.on_first_token(1)
        m.on_token(1)
        m.on_decode_step(1, tenant_active={"busy": 1})
        m.on_done(1, step=2)
        ts = m.tenant_summary()
        z = ts["z"]
        assert z["submitted"] == z["completed"] == 1
        assert z["tokens"] == 0
        assert z["ttft_p50_s"] is None  # never produced a token
        assert z["latency_p50_s"] is not None  # but did complete
        assert z["slot_share"] == 0.0
        assert ts["busy"]["slot_share"] == 1.0

    def test_spec_counters_without_any_round(self):
        # speculate= on, but every request completes at its prefill token
        # (max_new=1) — no speculative round ever drafts; the spec counters
        # and the describe surface must report the absence, not divide by it
        from repro.spec import SpecConfig

        cfg, model, params = _tiny()
        eng = ServeEngine(model, params, batch_slots=2, max_len=16,
                          speculate=SpecConfig(k=2, draft_shift=1))
        rng = np.random.default_rng(0)
        eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                           max_new=1, rid=0))
        done = eng.drain()
        assert len(done[0]) == 1
        s = eng.metrics.summary()
        assert s["spec_rounds"] == 0
        assert s["acceptance_rate"] is None
        assert s["verify_steps_per_token"] is None
        assert "0 rounds" in eng.describe_speculation()
