"""Paged KV cache (repro.serve.paged): page-table primitives, the PagePool
allocator (sharing / COW / LRU prefix cache), the KVLayout engine seam, and
the differential guarantee — at full precision the paged layout is
bit-identical to the dense ring layout for every architecture family, under
preemption parking, speculative rollback, and ring wrap.

One discovered subtlety pinned here: the scheduler clamps each request's
budget to the *global* cache capacity (``max_len``), so the main cache never
ring-wraps mid-decode — genuine wrap (and therefore wrap-into-shared-pages
COW) only occurs in hybrid local-window caches where cap = window < max_len.
The COW engine test uses the hybrid arch for exactly that reason.
"""
import dataclasses
import functools

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.adapt import PageTierController, PageTierPolicy
from repro.configs import get_smoke_config
from repro.core.policy import NATIVE_F32
from repro.models import build_model
from repro.models.layers import (
    kv_cache_init,
    kv_cache_append_slots,
    paged_cache_init,
    paged_append,
    paged_scatter_rows,
    paged_view,
)
from repro.serve import (
    CacheConfig,
    PagePool,
    Request,
    ServeConfig,
    ServeEngine,
)
from repro.serve.scheduler import DECODE, Scheduler


@functools.lru_cache(maxsize=None)
def _tiny(arch="qwen1.5-0.5b", n_layers=2):
    cfg = get_smoke_config(arch).with_policy(NATIVE_F32)
    cfg = dataclasses.replace(cfg, n_layers=n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _requests(vocab, n=3, prompt_len=5, max_new=6, shared_prefix=None):
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n):
        if shared_prefix is not None:
            prompt = list(shared_prefix) + [i % vocab]
        else:
            prompt = rng.integers(0, vocab, size=prompt_len).tolist()
        reqs.append(Request(prompt, max_new, rid=i))
    return reqs


def _run(model, params, reqs, **cfg_kw):
    eng = ServeEngine(model, params, config=ServeConfig(**cfg_kw))
    return eng.generate_batch(reqs), eng


# ---------------------------------------------------------------------------
# Device primitives
# ---------------------------------------------------------------------------


def _mapped(batch, cap, n_kv, hd, dtype, ps):
    """A paged cache with a private identity table: row b owns pages
    [b*per_row+1, ...) — no sharing, so it must behave exactly like a dense
    per-slot ring of the same cap."""
    per_row = -(-cap // ps)
    c = paged_cache_init(batch, cap, n_kv, hd, dtype,
                         n_pages=batch * per_row, page_size=ps)
    tbl = (np.arange(batch * per_row, dtype=np.int32)
           .reshape(batch, per_row) + 1)
    return dataclasses.replace(c, page_tbl=jnp.asarray(tbl))


class TestPagedPrimitives:
    def test_append_view_matches_dense(self):
        d = kv_cache_init(2, 8, 1, 4, "bfloat16", per_slot=True)
        p = _mapped(2, 8, 1, 4, "bfloat16", ps=4)
        rng = np.random.default_rng(1)
        for t in range(5):
            k = jnp.asarray(rng.normal(size=(2, 1, 1, 4)), jnp.float32)
            v = jnp.asarray(rng.normal(size=(2, 1, 1, 4)), jnp.float32)
            d = kv_cache_append_slots(d, k, v)
            p = paged_append(p, k, v)
        pk, pv, _, _ = paged_view(p)
        np.testing.assert_array_equal(np.asarray(p.pos), np.asarray(d.pos))
        np.testing.assert_array_equal(np.asarray(p.length),
                                      np.asarray(d.length))
        # compare only at valid positions — unwritten virtual slots read the
        # scratch page / stale pool memory by design (pos==-1 masks them)
        m = np.asarray(d.pos) >= 0
        np.testing.assert_array_equal(
            np.asarray(pk, np.float32)[m], np.asarray(d.k, np.float32)[m])
        np.testing.assert_array_equal(
            np.asarray(pv, np.float32)[m], np.asarray(d.v, np.float32)[m])

    def test_ring_wrap_matches_dense(self):
        d = kv_cache_init(1, 4, 1, 2, "bfloat16", per_slot=True)
        p = _mapped(1, 4, 1, 2, "bfloat16", ps=2)
        for t in range(7):
            k = jnp.full((1, 1, 1, 2), t, jnp.float32)
            d = kv_cache_append_slots(d, k, k)
            p = paged_append(p, k, k)
        pk, _, _, _ = paged_view(p)
        np.testing.assert_array_equal(np.asarray(p.pos), np.asarray(d.pos))
        np.testing.assert_array_equal(
            np.asarray(pk, np.float32), np.asarray(d.k, np.float32))

    def test_scatter_rows_roundtrips_view(self):
        p = _mapped(2, 8, 1, 4, "bfloat16", ps=4)
        rng = np.random.default_rng(2)
        for _ in range(6):
            k = jnp.asarray(rng.normal(size=(2, 1, 1, 4)), jnp.float32)
            p = paged_append(p, k, k)
        k0, v0, _, _ = paged_view(p)
        p2 = paged_scatter_rows(p, k0, v0, None, None, p.pos, p.length)
        k1, v1, _, _ = paged_view(p2)
        m = np.asarray(p.pos) >= 0
        np.testing.assert_array_equal(np.asarray(k0)[m], np.asarray(k1)[m])
        np.testing.assert_array_equal(np.asarray(v0)[m], np.asarray(v1)[m])

    def test_int8_append_view_matches_dense(self):
        d = kv_cache_init(1, 8, 1, 4, "int8", per_slot=True)
        p = _mapped(1, 8, 1, 4, "int8", ps=4)
        rng = np.random.default_rng(3)
        for _ in range(4):
            k = jnp.asarray(rng.normal(size=(1, 1, 1, 4)), jnp.float32)
            d = kv_cache_append_slots(d, k, k)
            p = paged_append(p, k, k)
        pk, _, ks, _ = paged_view(p)
        m = np.asarray(d.pos) >= 0
        np.testing.assert_array_equal(np.asarray(pk)[m], np.asarray(d.k)[m])
        np.testing.assert_array_equal(np.asarray(ks)[m],
                                      np.asarray(d.k_scale)[m])


# ---------------------------------------------------------------------------
# PagePool allocator
# ---------------------------------------------------------------------------


def _keys(prompt, ps):
    a = np.asarray(prompt, np.int32)
    return [a[: (j + 1) * ps].tobytes() for j in range(len(a) // ps)]


class TestPagePool:
    def test_pages_for_ring_clamps_at_cap(self):
        pool = PagePool(8, page_size=4, cap=8, rows=2)
        assert pool.pages_for(1) == 1
        assert pool.pages_for(8) == 2
        assert pool.pages_for(100) == 2  # virtual space wraps at cap

    def test_attach_free_recycles(self):
        pool = PagePool(4, page_size=4, cap=8, rows=2)
        wt = pool.attach(0, 5, None)
        assert wt is not None and (pool.tbl[0, :2] > 0).all()
        assert pool.available() == 2
        pool.free_row(0)
        assert pool.available() == 4
        assert (pool.tbl[0] == -1).all()

    def test_prefix_sharing_refcounts(self):
        pool = PagePool(6, page_size=4, cap=8, rows=3)
        keys = _keys([7] * 8, 4)
        pool.attach(0, 8, keys)
        wt1 = pool.attach(1, 8, keys)
        assert pool.shared_hits == 2  # both full prompt pages hit
        # shared pages arrive read-only: the write table skips them
        assert (wt1[:2] == -1).all()
        p0 = int(pool.tbl[0, 0])
        assert int(pool.tbl[1, 0]) == p0 and pool.ref[p0] == 2

    def test_peek_needed_counts_sharing_hits(self):
        pool = PagePool(6, page_size=4, cap=16, rows=3)
        keys = _keys([3] * 8, 4)
        assert pool.peek_needed(8, keys) == 3  # 2 prompt + 1 append page
        pool.attach(0, 8, keys)
        assert pool.peek_needed(8, keys) == 1  # both prompt pages now shared

    def test_ensure_extends_and_reports_exhaustion(self):
        pool = PagePool(2, page_size=4, cap=8, rows=2)
        pool.attach(0, 2, None)
        assert pool.ensure(0, 8)  # second page allocates on demand
        pool.attach(1, 2, None) is None  # pool dry
        assert not pool.ensure(1, 8)

    def test_cow_forks_shared_not_private(self):
        pool = PagePool(6, page_size=4, cap=8, rows=2)
        keys = _keys([5] * 4, 4)
        pool.attach(0, 4, keys)
        pool.attach(1, 4, keys)
        shared = int(pool.tbl[1, 0])
        pairs = pool.cow(1, 0, 4)
        assert pairs and pairs[0][0] == shared
        assert int(pool.tbl[1, 0]) != shared and pool.ref[shared] == 1
        # row 1's fork is now exclusively owned: a second cow is a no-op
        assert pool.cow(1, 0, 4) == []
        # row 0 still references an index-held page: it must fork too
        assert len(pool.cow(0, 0, 4)) == 1
        assert pool.cow_copies == 2

    def test_index_lru_reclaim(self):
        pool = PagePool(2, page_size=4, cap=8, rows=2)
        keys = _keys([9] * 4, 4)
        pool.attach(0, 4, keys)
        pool.free_row(0)
        # index-held page parks in the LRU cache instead of the free list
        assert pool.available() == 2 and len(pool.cached) == 1
        wt = pool.attach(1, 8, None)  # needs both pages: reclaims the cached one
        assert wt is not None
        assert pool.index_evictions == 1 and not pool.index

    def test_reservations_gate_availability(self):
        pool = PagePool(4, page_size=4, cap=16, rows=2)
        assert pool.available() == 4
        pool.reserved = 3
        assert pool.available() == 1
        pool.reserved = 0


# ---------------------------------------------------------------------------
# Engine differential: paged == dense, token for token
# ---------------------------------------------------------------------------


PAGED = CacheConfig(layout="paged", page_size=4)


class TestPagedEngine:
    @pytest.mark.parametrize("arch",
                             ["qwen1.5-0.5b", "mamba2-2.7b",
                              "recurrentgemma-9b"])
    def test_paged_matches_dense(self, arch):
        cfg, model, params = _tiny(arch)
        reqs = _requests(cfg.vocab)
        dense, _ = _run(model, params, reqs, batch_slots=3, max_len=16)
        paged, eng = _run(model, params, _requests(cfg.vocab),
                          batch_slots=3, max_len=16, cache=PAGED)
        assert paged == dense
        assert eng.metrics.summary()["pages"] is not None

    def test_speculative_rollback_paged_matches_dense(self):
        from repro.spec import SpecConfig

        cfg, model, params = _tiny()
        reqs = _requests(cfg.vocab, max_new=8)
        dense, _ = _run(model, params, reqs, batch_slots=3, max_len=20)
        paged, _ = _run(model, params, _requests(cfg.vocab, max_new=8),
                        batch_slots=3, max_len=20, cache=PAGED,
                        spec=SpecConfig(k=2))
        assert paged == dense

    def test_pool_exhaustion_evicts_not_corrupts(self):
        cfg, model, params = _tiny()
        mk = lambda: _requests(cfg.vocab, n=6, prompt_len=4, max_new=7)
        dense, _ = _run(model, params, mk(), batch_slots=4, max_len=12)
        # 8 pages, 3 pages/row: two dense-equivalent slots, but four slots
        # run concurrently — pressure must evict, never corrupt
        small = CacheConfig(layout="paged", page_size=4, pool_pages=8,
                            prefix_sharing=False)
        paged, eng = _run(model, params, mk(), batch_slots=4, max_len=12,
                          cache=small)
        assert paged == dense
        s = eng.metrics.summary()
        assert s["pages"]["page_evictions"] >= 1
        # the ISSUE's concurrency criterion: with slots > pool capacity the
        # engine still ran more rows in flight than a dense layout of the
        # same memory could admit at all
        assert s["peak_active"] > s["pages"]["dense_equiv_slots"]

    def test_prefix_sharing_identical_tokens(self):
        cfg, model, params = _tiny()
        shared = [7] * 8
        mk = lambda: _requests(cfg.vocab, n=3, max_new=6, shared_prefix=shared)
        dense, _ = _run(model, params, mk(), batch_slots=3, max_len=20)
        paged, eng = _run(model, params, mk(), batch_slots=3, max_len=20,
                          cache=PAGED)
        assert paged == dense
        s = eng.metrics.summary()["pages"]
        assert s["shared_hits"] > 0 and s["sharing_peak"] > 0

    def test_cow_on_hybrid_local_window_wrap(self):
        # the hybrid local-window cache (cap = window < max_len) is the one
        # place ring wrap genuinely happens mid-decode — decoding past the
        # window writes back into the shared prompt pages, forcing COW forks
        cfg, model, params = _tiny("recurrentgemma-9b", n_layers=3)
        shared = [7] * 8
        mk = lambda: _requests(cfg.vocab, n=3, max_new=30,
                               shared_prefix=shared)
        dense, _ = _run(model, params, mk(), batch_slots=3, max_len=48)
        paged, eng = _run(model, params, mk(), batch_slots=3, max_len=48,
                          cache=PAGED)
        assert paged == dense
        s = eng.metrics.summary()["pages"]
        assert s["cow_copies"] > 0 and s["shared_hits"] > 0

    def test_preemption_parking_paged(self):
        from repro.serve import RequestClass, Tenant, class_requests

        cfg, model, params = _tiny()
        tenants = [Tenant("chat", priority=0, share=1.0),
                   Tenant("bulk", priority=2, share=1.0)]
        classes = [RequestClass("chat", slo_steps=6, prompt_len=3, max_new=4),
                   RequestClass("batch", prompt_len=5, max_new=8)]

        def mk():
            rng = np.random.default_rng(0)
            reqs = class_requests(classes[1], tenants[1], 2, cfg.vocab, rng)
            reqs += class_requests(classes[0], tenants[0], 2, cfg.vocab, rng,
                                   rid_base=100)
            return reqs

        def go(cache):
            eng = ServeEngine(model, params, config=ServeConfig(
                batch_slots=2, max_len=16, cache=cache,
                scheduling=dataclasses.replace(
                    ServeConfig(batch_slots=2, max_len=16).scheduling,
                    tenants=tenants, classes=classes, min_quantum=1)))
            reqs = mk()
            for r in reqs[:2]:  # bulk fills both slots first
                eng.submit(r)
            for _ in range(3):  # bulk decodes a few steps...
                eng.step()
            for r in reqs[2:]:  # ...then urgent chat arrives and preempts
                eng.submit(r)
            return eng.drain(), eng

        dense, _ = go(CacheConfig())
        paged, eng = go(PAGED)
        assert paged == dense
        assert eng.metrics.summary()["preemptions"] >= 1

    def test_int8_paged_matches_dense(self):
        cfg, model, params = _tiny()
        cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
        model8 = build_model(cfg8)
        params8 = model8.init(jax.random.key(0))
        reqs = _requests(cfg8.vocab)
        dense, _ = _run(model8, params8, reqs, batch_slots=3, max_len=16)
        paged, _ = _run(model8, params8, _requests(cfg8.vocab),
                        batch_slots=3, max_len=16, cache=PAGED)
        assert paged == dense

    def test_generate_batch_is_submit_drain(self):
        # generate_batch is pinned to be a thin wrapper: identical tokens to
        # driving submit()+drain() by hand on a fresh engine
        cfg, model, params = _tiny()
        wrapped, _ = _run(model, params, _requests(cfg.vocab),
                          batch_slots=3, max_len=16)
        eng = ServeEngine(model, params,
                          config=ServeConfig(batch_slots=3, max_len=16))
        rids = [eng.submit(r) for r in _requests(cfg.vocab)]
        manual = eng.drain()
        assert wrapped == {rid: manual[rid] for rid in rids}


# ---------------------------------------------------------------------------
# Precision tiers
# ---------------------------------------------------------------------------


class TestPageTiers:
    def test_open_loop_demotion_runs(self):
        cfg, model, params = _tiny()
        tiers = PageTierPolicy(levels=(5, 3), cold_after=4, every=2)
        paged = CacheConfig(layout="paged", page_size=4, tier_policy=tiers)
        out, eng = _run(model, params,
                        _requests(cfg.vocab, prompt_len=8, max_new=10),
                        batch_slots=3, max_len=24, cache=paged)
        assert all(len(v) == 10 for v in out.values())
        s = eng.metrics.summary()["pages"]
        assert s["tier_ticks"] >= 1 and s["tier_demoted"] >= 1
        assert s["tier_err_max"] > 0  # truncation left a measured residual

    def test_budgeted_tiers_respect_budget(self):
        cfg, model, params = _tiny()
        budget = 0.05
        tiers = PageTierPolicy(levels=(6, 4), cold_after=4, every=2,
                               budget=budget)
        paged = CacheConfig(layout="paged", page_size=4, tier_policy=tiers)
        _, eng = _run(model, params,
                      _requests(cfg.vocab, prompt_len=8, max_new=10),
                      batch_slots=3, max_len=24, cache=paged)
        s = eng.metrics.summary()["pages"]
        assert s["tier_ticks"] >= 1
        assert s["tier_err_max"] <= budget

    def test_tiers_require_bf16_cache(self):
        cfg, model, params = _tiny()
        cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
        model8 = build_model(cfg8)
        params8 = model8.init(jax.random.key(0))
        tiers = PageTierPolicy(levels=(5,))
        with pytest.raises(ValueError, match="bf16|bfloat16"):
            ServeEngine(model8, params8, config=ServeConfig(
                batch_slots=2, max_len=12,
                cache=CacheConfig(layout="paged", page_size=4,
                                  tier_policy=tiers)))

    def test_controller_hysteresis(self):
        tc = PageTierController(PageTierPolicy(
            levels=(6, 4), budget=0.1, cooldown=0))
        assert tc.depth == 0 and tc.target_keep is None
        # headroom below budget: the controller deepens one rung per tick
        tc.observe(0, err=0.0, err_down=0.01)
        assert tc.depth == 1 and tc.target_keep == 6
        tc.observe(1, err=0.01, err_down=0.02)
        assert tc.depth == 2 and tc.target_keep == 4
        # violation backs off
        tc.observe(2, err=0.5, err_down=0.5)
        assert tc.depth == 1


# ---------------------------------------------------------------------------
# ServeConfig redesign + scheduler hooks
# ---------------------------------------------------------------------------


class TestServeConfig:
    def test_config_equals_legacy_kwargs(self):
        cfg, model, params = _tiny()
        reqs = _requests(cfg.vocab)
        via_cfg, _ = _run(model, params, reqs, batch_slots=3, max_len=16)
        legacy = ServeEngine(model, params, batch_slots=3, max_len=16)
        assert legacy.generate_batch(_requests(cfg.vocab)) == via_cfg

    def test_config_and_kwargs_mutually_exclusive(self):
        cfg, model, params = _tiny()
        with pytest.raises(ValueError):
            ServeEngine(model, params, batch_slots=2, max_len=8,
                        config=ServeConfig(batch_slots=2, max_len=8))
        with pytest.raises(TypeError):
            ServeEngine(model, params)

    def test_frozen(self):
        c = ServeConfig(batch_slots=2, max_len=8)
        with pytest.raises(dataclasses.FrozenInstanceError):
            c.max_len = 16

    def test_validation(self):
        with pytest.raises(ValueError):
            ServeConfig(batch_slots=0, max_len=8)
        with pytest.raises(ValueError):
            CacheConfig(layout="ragged")
        with pytest.raises(ValueError):
            CacheConfig(tier_policy=PageTierPolicy(levels=(5,)))  # dense

    def test_from_flags_builds_paged_cache(self):
        import argparse

        ns = argparse.Namespace(
            slots=0, requests=4, prompt_len=6, max_new=8, accuracy=None,
            tune_table="", scheduler_policy="priority", adapt=False,
            adapt_every=4, speculate=False, paged=True, page_size=8,
            pool_pages=32, no_prefix_sharing=False, tier_levels="5,3",
            tier_cold_after=16, tier_every=4, tier_budget=0.1)
        c = ServeConfig.from_flags(ns)
        assert c.batch_slots == 4 and c.cache.layout == "paged"
        assert c.cache.page_size == 8 and c.cache.pool_pages == 32
        assert c.cache.tier_policy.levels == (5, 3)
        assert c.cache.tier_policy.budget == 0.1


class TestSchedulerPagePressure:
    def test_admit_gate_skips_in_place(self):
        sch = Scheduler(slots=2, max_len=32)
        sch.submit(Request([1] * 8, 4, rid=0))
        sch.submit(Request([1] * 2, 4, rid=1))
        # the gate refuses the big request; the small one behind still lands
        admitted = sch.admit(can_admit=lambda t: len(t.prompt) < 4)
        assert [t.rid for _, t in admitted] == [1]
        # the refused ticket stays queued, keeping its rank
        assert [t.rid for t in sch.queue] == [0]
        assert [t.rid for _, t in sch.admit()] == [0]

    def test_page_victim_least_urgent_decode(self):
        sch = Scheduler(slots=3, max_len=32)
        for rid, prio in ((0, 0), (1, 2), (2, 1)):
            sch.submit(Request([1, 2], 4, rid=rid))
            sch.tickets[rid].priority = prio
        admitted = sch.admit()
        for _, t in admitted:
            t.state = DECODE
        v = sch.page_victim()
        assert v is not None and v.rid == 1  # lowest urgency parks first
        # victim selection mutates nothing
        assert all(t.state == DECODE for t in sch.by_slot.values())
