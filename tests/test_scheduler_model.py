"""Property-based + deterministic scheduler invariant tests.

The harness lives in tests/scheduler_model.py; this module feeds it traces.
Two layers:

  * hypothesis property tests (skip cleanly without hypothesis via
    tests/hypothesis_compat.py): randomized submission traces x scheduler
    configs through the full invariant battery — conservation, slot
    accounting, priority consistency, intra-class FIFO, aging/no-starvation
    bound, preemption quantum, and real-vs-reference event-stream
    equivalence.
  * deterministic tier-1 tests: seeded versions of the same battery (so
    the invariants stay exercised without dev extras), the
    submission-order tie-break pin, and the aging-beats-flood starvation
    test.

Everything here is model-free (drive() emits counted zero tokens); the
engine-level token-identity half of the harness runs in test_tenancy.py.
"""
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from scheduler_model import (ADMIT, FINISH, PREEMPT, RefScheduler, Spec,
                             check_aging_bound, check_all, check_conservation,
                             check_equivalence, check_intra_class_fifo,
                             check_quantum, drive, trace_from_specs)
from repro.serve import Request, Scheduler
from repro.serve.tenancy import RequestClass, Tenant

MAX_LEN = 10
TENANTS = [
    Tenant("t0", priority=0, share=2.0),
    Tenant("t1", priority=1),
    Tenant("t2", priority=3),
]
CLASSES = [
    RequestClass("chat", slo_steps=8, prompt_len=4, max_new=4),
    RequestClass("batch", prompt_len=6, max_new=10),
]
TENANT_NAMES = ["t0", "t1", "t2", "default"]
CLASS_NAMES = ["chat", "batch", "default"]

# one trace entry: (submit step, tenant, class, prompt_len, max_new);
# max_new=0 exercises the zero-budget drain path
ENTRY = st.tuples(
    st.integers(0, 12),
    st.sampled_from(TENANT_NAMES),
    st.sampled_from(CLASS_NAMES),
    st.integers(1, 8),
    st.integers(0, 8),
)
ENTRIES = st.lists(ENTRY, min_size=1, max_size=24)
# (slots, aging_steps, preempt, min_quantum)
CONFIG = st.tuples(
    st.integers(1, 4),
    st.sampled_from([0, 1, 4, 8]),
    st.booleans(),
    st.integers(1, 3),
)


def _specs(entries):
    return [Spec(step, rid=i, tenant=tn, rclass=rc,
                 prompt_len=pl, max_new=mn)
            for i, (step, tn, rc, pl, mn) in enumerate(entries)]


def _sched(config, policy="priority"):
    slots, aging, preempt, quantum = config
    return Scheduler(slots, MAX_LEN, tenants=TENANTS, classes=CLASSES,
                     policy=policy, aging_steps=aging, preempt=preempt,
                     min_quantum=quantum)


def _ref(config, policy="priority"):
    slots, aging, preempt, quantum = config
    return RefScheduler(slots, MAX_LEN, tenants=TENANTS, classes=CLASSES,
                        policy=policy, aging_steps=aging, preempt=preempt,
                        min_quantum=quantum)


def _battery(entries, config, policy="priority"):
    """Drive the real scheduler (per-step slot-accounting and priority
    checks run inside drive) and the whole-log battery, then the reference
    scheduler, and require identical event streams."""
    trace = trace_from_specs(_specs(entries))
    sched = _sched(config, policy)
    log = drive(sched, trace)
    check_all(sched, log)
    ref = _ref(config, policy)
    log_ref = drive(ref, [list(s) for s in trace], per_step_checks=False)
    check_conservation(ref, log_ref)
    check_equivalence(log, log_ref)
    return sched, log


def _random_entries(rng, n):
    return [
        (int(rng.integers(0, 13)),
         TENANT_NAMES[int(rng.integers(0, len(TENANT_NAMES)))],
         CLASS_NAMES[int(rng.integers(0, len(CLASS_NAMES)))],
         int(rng.integers(1, 9)),
         int(rng.integers(0, 9)))
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# hypothesis layer (skipped cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------


class TestProperties:
    @settings(max_examples=60, deadline=None)
    @given(entries=ENTRIES, config=CONFIG)
    def test_conservation_and_slot_accounting(self, entries, config):
        # per-step slot accounting + whole-log conservation over random
        # traces; drive() itself asserts the trace drains (no starvation)
        trace = trace_from_specs(_specs(entries))
        sched = _sched(config)
        log = drive(sched, trace)
        check_conservation(sched, log)

    @settings(max_examples=60, deadline=None)
    @given(entries=ENTRIES, config=CONFIG)
    def test_intra_class_fifo_order(self, entries, config):
        trace = trace_from_specs(_specs(entries))
        sched = _sched(config)
        log = drive(sched, trace)
        check_intra_class_fifo(sched, log)

    @settings(max_examples=60, deadline=None)
    @given(entries=ENTRIES, config=CONFIG)
    def test_aging_bounds_every_wait(self, entries, config):
        trace = trace_from_specs(_specs(entries))
        sched = _sched(config)
        log = drive(sched, trace)
        check_aging_bound(sched, log)

    @settings(max_examples=60, deadline=None)
    @given(entries=ENTRIES, config=CONFIG)
    def test_preemption_respects_quantum(self, entries, config):
        trace = trace_from_specs(_specs(entries))
        sched = _sched(config)
        log = drive(sched, trace)
        check_quantum(sched, log)

    @settings(max_examples=60, deadline=None)
    @given(entries=ENTRIES, config=CONFIG)
    def test_matches_reference_model(self, entries, config):
        _battery(entries, config)

    @settings(max_examples=40, deadline=None)
    @given(entries=ENTRIES, config=CONFIG)
    def test_fifo_policy_admits_in_submission_order(self, entries, config):
        sched = _sched(config, policy="fifo")
        log = drive(sched, trace_from_specs(_specs(entries)))
        check_conservation(sched, log)
        first = []
        seen = set()
        for _, kind, rid, _ in log:
            if kind == ADMIT and rid not in seen:
                seen.add(rid)
                first.append(sched.tickets[rid].seq)
        assert first == sorted(first)


# ---------------------------------------------------------------------------
# deterministic tier-1 layer (always runs)
# ---------------------------------------------------------------------------


class TestDeterministic:
    @pytest.mark.parametrize("seed", range(8))
    def test_battery_on_seeded_traces(self, seed):
        rng = np.random.default_rng(seed)
        entries = _random_entries(rng, int(rng.integers(4, 25)))
        config = (int(rng.integers(1, 5)),
                  [0, 1, 4, 8][int(rng.integers(0, 4))],
                  bool(rng.integers(0, 2)),
                  int(rng.integers(1, 4)))
        _battery(entries, config)

    @pytest.mark.parametrize("seed", range(4))
    def test_fifo_battery_on_seeded_traces(self, seed):
        rng = np.random.default_rng(100 + seed)
        entries = _random_entries(rng, 16)
        _battery(entries, (2, 8, True, 2), policy="fifo")

    def test_equal_key_ties_break_by_submission_order(self):
        # satellite pin: equal-priority, equal-arrival requests must admit
        # in submission order — the seq tie-break makes the sort total, so
        # admission can never depend on dict/hash iteration order
        for policy in ("priority", "fifo"):
            s = Scheduler(2, MAX_LEN, tenants=TENANTS, classes=CLASSES,
                          policy=policy)
            rng = np.random.default_rng(0)
            for rid in range(6):  # same step, tenant, class -> equal keys
                s.submit(Request(
                    prompt=rng.integers(0, 64, 4).astype(np.int32),
                    max_new=4, rid=rid, tenant="t1", rclass="chat"))
            assert [t.rid for _, t in s.admit()] == [0, 1]
            assert [t.rid for t in s.queue] == [2, 3, 4, 5]
            s.complete(0)
            s.complete(1)
            assert [t.rid for _, t in s.admit()] == [2, 3]

    def test_default_config_degenerates_to_fifo(self):
        # back-compat pin: a Scheduler built the pre-tenancy way (all
        # requests default tenant/class, no deadlines) must order exactly
        # like pure FIFO even under the priority policy
        rng = np.random.default_rng(1)
        entries = [(int(rng.integers(0, 8)), "default", "default",
                    int(rng.integers(1, 8)), int(rng.integers(1, 8)))
                   for _ in range(12)]
        trace = trace_from_specs(_specs(entries))
        a = Scheduler(2, MAX_LEN)
        log_a = drive(a, trace)
        b = Scheduler(2, MAX_LEN, policy="fifo")
        log_b = drive(b, [list(s) for s in trace], per_step_checks=False)
        check_equivalence(log_a, log_b)

    def test_aging_beats_priority_flood(self):
        # no-starvation: a priority-5 request submitted at step 0 against a
        # continuous priority-0 flood must still be served long before the
        # flood ends — its effective priority falls one rung per
        # aging_steps ticks until it out-ranks every fresh arrival
        tenants = TENANTS + [Tenant("lowly", priority=5)]
        specs = [Spec(0, rid=0, tenant="lowly", rclass="batch",
                      prompt_len=4, max_new=4)]
        specs += [Spec(step, rid=1 + step, tenant="t0", rclass="chat",
                       prompt_len=4, max_new=2) for step in range(40)]
        sched = Scheduler(1, MAX_LEN, tenants=tenants, classes=CLASSES,
                          aging_steps=2, min_quantum=1)
        log = drive(sched, trace_from_specs(specs))
        check_conservation(sched, log)
        admit_step = next(step for step, kind, rid, _ in log
                          if kind == ADMIT and rid == 0)
        assert admit_step < 30, f"rid 0 starved until step {admit_step}"

    def test_no_aging_starves_without_preemption_pressure(self):
        # the converse control: with aging_steps=0 and the same flood, the
        # low-priority request only runs after the flood drains — pinning
        # that the no-starvation property really is the aging term's doing
        tenants = TENANTS + [Tenant("lowly", priority=5)]
        specs = [Spec(0, rid=0, tenant="lowly", rclass="batch",
                      prompt_len=4, max_new=4)]
        specs += [Spec(step, rid=1 + step, tenant="t0", rclass="chat",
                       prompt_len=4, max_new=2) for step in range(40)]
        sched = Scheduler(1, MAX_LEN, tenants=tenants, classes=CLASSES,
                          aging_steps=0, min_quantum=1)
        log = drive(sched, trace_from_specs(specs))
        check_conservation(sched, log)
        admit_step = next(step for step, kind, rid, _ in log
                          if kind == ADMIT and rid == 0)
        assert admit_step > 40

    def test_preemption_events_appear_under_contention(self):
        # a long-running low-priority ticket must actually get preempted
        # when urgent work arrives mid-flight (and later resume + finish)
        specs = [Spec(0, rid=0, tenant="t2", rclass="batch",
                      prompt_len=2, max_new=9)]
        specs += [Spec(4, rid=1, tenant="t0", rclass="chat",
                       prompt_len=4, max_new=3)]
        sched = Scheduler(1, MAX_LEN, tenants=TENANTS, classes=CLASSES,
                          aging_steps=8, min_quantum=2)
        log = drive(sched, trace_from_specs(specs))
        check_conservation(sched, log)
        kinds = [(kind, rid) for _, kind, rid, _ in log]
        assert (PREEMPT, 0) in kinds
        assert kinds.index((PREEMPT, 0)) < kinds.index((FINISH, 1))
        # the victim resumed and finished with its full budget
        assert len(sched.tickets[0].tokens) == sched.tickets[0].budget

    def test_hypothesis_status_is_visible(self):
        # not an invariant — documents which layer ran in this environment
        assert HAVE_HYPOTHESIS in (True, False)
