"""Autotuner subsystem (repro.tune): tuning-table JSON round-trip, planner
resolution precedence (exact table hit > scaled neighbor > roofline),
TUNE_TABLE env/arg override plumbing, the tuner CLI end-to-end, and the CI
regression/drift gates (benchmarks/check_regression.py,
make_experiments_md --check)."""

import json
import os

import pytest

from repro.core.precision import Mode
from repro.plan import (
    DEFAULT_BALANCE,
    NATIVE_REL_ERROR,
    cheapest_mode,
    clear_plan_cache,
    plan_matmul,
    plan_model_policy,
    set_tune_table,
)
from repro.tune import SCHEMA_VERSION, TuneRecord, TuneTable, mode_key

REPO = os.path.join(os.path.dirname(__file__), "..")
COMMITTED_TABLE = os.path.join(REPO, "tuning", "cpu.json")

ACCURACIES = (2.0**-4, 2.0**-12, 2.0**-20)


@pytest.fixture(autouse=True)
def _fresh_planner(monkeypatch):
    monkeypatch.delenv("TUNE_TABLE", raising=False)
    set_tune_table(None)
    clear_plan_cache()
    yield
    set_tune_table(None)
    clear_plan_cache()


def _rec(m, k, n, mode, impl, depth, wall_us, block=None):
    return TuneRecord(
        m=m,
        k=k,
        n=n,
        mode=mode_key(mode, impl),
        impl=impl,
        depth=depth,
        wall_us=wall_us,
        flops_per_s=2.0 * m * k * n / (wall_us * 1e-6),
        max_abs_err=1e-3,
        rel_err=1e-6,
        block=block,
        iters=1,
    )


def _planner_candidates(n, accuracy, table):
    """The (impl, depth) set the planner considers for a cpu square-n cell,
    restricted to points the table measured."""
    mode = cheapest_mode(accuracy)
    impls = ["xla"]
    if NATIVE_REL_ERROR <= accuracy:
        impls.insert(0, "native")
    cells = {}
    for impl in impls:
        for depth in (0, 1):
            if depth and n < 256:
                continue
            rec = table.lookup(n, n, n, mode, impl, depth)
            if rec is not None:
                cells[(impl, depth)] = rec
    return cells


# ---------------------------------------------------------------------------
# Table persistence
# ---------------------------------------------------------------------------


class TestTableRoundTrip:
    def test_save_load_identity(self, tmp_path):
        table = TuneTable(
            backend="cpu",
            records=(
                _rec(128, 128, 128, Mode.M8, "xla", 0, 100.0),
                _rec(128, 128, 128, Mode.M16, "pallas", 0, 50.0, block=(128, 128, 128)),
            ),
            align=128,
            jax_version="0.0.test",
            iters=3,
        )
        path = tmp_path / "t.json"
        table.save(str(path))
        loaded = TuneTable.load(str(path))
        assert loaded == table
        assert loaded.fingerprint == table.fingerprint
        assert loaded.records[1].block == (128, 128, 128)

    def test_schema_version_enforced(self, tmp_path):
        path = tmp_path / "bad.json"
        path.write_text(
            json.dumps({"schema_version": 999, "backend": "cpu", "records": []})
        )
        with pytest.raises(ValueError, match="schema_version"):
            TuneTable.load(str(path))
        doc = json.load(open(COMMITTED_TABLE))
        assert doc["schema_version"] == SCHEMA_VERSION

    def test_lookup_prefers_fastest_block_variant(self):
        table = TuneTable(
            backend="tpu",
            records=(
                _rec(256, 256, 256, Mode.M16, "pallas", 0, 90.0, block=(128, 128, 128)),
                _rec(256, 256, 256, Mode.M16, "pallas", 0, 40.0, block=(128, 128, 256)),
            ),
        )
        rec = table.lookup(256, 256, 256, Mode.M16, "pallas", 0)
        assert rec.block == (128, 128, 256) and rec.wall_us == 40.0

    def test_nearest_scales_and_bounds(self):
        table = TuneTable(
            backend="cpu", records=(_rec(256, 256, 256, Mode.M8, "xla", 0, 100.0),)
        )
        assert table.lookup(512, 512, 512, Mode.M8, "xla", 0) is None
        rec, ratio = table.nearest(512, 512, 512, Mode.M8, "xla", 0)
        assert rec.m == 256 and ratio == pytest.approx(8.0)
        # 256 -> 16384 is a 2^18 flop ratio: outside the extrapolation bound
        assert table.nearest(16384, 16384, 16384, Mode.M8, "xla", 0) is None
        # no same-config record at all
        assert table.nearest(512, 512, 512, Mode.M16, "xla", 0) is None

    def test_native_records_collapse_the_mode(self):
        table = TuneTable(
            backend="cpu", records=(_rec(128, 128, 128, Mode.M24, "native", 0, 10.0),)
        )
        for mode in (Mode.M8, Mode.M16, Mode.M24):
            assert table.lookup(128, 128, 128, mode, "native", 0) is not None


# ---------------------------------------------------------------------------
# Planner resolution: exact hit > neighbor > roofline
# ---------------------------------------------------------------------------


def _synthetic_table():
    # measurement says native is 100x faster than the roofline's xla pick
    return TuneTable(
        backend="cpu",
        records=(
            _rec(256, 256, 256, Mode.M24, "native", 0, 10.0),
            _rec(256, 256, 256, Mode.M8, "xla", 0, 1000.0),
        ),
    )


class TestResolutionPrecedence:
    def test_exact_hit_overrides_roofline(self):
        table = _synthetic_table()
        base = plan_matmul(
            (256, 256), (256, 256), accuracy=2**-4, backend="cpu", tune_table=False
        )
        tuned = plan_matmul(
            (256, 256), (256, 256), accuracy=2**-4, backend="cpu", tune_table=table
        )
        assert base.impl == "xla" and base.source == "roofline"
        assert tuned.impl == "native" and tuned.source == "measured"
        assert tuned.t_resolved_s == pytest.approx(10e-6)

    def test_neighbor_interpolates_when_no_exact_hit(self):
        tuned = plan_matmul(
            (320, 256),
            (256, 256),
            accuracy=2**-4,
            backend="cpu",
            tune_table=_synthetic_table(),
        )
        assert tuned.source == "interpolated"
        assert tuned.impl == "native"  # scaled times preserve the measured order
        assert tuned.t_resolved_s == pytest.approx(10e-6 * 320 / 256)

    def test_roofline_fallback_beyond_neighbor_bound(self):
        tuned = plan_matmul(
            (16384, 16384),
            (16384, 16384),
            accuracy=2**-4,
            backend="cpu",
            tune_table=_synthetic_table(),
        )
        assert tuned.source == "roofline"

    def test_roofline_fallback_uses_fitted_balance(self):
        table = _synthetic_table()
        base = plan_matmul(
            (16384, 16384),
            (16384, 16384),
            accuracy=2**-4,
            backend="cpu",
            tune_table=False,
        )
        tuned = plan_matmul(
            (16384, 16384),
            (16384, 16384),
            accuracy=2**-4,
            backend="cpu",
            tune_table=table,
        )
        assert table.balance.peak_flops != DEFAULT_BALANCE.peak_flops
        assert tuned.cost.t_total_s != base.cost.t_total_s

    def test_backend_mismatch_ignores_table(self):
        tuned = plan_matmul(
            (256, 256),
            (256, 256),
            accuracy=2**-4,
            backend="tpu",
            tune_table=_synthetic_table(),  # a cpu table
        )
        assert tuned.source == "roofline"

    def test_table_fingerprint_in_plan_cache_key(self):
        base = plan_matmul((256, 256), (256, 256), accuracy=2**-4, backend="cpu")
        tuned = plan_matmul(
            (256, 256),
            (256, 256),
            accuracy=2**-4,
            backend="cpu",
            tune_table=_synthetic_table(),
        )
        assert base is not tuned
        assert base.impl != tuned.impl


class TestOverridePlumbing:
    def test_env_var_file(self, tmp_path, monkeypatch):
        path = tmp_path / "cpu.json"
        _synthetic_table().save(str(path))
        monkeypatch.setenv("TUNE_TABLE", str(path))
        set_tune_table(None)  # drop the resolved-empty cache; re-read the env
        p = plan_matmul((256, 256), (256, 256), accuracy=2**-4, backend="cpu")
        assert p.source == "measured" and p.impl == "native"

    def test_env_var_directory(self, tmp_path, monkeypatch):
        _synthetic_table().save(str(tmp_path / "cpu.json"))
        monkeypatch.setenv("TUNE_TABLE", str(tmp_path))
        set_tune_table(None)
        p = plan_matmul((256, 256), (256, 256), accuracy=2**-4, backend="cpu")
        assert p.source == "measured"
        # tpu plans are untouched by the cpu table
        q = plan_matmul((256, 256), (256, 256), accuracy=2**-4, backend="tpu")
        assert q.source == "roofline"

    def test_set_tune_table_explicit(self):
        set_tune_table(_synthetic_table())
        p = plan_matmul((256, 256), (256, 256), accuracy=2**-4, backend="cpu")
        assert p.source == "measured"
        set_tune_table(None)
        q = plan_matmul((256, 256), (256, 256), accuracy=2**-4, backend="cpu")
        assert q.source == "roofline"

    def test_arg_false_forces_roofline(self, monkeypatch, tmp_path):
        path = tmp_path / "cpu.json"
        _synthetic_table().save(str(path))
        monkeypatch.setenv("TUNE_TABLE", str(path))
        set_tune_table(None)
        p = plan_matmul(
            (256, 256), (256, 256), accuracy=2**-4, backend="cpu", tune_table=False
        )
        assert p.source == "roofline"

    def test_path_arg(self, tmp_path):
        path = tmp_path / "anywhere.json"
        _synthetic_table().save(str(path))
        p = plan_matmul(
            (256, 256), (256, 256), accuracy=2**-4, backend="cpu", tune_table=str(path)
        )
        assert p.source == "measured"

    def test_plan_model_policy_plumbs_table(self):
        from repro.configs import get_smoke_config

        cfg = get_smoke_config("qwen1.5-0.5b")
        table = TuneTable.load(COMMITTED_TABLE)
        policy, plans = plan_model_policy(
            cfg, tokens=256, accuracy=2**-4, backend="cpu", tune_table=table
        )
        # model GEMMs are rectangular: they resolve via table hit or neighbor,
        # never the pure roofline, as long as they sit within the bound
        assert any(p.source in ("measured", "interpolated") for p in plans.values())


# ---------------------------------------------------------------------------
# The committed table + the tuner CLI (acceptance)
# ---------------------------------------------------------------------------


class TestCommittedTable:
    def test_flips_at_least_one_plan(self):
        """Acceptance: where the committed measurement disagrees with the
        roofline model, the planner follows the measurement — and at least
        one plan differs from the pure-roofline plan."""
        table = TuneTable.load(COMMITTED_TABLE)
        sizes = sorted({r.m for r in table.records})
        flips = []
        for n in sizes:
            for acc in ACCURACIES:
                kwargs = dict(accuracy=acc, backend="cpu", max_depth=1)
                base = plan_matmul((n, n), (n, n), tune_table=False, **kwargs)
                tuned = plan_matmul((n, n), (n, n), tune_table=table, **kwargs)
                assert tuned.source == "measured"
                cells = _planner_candidates(n, acc, table)
                # the tuned pick is the measured argmin over the candidates
                best_us = min(r.wall_us for r in cells.values())
                assert cells[(tuned.impl, tuned.strassen_depth)].wall_us == best_us
                if (base.impl, base.strassen_depth) != (
                    tuned.impl,
                    tuned.strassen_depth,
                ):
                    # measurement must actually disagree with the model here
                    assert cells[(base.impl, base.strassen_depth)].wall_us > best_us
                    flips.append((n, acc, base.impl, tuned.impl))
        assert flips, "committed table never disagrees with the roofline"


class TestTunerCLI:
    def test_cli_table_feeds_planner(self, tmp_path):
        """Acceptance: `python -m repro.tune --sizes 128,256 --out /tmp/t.json`
        produces a valid table the planner resolves measured costs from."""
        from repro.tune.__main__ import main

        out = tmp_path / "t.json"
        main(["--sizes", "128,256", "--iters", "1", "--out", str(out)])
        doc = json.load(open(out))
        assert doc["schema_version"] == SCHEMA_VERSION
        assert doc["backend"] == "cpu"
        assert {r["impl"] for r in doc["records"]} >= {"native", "xla"}
        table = TuneTable.load(str(out))
        for n in (128, 256):
            for acc in ACCURACIES:
                kwargs = dict(accuracy=acc, backend="cpu", max_depth=1)
                base = plan_matmul((n, n), (n, n), tune_table=False, **kwargs)
                tuned = plan_matmul((n, n), (n, n), tune_table=table, **kwargs)
                assert tuned.source == "measured"
                cells = _planner_candidates(n, acc, table)
                best_us = min(r.wall_us for r in cells.values())
                assert cells[(tuned.impl, tuned.strassen_depth)].wall_us == best_us
                if cells[(base.impl, base.strassen_depth)].wall_us > best_us:
                    # measurement disagrees with the model: plan must differ
                    assert (base.impl, base.strassen_depth) != (
                        tuned.impl,
                        tuned.strassen_depth,
                    )


# ---------------------------------------------------------------------------
# CI gates: perf regression + docs drift
# ---------------------------------------------------------------------------


class TestCheckRegression:
    def _compare(self, base, new, **kw):
        from benchmarks.check_regression import compare

        return compare(base, new, **kw)

    def test_identical_passes(self):
        cells = {("a",): 10.0, ("b",): 20.0, ("c",): 30.0}
        report = self._compare(cells, dict(cells), tolerance=0.25)
        assert report["violations"] == []

    def test_uniform_slowdown_normalizes_away(self):
        base = {("a",): 10.0, ("b",): 20.0, ("c",): 30.0}
        new = {k: v * 10.0 for k, v in base.items()}  # a 10x slower machine
        report = self._compare(base, new, tolerance=0.25)
        assert report["violations"] == []
        assert report["speed_factor"] == pytest.approx(10.0)

    def test_relative_regression_flagged(self):
        base = {("a",): 10.0, ("b",): 20.0, ("c",): 30.0}
        new = {("a",): 100.0, ("b",): 200.0, ("c",): 600.0}  # c regressed 2x
        report = self._compare(base, new, tolerance=0.25)
        assert [v["cell"] for v in report["violations"]] == [["c"]]

    def test_absolute_mode_flags_uniform_slowdown(self):
        base = {("a",): 10.0, ("b",): 20.0}
        new = {k: v * 2.0 for k, v in base.items()}
        report = self._compare(base, new, tolerance=0.25, absolute=True)
        assert len(report["violations"]) == 2

    def test_insufficient_overlap_raises(self):
        with pytest.raises(ValueError, match="overlap"):
            self._compare({("a",): 1.0}, {("b",): 1.0}, tolerance=0.25)

    def test_gate_against_committed_baselines(self):
        """The committed BENCH files gate cleanly against themselves — the
        shape of the CI perf-gate invocation."""
        from benchmarks.check_regression import (
            load,
            plan_cells,
            plan_selection_cells,
            serve_cells,
        )

        doc = load(os.path.join(REPO, "BENCH_plan.json"))
        plan = plan_cells(doc)
        selections = plan_selection_cells(doc)
        serve = serve_cells(load(os.path.join(REPO, "BENCH_serve.json")))
        assert len(plan) >= 3 and len(selections) >= 9 and len(serve) >= 3
        for cells in (plan, selections, serve):
            report = self._compare(cells, dict(cells), tolerance=0.25)
            assert report["violations"] == []

    def test_plan_selections_are_deterministic_vs_baseline(self):
        """CI's plan-gate layer: freshly computed planner selections must
        estimate the committed baseline cells identically (model output vs
        model output) — any drift is a planner/cost-model change, which is
        exactly what the gate exists to catch (regen the baseline when the
        change is intentional)."""
        from benchmarks.check_regression import compare, load, plan_selection_cells
        from benchmarks.plan_sweep import planner_selections

        doc = load(os.path.join(REPO, "BENCH_plan.json"))
        base = plan_selection_cells(doc)
        fresh = {}
        for backend in doc["planner"]:
            for r in planner_selections(tuple(doc["sizes"]) + (4096, 16384), backend):
                fresh[(backend, r["n"], f"{r['accuracy']:.3e}")] = float(r["est_t_us"])
        report = compare(base, fresh, tolerance=0.0, absolute=True)
        assert report["n_cells"] == len(base)
        assert report["violations"] == []


class TestSpecGate:
    """Semantic gate for BENCH_spec.json (check_regression --spec-new)."""

    def _cell(self, **over):
        cell = {
            "k": 2, "draft_shift": 1, "adaptive_shift": False,
            "accuracy": None, "exact_match": True, "acceptance_rate": 0.9,
            "verify_steps_per_token": 0.5, "spec_compile_count": 1,
        }
        cell.update(over)
        return cell

    def _problems(self, cells):
        from benchmarks.check_regression import spec_semantics

        return spec_semantics({"cells": cells})

    def test_clean_doc_passes(self):
        assert self._problems([self._cell(), self._cell(k=4)]) == []

    def test_committed_bench_spec_passes(self):
        from benchmarks.check_regression import load, spec_semantics

        assert spec_semantics(load(os.path.join(REPO, "BENCH_spec.json"))) == []

    def test_output_divergence_fails(self):
        probs = self._problems([self._cell(exact_match=False)])
        assert any("diverged" in p for p in probs)

    def test_inert_speculation_fails(self):
        probs = self._problems(
            [self._cell(acceptance_rate=0.0, verify_steps_per_token=1.0)])
        assert any("inert" in p for p in probs)

    def test_retrace_fails(self):
        probs = self._problems([self._cell(spec_compile_count=3)])
        assert any("retrace" in p for p in probs)

    def test_verify_cost_above_baseline_fails(self):
        probs = self._problems([self._cell(verify_steps_per_token=1.4)])
        assert any("above the baseline cost" in p for p in probs)

    def test_empty_doc_fails(self):
        assert self._problems([]) == ["no spec cells found"]


class TestDocsDrift:
    def test_check_detects_stale_block(self, tmp_path, capsys):
        from benchmarks.make_experiments_md import (
            BEGIN_MARK,
            END_MARK,
            check_experiments_md,
            write_experiments_md,
        )

        path = tmp_path / "EXPERIMENTS.md"
        path.write_text(f"# doc\n\n{BEGIN_MARK}\nstale\n{END_MARK}\n")
        assert not check_experiments_md(str(path))
        write_experiments_md(str(path))
        capsys.readouterr()
        assert check_experiments_md(str(path))
