"""Strassen block matmul (paper C4): correctness, FLOP economy, engine leaves."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st  # property tests skip w/o hypothesis

from repro.core import Mode, mp_matmul
from repro.core.strassen import flops_ratio, leaf_products, strassen_matmul


class TestCorrectness:
    @pytest.mark.parametrize("depth", [0, 1, 2])
    def test_matches_classical(self, rng, depth):
        a = jnp.asarray(rng.standard_normal((96, 64)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((64, 80)).astype(np.float32))
        out = np.asarray(strassen_matmul(a, b, depth=depth, align=8))
        ref = np.asarray(a) @ np.asarray(b)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-4)

    @given(st.integers(1, 50), st.integers(1, 50), st.integers(1, 50))
    @settings(max_examples=15, deadline=None)
    def test_arbitrary_shapes_padded(self, m, k, n):
        rng = np.random.default_rng(m + 100 * k + 10000 * n)
        a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        out = np.asarray(strassen_matmul(a, b, depth=1, align=4))
        ref = np.asarray(a) @ np.asarray(b)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_rmpm_leaf(self, rng):
        # paper's full stack: Strassen outside, multi-precision engine inside
        a = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
        out = np.asarray(mp_matmul(a, b, Mode.M16, strassen_depth=1))
        ref = np.asarray(a) @ np.asarray(b)
        rel = np.abs(out - ref).max() / np.abs(ref).max()
        assert rel < 2**-12  # M16 ladder with Strassen conditioning slack


class TestEconomy:
    def test_leaf_products(self):
        assert [leaf_products(d) for d in range(4)] == [1, 7, 49, 343]

    def test_flops_ratio(self):
        assert flops_ratio(1) == pytest.approx(7 / 8)
        assert flops_ratio(2) == pytest.approx(49 / 64)

    @pytest.mark.parametrize("depth", [1, 2])
    def test_hlo_dot_count_is_7_pow_depth(self, depth):
        # The compiled graph must contain exactly 7^depth leaf dots —
        # the paper's "7 multiplications instead of 8" at every level.
        a = jax.ShapeDtypeStruct((64 * 2**depth, 64 * 2**depth), jnp.float32)
        def fn(x, y):
            return strassen_matmul(x, y, depth=depth, align=64)

        hlo = jax.jit(fn).lower(a, a).as_text()
        assert hlo.count("dot_general") == 7**depth

    def test_hlo_flops_reduced(self):
        # cost_analysis FLOPs at depth 1 must be < classical (adds overhead
        # included) — the compute-roofline lever used in section Perf.
        n = 512
        a = jax.ShapeDtypeStruct((n, n), jnp.float32)
        classical = jax.jit(lambda x, y: jnp.dot(x, y)).lower(a, a).compile()
        strassen = (
            jax.jit(lambda x, y: strassen_matmul(x, y, depth=1, align=64))
            .lower(a, a)
            .compile()
        )
        def flops(compiled):
            ca = compiled.cost_analysis()
            if isinstance(ca, list):  # jax < 0.5 returns [dict]
                ca = ca[0]
            return ca["flops"]

        fc = flops(classical)
        fs = flops(strassen)
        assert fs < fc
        # 7/8 on the dots plus O(n^2) adds: allow [0.85, 0.95]
        assert 0.80 < fs / fc < 0.95
