"""Import-guard for the optional ``hypothesis`` dependency.

Tier-1 must collect and run without dev extras installed (the container
image ships only jax + pytest).  Property-based tests use hypothesis when
available (``pip install -r requirements-dev.txt``) and skip cleanly when it
is absent — the same effect as ``pytest.importorskip("hypothesis")`` but
scoped to the ``@given`` tests instead of skipping whole modules.

Usage in a test module::

    from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # property tests skip cleanly when absent
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any ``st.<strategy>(...)`` call; decorators ignore it."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        def deco(f):
            @pytest.mark.skip(
                reason="hypothesis not installed (pip install -r requirements-dev.txt)"
            )
            def _skipped(*args, **kwargs):
                pass  # pragma: no cover

            _skipped.__name__ = f.__name__
            _skipped.__doc__ = f.__doc__
            return _skipped

        return deco
