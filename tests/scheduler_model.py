"""Reusable scheduler/engine invariant harness.

Three pieces, shared by the property-based suite (test_scheduler_model.py),
the deterministic tier-1 tests and the differential serving tests
(test_tenancy.py):

  * :class:`RefScheduler` — an independent pure-Python reference
    implementation of the scheduler contract (admission by (aged priority,
    deadline, seq), quantum-guarded preemption, budget clamp, zero-budget
    drain).  It shares no code with ``repro.serve.scheduler`` — only the
    contract — so bookkeeping bugs in either implementation surface as an
    event-stream divergence rather than agreeing with themselves.
  * :func:`drive` — a model-free simulation of ``ServeEngine.step()``'s
    scheduler interactions (tick -> preempt -> admit/resume -> one decode
    token per active slot), recording an event log and checking the
    per-step invariants as it goes.  Token *values* are irrelevant here
    (the model emits zeros); token *counting* is exact, which is what the
    conservation and quantum invariants need.
  * ``check_*`` invariant functions over a finished log + scheduler.

The contract pinned by the harness (DESIGN.md section Multi-tenant
scheduling):

  conservation      every submitted rid completes exactly once, with
                    exactly ``budget`` tokens, and no ticket is lost in a
                    queue or slot at drain
  slot accounting   at every step: occupied slots and the free list
                    partition ``range(slots)``; each occupied ticket knows
                    its slot
  intra-class FIFO  within one (tenant, class), *first* admissions happen
                    in submission order (same priority + same relative
                    deadline + monotone seq => the key preserves seq order)
  priority order    under the priority policy, nothing admits while a
                    strictly better-keyed waiter stays queued
  no starvation     with aging on, every trace drains within the driver's
                    step bound (effective priority falls without bound)
  ref equivalence   the real scheduler and :class:`RefScheduler` produce
                    identical (step, kind, rid, slot) event streams
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.serve.scheduler import (DECODE, DONE, PREEMPTED, PREFILL, Request,
                                   WAITING)

# event kinds recorded by drive()
SUBMIT, ADMIT, RESUME, PREEMPT, TOKEN, FINISH = (
    "submit", "admit", "resume", "preempt", "token", "finish")


@dataclasses.dataclass
class Spec:
    """One abstract request for trace generation: submit at engine step
    ``step`` (steps are relative to drive() start; same-step specs submit
    in list order, which defines seq order)."""

    step: int
    rid: int
    tenant: str = "default"
    rclass: str = "default"
    prompt_len: int = 4
    max_new: int = 4

    def request(self, vocab: int = 64) -> Request:
        rng = np.random.default_rng(self.rid)
        return Request(
            prompt=rng.integers(0, vocab, self.prompt_len).astype(np.int32),
            max_new=self.max_new, rid=self.rid,
            tenant=self.tenant, rclass=self.rclass)


def trace_from_specs(specs: list[Spec]) -> list[list[Spec]]:
    """Group specs into drive()'s per-step submission lists (index = step,
    padded with empty steps; within a step, list order = submission order)."""
    if not specs:
        return []
    horizon = max(s.step for s in specs) + 1
    steps: list[list[Spec]] = [[] for _ in range(horizon)]
    for s in specs:
        steps[s.step].append(s)
    return steps


# ---------------------------------------------------------------------------
# Reference scheduler: an independent implementation of the contract
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RefTicket:
    rid: int
    budget: int
    tenant: str
    rclass: str
    priority: int
    deadline: float
    seq: int
    submit_step: int
    queued_step: int
    tokens_at_admit: int = 0
    preemptions: int = 0
    state: str = WAITING
    slot: int = -1
    tokens: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def remaining(self) -> int:
        return max(self.budget - len(self.tokens), 0)


class RefScheduler:
    """Pure-Python reference scheduler: same contract as
    ``repro.serve.Scheduler``, implemented independently.  Free slots are
    recycled FIFO (freed order) — part of the contract, since the real
    scheduler hands the longest-free slot to the best-keyed waiter."""

    def __init__(self, slots: int, max_len: int, *, tenants=None,
                 classes=None, policy: str = "priority",
                 aging_steps: int = 8, preempt: bool = True,
                 min_quantum: int = 2):
        from repro.serve.tenancy import normalize_classes, normalize_tenants

        self.slots = slots
        self.max_len = max_len
        self.tenants = normalize_tenants(tenants)
        self.classes = normalize_classes(classes)
        self.policy = policy
        self.aging_steps = aging_steps
        self.preempt_enabled = bool(preempt) and policy == "priority"
        self.min_quantum = min_quantum
        self.clock = 0
        self.queue: list[RefTicket] = []
        self.free: list[int] = list(range(slots))
        self.tickets: dict[int, RefTicket] = {}
        self.by_slot: dict[int, RefTicket] = {}
        self.completed: list[int] = []
        self.preemptions = 0
        self._seq = 0

    def tick(self) -> None:
        self.clock += 1

    def submit(self, req: Request) -> int:
        tenant = self.tenants[req.tenant]
        rc = self.classes[req.rclass]
        n = len(req.prompt)
        t = RefTicket(
            rid=req.rid,
            budget=max(min(req.max_new, self.max_len - n + 1), 0),
            tenant=tenant.name, rclass=rc.name, priority=tenant.priority,
            deadline=(self.clock + rc.slo_steps
                      if rc.slo_steps is not None else math.inf),
            seq=self._seq, submit_step=self.clock, queued_step=self.clock)
        self._seq += 1
        self.tickets[req.rid] = t
        self.queue.append(t)
        return req.rid

    def eff_priority(self, t: RefTicket) -> int:
        if not self.aging_steps:
            return t.priority
        return t.priority - (self.clock - t.queued_step) // self.aging_steps

    def admission_key(self, t: RefTicket):
        if self.policy == "fifo":
            return (t.seq,)
        return (self.eff_priority(t), t.deadline, t.seq)

    def admit(self):
        out = []
        waiting = []
        for t in self.queue:
            if t.budget == 0:
                self.complete(t.rid)
                out.append((-1, t))
            else:
                waiting.append(t)
        self.queue = sorted(waiting, key=self.admission_key)
        while self.queue and self.free:
            t = self.queue.pop(0)
            slot = self.free.pop(0)
            t.slot = slot
            t.state = DECODE if t.tokens else PREFILL
            t.tokens_at_admit = len(t.tokens)
            self.by_slot[slot] = t
            out.append((slot, t))
        return out

    def plan_preemptions(self):
        if not (self.preempt_enabled and self.queue):
            return []
        victims, taken = [], set()
        spare = len(self.free)
        for w in sorted((t for t in self.queue if t.budget > 0),
                        key=self.admission_key):
            if spare > 0:
                spare -= 1
                continue
            cands = [
                t for t in self.by_slot.values()
                if t.state == DECODE and t.rid not in taken
                and t.priority > w.priority
                and len(t.tokens) - t.tokens_at_admit >= self.min_quantum
            ]
            if cands:
                v = max(cands, key=lambda t: (t.priority, t.deadline, t.seq))
                victims.append(v)
                taken.add(v.rid)
        return victims

    def preempt(self, rid: int) -> None:
        t = self.tickets[rid]
        del self.by_slot[t.slot]
        self.free.append(t.slot)
        t.slot = -1
        t.state = PREEMPTED
        t.queued_step = self.clock
        t.preemptions += 1
        self.preemptions += 1
        self.queue.append(t)

    def start_decode(self, rid: int) -> None:
        self.tickets[rid].state = DECODE

    def complete(self, rid: int) -> None:
        t = self.tickets[rid]
        if t.done:
            return
        t.state = DONE
        self.completed.append(rid)
        if t.slot >= 0:
            del self.by_slot[t.slot]
            self.free.append(t.slot)
            t.slot = -1

    def has_work(self) -> bool:
        return bool(self.queue or self.by_slot)


# ---------------------------------------------------------------------------
# Driver: the engine's scheduler interactions, without a model
# ---------------------------------------------------------------------------


def check_slot_accounting(sched) -> None:
    """Occupied + free must partition range(slots), with no slot counted
    twice and every occupied ticket knowing its slot."""
    occupied = set(sched.by_slot)
    free = list(sched.free)
    assert len(free) == len(set(free)), f"duplicate free slots: {free}"
    assert not (occupied & set(free)), "slot both free and occupied"
    assert occupied | set(free) == set(range(sched.slots)), (
        f"slots leaked: occupied={occupied} free={free}")
    for slot, t in sched.by_slot.items():
        assert t.slot == slot, f"ticket {t.rid} thinks slot {t.slot}, is {slot}"
        assert not t.done, f"done ticket {t.rid} still holds slot {slot}"


def check_priority_consistency(sched, admitted) -> None:
    """Under the priority policy, every ticket admitted this step must have
    a key <= every ticket still waiting (no queue-jumping past the sort)."""
    if sched.policy != "priority" or not admitted:
        return
    waiting_keys = [sched.admission_key(t) for t in sched.queue
                    if t.budget > 0]
    if not waiting_keys:
        return
    best_waiting = min(waiting_keys)
    for t in admitted:
        assert sched.admission_key(t) <= best_waiting, (
            f"admitted {t.rid} with key {sched.admission_key(t)} while a "
            f"better waiter (key {best_waiting}) stayed queued")


def drive(sched, trace: list[list[Spec]], vocab: int = 64,
          max_steps: int = 5000, per_step_checks: bool = True):
    """Run a submission trace to drain, mirroring ServeEngine.step()'s
    scheduler protocol exactly: per step, submit this step's requests, tick
    the clock, preempt planned victims, admit (fresh admissions emit their
    first token; resumed ones emit nothing), then emit one decode token for
    every active slot.  Returns the event log as a list of
    (step, kind, rid, slot) tuples.  Raises AssertionError if the trace
    fails to drain within ``max_steps`` — the no-starvation bound."""
    log: list[tuple[int, str, int, int]] = []
    pending = [list(step) for step in trace]
    steps = 0

    def emit(t) -> None:
        t.tokens.append(0)
        log.append((sched.clock, TOKEN, t.rid, t.slot))
        if len(t.tokens) >= t.budget:
            slot = t.slot
            sched.complete(t.rid)
            log.append((sched.clock, FINISH, t.rid, slot))
        else:
            sched.start_decode(t.rid)

    while pending or sched.has_work():
        steps += 1
        assert steps <= max_steps, (
            f"starvation: trace did not drain in {max_steps} steps "
            f"(waiting: {[t.rid for t in sched.queue]})")
        if pending:
            for spec in pending.pop(0):
                sched.submit(spec.request(vocab))
                log.append((sched.clock, SUBMIT, spec.rid, -1))
        sched.tick()
        for v in sched.plan_preemptions():
            slot = v.slot
            sched.preempt(v.rid)
            log.append((sched.clock, PREEMPT, v.rid, slot))
        admitted = []
        for slot, t in sched.admit():
            if slot < 0:
                log.append((sched.clock, FINISH, t.rid, -1))
                continue
            admitted.append(t)
            if t.tokens:
                log.append((sched.clock, RESUME, t.rid, slot))
            else:
                log.append((sched.clock, ADMIT, t.rid, slot))
                emit(t)
        if per_step_checks:
            check_priority_consistency(sched, admitted)
        for slot in sorted(sched.by_slot):
            emit(sched.by_slot[slot])
        if per_step_checks:
            check_slot_accounting(sched)
    return log


# ---------------------------------------------------------------------------
# Whole-log invariants
# ---------------------------------------------------------------------------


def check_conservation(sched, log) -> None:
    """Every submitted rid completes exactly once with exactly its budget
    of tokens; nothing is left queued or running."""
    submitted = [rid for _, kind, rid, _ in log if kind == SUBMIT]
    finished = [rid for _, kind, rid, _ in log if kind == FINISH]
    assert sorted(submitted) == sorted(finished), (
        f"lost/duplicated requests: submitted {sorted(submitted)} "
        f"finished {sorted(finished)}")
    assert len(set(finished)) == len(finished), "a rid finished twice"
    assert sorted(sched.completed) == sorted(submitted)
    assert not sched.queue and not sched.by_slot
    for rid in submitted:
        t = sched.tickets[rid]
        assert t.done and len(t.tokens) == t.budget, (
            f"rid {rid}: {len(t.tokens)} tokens vs budget {t.budget}")


def check_intra_class_fifo(sched, log) -> None:
    """Within one (tenant, class), first admissions happen in submission
    (seq) order — the deterministic-tie-break pin, generalized."""
    first_admit: dict[int, int] = {}
    for i, (_, kind, rid, _) in enumerate(log):
        if kind == ADMIT and rid not in first_admit:
            first_admit[rid] = i
    by_group: dict[tuple[str, str], list[int]] = {}
    for rid, pos in sorted(first_admit.items(), key=lambda kv: kv[1]):
        t = sched.tickets[rid]
        by_group.setdefault((t.tenant, t.rclass), []).append(t.seq)
    for group, seqs in by_group.items():
        assert seqs == sorted(seqs), (
            f"{group}: first admissions out of submission order: {seqs}")


def check_aging_bound(sched, log) -> None:
    """With aging on, no request waits unboundedly: every wait between
    joining the queue and (re-)admission is finite and, for the traces the
    generators produce, below an explicit bound derived from the aging
    rate (priority spread shrinks one rung per aging_steps ticks, and each
    admission frees a slot within max-budget tokens)."""
    if not sched.aging_steps or sched.policy != "priority":
        return
    spread = max(t.priority for t in sched.tickets.values()) - min(
        (t.priority for t in sched.tickets.values()), default=0)
    max_budget = max((t.budget for t in sched.tickets.values()), default=1)
    # crude but sufficient: once aged past the spread, a waiter out-ranks
    # every arrival; it then waits at most one full rotation of the slots
    bound = (spread + 2) * sched.aging_steps + (
        len(sched.tickets) + sched.slots) * max(max_budget, 1)
    queued_at: dict[int, int] = {}
    for step, kind, rid, _ in log:
        if kind == SUBMIT or kind == PREEMPT:
            queued_at[rid] = step
        elif kind in (ADMIT, RESUME) and rid in queued_at:
            wait = step - queued_at.pop(rid)
            assert wait <= bound, (
                f"rid {rid} waited {wait} steps (bound {bound})")


def check_quantum(sched, log) -> None:
    """Every preempted ticket emitted at least ``min_quantum`` tokens since
    its previous admission — preemption can never cancel progress."""
    tokens_since: dict[int, int] = {}
    for _, kind, rid, _ in log:
        if kind in (ADMIT, RESUME):
            tokens_since[rid] = 0
        elif kind == TOKEN:
            if rid in tokens_since:
                tokens_since[rid] += 1
        elif kind == PREEMPT:
            assert tokens_since.get(rid, 0) >= sched.min_quantum, (
                f"rid {rid} preempted after only {tokens_since.get(rid)} "
                f"tokens (min_quantum {sched.min_quantum})")


def check_equivalence(log_real, log_ref) -> None:
    """The real scheduler and the reference produce identical event
    streams (step, kind, rid, slot) — the differential core."""
    if log_real == log_ref:
        return
    for i, (a, b) in enumerate(zip(log_real, log_ref)):
        assert a == b, f"event {i} diverges: real {a} vs ref {b}"
    raise AssertionError(
        f"log lengths diverge: real {len(log_real)} vs ref {len(log_ref)}")


def check_all(sched, log) -> None:
    """The full single-scheduler invariant battery."""
    check_conservation(sched, log)
    check_intra_class_fifo(sched, log)
    check_aging_bound(sched, log)
    check_quantum(sched, log)


# ---------------------------------------------------------------------------
# Consumer mode: replay a real engine's trace through the same invariants
# ---------------------------------------------------------------------------

#: repro.obs event kinds -> drive() log kinds.  Everything else in the
#: trace (decode_step, mode_switch, cow_fork, ...) is engine detail the
#: scheduler contract does not speak about and is dropped by the mapping.
TRACE_KINDS = {
    "submit": SUBMIT,
    "admit": ADMIT,
    "resume": RESUME,
    "preempt": PREEMPT,
    "token": TOKEN,
    "done": FINISH,
}


def log_from_trace(events, *, skip_causes: tuple[str, ...] = ()) -> list:
    """Project a repro.obs event stream onto drive()'s
    ``(step, kind, rid, slot)`` log.  ``skip_causes`` drops lifecycle
    events whose cause is exempt from a specific invariant — e.g. the
    quantum check runs with ``skip_causes=("page_pressure",)`` because
    page-pressure eviction deliberately ignores ``min_quantum`` (memory
    pressure is a correctness condition, not a fairness policy)."""
    log = []
    for e in events:
        kind = TRACE_KINDS.get(e.kind)
        if kind is None or e.rid is None:
            continue
        if e.cause is not None and e.cause in skip_causes:
            continue
        log.append((e.step, kind, e.rid,
                    -1 if e.slot is None else int(e.slot)))
    return log


def check_replay(engine) -> list:
    """Replay a drained traced engine's event stream through the scheduler
    invariants — every trace becomes a checkable artifact.

    Checks always: lossless ring (no dropped events), request-span
    lifecycle order (repro.obs.span_violations), conservation, and the
    quantum bound over priority preemptions (page-pressure evictions are
    cause-exempt).  The FIFO and aging checks only run on traces without
    admission refusals/deferrals: under memory pressure the layout legally
    reorders admissions (slot order is a preference, not a barrier) and
    re-ranks waits, which those two checks would misread as violations.
    Returns the projected log."""
    from repro.obs import span_violations

    tracer = engine.tracer
    assert tracer.enabled, "check_replay needs a traced engine"
    assert tracer.dropped == 0, (
        f"{tracer.dropped} events dropped — raise TraceConfig.capacity to "
        f"make the trace replayable")
    events = list(tracer.events)
    bad = span_violations(events)
    assert not bad, f"lifecycle violations: {bad}"
    sched = engine.scheduler
    log = log_from_trace(events)
    check_conservation(sched, log)
    pressured = any(e.kind in ("admit_defer", "admit_refuse") for e in events)
    if not pressured:
        check_intra_class_fifo(sched, log)
        check_aging_bound(sched, log)
    check_quantum(sched, log_from_trace(events,
                                        skip_causes=("page_pressure",)))
    return log
