"""Planner subsystem (repro.plan): cost-model selection, execution
correctness vs jnp.dot at mode tolerance, plan-cache behaviour, and the
doctested plan_matmul example."""
import dataclasses
import doctest

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.precision import DoubleF32, Mode, df32_from_f32
from repro.plan import (
    MODE_REL_ERROR,
    clear_plan_cache,
    estimate,
    execute,
    matmul,
    plan_cache_stats,
    plan_matmul,
    plan_model_policy,
)
from repro.plan import planner as planner_mod


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


class TestSelection:
    """plan_matmul must pick distinct (mode, depth, impl) across scenarios."""

    def test_four_distinct_scenarios(self):
        scenarios = [
            # (shape_a, shape_b, kwargs)
            ((4096, 4096), (4096, 4096), dict(accuracy=2**-12, backend="tpu")),
            ((256, 256), (256, 256), dict(accuracy=2**-4, backend="cpu")),
            ((1024, 1024), (1024, 1024), dict(accuracy=None, backend="tpu")),
            ((512, 512), (512, 512),
             dict(accuracy=2**-30, backend="tpu", dtype="df32")),
        ]
        picks = [plan_matmul(a, b, **kw) for a, b, kw in scenarios]
        decisions = {(p.mode, p.impl, p.strassen_depth) for p in picks}
        assert len(decisions) == len(scenarios), [p.describe() for p in picks]
        # the specific levers the cost model must exercise:
        large, coarse, default, extended = picks
        assert large.mode == Mode.M16 and large.strassen_depth >= 1
        assert large.impl == "pallas"  # fused limb extraction on TPU
        assert coarse.mode == Mode.M8  # cheapest adequate mode
        assert default.mode == Mode.M24  # single-precision fidelity default
        assert extended.mode in (Mode.M32, Mode.M48) and extended.impl == "xla"
        assert extended.strassen_depth == 0  # DoubleF32 leaves: no block adds

    def test_accuracy_ladder_monotone(self):
        modes = [
            plan_matmul((256, 256), (256, 256), accuracy=acc, backend="tpu").mode
            for acc in (2**-4, 2**-12, 2**-20)
        ]
        assert modes == [Mode.M8, Mode.M16, Mode.M24]

    def test_depth_grows_with_size(self):
        depths = [
            plan_matmul((n, n), (n, n), accuracy=2**-12, backend="tpu",
                        max_depth=3).strassen_depth
            for n in (128, 4096, 16384)
        ]
        assert depths[0] == 0
        assert depths == sorted(depths)
        assert depths[-1] >= 2

    def test_tiny_shapes_stay_classical(self):
        p = plan_matmul((8, 16), (16, 8), accuracy=2**-12, backend="tpu")
        assert p.strassen_depth == 0

    def test_pinned_mode_and_impl_respected(self):
        p = plan_matmul((512, 512), (512, 512), mode=Mode.M8, impl="xla",
                        backend="tpu", max_depth=2)
        assert p.mode == Mode.M8 and p.impl == "xla"

    def test_native_never_on_tpu(self):
        p = plan_matmul((256, 256), (256, 256), accuracy=2**-4, backend="tpu")
        assert p.impl != "native"

    def test_auto_mode_rejected(self):
        with pytest.raises(ValueError, match="AUTO"):
            plan_matmul((64, 64), (64, 64), mode=Mode.AUTO)

    def test_shape_mismatch_rejected(self):
        with pytest.raises(ValueError, match="mismatch"):
            plan_matmul((64, 32), (64, 64))

    def test_cost_estimate_sane(self):
        p = plan_matmul((1024, 1024), (1024, 1024), accuracy=None, backend="tpu")
        # M24 = 6 bf16 passes over 2*n^3 flops
        assert p.cost.flops == pytest.approx(6 * 2 * 1024**3, rel=0.01)
        assert p.cost.t_total_s > 0
        assert p.cost.dominant in ("compute", "memory")

    def test_strassen_estimate_trades_flops_for_bytes(self):
        e0 = estimate(4096, 4096, 4096, Mode.M16, "pallas", 0)
        e1 = estimate(4096, 4096, 4096, Mode.M16, "pallas", 1)
        assert e1.flops < e0.flops  # 7/8 leaf saving (plus small adds)
        assert e1.hbm_bytes > e0.hbm_bytes  # O(n^2) block-add traffic


class TestExecution:
    """execute(plan, a, b) must agree with jnp.dot to mode tolerance."""

    @pytest.mark.parametrize("depth", [2, 3])
    def test_strassen_deep_matches_dot(self, rng, depth):
        a = _rand(rng, 256, 256)
        b = _rand(rng, 256, 256)
        p = plan_matmul(a.shape, b.shape, mode=Mode.M24, impl="xla",
                        max_depth=depth, align=32)
        # force the requested depth through a pinned plan if cost said less
        p = dataclasses.replace(p, strassen_depth=depth)
        out = np.asarray(execute(p, a, b), np.float64)
        ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        rel = np.abs(out - ref).max() / np.abs(ref).max()
        assert rel < MODE_REL_ERROR[Mode.M24] * 2**depth  # conditioning slack

    @pytest.mark.parametrize(
        "m,k,n", [(300, 270, 130), (1, 17, 5), (257, 129, 65), (33, 470, 31)]
    )
    def test_nonsquare_odd_shapes(self, rng, m, k, n):
        a, b = _rand(rng, m, k), _rand(rng, k, n)
        p = plan_matmul(a.shape, b.shape, mode=Mode.M16, impl="xla",
                        max_depth=2, align=16)
        p = dataclasses.replace(p, strassen_depth=2)
        out = np.asarray(execute(p, a, b), np.float64)
        ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        rel = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
        assert rel < MODE_REL_ERROR[Mode.M16] * 8  # padding + recombine slack

    def test_batched_leading_dims_vmap_safe(self, rng):
        a = _rand(rng, 3, 2, 64, 64)
        b = _rand(rng, 64, 64)
        p = plan_matmul(a.shape, b.shape, mode=Mode.M24, impl="xla",
                        max_depth=1, align=16)
        p = dataclasses.replace(p, strassen_depth=1)
        out = execute(p, a, b)
        assert out.shape == (3, 2, 64, 64)
        ref = np.einsum("btmk,kn->btmn", np.asarray(a, np.float64),
                        np.asarray(b, np.float64))
        np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                                   rtol=1e-4, atol=1e-4)
        # and the executor itself can sit under an outer vmap
        outer = jax.vmap(lambda x: execute(
            plan_matmul(x.shape, b.shape, mode=Mode.M24, impl="xla"), x, b
        ))(a.reshape(6, 64, 64))
        np.testing.assert_allclose(
            np.asarray(outer), np.asarray(out).reshape(6, 64, 64),
            rtol=1e-5, atol=1e-5)

    def test_matmul_convenience_df32(self, rng):
        a, b = _rand(rng, 48, 256), _rand(rng, 256, 32)
        out = matmul(df32_from_f32(a), df32_from_f32(b), accuracy=2**-28)
        assert isinstance(out, DoubleF32)
        ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        o64 = np.asarray(out.hi, np.float64) + np.asarray(out.lo, np.float64)
        assert np.abs(o64 - ref).max() / np.abs(ref).max() < 2**-28

    def test_execute_rejects_wrong_shapes(self, rng):
        a, b = _rand(rng, 32, 32), _rand(rng, 32, 32)
        p = plan_matmul((64, 32), (32, 32))
        with pytest.raises(ValueError, match="do not match plan"):
            execute(p, a, b)


class TestPlanCache:
    def test_hit_returns_same_object(self):
        p1 = plan_matmul((128, 128), (128, 128), accuracy=2**-12, backend="tpu")
        s = plan_cache_stats()
        assert (s.hits, s.misses) == (0, 1)
        p2 = plan_matmul((128, 128), (128, 128), accuracy=2**-12, backend="tpu")
        assert p2 is p1
        s = plan_cache_stats()
        assert (s.hits, s.misses) == (1, 1)

    def test_distinct_keys_miss(self):
        plan_matmul((128, 128), (128, 128), accuracy=2**-12, backend="tpu")
        plan_matmul((128, 128), (128, 128), accuracy=2**-4, backend="tpu")
        plan_matmul((128, 256), (256, 128), accuracy=2**-12, backend="tpu")
        s = plan_cache_stats()
        assert (s.hits, s.misses) == (0, 3)
        assert s.entries == 3

    def test_clear(self):
        plan_matmul((128, 128), (128, 128))
        clear_plan_cache()
        s = plan_cache_stats()
        assert (s.hits, s.misses, s.entries) == (0, 0, 0)

    def test_model_trace_plans_each_gemm_once(self, rng):
        # a scanned/jitted trace re-uses the cached plan per distinct shape
        from repro.core.policy import PrecisionPolicy
        from repro.models.layers import pmm

        policy = PrecisionPolicy()
        x = _rand(rng, 8, 64)
        w = _rand(rng, 64, 64)

        def f(x, w):
            for _ in range(5):
                x = pmm(x, w, "mlp_up", policy)
            return x

        jax.jit(f).lower(x, w)
        s = plan_cache_stats()
        assert s.misses == 1 and s.hits == 4


class TestPolicyBridge:
    def test_plan_model_policy(self):
        from repro.configs import get_smoke_config

        cfg = get_smoke_config("qwen1.5-0.5b")
        policy, plans = plan_model_policy(cfg, tokens=8 * 128,
                                          accuracy=2**-4, backend="tpu")
        assert policy.default == Mode.M8  # bulk GEMMs at the coarse budget
        # sensitive ops planned tighter than the bulk default
        assert policy.mode_for("logits").value > Mode.M8.value
        assert "mlp_up" in plans and plans["mlp_up"].impl in ("xla", "pallas")


def test_plan_matmul_doctest():
    results = doctest.testmod(planner_mod, verbose=False)
    assert results.attempted >= 2
    assert results.failed == 0
