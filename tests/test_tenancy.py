"""Multi-tenant serving: differential bit-identity vs solo runs (dense /
ssm / hybrid, with speculation, under forced preemption), per-tenant adapt
isolation, ServeMetrics per-tenant edge cases, and engine/scheduler
agreement under preemption.

The scheduling-contract invariants themselves live in
tests/test_scheduler_model.py (model-free); this module is the engine half
of the harness: real models, real state parking, real tokens.
"""
import dataclasses

import numpy as np
import pytest
import jax

from scheduler_model import check_slot_accounting
from repro.adapt import SLO
from repro.adapt.workload import conditioned_model
from repro.configs import get_smoke_config
from repro.core.policy import NATIVE_F32
from repro.core.precision import Mode
from repro.models import build_model
from repro.serve import Request, ServeEngine
from repro.serve.metrics import ServeMetrics
from repro.serve.tenancy import (RequestClass, Tenant, class_requests,
                                 normalize_classes, normalize_tenants)
from repro.spec import SpecConfig

TENANTS = [
    Tenant("interactive", priority=0, share=2.0),
    Tenant("bulk", priority=2, share=1.0),
]
CLASSES = [
    RequestClass("chat", slo_steps=8, prompt_len=6, max_new=5),
    RequestClass("batch", prompt_len=8, max_new=12),
]


def _tiny(arch="qwen1.5-0.5b", n_layers=2, seed=0, **over):
    cfg = get_smoke_config(arch).with_policy(NATIVE_F32)
    cfg = dataclasses.replace(cfg, n_layers=n_layers, **over)
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    return cfg, model, params


def _mixed_requests(vocab, rng):
    """3 bulk/batch + 2 interactive/chat — bulk submitted first so it
    saturates the slots before the urgent traffic arrives."""
    bulk = class_requests(CLASSES[1], TENANTS[1], 3, vocab, rng, rid_base=0)
    chat = class_requests(CLASSES[0], TENANTS[0], 2, vocab, rng, rid_base=10)
    return bulk, chat


def _contended_drain(eng, bulk, chat, warmup=3):
    """Fill the slots with bulk, let it run ``warmup`` steps, then drop the
    urgent chat traffic on top and drain."""
    for r in bulk:
        eng.submit(r)
    for _ in range(warmup):
        eng.step()
    for r in chat:
        eng.submit(r)
    out = eng.drain()
    check_slot_accounting(eng.scheduler)
    return out


def _solo_outputs(model, params, reqs, max_len=32):
    """Each request served alone at batch_slots=1 — the bit-identity
    reference (one engine reused; rids offset to keep them unique)."""
    eng = ServeEngine(model, params, batch_slots=1, max_len=max_len)
    out = {}
    for r in reqs:
        clone = Request(prompt=r.prompt, max_new=r.max_new, rid=r.rid + 1000)
        out[r.rid] = eng.generate_batch([clone])[clone.rid]
    return out


class TestDifferentialExactness:
    """ISSUE 6 acceptance: every request's tokens under multi-tenant
    scheduling — including preempted-and-resumed ones — are bit-identical
    to the same request served alone."""

    @pytest.mark.parametrize(
        "arch", ["qwen1.5-0.5b", "mamba2-2.7b", "recurrentgemma-9b"])
    def test_families_exact_under_preemption(self, arch):
        cfg, model, params = _tiny(arch)
        rng = np.random.default_rng(2)
        bulk, chat = _mixed_requests(cfg.vocab, rng)
        eng = ServeEngine(model, params, batch_slots=2, max_len=32,
                          tenants=TENANTS, classes=CLASSES,
                          aging_steps=4, min_quantum=1)
        out = _contended_drain(eng, bulk, chat)
        # contention is real: at least one bulk request was parked/resumed
        assert eng.scheduler.preemptions >= 1
        solo = _solo_outputs(model, params, bulk + chat)
        for r in bulk + chat:
            assert out[r.rid] == solo[r.rid], f"{arch} rid {r.rid}"

    def test_exact_with_speculation(self):
        # speculate= + tenants= (static verify table): preempted/resumed
        # slots must roll back and park consistently inside spec rounds
        cfg, model, params = _tiny()
        rng = np.random.default_rng(3)
        bulk, chat = _mixed_requests(cfg.vocab, rng)
        eng = ServeEngine(model, params, batch_slots=2, max_len=32,
                          tenants=TENANTS, classes=CLASSES,
                          aging_steps=4, min_quantum=1,
                          speculate=SpecConfig(k=2, draft_shift=1))
        out = _contended_drain(eng, bulk, chat)
        assert eng.scheduler.preemptions >= 1
        solo = _solo_outputs(model, params, bulk + chat)
        for r in bulk + chat:
            assert out[r.rid] == solo[r.rid], f"rid {r.rid}"

    def test_fifo_policy_also_exact(self):
        # the baseline arm of the tenant sweep: same workload, no
        # reordering, still bit-identical per request
        cfg, model, params = _tiny()
        rng = np.random.default_rng(4)
        bulk, chat = _mixed_requests(cfg.vocab, rng)
        eng = ServeEngine(model, params, batch_slots=2, max_len=32,
                          tenants=TENANTS, classes=CLASSES,
                          scheduler_policy="fifo")
        out = _contended_drain(eng, bulk, chat)
        assert eng.scheduler.preemptions == 0
        solo = _solo_outputs(model, params, bulk + chat)
        for r in bulk + chat:
            assert out[r.rid] == solo[r.rid], f"rid {r.rid}"

    def test_forced_preemption_roundtrip_exact(self):
        # minimal single-slot park/resume: one long bulk request preempted
        # by an urgent one must resume from its parked row and finish with
        # exactly its solo token stream (no re-prefill, no drift)
        cfg, model, params = _tiny()
        rng = np.random.default_rng(5)
        long = Request(prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                       max_new=10, rid=0, tenant="bulk", rclass="batch")
        urgent = Request(prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                         max_new=3, rid=1, tenant="interactive", rclass="chat")
        eng = ServeEngine(model, params, batch_slots=1, max_len=32,
                          tenants=TENANTS, classes=CLASSES, min_quantum=1)
        eng.submit(long)
        for _ in range(3):
            eng.step()
        eng.submit(urgent)
        out = eng.drain()
        assert eng.scheduler.tickets[0].preemptions >= 1
        solo = _solo_outputs(model, params, [long, urgent])
        assert out[0] == solo[0]
        assert out[1] == solo[1]


class TestPerTenantAdaptIsolation:
    """One tenant's hot workload must not drag another tenant's mode
    table: each tenant owns a private table + controller, probed only on
    its own slots."""

    def test_hot_tenant_shifts_cold_tenant_holds(self):
        wl = conditioned_model()
        rng = np.random.default_rng(0)
        tenants = [Tenant("hot", priority=1), Tenant("cold", priority=1)]
        eng = ServeEngine(wl.model, wl.params, batch_slots=4, max_len=48,
                          slo=SLO(max_err=0.5), adapt_every=1,
                          tenants=tenants)
        assert eng.mode_table is None  # per-tenant mode: no shared table
        hot = wl.requests(4, hot={0, 1, 2, 3}, rng=rng, max_new=12)
        cold = wl.requests(4, hot=set(), rng=rng, max_new=12)
        for r in hot:
            eng.submit(dataclasses.replace(r, tenant="hot"))
        for r in cold:
            eng.submit(dataclasses.replace(r, rid=r.rid + 100, tenant="cold"))
        eng.drain()
        assert eng.tenant_ctrl["hot"].up_shifts >= 1
        assert int(Mode[eng.tenant_tables["hot"].label()]) > int(Mode.M8)
        # isolation: the cold tenant's controller never saw the hot
        # residuals, so its table never moved
        assert eng.tenant_ctrl["cold"].up_shifts == 0
        assert eng.tenant_tables["cold"].label() == "M8"
        # one compiled step serves every table combination
        assert eng.decode_compile_count == 1
        assert "per-tenant" in eng.describe_adaptation()

    def test_speculate_with_per_tenant_adapt_refused(self):
        wl = conditioned_model()
        with pytest.raises(NotImplementedError, match="per-tenant"):
            ServeEngine(wl.model, wl.params, batch_slots=2, max_len=32,
                        slo=SLO(max_err=0.5), tenants=[Tenant("a")],
                        speculate=SpecConfig(k=2, draft_shift=1))

    def test_shared_controller_with_tenants_refused(self):
        from repro.adapt import HysteresisController

        wl = conditioned_model()
        with pytest.raises(ValueError, match="per-tenant"):
            ServeEngine(wl.model, wl.params, batch_slots=2, max_len=32,
                        slo=SLO(max_err=0.5), tenants=[Tenant("a")],
                        controller=HysteresisController(SLO(max_err=0.5)))


class TestMetricsEdgeCases:
    """Satellite: ServeMetrics per-tenant accounting corners."""

    def test_zero_completed_tenant(self):
        m = ServeMetrics(slots=2)
        m.set_tenant_shares({"a": 2.0, "b": 1.0, "idle": 1.0})
        m.on_submit(0, tenant="a", rclass="chat", slo_steps=4, step=0)
        m.on_first_token(0)
        m.on_token(0)
        m.on_decode_step(1, tenant_active={"a": 1})
        # tenant "b" submitted but completed nothing; "idle" never submitted
        m.on_submit(1, tenant="b", rclass="chat", slo_steps=4, step=0)
        ts = m.tenant_summary()
        assert ts["b"]["completed"] == 0
        assert ts["b"]["latency_p50_s"] is None
        assert ts["b"]["latency_p99_s"] is None
        # deadline-carrying but unfinished: a miss, not missing data
        assert ts["b"]["attainment"] == 0.0
        assert ts["idle"]["submitted"] == 0
        assert ts["idle"]["attainment"] is None
        assert ts["idle"]["entitlement"] == 0.0  # never submitted: no claim
        # entitlement renormalizes over submitting tenants only
        assert ts["a"]["entitlement"] == pytest.approx(2 / 3)
        assert ts["a"]["slot_share"] == 1.0

    def test_preempted_ttft_is_recorded_once(self):
        t = [0.0]

        def clock():
            t[0] += 1.0
            return t[0]

        m = ServeMetrics(slots=1, clock=clock)
        m.on_submit(0, tenant="a", step=0)
        m.on_first_token(0)
        first = m.ttft(0)
        m.on_preempt(0)
        # a resume must NOT look like a second first token; guard ignores it
        m.on_first_token(0)
        assert m.ttft(0) == first
        assert m.prefills == 1
        assert m.requests[0].preemptions == 1
        m.on_done(0, step=7)
        assert m.latency(0) is not None

    def test_engine_metrics_agree_with_scheduler_under_preemption(self):
        cfg, model, params = _tiny()
        rng = np.random.default_rng(6)
        bulk, chat = _mixed_requests(cfg.vocab, rng)
        eng = ServeEngine(model, params, batch_slots=2, max_len=32,
                          tenants=TENANTS, classes=CLASSES,
                          aging_steps=4, min_quantum=1)
        out = _contended_drain(eng, bulk, chat)
        assert eng.scheduler.preemptions >= 1
        s = eng.metrics.summary()
        assert s["completed"] == len(eng.scheduler.completed) == len(out)
        assert s["preemptions"] == eng.scheduler.preemptions
        assert s["tokens_out"] == sum(len(v) for v in out.values())
        ts = s["tenants"]
        assert ts["bulk"]["preemptions"] == eng.scheduler.preemptions
        assert ts["interactive"]["preemptions"] == 0
        # every request prefilled exactly once (resumes don't re-prefill)
        assert eng.metrics.prefills == len(out)
        # slot-share accounting balances to 1 across tenants that decoded
        total_share = sum(v["slot_share"] for v in ts.values())
        assert total_share == pytest.approx(1.0)
        # attainment exists for the deadline-carrying class only
        assert ts["interactive"]["attainment"] is not None
        assert ts["bulk"]["attainment"] is None
        assert "interactive" in eng.describe_tenancy()

    def test_tenant_registry_validation(self):
        cfg, model, params = _tiny()
        eng = ServeEngine(model, params, batch_slots=1, max_len=32,
                          tenants=TENANTS, classes=CLASSES)
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab, 4).astype(np.int32)
        with pytest.raises(ValueError, match="unknown tenant"):
            eng.submit(Request(prompt=prompt, rid=0, tenant="nope"))
        with pytest.raises(ValueError, match="unknown request class"):
            eng.submit(Request(prompt=prompt, rid=0, tenant="bulk",
                               rclass="nope"))

    def test_normalize_helpers_and_validation(self):
        reg = normalize_tenants(TENANTS)
        assert set(reg) == {"interactive", "bulk", "default"}
        assert normalize_classes(None) == {"default": RequestClass("default")}
        with pytest.raises(ValueError, match="share"):
            Tenant("x", share=0)
        with pytest.raises(ValueError, match="slo_steps"):
            RequestClass("x", slo_steps=0)
        with pytest.raises(TypeError):
            normalize_tenants([RequestClass("x")])
