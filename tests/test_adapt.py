"""repro.adapt: runtime mode table + binding, probes, hysteresis controller,
and the end-to-end closed loop (ISSUE 4 acceptance: an ill-conditioned
prompt batch shifts the decode mode up within the cooldown window and back
down after, with zero recompiles)."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.adapt import (
    SLO,
    GradDriftProbe,
    HysteresisController,
    ModeTable,
    TrainPrecisionSchedule,
    bind_modes,
    logit_residual,
    runtime_mode_for,
    sampled_matmul_residual,
)
from repro.adapt.workload import conditioned_model
from repro.core.precision import Mode
from repro.core.rmpm import mp_einsum, mp_matmul, mp_matmul_runtime
from repro.serve import ServeEngine


class TestModeTable:
    def test_clamps_to_ladder(self):
        t = ModeTable({"mlp_up": Mode.M8})
        assert t.shift("mlp_up", -1) is False  # already at min
        assert t.shift("mlp_up", +5) is True
        assert t.modes()["mlp_up"] == Mode.M24  # clamped at max
        assert t.at_max

    def test_shift_all_preserves_stagger(self):
        t = ModeTable({"mlp_up": Mode.M8, "attn_qk": Mode.M16})
        assert t.shift_all(+1)
        assert t.modes() == {"mlp_up": Mode.M16, "attn_qk": Mode.M24}
        # attn_qk clamps at max; mlp_up keeps climbing
        assert t.shift_all(+1)
        assert t.modes() == {"mlp_up": Mode.M24, "attn_qk": Mode.M24}
        assert t.switches == 2 and len(t.history) == 2

    def test_scalars_shifted_clamped(self):
        t = ModeTable({"a": Mode.M16})
        assert int(t.scalars()["a"]) == int(Mode.M16)
        assert int(t.scalars_shifted(+2)["a"]) == int(Mode.M24)
        assert int(t.scalars_shifted(-5)["a"]) == int(Mode.M8)

    def test_rejects_non_f32_ladder(self):
        with pytest.raises(ValueError):
            ModeTable({"a": Mode.M8}, max_mode=Mode.M48)
        with pytest.raises(ValueError):
            ModeTable({})

    def test_label(self):
        assert ModeTable({"a": Mode.M8, "b": Mode.M8}).label() == "M8"
        assert ModeTable({"a": Mode.M8, "b": Mode.M16}).label() == "M16/M8"

    def test_from_plans_skips_unswitchable(self):
        from repro.plan import plan_matmul

        p8 = plan_matmul((64, 64), (64, 64), accuracy=2**-4, backend="cpu")
        pdd = plan_matmul((64, 64), (64, 64), dtype="df32", backend="cpu")
        t = ModeTable.from_plans({"mlp_up": p8, "exotic": pdd})
        assert set(t.modes()) == {"mlp_up"}


class TestBinding:
    def test_unbound_returns_none(self):
        assert runtime_mode_for("mlp_up") is None

    def test_bound_with_default(self):
        with bind_modes({"mlp_up": 1, "*": 3}):
            assert runtime_mode_for("mlp_up") == 1
            assert runtime_mode_for("logits") == 3
        assert runtime_mode_for("mlp_up") is None

    def test_runtime_switch_matches_static(self, rng):
        """The lax.switch branch selected by a runtime scalar must compute
        exactly what the static-mode dispatch computes."""
        a = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
        for mode in (Mode.M8, Mode.M16, Mode.M24):
            static = mp_matmul(a, b, mode)
            runtime = mp_matmul_runtime(a, b, jnp.int32(int(mode)))
            np.testing.assert_array_equal(np.asarray(static), np.asarray(runtime))

    def test_pmm_reads_bound_scalar(self, rng):
        """pmm under bind_modes switches with the scalar, without retracing."""
        from repro.core.policy import PrecisionPolicy
        from repro.models.layers import pmm

        x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
        policy = PrecisionPolicy(default=Mode.M8)
        traces = []

        @jax.jit
        def f(x, w, scalar):
            traces.append(1)
            with bind_modes({"mlp_up": scalar}):
                return pmm(x, w, "mlp_up", policy)

        out8 = f(x, w, jnp.int32(int(Mode.M8)))
        out24 = f(x, w, jnp.int32(int(Mode.M24)))
        np.testing.assert_array_equal(np.asarray(out8),
                                      np.asarray(mp_matmul(x, w, Mode.M8)))
        np.testing.assert_array_equal(np.asarray(out24),
                                      np.asarray(mp_matmul(x, w, Mode.M24)))
        assert len(traces) == 1  # one trace, two mode values


class TestBlockPlumb:
    """Satellite: the Pallas block override survives the runtime mode switch
    (and mp_einsum's pallas matmul dispatch)."""

    def _spy(self, monkeypatch):
        calls = []
        from repro.kernels.limb_matmul import ops as limb_ops

        real = limb_ops.limb_matmul

        def spy(a, b, k, **kw):
            calls.append(kw)
            return real(a, b, k, **kw)  # interpret=True default: CPU-exec
        monkeypatch.setattr(limb_ops, "limb_matmul", spy)
        return calls

    def test_runtime_matmul_forwards_block(self, rng, monkeypatch):
        calls = self._spy(monkeypatch)
        a = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
        mp_matmul_runtime(a, b, jnp.int32(int(Mode.M8)), impl="pallas",
                          block=(8, 8, 8))
        assert calls and all(
            (c.get("bm"), c.get("bn"), c.get("bk")) == (8, 8, 8) for c in calls)

    def test_einsum_matmul_forwards_block(self, rng, monkeypatch):
        calls = self._spy(monkeypatch)
        a = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
        mp_einsum("mk,kn->mn", a, b, Mode.M16, impl="pallas", block=(8, 8, 8))
        assert calls and calls[0].get("bm") == 8

    def test_einsum_runtime_forwards_impl_and_block(self, rng, monkeypatch):
        from repro.core.rmpm import mp_einsum_runtime

        calls = self._spy(monkeypatch)
        a = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
        mp_einsum_runtime("mk,kn->mn", a, b, jnp.int32(int(Mode.M8)),
                          impl="pallas", block=(8, 8, 8))
        assert calls and all(c.get("bm") == 8 for c in calls)
        # native would make every switch branch identical — rejected
        with pytest.raises(ValueError):
            mp_einsum_runtime("mk,kn->mn", a, b, jnp.int32(1), impl="native")


class TestController:
    def test_upshift_on_violation(self):
        c = HysteresisController(SLO(max_err=0.1), cooldown=0)
        assert c.observe(1, err=0.5, err_down=0.5) == +1

    def test_dead_band_holds(self):
        c = HysteresisController(SLO(max_err=0.1, down_factor=0.25), cooldown=0)
        # err below SLO but the would-be one-down error is inside the band
        assert c.observe(1, err=0.01, err_down=0.05) == 0

    def test_downshift_only_when_down_is_safe(self):
        c = HysteresisController(SLO(max_err=0.1, down_factor=0.25), cooldown=0)
        assert c.observe(1, err=0.001, err_down=0.01) == -1

    def test_cooldown_blocks_consecutive_shifts(self):
        c = HysteresisController(SLO(max_err=0.1), cooldown=2)
        assert c.observe(1, err=0.5) == +1
        assert c.observe(2, err=0.5) == 0  # cooling down
        assert c.observe(3, err=0.5) == 0
        assert c.observe(4, err=0.5) == +1

    def test_latency_pressure_relaxes_down_threshold(self):
        slo = SLO(max_err=0.1, target_ms=10.0, down_factor=0.25)
        c = HysteresisController(slo, cooldown=0)
        # err_down in the dead band: held without latency pressure...
        assert c.observe(1, err=0.05, err_down=0.05, step_ms=5.0) == 0
        # ...but shifted down when the step overshoots the latency target
        assert c.observe(2, err=0.05, err_down=0.05, step_ms=50.0) == -1
        # and never past the accuracy SLO itself
        assert c.observe(3, err=0.2, err_down=0.2, step_ms=50.0) == +1

    def test_clamped_table_suppresses_decision(self):
        c = HysteresisController(SLO(max_err=0.1), cooldown=0)
        assert c.observe(1, err=0.5, can_up=False) == 0
        assert c.observe(2, err=0.001, err_down=0.001, can_down=False) == 0

    def test_slo_validation(self):
        with pytest.raises(ValueError):
            SLO(max_err=0.0)
        with pytest.raises(ValueError):
            SLO(max_err=0.1, down_factor=1.5)


class TestProbes:
    def test_logit_residual_masks_inactive(self):
        ref = jnp.ones((2, 4))
        lo = ref.at[0, 0].add(100.0)
        active = jnp.asarray([False, True])
        assert float(logit_residual(lo, ref, active)) == 0.0
        assert float(logit_residual(lo, ref)) > 0.0

    def test_sampled_matmul_residual_orders_modes(self, rng):
        x = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
        r8 = float(sampled_matmul_residual(x, w, Mode.M8))
        r16 = float(sampled_matmul_residual(x, w, Mode.M16))
        r24 = float(sampled_matmul_residual(x, w, Mode.M24))
        assert r8 > r16 > r24 == 0.0  # M24 has no mode above to shadow with

    def test_grad_drift_warmup_and_spike(self):
        p = GradDriftProbe(warmup=2)
        assert p.update(1.0) == 0.0
        assert p.update(1.0) == 0.0
        assert p.update(1.0) < 0.01
        assert p.update(10.0) > 1.0  # spike


class TestTrainSchedule:
    def test_clamped_floor_does_not_eat_cooldown(self):
        """Idle probes at the ladder floor must not register phantom down
        decisions — a drift spike arriving right after them has to up-shift
        immediately, not wait out a cooldown the clamp consumed."""
        table = ModeTable({"mlp_up": Mode.M8})
        sched = TrainPrecisionSchedule(
            table, SLO(max_err=0.5),
            controller=HysteresisController(SLO(max_err=0.5), cooldown=2),
            probe=GradDriftProbe(warmup=1),
        )
        for step in range(1, 6):
            assert sched.observe(step, {"grad_norm": 2.0}) == 0
        assert sched.observe(6, {"grad_norm": 50.0}) == +1
        assert table.modes()["mlp_up"] == Mode.M16

    def test_relaxes_down_then_recovers_up(self):
        table = ModeTable({"mlp_up": Mode.M24, "logits": Mode.M24})
        sched = TrainPrecisionSchedule(
            table, SLO(max_err=0.5),
            controller=HysteresisController(SLO(max_err=0.5), cooldown=0),
            probe=GradDriftProbe(warmup=1),
        )
        for step in range(1, 5):
            sched.observe(step, {"grad_norm": 2.0})
        assert table.modes()["mlp_up"] == Mode.M8  # stable -> relaxed down
        sched.observe(5, {"grad_norm": 40.0})  # drift spike
        assert table.modes()["mlp_up"] == Mode.M16
        assert table.switches >= 3


def _submit(eng, reqs, base):
    for r in reqs:
        eng.submit(dataclasses.replace(r, rid=r.rid + base))


@pytest.mark.slow
class TestClosedLoop:
    """ISSUE 4 acceptance: the conditioned workload drives the full loop."""

    def test_hot_batch_shifts_up_then_back_down(self):
        wl = conditioned_model()
        rng = np.random.default_rng(0)
        eng = ServeEngine(wl.model, wl.params, batch_slots=4, max_len=48,
                          slo=SLO(max_err=0.5), adapt_every=1)
        assert eng.mode_table.label() == "M8"  # policy pick = initial condition

        # phase 1: tame traffic holds the cheap mode
        _submit(eng, wl.requests(4, hot=set(), rng=rng, max_new=8), 0)
        eng.drain()
        assert eng.mode_table.label() == "M8"
        up_before = eng.controller.up_shifts

        # phase 2: ill-conditioned batch -> up within the cooldown window
        _submit(eng, wl.requests(4, hot={0, 1, 2}, rng=rng, max_new=12), 100)
        steps_at_join = eng.metrics.decode_steps
        while eng.scheduler.has_work():
            eng.step()
            if eng.controller.up_shifts > up_before:
                break
        window = eng.metrics.decode_steps - steps_at_join
        assert eng.controller.up_shifts == up_before + 1
        assert window <= eng.controller.cooldown + 2 * eng.adapt_every
        assert int(Mode[eng.mode_table.label()]) > int(Mode.M8)
        eng.drain()

        # phase 3: tame traffic again -> back down to the cheap mode
        _submit(eng, wl.requests(4, hot=set(), rng=rng, max_new=8), 200)
        eng.drain()
        assert eng.mode_table.label() == "M8"
        assert eng.controller.down_shifts >= 1
        assert eng.metrics.mode_switches >= 2

        # mode timeline recorded the excursion (M8 -> up -> ... -> M8)
        labels = [lab for _, lab in eng.metrics.mode_timeline]
        assert labels[0] == "M8" and labels[-1] == "M8" and len(labels) >= 3

        # zero recompiles: one compiled decode step across all mode values
        if eng.decode_compile_count is not None:
            assert eng.decode_compile_count == 1

    def test_monitor_mode_never_shifts(self):
        wl = conditioned_model()
        rng = np.random.default_rng(1)
        eng = ServeEngine(wl.model, wl.params, batch_slots=2, max_len=48,
                          slo=SLO(max_err=0.5), adapt_every=1, adapt=False)
        _submit(eng, wl.requests(2, hot={0, 1}, rng=rng, max_new=8), 0)
        eng.drain()
        assert eng.mode_table.label() == "M8"
        assert eng.metrics.mode_switches == 0
        # the probe still saw the violation the controller would act on
        assert max(e for _, e in eng.metrics.probe_errs) > 0.5

    def test_per_mode_occupancy_and_probe_stats_in_summary(self):
        wl = conditioned_model()
        rng = np.random.default_rng(2)
        eng = ServeEngine(wl.model, wl.params, batch_slots=2, max_len=48,
                          slo=SLO(max_err=0.5), adapt_every=2)
        _submit(eng, wl.requests(2, hot={0}, rng=rng, max_new=10), 0)
        eng.drain()
        s = eng.metrics.summary()
        assert abs(sum(s["mode_occupancy"].values()) - 1.0) < 1e-6
        assert s["probe_err_max"] >= s["probe_err_mean"] > 0.0
        assert "modes" in eng.metrics.format_summary()

    def test_static_engine_reports_static_mode_occupancy(self):
        """Satellite: non-adaptive engines surface their (single) decode
        mode in the per-mode occupancy, so serve_sweep rows always carry
        the column."""
        wl = conditioned_model()
        rng = np.random.default_rng(3)
        eng = ServeEngine(wl.model, wl.params, batch_slots=2, max_len=48)
        _submit(eng, wl.requests(2, hot=set(), rng=rng, max_new=6), 0)
        eng.drain()
        s = eng.metrics.summary()
        assert s["mode_occupancy"] == {"M8": 1.0}
        assert s["mode_switches"] == 0
