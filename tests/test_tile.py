"""Partitioned-SIMD tile kernel: exactness, maps, dispatch, blocking.

Pins the three contracts the tile path is built on (DESIGN.md section
Partitioned tile kernels):

  * uniform maps are BIT-identical to ``mp_matmul(impl='pallas')`` at the
    same blocks, for every f32-ladder mode, every rounding, and degenerate
    shapes on every axis;
  * mixed maps match an independent per-tile oracle bitwise, and
    magnitude-statistics maps stay inside their error budget while using
    cheaper modes for small-magnitude tiles;
  * runtime-bound call sites run ONE fused dispatch (no ``lax.switch``) and
    never retrace across mode changes.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.precision import F32_MODES, MODE_LIMBS, Mode
from repro.core.rmpm import mp_matmul, mp_matmul_runtime, mp_einsum_runtime
from repro.kernels.blocking import ceil_to, clamp_block, pad_to_block
from repro.kernels.tile_matmul.ops import (
    tile_grid,
    tile_matmul,
    tile_matmul_auto,
    tile_matmul_mode,
    tile_matmul_runtime,
)
from repro.kernels.tile_matmul.ref import tile_matmul_ref
from repro.kernels.tile_matmul.tile_policy import (
    dispatch_stats,
    magnitude_map,
    table_map,
    uniform_map,
)

BLK = dict(bm=32, bn=32, bk=64)
BLOCK = (32, 32, 64)


def _ab(rng, m, kd, n):
    a = jnp.asarray(rng.standard_normal((m, kd)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((kd, n)).astype(np.float32))
    return a, b


class TestUniformExactness:
    @pytest.mark.parametrize("mode", F32_MODES)
    @pytest.mark.parametrize(
        "m,kd,n",
        [
            (64, 128, 64),  # block multiples
            (100, 300, 70),  # non-multiple on every axis
            (1, 96, 48),  # M=1 decode row
            (48, 96, 1),  # N=1 vector
            (16, 24, 16),  # K smaller than bk
        ],
    )
    def test_bitwise_vs_pallas(self, rng, mode, m, kd, n):
        a, b = _ab(rng, m, kd, n)
        t = np.asarray(mp_matmul(a, b, mode, impl="tile", block=BLOCK))
        p = np.asarray(mp_matmul(a, b, mode, impl="pallas", block=BLOCK))
        assert (t == p).all()

    @pytest.mark.parametrize("mode", F32_MODES)
    def test_batched_lhs_bitwise(self, rng, mode):
        a = jnp.asarray(rng.standard_normal((2, 3, 48)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((48, 40)).astype(np.float32))
        t = np.asarray(mp_matmul(a, b, mode, impl="tile", block=BLOCK))
        assert t.shape == (2, 3, 40)
        p = np.asarray(
            mp_matmul(a.reshape(6, 48), b, mode, impl="pallas", block=BLOCK)
        )
        assert (t.reshape(6, 40) == p).all()

    @pytest.mark.parametrize("rounding", ["grte", "trunc"])
    def test_grte_prepass_composition(self, rng, rounding):
        # kmax=2 (M16): the pre-pass quantizes to 15 mantissa bits — a real
        # transformation (kmax=3 keeps 23 bits, the f32 identity), so this
        # pins that the tile path composes the rounding pre-pass exactly as
        # the uniform kernel does.
        a, b = _ab(rng, 40, 80, 56)
        t = np.asarray(
            tile_matmul_mode(a, b, Mode.M16, rounding=rounding, **BLK)
        )
        p = np.asarray(
            mp_matmul(a, b, Mode.M16, rounding=rounding, impl="pallas", block=BLOCK)
        )
        assert (t == p).all()

    def test_uniform_map_constructor_matches_mode_path(self, rng):
        a, b = _ab(rng, 64, 128, 64)
        mm = uniform_map(a.shape, b.shape, Mode.M16, **BLK)
        t = np.asarray(tile_matmul(a, b, mm, kmax=2, **BLK))
        p = np.asarray(tile_matmul_mode(a, b, Mode.M16, **BLK))
        assert (t == p).all()


class TestMixedMaps:
    @pytest.mark.parametrize("per_k", [False, True])
    def test_mixed_vs_independent_oracle(self, rng, per_k):
        m, kd, n = 96, 192, 64
        a, b = _ab(rng, m, kd, n)
        grid, (bm, bn, bk) = tile_grid(m, n, kd, **BLK)
        shape = grid if per_k else grid[:2]
        mm = jnp.asarray(rng.integers(1, 4, size=shape), jnp.int32)
        out = np.asarray(tile_matmul(a, b, mm, **BLK))
        ref = np.asarray(
            tile_matmul_ref(
                pad_to_block(a, bm, bk), pad_to_block(b, bk, bn),
                np.asarray(mm), bm=bm, bn=bn, bk=bk,
            )
        )[:m, :n]
        assert (out == ref).all()

    def test_map_shape_validated(self, rng):
        a, b = _ab(rng, 64, 128, 64)
        bad = jnp.ones((5, 5), jnp.int32)
        with pytest.raises(ValueError, match="mode_map shape"):
            tile_matmul(a, b, bad, **BLK)

    def test_magnitude_map_isolates_outlier_tile(self, rng):
        # background ~1e-3, one hot row-tile ~1: only tiles fed by the hot
        # rows need the expensive mode; the budget still holds globally.
        m, kd, n = 96, 128, 64
        a = jnp.asarray(rng.standard_normal((m, kd)).astype(np.float32)) * 1e-3
        a = a.at[:32].set(a[:32] * 1e3)
        b = jnp.asarray(rng.standard_normal((kd, n)).astype(np.float32))
        budget = 2.0**-12
        mm = np.asarray(magnitude_map(a, b, budget, **BLK))
        assert mm.shape == tile_grid(m, n, kd, **BLK)[0][:2]
        assert len(np.unique(mm)) >= 2, "mixed-precision map expected"
        assert mm[0].max() > mm[1:].max(), "hot tiles must get more limbs"
        out = np.asarray(tile_matmul_auto(a, b, budget, **BLK), np.float64)
        ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        # budget is relative to the magnitude envelope S = amax*bmax*K,
        # which upper-bounds max|ref|; measured error must sit inside it
        scale = float(np.abs(a).max()) * float(np.abs(b).max()) * kd
        assert np.abs(out - ref).max() <= budget * scale

    def test_magnitude_map_uniform_data_meets_budget(self, rng):
        a, b = _ab(rng, 128, 128, 128)
        for budget in (2.0**-6, 2.0**-12, 2.0**-20):
            out = np.asarray(tile_matmul_auto(a, b, budget, **BLK), np.float64)
            ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
            scale = float(np.abs(a).max()) * float(np.abs(b).max()) * 128
            assert np.abs(out - ref).max() <= budget * scale

    def test_table_map_broadcasts_scalar(self):
        mm = table_map((64, 128), (128, 64), jnp.int32(2), **BLK)
        assert mm.shape == (2, 2)
        assert (np.asarray(mm) == 2).all()


class TestRuntimeDispatch:
    def test_runtime_tile_matches_switch_all_modes(self, rng):
        a, b = _ab(rng, 64, 128, 64)
        for mv in (1, 2, 3):
            t = np.asarray(
                mp_matmul_runtime(a, b, jnp.int32(mv), impl="tile",
                                  block=BLOCK, allow_auto=False)
            )
            p = np.asarray(
                mp_matmul_runtime(a, b, jnp.int32(mv), impl="pallas",
                                  block=BLOCK, allow_auto=False)
            )
            assert (t == p).all()

    def test_single_dispatch_no_switch(self, rng):
        a, b = _ab(rng, 64, 128, 64)

        def tile_fn(a_, b_, s):
            return mp_matmul_runtime(a_, b_, s, impl="tile", block=BLOCK,
                                     allow_auto=False)

        def switch_fn(a_, b_, s):
            return mp_matmul_runtime(a_, b_, s, impl="pallas", block=BLOCK,
                                     allow_auto=False)

        t_stats = dispatch_stats(tile_fn, a, b, jnp.int32(2))
        s_stats = dispatch_stats(switch_fn, a, b, jnp.int32(2))
        assert t_stats == {"switches": 0, "pallas_calls": 1}
        assert s_stats["switches"] == 1

    def test_zero_recompile_across_modes(self, rng):
        a, b = _ab(rng, 64, 128, 64)
        calls = jax.jit(
            lambda a_, b_, s: mp_matmul_runtime(
                a_, b_, s, impl="tile", block=BLOCK, allow_auto=False
            )
        )
        outs = [calls(a, b, jnp.int32(mv)) for mv in (1, 2, 3, 2, 1)]
        jax.block_until_ready(outs)
        assert calls._cache_size() == 1

    def test_runtime_map_changes_zero_recompile(self, rng):
        a, b = _ab(rng, 64, 128, 64)
        grid, _ = tile_grid(64, 64, 128, **BLK)
        f = jax.jit(lambda a_, b_, mm: tile_matmul(a_, b_, mm, **BLK))
        for seed in range(3):
            mm = jnp.asarray(
                np.random.default_rng(seed).integers(1, 4, size=grid[:2]),
                jnp.int32,
            )
            jax.block_until_ready(f(a, b, mm))
        assert f._cache_size() == 1

    def test_einsum_runtime_tile_2d_and_fallback(self, rng):
        a, b = _ab(rng, 64, 128, 64)
        t = np.asarray(
            mp_einsum_runtime("mk,kn->mn", a, b, jnp.int32(2), impl="tile",
                              block=BLOCK)
        )
        p = np.asarray(
            mp_matmul_runtime(a, b, jnp.int32(2), impl="pallas", block=BLOCK,
                              allow_auto=False)
        )
        assert (t == p).all()
        # non-2D contraction: tile falls back to the xla switch, same result
        a3 = jnp.asarray(rng.standard_normal((2, 8, 16)).astype(np.float32))
        b3 = jnp.asarray(rng.standard_normal((2, 16, 8)).astype(np.float32))
        t3 = np.asarray(
            mp_einsum_runtime("bmk,bkn->bmn", a3, b3, jnp.int32(2), impl="tile")
        )
        x3 = np.asarray(
            mp_einsum_runtime("bmk,bkn->bmn", a3, b3, jnp.int32(2), impl="xla")
        )
        assert (t3 == x3).all()

    def test_bound_pmm_sites_fuse_dispatch(self, rng):
        # >= 2 lax.switch call sites replaced by single fused dispatches:
        # two runtime-bound pmm sites -> 0 switches, 2 pallas calls, one
        # compiled executable across all mode pairs, bit-identical to the
        # static pallas execution the switch would have selected.
        from repro.adapt.runtime_policy import bind_modes
        from repro.core.policy import PrecisionPolicy
        from repro.models.layers import pmm
        from repro.plan import clear_plan_cache

        clear_plan_cache()
        pol = PrecisionPolicy(default=Mode.M16, impl="pallas")
        x = jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32))
        w1 = jnp.asarray(rng.standard_normal((128, 96)).astype(np.float32))
        w2 = jnp.asarray(rng.standard_normal((96, 64)).astype(np.float32))

        def step(x_, w1_, w2_, s1, s2):
            with bind_modes({"mlp_up": s1, "mlp_down": s2}):
                h = pmm(x_, w1_, "mlp_up", pol)
                return pmm(h, w2_, "mlp_down", pol)

        stats = dispatch_stats(step, x, w1, w2, jnp.int32(2), jnp.int32(1))
        assert stats == {"switches": 0, "pallas_calls": 2}

        f = jax.jit(step)
        for m1 in (1, 2, 3):
            for m2 in (1, 2, 3):
                out = f(x, w1, w2, jnp.int32(m1), jnp.int32(m2))
                h = mp_matmul(x, w1, Mode(m1), impl="pallas")
                ref = mp_matmul(h, w2, Mode(m2), impl="pallas")
                assert (np.asarray(out) == np.asarray(ref)).all(), (m1, m2)
        assert f._cache_size() == 1

    def test_xla_plans_keep_switch(self, rng):
        from repro.adapt.runtime_policy import bind_modes
        from repro.core.policy import PrecisionPolicy
        from repro.models.layers import pmm
        from repro.plan import clear_plan_cache

        clear_plan_cache()
        pol = PrecisionPolicy(default=Mode.M16, impl="xla")
        x = jnp.asarray(rng.standard_normal((32, 64)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))

        def step(x_, w_, s):
            with bind_modes({"mlp_up": s}):
                return pmm(x_, w_, "mlp_up", pol)

        stats = dispatch_stats(step, x, w, jnp.int32(2))
        assert stats["switches"] == 1 and stats["pallas_calls"] == 0


class TestBlocking:
    def test_clamp_block_pins(self):
        assert clamp_block(128, 1) == 8  # M=1 decode row -> quantum block
        assert clamp_block(128, 100) == 104  # next multiple of 8, not 100
        assert clamp_block(128, 128) == 128
        assert clamp_block(128, 256) == 128  # dim fills the block: keep it
        assert clamp_block(512, 300) == 304
        assert ceil_to(1, 8) == 8 and ceil_to(16, 8) == 16

    def test_tile_grid_degenerate_shapes(self):
        grid, blocks = tile_grid(1, 64, 128, bm=128, bn=128, bk=512)
        assert blocks == (8, 64, 128)
        assert grid == (1, 1, 1)

    def test_pad_to_block_zero_exact(self, rng):
        x = jnp.asarray(rng.standard_normal((10, 20)).astype(np.float32))
        p = pad_to_block(x, 8, 16)
        assert p.shape == (16, 32)
        assert (np.asarray(p[:10, :20]) == np.asarray(x)).all()
        assert float(np.abs(np.asarray(p[10:])).max()) == 0.0


class TestInterpretDefault:
    """Backend-aware interpret default, verified with a spy on the kernel."""

    def _spy(self, monkeypatch, module, name):
        seen = {}
        import importlib

        mod = importlib.import_module(module)
        orig = getattr(mod, name)

        def wrapper(*args, **kwargs):
            seen["interpret"] = kwargs.get("interpret")
            return orig(*args, **kwargs)

        monkeypatch.setattr(mod, name, wrapper)
        return seen

    def test_limb_matmul_interprets_on_cpu(self, rng, monkeypatch):
        from repro.kernels.limb_matmul import ops as limb_ops

        seen = self._spy(monkeypatch, "repro.kernels.limb_matmul.ops",
                         "limb_matmul_pallas")
        # unique shape so the jitted inner body re-traces and the spy fires
        a, b = _ab(rng, 24, 40, 24)
        monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
        limb_ops.limb_matmul(a, b, 2, bm=8, bn=8, bk=8)
        assert seen["interpret"] is True

    def test_limb_matmul_compiles_off_cpu(self, rng, monkeypatch):
        from repro.kernels.limb_matmul import ops as limb_ops

        seen = self._spy(monkeypatch, "repro.kernels.limb_matmul.ops",
                         "limb_matmul_pallas")
        a, b = _ab(rng, 24, 40, 24)
        monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
        # trace only: Mosaic lowering cannot run on this host, but the
        # interpret flag is resolved OUTSIDE jit, at trace time
        jaxpr = jax.make_jaxpr(
            lambda a_, b_: limb_ops.limb_matmul(a_, b_, 2, bm=8, bn=8, bk=8)
        )(a, b)
        assert seen["interpret"] is False
        assert "pallas_call" in str(jaxpr)

    def test_explicit_override_wins(self, rng, monkeypatch):
        from repro.kernels.limb_matmul import ops as limb_ops

        seen = self._spy(monkeypatch, "repro.kernels.limb_matmul.ops",
                         "limb_matmul_pallas")
        a, b = _ab(rng, 16, 40, 24)
        monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
        limb_ops.limb_matmul(a, b, 2, bm=8, bn=8, bk=8, interpret=True)
        assert seen["interpret"] is True

    def test_quantize_interprets_on_cpu(self, rng, monkeypatch):
        from repro.kernels.quantize_mantissa import ops as q_ops

        seen = self._spy(monkeypatch, "repro.kernels.quantize_mantissa.ops",
                         "quantize_mantissa_pallas")
        x = jnp.asarray(rng.standard_normal((9, 11)).astype(np.float32))
        monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
        q_ops.quantize_mantissa_op(x, 7)
        assert seen["interpret"] is True

    def test_tile_matmul_interprets_on_cpu(self, rng, monkeypatch):
        from repro.kernels.tile_matmul import ops as tile_ops

        seen = self._spy(monkeypatch, "repro.kernels.tile_matmul.ops",
                         "tile_matmul_pallas")
        a, b = _ab(rng, 24, 48, 24)
        monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
        tile_ops.tile_matmul_mode(a, b, Mode.M16, bm=8, bn=8, bk=16)
        assert seen["interpret"] is True


class TestPlannerTile:
    def test_impl_validation_admits_tile(self):
        from repro.plan import plan_matmul

        p = plan_matmul((64, 64), (64, 64), impl="tile", mode=Mode.M16)
        assert p.impl == "tile"
        with pytest.raises(ValueError, match="unknown impl"):
            plan_matmul((64, 64), (64, 64), impl="mosaic")

    def test_tile_in_tpu_candidates_but_ties_keep_pallas(self):
        from repro.plan import plan_matmul
        from repro.plan.planner import _impl_candidates

        cands = _impl_candidates(Mode.M16, None, "tpu", 2**-12, False, "rne")
        assert "tile" in cands and cands.index("pallas") < cands.index("tile")
        p = plan_matmul((4096, 4096), (4096, 4096), accuracy=2**-12,
                        backend="tpu")
        assert p.impl == "pallas"  # committed baselines stay stable on ties

    def test_map_source_validation(self):
        from repro.plan import plan_matmul

        with pytest.raises(ValueError, match="map_source"):
            plan_matmul((64, 64), (64, 64), map_source="entropy")
        with pytest.raises(ValueError, match="accuracy"):
            plan_matmul((64, 64), (64, 64), map_source="magnitude")
        with pytest.raises(ValueError, match="impl='tile'"):
            plan_matmul((64, 64), (64, 64), accuracy=2**-12,
                        map_source="magnitude", impl="xla")

    def test_magnitude_plan_cache_key_and_execution(self, rng):
        from repro.plan import clear_plan_cache, execute, plan_matmul

        clear_plan_cache()
        uni = plan_matmul((128, 128), (128, 128), accuracy=2**-12)
        mag = plan_matmul((128, 128), (128, 128), accuracy=2**-12,
                          map_source="magnitude")
        assert uni is not mag  # map_source is part of the plan-cache key
        assert mag.impl == "tile" and mag.map_source == "magnitude"
        assert mag.strassen_depth == 0
        a, b = _ab(rng, 128, 128, 128)
        out = np.asarray(execute(mag, a, b), np.float64)
        ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        scale = float(np.abs(a).max()) * float(np.abs(b).max()) * 128
        assert np.abs(out - ref).max() <= 2**-12 * scale

    def test_tile_plan_executes_bitwise_vs_pallas(self, rng):
        from repro.plan import execute, plan_matmul

        a, b = _ab(rng, 96, 128, 64)
        pt = plan_matmul((96, 128), (128, 64), mode=Mode.M24, impl="tile")
        pp = plan_matmul((96, 128), (128, 64), mode=Mode.M24, impl="pallas")
        assert (np.asarray(execute(pt, a, b)) == np.asarray(execute(pp, a, b))).all()

    def test_tune_candidates_include_tile(self):
        from repro.tune.runner import candidates

        cands = candidates(512, 512, 512, "tpu")
        tile = [c for c in cands if c.impl == "tile"]
        assert tile and all(c.block is not None for c in tile)
        assert {int(c.mode) for c in tile} == {int(m) for m in F32_MODES}

    def test_tune_measure_tile(self):
        from repro.tune.runner import Candidate, measure

        rec = measure(64, 64, 64, Candidate(Mode.M16, "tile", 0, (32, 32, 32)),
                      iters=1)
        assert rec.impl == "tile" and rec.rel_err < 2.0**-12
