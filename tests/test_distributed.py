"""Distributed tests on 8 virtual devices (subprocess isolates XLA_FLAGS —
the main test process must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# These cells drive the explicit-mesh API; on older jax they cannot even
# construct the mesh.  CI installs current jax[cpu] and runs them for real.
requires_set_mesh = pytest.mark.skipif(
    not (hasattr(jax, "set_mesh") and hasattr(jax.sharding, "AxisType")),
    reason="needs jax.set_mesh / jax.sharding.AxisType (jax >= 0.5)",
)


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=420,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
@requires_set_mesh
class TestSharded:
    def test_sharded_train_step_matches_single_device(self):
        run_with_devices("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.configs import get_smoke_config
            from repro.core.policy import NATIVE_F32
            from repro.models import build_model
            from repro.optim import adamw
            from repro.train.step import TrainConfig, init_train_state, make_train_step
            from repro.distributed.sharding import param_shardings, input_shardings, replicated

            cfg = get_smoke_config("qwen1.5-0.5b").with_policy(NATIVE_F32)
            model = build_model(cfg)
            tcfg = TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-3, warmup_steps=0))
            step = make_train_step(model, tcfg)
            state = init_train_state(model, jax.random.key(0), tcfg)
            rng = np.random.default_rng(0)
            batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
                     "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)}
            # single device reference
            _, m_ref = jax.jit(step)(state, batch)
            # sharded over (data=4, model=2)
            mesh = jax.make_mesh((4, 2), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            ps = param_shardings(jax.eval_shape(lambda: state["params"]), cfg, mesh)
            ss = {"params": ps, "opt": {"step": replicated(mesh), "m": ps, "v": ps}}
            bs = input_shardings(jax.eval_shape(lambda: batch), mesh)
            with jax.set_mesh(mesh):
                state_s = jax.device_put(state, ss)
                batch_s = jax.device_put(batch, bs)
                _, m_sh = jax.jit(step, in_shardings=(ss, bs))(state_s, batch_s)
            d = abs(float(m_ref["loss"]) - float(m_sh["loss"]))
            print("loss delta:", d)
            assert d < 5e-4, d
        """)

    def test_compressed_psum_pod_numerics(self):
        run_with_devices("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.distributed.compress import compressed_psum_pod
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*3)
            rng = np.random.default_rng(0)
            g = {"w": jnp.asarray(rng.standard_normal(1024).astype(np.float32))}
            r = {"w": jnp.zeros(1024, jnp.float32)}
            with jax.set_mesh(mesh):
                red, new_r = jax.jit(lambda a, b: compressed_psum_pod(a, b, mesh))(g, r)
            # replicated inputs -> mean == value, within int8 quantization error
            err = float(jnp.abs(red["w"] - g["w"]).max())
            bound = float(jnp.abs(g["w"]).max()) / 127.0
            print("err", err, "bound", bound)
            assert err <= bound * 1.01
            # residual == quantization error (error feedback)
            np.testing.assert_allclose(np.asarray(new_r["w"]),
                                       np.asarray(g["w"] - red["w"]), atol=1e-6)
        """)

    def test_compressed_collective_is_int8_in_hlo(self):
        run_with_devices("""
            import jax, jax.numpy as jnp
            from repro.distributed.compress import compressed_psum_pod
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*3)
            g = {"w": jnp.zeros(4096, jnp.float32)}
            r = {"w": jnp.zeros(4096, jnp.float32)}
            with jax.set_mesh(mesh):
                txt = jax.jit(lambda a, b: compressed_psum_pod(a, b, mesh)).lower(g, r).compile().as_text()
            assert "s8[" in txt and "all-gather" in txt, "int8 all-gather missing"
            print("ok")
        """)

    def test_dryrun_cell_on_8_devices(self):
        # the full dry-run machinery on a small mesh: proves the machinery
        # is device-count independent
        run_with_devices("""
            import jax
            from repro.configs import get_smoke_config
            from repro.launch.shapes import build_cell, ShapeSpec
            from repro.launch import hlo_cost
            cfg = get_smoke_config("qwen1.5-0.5b")
            mesh = jax.make_mesh((4, 2), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            cell = build_cell(cfg, ShapeSpec("t", "train", 64, 8), mesh)
            with jax.set_mesh(mesh):
                c = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                            out_shardings=cell.get("out_shardings"),
                            donate_argnums=cell["donate"]).lower(*cell["args"]).compile()
            cost = hlo_cost.parse_hlo_cost(c.as_text())
            assert cost.flops > 0
            print("flops/dev:", cost.flops)
        """)

    def test_pipeline_parallel_matches_sequential(self):
        run_with_devices("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.distributed.pipeline import pipeline_apply, bubble_fraction
            S, M, B, D = 4, 6, 2, 16
            mesh = jax.make_mesh((S,), ("pod",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
            rng = np.random.default_rng(0)
            ws = jnp.asarray(rng.standard_normal((S, D, D)).astype(np.float32) * 0.3)
            xs = jnp.asarray(rng.standard_normal((M, B, D)).astype(np.float32))
            stage_fn = lambda w, x: jnp.tanh(x @ w)
            with jax.set_mesh(mesh):
                out = pipeline_apply(stage_fn, ws, xs, mesh, axis="pod")
            # sequential reference: all stages applied in order
            ref = xs
            for i in range(S):
                ref = jnp.tanh(ref @ ws[i])
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-6)
            assert abs(bubble_fraction(S, M) - 3/9) < 1e-9
            # AD flows through the pipeline (training-capable)
            with jax.set_mesh(mesh):
                g = jax.grad(lambda w: pipeline_apply(stage_fn, w, xs, mesh, axis="pod").sum())(ws)
            gref = jax.grad(lambda w: _seq(w, xs).sum())(ws)
            print("pipeline fwd+bwd ok")
            np.testing.assert_allclose(np.asarray(g), np.asarray(gref), rtol=2e-4, atol=2e-5)
        """.replace("_seq(w, xs)", "jnp.tanh(jnp.tanh(jnp.tanh(jnp.tanh(xs @ w[0]) @ w[1]) @ w[2]) @ w[3])"))

    def test_elastic_restore_different_mesh(self):
        run_with_devices("""
            import tempfile, numpy as np, jax, jax.numpy as jnp
            from repro.checkpoint.manager import CheckpointManager
            from jax.sharding import PartitionSpec as P
            d = tempfile.mkdtemp()
            mgr = CheckpointManager(d, async_save=False)
            mesh1 = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
            x = jax.device_put(jnp.arange(64.0), jax.NamedSharding(mesh1, P("data")))
            mgr.save(1, {"x": x})
            # restore onto a DIFFERENT mesh shape (elastic restart)
            mesh2 = jax.make_mesh((2, 4), ("data", "model"),
                                  axis_types=(jax.sharding.AxisType.Auto,)*2)
            sh = {"x": jax.NamedSharding(mesh2, P("model"))}
            step, st = mgr.restore(shardings=sh)
            np.testing.assert_array_equal(np.asarray(st["x"]), np.arange(64.0))
            assert st["x"].sharding.spec == P("model")
            print("elastic ok")
        """)
