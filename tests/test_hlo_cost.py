"""Unit tests for the scan-correct HLO cost parser — the roofline's foundation."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import Cost, parse_hlo_cost, roofline_terms


def _flops(fn, *shapes):
    return parse_hlo_cost(jax.jit(fn).lower(*shapes).compile().as_text()).flops


class TestParser:
    def test_single_dot_exact(self):
        a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
        assert _flops(lambda x, y: x @ y, a, b) == 2 * 128 * 256 * 64

    def test_scan_trip_count_multiplies(self):
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

        def scan5(x):
            def body(c, _):
                return c @ c, None

            out, _ = jax.lax.scan(body, x, None, length=5)
            return out

        assert _flops(scan5, a) == 5 * 2 * 64**3

    def test_nested_scans_multiply(self):
        a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

        def nested(x):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ ci, None

                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, None
            out, _ = jax.lax.scan(outer, x, None, length=4)
            return out

        assert _flops(nested, a) == 12 * 2 * 32**3

    def test_remat_grad_counts_recompute(self):
        a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

        def loss(x):
            body = jax.checkpoint(lambda c, _: (jnp.tanh(c @ c), None))
            out, _ = jax.lax.scan(body, x, None, length=4)
            return out.sum()

        fl = _flops(jax.grad(loss), a)
        # fwd + recompute + 2 bwd matmuls per layer = ~4 units (allow fusion slack)
        assert fl >= 4 * 3 * 2 * 32**3

    @pytest.mark.skipif(
        not (hasattr(jax, "set_mesh") and hasattr(jax.sharding, "AxisType")),
        reason="needs jax.set_mesh / jax.sharding.AxisType (jax >= 0.5)",
    )
    def test_collective_bytes_multi_device(self):
        import os
        import subprocess
        import sys
        import textwrap
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.launch.hlo_cost import parse_hlo_cost
            mesh = jax.make_mesh((8,), ("model",), axis_types=(jax.sharding.AxisType.Auto,))
            a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
            w = jax.ShapeDtypeStruct((256, 64), jnp.float32)
            sa = jax.NamedSharding(mesh, P(None, "model"))
            sw = jax.NamedSharding(mesh, P("model", None))
            with jax.set_mesh(mesh):
                c = jax.jit(lambda x, y: x @ y, in_shardings=(sa, sw)).lower(a, w).compile()
            cost = parse_hlo_cost(c.as_text())
            assert cost.collective_bytes > 0, "contraction over sharded dim must psum"
            assert "all-reduce" in cost.by_collective
            print("OK", cost.collective_bytes)
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
        out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout

    def test_roofline_terms_dominance(self):
        c = Cost(flops=197e12, hbm_bytes=1.0, collective_bytes=1.0)
        t = roofline_terms(c)
        assert t["dominant"] == "compute" and t["t_compute_s"] == pytest.approx(1.0)
        c = Cost(flops=1.0, hbm_bytes=819e9 * 2, collective_bytes=1.0)
        assert roofline_terms(c)["dominant"] == "memory"
        c = Cost(flops=1.0, hbm_bytes=1.0, collective_bytes=50e9 * 3)
        t = roofline_terms(c)
        assert t["dominant"] == "collective" and t["t_collective_s"] == pytest.approx(3.0)
