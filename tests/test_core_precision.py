"""Core precision engine: mode ladder, limb algebra, auto-mode, rounding.

Validates the paper's central claims at the numeric level:
  * error decreases monotonically with precision mode (Table 9 / Fig 17)
  * k-limb mode error ~ 2^-8k on well-conditioned inputs
  * auto-mode picks cheap modes for integer-valued data (Fig 7)
  * GRTE rounding (Eq. 10) behaves between truncation and RNE
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from hypothesis_compat import given, settings, st  # property tests skip w/o hypothesis

from repro.core import (
    DoubleF32,
    Mode,
    auto_mode,
    classify,
    df32_from_f32,
    mode_mismatch_error,
    mp_einsum,
    mp_matmul,
    mp_matmul_runtime,
    quantize_mantissa,
)
from repro.core import limb as limb_lib

F32_LADDER = (Mode.M8, Mode.M16, Mode.M24)


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


class TestLimbSplit:
    def test_three_limbs_reconstruct_f32_exactly(self, rng):
        x = _rand(rng, 128, 64)
        rec = limb_lib.reconstruct(limb_lib.split_limbs(x, 3))
        assert np.array_equal(np.asarray(rec), np.asarray(x))

    def test_limb_residual_shrinks_geometrically(self, rng):
        x = _rand(rng, 256)
        errs = []
        for k in (1, 2, 3):
            rec = limb_lib.reconstruct(limb_lib.split_limbs(x, k))
            errs.append(float(jnp.max(jnp.abs(rec - x)) / jnp.max(jnp.abs(x))))
        assert errs[0] < 2**-7
        assert errs[1] < 2**-15
        assert errs[2] == 0.0

    def test_product_terms_karatsuba_truncation(self):
        # |{(i,j): i+j<k}| = k(k+1)/2 — the retained Karatsuba economy.
        for k in (1, 2, 3, 4, 6):
            terms = limb_lib.limb_product_terms(k)
            assert len(terms) == k * (k + 1) // 2
            assert all(i + j < k for i, j in terms)
            # ordered high-order (small magnitude) first
            orders = [i + j for i, j in terms]
            assert orders == sorted(orders, reverse=True)

    def test_df32_limbs_extend_past_f32(self, rng):
        hi = _rand(rng, 64)
        lo = hi * np.float32(2**-26) * _rand(rng, 64)
        x = DoubleF32(hi, lo)
        limbs = limb_lib.split_limbs(x, 6)
        assert limbs.shape == (6, 64)
        # 6 limbs must reconstruct hi+lo past f32 fidelity (sum in f64 —
        # reconstruct() itself returns f32 and would cap at 2^-24).
        rec6 = np.asarray(limbs.astype(jnp.float32), np.float64).sum(axis=0)
        err = np.abs(rec6 - (np.asarray(hi, np.float64) + np.asarray(lo, np.float64)))
        assert (err / np.abs(np.asarray(hi, np.float64))).max() < 2**-38


class TestModeLadder:
    def test_error_monotone_in_mode(self, rng):
        a, b = _rand(rng, 96, 128), _rand(rng, 128, 80)
        ref = np.asarray(jnp.dot(a, b)).astype(np.float64)
        scale = np.abs(ref).max()
        errs = {}
        for mode in F32_LADDER:
            out = np.asarray(mp_matmul(a, b, mode), np.float64)
            errs[mode] = np.abs(out - ref).max() / scale
        assert errs[Mode.M8] > errs[Mode.M16] > errs[Mode.M24]
        assert errs[Mode.M8] < 2**-7
        assert errs[Mode.M16] < 2**-15
        assert errs[Mode.M24] < 2**-21  # f32-accumulation limited

    def test_high_modes_beat_f32(self, rng):
        a, b = _rand(rng, 48, 256), _rand(rng, 256, 32)
        A, B = df32_from_f32(a), df32_from_f32(b)
        ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        scale = np.abs(ref).max()
        prev = np.abs(np.asarray(mp_matmul(a, b, Mode.M24), np.float64) - ref).max() / scale
        for mode, bound in ((Mode.M32, 2**-28), (Mode.M48, 2**-35)):
            out = mp_matmul(A, B, mode)
            assert isinstance(out, DoubleF32)
            o64 = np.asarray(out.hi, np.float64) + np.asarray(out.lo, np.float64)
            err = np.abs(o64 - ref).max() / scale
            assert err < bound
            assert err < prev
            prev = err

    def test_einsum_matches_matmul(self, rng):
        a, b = _rand(rng, 32, 64), _rand(rng, 64, 16)
        out_e = mp_einsum("mk,kn->mn", a, b, Mode.M16)
        out_m = mp_matmul(a, b, Mode.M16)
        np.testing.assert_array_equal(np.asarray(out_e), np.asarray(out_m))

    def test_batched_lhs(self, rng):
        a = _rand(rng, 4, 6, 32)
        b = _rand(rng, 32, 24)
        out = mp_matmul(a, b, Mode.M24)
        assert out.shape == (4, 6, 24)
        ref = np.asarray(jnp.einsum("bsk,kn->bsn", a, b))
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)


class TestRuntimeReconfiguration:
    def test_switch_equals_static(self, rng):
        a, b = _rand(rng, 32, 48), _rand(rng, 48, 16)
        for mode in F32_LADDER:
            rt = mp_matmul_runtime(a, b, jnp.int32(int(mode)))
            static = mp_matmul(a, b, mode)
            np.testing.assert_array_equal(np.asarray(rt), np.asarray(static))

    def test_one_executable_no_recompile(self, rng):
        # Mode is a traced scalar: one lowering serves every mode (the FPGA
        # paper's "no re-synthesis at run time").
        a, b = _rand(rng, 16, 32), _rand(rng, 32, 8)
        fn = jax.jit(mp_matmul_runtime)
        outs = [np.asarray(fn(a, b, jnp.int32(m))) for m in (1, 2, 3)]
        assert fn._cache_size() == 1
        ref = np.asarray(jnp.dot(a, b))
        errs = [np.abs(o - ref).max() for o in outs]
        assert errs[0] > errs[1] > errs[2]

    def test_auto_mode_integers_select_m8(self, rng):
        ai = jnp.asarray(rng.integers(0, 127, (32, 32)).astype(np.float32))
        bi = jnp.asarray(rng.integers(0, 127, (32, 32)).astype(np.float32))
        assert int(auto_mode(ai, bi)) == int(Mode.M8)
        # and the M8 product of small integers is EXACT (paper's
        # "integer-level precision" claim for low modes)
        out = mp_matmul_runtime(ai, bi, Mode.AUTO)
        ref = np.asarray(ai, np.float64) @ np.asarray(bi, np.float64)
        np.testing.assert_array_equal(np.asarray(out, np.float64), ref)

    def test_auto_mode_full_precision_floats(self, rng):
        a, b = _rand(rng, 32, 32), _rand(rng, 32, 32)
        assert int(auto_mode(a, b)) == int(Mode.M24)

    def test_auto_mode_with_tolerance_relaxes(self, rng):
        a, b = _rand(rng, 32, 32), _rand(rng, 32, 32)
        assert int(auto_mode(a, b, tol=2**-6)) < int(Mode.M24)


class TestRounding:
    @pytest.mark.parametrize("keep", [0, -1, -5])
    def test_nonpositive_keep_bits_rejected(self, keep):
        # satellite regression: the oracle clamped keep_bits from above
        # (min(keep_bits, 23)) but not from below — keep_bits <= 0 made
        # drop > 23 and the mask/carry corrupted exponent and sign
        x = jnp.asarray(np.float32([1.5, -2.25, 3.0]))
        with pytest.raises(ValueError, match="keep_bits must be >= 1"):
            quantize_mantissa(x, keep)

    def test_keep_one_bit_stays_a_float(self):
        # the smallest legal width must still return a sane coarse float
        # (sign and exponent untouched up to the documented rounding carry)
        x = jnp.asarray(np.float32([1.9, -1.9, 0.7]))
        q = np.asarray(quantize_mantissa(x, 1, "trunc"))
        assert np.all(np.sign(q) == np.sign(np.asarray(x)))
        assert np.all(np.abs(q) <= np.abs(np.asarray(x)))
        assert np.all(np.isfinite(q))

    @given(st.integers(1, 22), st.sampled_from(["trunc", "rne", "grte"]))
    @settings(max_examples=30, deadline=None)
    def test_error_bounded_by_kept_bits(self, keep, rounding):
        rng = np.random.default_rng(keep)
        x = jnp.asarray(rng.standard_normal(256).astype(np.float32))
        q = quantize_mantissa(x, keep, rounding)
        rel = np.abs(np.asarray(q) - np.asarray(x)) / np.abs(np.asarray(x))
        # trunc: < 2^-keep ; round-up/RNE: <= 2^-keep (worst case one ULP)
        assert rel.max() <= 2.0**-keep

    def test_grte_matches_paper_truth_table(self):
        # Eq. 10: rnd = G & (R | T | E).  Craft mantissa patterns directly.
        def f32_from_bits(mant23):
            return np.uint32((127 << 23) | mant23).view(np.float32)  # 1.mant

        keep = 7
        drop = 23 - keep
        cases = [
            # (dropped-field bits, expect round-up)
            (0b1000000000000000, True),   # G=1 R=0 E=0 T=0 -> G&(R|T|E)=0? No:
            (0b1100000000000000, True),   # G=1 R=1 -> up
            (0b1010000000000000, True),   # G=1 E=1 -> up
            (0b1000000000000001, True),   # G=1 T=1 -> up
            (0b0111111111111111, False),  # G=0 -> never up
            (0b0000000000000000, False),
        ]
        # correction: first case G=1, R=T=E=0 -> rnd = 0 (no round-up)
        cases[0] = (0b1000000000000000, False)
        for dropped, expect_up in cases:
            mant = (0b0101010 << drop) | dropped
            x = jnp.asarray([f32_from_bits(mant)])
            q = np.asarray(quantize_mantissa(x, keep, "grte")).view(np.uint32)[0]
            kept = (int(q) >> drop) & 0x7F
            base = 0b0101010
            assert kept == base + (1 if expect_up else 0), (
                f"dropped={dropped:016b} expect_up={expect_up} kept={kept:07b}"
            )

    def test_rounding_preserves_specials(self):
        x = jnp.asarray([np.inf, -np.inf, np.nan, 0.0, -0.0], jnp.float32)
        q = np.asarray(quantize_mantissa(x, 7, "grte"))
        assert np.isinf(q[0]) and q[0] > 0
        assert np.isinf(q[1]) and q[1] < 0
        assert np.isnan(q[2])
        assert q[3] == 0 and q[4] == 0

    @given(st.sampled_from([3, 7, 11, 15, 19]))
    @settings(max_examples=10, deadline=None)
    def test_grte_error_at_most_one_ulp_worse_than_rne(self, keep):
        rng = np.random.default_rng(keep)
        x = jnp.asarray((rng.standard_normal(512) * 10).astype(np.float32))
        q_rne = np.asarray(quantize_mantissa(x, keep, "rne"), np.float64)
        q_grte = np.asarray(quantize_mantissa(x, keep, "grte"), np.float64)
        x64 = np.asarray(x, np.float64)
        # GRTE is a cheap scheme; its error must stay within 1 ULP of RNE's.
        ulp = 2.0**-keep * np.abs(x64)
        assert (np.abs(q_grte - x64) <= np.abs(q_rne - x64) + ulp + 1e-30).all()


class TestExceptionSignals:
    def test_classify_flags(self):
        x = jnp.asarray([0.0, np.inf, np.nan, 1e-40, 1.0], jnp.float32)
        c = classify(x)
        assert bool(c["zero"][0]) and bool(c["infinity"][1]) and bool(c["nan"][2])
        assert bool(c["denormal"][3]) and not bool(c["denormal"][4])

    def test_mode_mismatch_signal(self):
        assert bool(mode_mismatch_error(1, 2))
        assert not bool(mode_mismatch_error(3, 3))


class TestPropertyBased:
    @given(
        st.integers(1, 3),
        st.integers(1, 64),
        st.integers(1, 64),
        st.integers(1, 64),
    )
    @settings(max_examples=20, deadline=None)
    def test_limb_matmul_error_bound_random_shapes(self, k, m, kd, n):
        rng = np.random.default_rng(m * 1000 + kd * 10 + n)
        a = jnp.asarray(rng.standard_normal((m, kd)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((kd, n)).astype(np.float32))
        out = np.asarray(mp_matmul(a, b, Mode(k)), np.float64)
        ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        # Frobenius-relative bound: c * 2^-8k * ||a|| ||b|| per entry
        row = np.linalg.norm(np.asarray(a, np.float64), axis=1)[:, None]
        col = np.linalg.norm(np.asarray(b, np.float64), axis=0)[None, :]
        bound = 4.0 * 2.0 ** (-8 * k) * row * col + 1e-6
        assert (np.abs(out - ref) <= bound).all()

    @given(st.integers(0, 10))
    @settings(max_examples=10, deadline=None)
    def test_scaling_invariance(self, p):
        # Limb split is exponent-aligned per element: scaling by 2^p is exact.
        rng = np.random.default_rng(p)
        a = jnp.asarray(rng.standard_normal((16, 16)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((16, 16)).astype(np.float32))
        s = np.float32(2.0**p)
        out1 = np.asarray(mp_matmul(a * s, b, Mode.M16))
        out2 = np.asarray(mp_matmul(a, b, Mode.M16)) * s
        np.testing.assert_array_equal(out1, out2)
