"""checkpoint/manager.py: save/restore round-trips, retention, and
restoring under a different RMPM mode (the mode bits are not part of the
checkpoint — precision is a property of the execution, not of the saved
numbers)."""
import dataclasses
import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager, _flatten, _unflatten
from repro.configs import get_smoke_config
from repro.core.policy import NATIVE_F32, PrecisionPolicy
from repro.core.precision import Mode
from repro.models import build_model
from repro.train.loop import resume_or_init
from repro.train.step import TrainConfig, init_train_state, make_train_step


def _tiny(policy=NATIVE_F32):
    cfg = get_smoke_config("qwen1.5-0.5b").with_policy(policy)
    cfg = dataclasses.replace(cfg, n_layers=1)
    return cfg, build_model(cfg)


def _batch(cfg, batch=2, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, (batch, seq + 1)).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}


class TestFlatten:
    def test_roundtrip_nested(self):
        tree = {"a": {"b": np.arange(3)}, "c": (np.zeros(2), np.ones(1))}
        flat = _flatten(tree)
        back = _unflatten(flat)
        assert set(flat) == {"a/b", "c/[0]", "c/[1]"}
        np.testing.assert_array_equal(back["a"]["b"], np.arange(3))
        assert isinstance(back["c"], tuple) and len(back["c"]) == 2


class TestSaveRestore:
    def test_train_state_roundtrip(self, tmp_path):
        cfg, model = _tiny()
        tcfg = TrainConfig()
        state = init_train_state(model, jax.random.key(0), tcfg)
        step_fn = jax.jit(make_train_step(model, tcfg))
        state, _ = step_fn(state, _batch(cfg))

        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(3, state)
        assert mgr.latest_step() == 3
        step, restored = mgr.restore()
        assert step == 3
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            state, restored,
        )
        # a restored state must be steppable (optimizer slots intact)
        restored, metrics = step_fn(restored, _batch(cfg, seed=1))
        assert np.isfinite(float(metrics["loss"]))

    def test_async_save_waits_and_commits(self, tmp_path):
        cfg, model = _tiny()
        state = init_train_state(model, jax.random.key(0), TrainConfig())
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(1, state)
        assert isinstance(mgr._thread, threading.Thread)
        mgr.wait()
        assert mgr.latest_step() == 1
        # atomic commit: no .tmp_ directories survive
        assert not [p for p in tmp_path.iterdir() if p.name.startswith(".tmp_")]

    def test_keep_k_gc(self, tmp_path):
        cfg, model = _tiny()
        state = init_train_state(model, jax.random.key(0), TrainConfig())
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        assert mgr.all_steps() == [3, 4]

    def test_restore_missing_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            mgr.restore()
        assert mgr.latest_step() is None

    def test_resume_or_init_prefers_checkpoint(self, tmp_path):
        cfg, model = _tiny()
        state = init_train_state(model, jax.random.key(0), TrainConfig())
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        start, out = resume_or_init(mgr, lambda: state)
        assert start == 0
        mgr.save(7, state)
        start, out = resume_or_init(mgr, lambda: (_ for _ in ()).throw(
            AssertionError("init_fn must not run when a checkpoint exists")))
        assert start == 7


class TestRestoreAcrossModes:
    def test_restore_under_different_rmpm_mode(self, tmp_path):
        """Save under the fast M8 policy, restore into an M24 model: the
        parameters are mode-agnostic f32; only the step's arithmetic
        changes.  This is the serving/training face of the paper's runtime
        reconfiguration — checkpoints survive mode shifts."""
        cfg8, model8 = _tiny(PrecisionPolicy(default=Mode.M8))
        tcfg = TrainConfig()
        state = init_train_state(model8, jax.random.key(0), tcfg)
        step8 = jax.jit(make_train_step(model8, tcfg))
        state, _ = step8(state, _batch(cfg8))
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, state)

        cfg24, model24 = _tiny(PrecisionPolicy(default=Mode.M24))
        step, restored = mgr.restore()
        step24 = jax.jit(make_train_step(model24, tcfg))
        restored, metrics = step24(restored, _batch(cfg24, seed=2))
        assert np.isfinite(float(metrics["loss"]))
        # and the other direction: the M24-trained state steps under M8
        back, metrics8 = step8(jax.tree.map(jnp.asarray, restored),
                               _batch(cfg8, seed=3))
        assert np.isfinite(float(metrics8["loss"]))
