"""repro.analysis: each rule proven on a known-good and a known-bad fixture
(the bad fixture must fire exactly its own rule ID and nothing else), the
tile_policy re-export compatibility contract, and the CLI gate.
"""
import json
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.analysis import (
    Expect,
    Violation,
    analyze_flow,
    audit,
    audit_stats,
    dispatch_stats,
    format_report,
    lint_source,
    rule_ids,
    write_json,
)
from repro.analysis.__main__ import main as analysis_main


@pytest.fixture(scope="module")
def mats():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((32, 48)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((48, 16)).astype(np.float32))
    return a, b


# --------------------------------------------------------------------------
# precision-flow rules

class TestFlow:
    def test_f64_bad(self, mats):
        a, _ = mats
        # allowlist the widen so ONLY the f64 rule can fire
        v = analyze_flow(lambda x: x.astype(jnp.float64) * 2, a, path="p",
                         widen_allow=(("float32", "float64"),))
        assert rule_ids(v) == {"FLOW-F64"}

    def test_f64_good_under_x64(self, mats):
        a, b = mats
        # f32 math stays f32 even traced under enable_x64
        assert analyze_flow(lambda x, y: x @ y, a, b, path="p") == []

    def test_widen_bad(self, mats):
        a, _ = mats
        h = a.astype(jnp.float16)
        v = analyze_flow(lambda x: x.astype(jnp.float32) + 1, h, path="p")
        assert rule_ids(v) == {"FLOW-WIDEN"}

    def test_widen_good_limb_accumulation(self, mats):
        a, _ = mats
        h = a.astype(jnp.bfloat16)
        # bf16 -> f32 is the allowlisted accumulation edge
        assert analyze_flow(lambda x: x.astype(jnp.float32) + 1, h,
                            path="p") == []

    def test_mode_bad_constant_folded(self, mats):
        a, _ = mats
        # the "mode" arg never reaches an equation: Python folded it
        v = analyze_flow(lambda x, m: x * 2.0, a, jnp.int32(1), path="p",
                         mode_args=(1,))
        assert rule_ids(v) == {"FLOW-MODE"}

    def test_mode_bad_dtype(self, mats):
        a, _ = mats
        v = analyze_flow(lambda x, m: x * m, a, jnp.float32(1.0), path="p",
                         mode_args=(1,))
        assert rule_ids(v) == {"FLOW-MODE"}

    def test_mode_good_traced_consumed(self, mats):
        a, _ = mats
        v = analyze_flow(lambda x, m: x * m.astype(jnp.float32), a,
                         jnp.int32(2), path="p", mode_args=(1,))
        assert v == []

    def test_mode_good_dict_with_inert_sites(self, mats):
        a, _ = mats
        # a ModeTable-style dict where only one site is consumed: fine —
        # unused leaves are inert traced args, not folded modes
        modes = {"used": jnp.int32(1), "inert": jnp.int32(2)}
        v = analyze_flow(
            lambda x, m: x * m["used"].astype(jnp.float32), a, modes,
            path="p", mode_args=(1,))
        assert v == []

    def test_narrow_bad_widening_impostor(self, mats):
        a, _ = mats
        h = a.astype(jnp.bfloat16)

        @jax.jit
        def quantize_mantissa_impostor(x):
            return x.astype(jnp.float32)

        v = analyze_flow(lambda x: quantize_mantissa_impostor(x) + 0.0, h,
                         path="p")
        assert rule_ids(v) == {"FLOW-NARROW"}

    def test_narrow_good_real_kernel(self, mats):
        from repro.kernels.quantize_mantissa.ops import quantize_mantissa_op
        a, _ = mats
        v = analyze_flow(lambda x: quantize_mantissa_op(x, keep=8), a,
                         path="p")
        assert v == []


# --------------------------------------------------------------------------
# dispatch rules

class TestDispatch:
    def _runtime(self, impl):
        from repro.core.rmpm import mp_matmul_runtime
        blk = (16, 16, 16)

        def fn(a, b, m):
            return mp_matmul_runtime(a, b, m, impl=impl, block=blk,
                                     allow_auto=False)
        return fn

    def test_count_good(self, mats):
        a, b = mats
        v = audit(self._runtime("tile"), (a, b, jnp.int32(2)),
                  Expect(exact={"switches": 0, "pallas_calls": 1}), "p")
        assert v == []

    def test_count_bad(self, mats):
        a, b = mats
        # the xla runtime path audited against the tile contract
        v = audit(self._runtime("xla"), (a, b, jnp.int32(2)),
                  Expect(exact={"switches": 0, "pallas_calls": 1}), "p")
        assert rule_ids(v) == {"DISP-COUNT"}
        assert len(v) == 2  # one per failed counter

    def test_bounds(self, mats):
        a, b = mats
        stats = audit_stats(self._runtime("xla"), a, b, jnp.int32(2))
        assert Expect(at_most={"switches": 1}).check(stats, "p") == []
        assert rule_ids(Expect(at_least={"pallas_calls": 1}).check(
            stats, "p")) == {"DISP-COUNT"}

    def test_densify_bad(self):
        pool = jnp.zeros((64, 4, 8), jnp.float32)
        idx = jnp.zeros((2, 64), jnp.int32)  # every row gathers the pool
        v = audit(lambda p, i: p[i], (pool, idx),
                  Expect(densify_bytes=4 * 4 * 8 * 2 * 8), "p")
        assert rule_ids(v) == {"DISP-DENSIFY"}

    def test_densify_good(self):
        pool = jnp.zeros((64, 4, 8), jnp.float32)
        idx = jnp.zeros((2, 8), jnp.int32)  # 8 pages/row <= the cap
        v = audit(lambda p, i: p[i], (pool, idx),
                  Expect(densify_bytes=4 * 4 * 8 * 2 * 8), "p")
        assert v == []

    def test_tile_policy_reexport_compat(self, mats):
        # the verify/CI contract: old import path, exactly two keys
        from repro.kernels.tile_matmul import tile_policy
        assert tile_policy.dispatch_stats is dispatch_stats
        a, b = mats
        s = dispatch_stats(self._runtime("tile"), a, b, jnp.int32(2))
        assert s == {"switches": 0, "pallas_calls": 1}


# --------------------------------------------------------------------------
# trace-hygiene linter

def _ids(src, path="src/repro/x.py"):
    return rule_ids(lint_source(textwrap.dedent(src), path))


class TestLint:
    def test_th001_bad_host_branch(self):
        assert _ids("""
            import jax
            @jax.jit
            def f(x):
                if x > 0:
                    return x
                return -x
            """) == {"TH001"}

    def test_th001_bad_ifexp_module_level_jit(self):
        # the engine idiom: jitted by reference, not by decorator
        assert _ids("""
            import jax
            def step(x):
                return x if x.sum() > 0 else -x
            compiled = jax.jit(step)
            """) == {"TH001"}

    def test_th001_bad_self_attr_jit(self):
        assert _ids("""
            import jax
            class Engine:
                def _masked_step(self, tokens, state):
                    while tokens > 0:
                        tokens = tokens - 1
                    return state
                def __init__(self):
                    self._step = jax.jit(self._masked_step)
            """) == {"TH001"}

    def test_th001_good_metadata_and_static(self):
        assert _ids("""
            import jax
            from functools import partial
            @partial(jax.jit, static_argnames=("rounding",))
            def f(x, rounding):
                if x.ndim < 2:
                    x = x.reshape(1, -1)
                if rounding != "rne":
                    x = x + 1
                if x is None:
                    return None
                y = x if x.ndim == 2 else x[None]
                return y
            """) == set()

    def test_th002_bad_wallclock(self):
        assert _ids("""
            import time
            def span():
                t0 = time.time()
                return time.time() - t0
            """) == {"TH002"}

    def test_th002_allowlisted_stamp(self):
        src = """
            import time
            def manifest():
                return {"time": time.time()}
            """
        assert _ids(src, path="src/repro/checkpoint/manager.py") == set()
        assert _ids(src) == {"TH002"}

    def test_th003_bad_numpy_on_traced(self):
        assert _ids("""
            import jax, numpy as np
            @jax.jit
            def f(x):
                return np.sum(x)
            """) == {"TH003"}

    def test_th003_bad_coercion(self):
        assert _ids("""
            import jax
            @jax.jit
            def f(x):
                return float(x)
            """) == {"TH003"}

    def test_th003_good_numpy_on_metadata(self):
        assert _ids("""
            import jax, numpy as np
            @jax.jit
            def f(x):
                n = np.prod(x.shape)
                return x * n
            """) == set()

    def test_th004_bad_interpret_in_jit(self):
        assert _ids("""
            import jax
            @jax.jit
            def f(x):
                interp = resolve_interpret(None)
                return kernel(x, interpret=interp)
            """) == {"TH004"}

    def test_th004_good_shell_resolution(self):
        assert _ids("""
            import jax
            def shell(x, interpret=None):
                interp = resolve_interpret(interpret)
                return _jitted(x, interpret=interp)
            """) == set()

    def test_th005_bad_mutable_default_arg(self):
        assert _ids("""
            def f(x, acc=[]):
                acc.append(x)
                return acc
            """) == {"TH005"}

    def test_th005_bad_dataclass_field(self):
        assert _ids("""
            import dataclasses
            @dataclasses.dataclass
            class Config:
                xs: list = []
            """) == {"TH005"}

    def test_th005_good_default_factory(self):
        assert _ids("""
            import dataclasses
            @dataclasses.dataclass
            class Config:
                xs: list = dataclasses.field(default_factory=list)
            def f(x, acc=None):
                return acc
            """) == set()

    def test_repo_src_is_clean(self):
        from repro.analysis import lint_paths
        from repro.analysis.__main__ import _default_src
        violations, files = lint_paths(_default_src())
        assert files, "linter found no files — wrong root?"
        assert violations == [], [v.format() for v in violations]


# --------------------------------------------------------------------------
# report + CLI

class TestReport:
    def test_format_and_json(self, tmp_path):
        v = [Violation("TH002", "a.py:3", "wall clock")]
        text = format_report(v, ["a.py"])
        assert "TH002 @ a.py:3" in text and "1 violation" in text
        out = tmp_path / "r.json"
        write_json(str(out), v, ["a.py"])
        doc = json.loads(out.read_text())
        assert doc["clean"] is False
        assert doc["violations"][0]["rule"] == "TH002"

    def test_cli_lint_only_bad_tree(self, tmp_path, capsys):
        bad = tmp_path / "mod.py"
        bad.write_text("import time\nT0 = time.time()\n")
        rc = analysis_main(["--skip-paths", "--src", str(tmp_path),
                            "--report", str(tmp_path / "r.json")])
        assert rc == 1
        doc = json.loads((tmp_path / "r.json").read_text())
        assert [v["rule"] for v in doc["violations"]] == ["TH002"]

    def test_cli_lint_only_clean_tree(self, tmp_path, capsys):
        (tmp_path / "mod.py").write_text("X = 1\n")
        assert analysis_main(["--skip-paths", "--src", str(tmp_path)]) == 0

    def test_cli_quick_paths_clean(self, tmp_path):
        # kernel + train hot paths must satisfy their pinned contracts
        rc = analysis_main(["--quick", "--skip-lint",
                            "--report", str(tmp_path / "r.json")])
        assert rc == 0
        doc = json.loads((tmp_path / "r.json").read_text())
        assert doc["clean"] is True
        assert "pmm-runtime-tile" in doc["checked"]
        assert "train-step" in doc["checked"]
