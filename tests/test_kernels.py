"""Per-kernel shape/dtype sweeps vs ref.py oracles (interpret mode on CPU)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.limb_matmul.limb_matmul import limb_matmul_dd_pallas
from repro.kernels.limb_matmul.ops import limb_matmul
from repro.kernels.limb_matmul.ref import limb_matmul_ref
from repro.kernels.quantize_mantissa.ops import quantize_mantissa_op
from repro.kernels.quantize_mantissa.ref import quantize_mantissa_ref


class TestLimbMatmulKernel:
    @pytest.mark.parametrize("k", [1, 2, 3])
    @pytest.mark.parametrize(
        "m,kd,n", [(32, 64, 32), (100, 300, 70), (17, 33, 9), (128, 128, 128)]
    )
    def test_vs_ref_shapes(self, rng, k, m, kd, n):
        a = jnp.asarray(rng.standard_normal((m, kd)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((kd, n)).astype(np.float32))
        out = np.asarray(limb_matmul(a, b, k, interpret=True, bm=32, bn=32, bk=64))
        ref = np.asarray(limb_matmul_ref(a, b, k))
        # K-tiling reorders the f32 accumulation; tolerance is a few ULP of
        # the result magnitude, not of the mode's precision.
        scale = max(np.abs(ref).max(), 1e-6)
        np.testing.assert_allclose(out / scale, ref / scale, atol=2e-6)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_input_dtypes(self, rng, dtype):
        a = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32)).astype(dtype)
        b = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32)).astype(dtype)
        out = np.asarray(limb_matmul(a, b, 2, interpret=True, bm=32, bn=32, bk=32))
        ref = np.asarray(
            limb_matmul_ref(a.astype(jnp.float32), b.astype(jnp.float32), 2)
        )
        scale = max(np.abs(ref).max(), 1e-6)
        np.testing.assert_allclose(out / scale, ref / scale, atol=2e-6)

    def test_batched_lhs(self, rng):
        a = jnp.asarray(rng.standard_normal((2, 3, 48)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((48, 24)).astype(np.float32))
        out = limb_matmul(a, b, 3, interpret=True, bm=8, bn=8, bk=16)
        assert out.shape == (2, 3, 24)
        ref = np.asarray(jnp.einsum("bsk,kn->bsn", a, b))
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)

    def test_grte_rounded_inputs(self, rng):
        a = jnp.asarray(rng.standard_normal((32, 32)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((32, 32)).astype(np.float32))
        out = limb_matmul(a, b, 2, rounding="grte", interpret=True, bm=16, bn=16, bk=16)
        ref = np.asarray(a) @ np.asarray(b)
        rel = np.abs(np.asarray(out) - ref).max() / np.abs(ref).max()
        assert rel < 2**-13

    def test_mode_error_ladder_through_kernel(self, rng):
        a = jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32))
        ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        scale = np.abs(ref).max()
        errs = []
        for k in (1, 2, 3):
            out = np.asarray(
                limb_matmul(a, b, k, interpret=True, bm=32, bn=32, bk=64), np.float64
            )
            errs.append(np.abs(out - ref).max() / scale)
        assert errs[0] > errs[1] > errs[2]

    def test_dd_variant_returns_pair(self, rng):
        a = jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32))
        hi, lo = limb_matmul_dd_pallas(a, b, 3, bm=32, bn=32, bk=64, interpret=True)
        ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        out = np.asarray(hi, np.float64) + np.asarray(lo, np.float64)
        rel = np.abs(out - ref).max() / np.abs(ref).max()
        assert rel < 2**-22  # MXU-accumulator-limited (DESIGN.md assumption 8)
        assert np.abs(np.asarray(lo)).max() < np.abs(np.asarray(hi)).max() * 2**-20


class TestQuantizeMantissaKernel:
    @pytest.mark.parametrize("rounding", ["trunc", "rne", "grte"])
    @pytest.mark.parametrize("keep", [1, 5, 7, 15, 20, 22])
    def test_bit_exact_vs_ref(self, rng, rounding, keep):
        x = (rng.standard_normal((57, 131)) * 10 ** rng.integers(-3, 3)).astype(
            np.float32
        )
        out = np.asarray(quantize_mantissa_op(jnp.asarray(x), keep, rounding, interpret=True))
        ref = quantize_mantissa_ref(x, keep, rounding)
        assert np.array_equal(out, ref), f"keep={keep} rounding={rounding}"

    def test_nd_shapes(self, rng):
        x = rng.standard_normal((3, 5, 7, 11)).astype(np.float32)
        out = np.asarray(quantize_mantissa_op(jnp.asarray(x), 7, "grte", interpret=True))
        ref = quantize_mantissa_ref(x, 7, "grte")
        assert out.shape == x.shape
        assert np.array_equal(out, ref.reshape(x.shape))

    def test_specials_passthrough(self):
        x = np.array([np.inf, -np.inf, np.nan, 0.0], np.float32)
        out = np.asarray(quantize_mantissa_op(jnp.asarray(x), 7, "grte", interpret=True))
        assert np.isinf(out[0]) and np.isinf(out[1]) and np.isnan(out[2]) and out[3] == 0

    @pytest.mark.parametrize("keep", [0, -1, -8])
    def test_nonpositive_keep_rejected(self, rng, keep):
        # satellite regression: keep <= 0 used to make drop > 23 so the
        # kept-mask and rounding carry reached the exponent/sign fields and
        # returned garbage instead of an error
        x = jnp.asarray(rng.standard_normal((8, 8)).astype(np.float32))
        with pytest.raises(ValueError, match="keep must be >= 1"):
            quantize_mantissa_op(x, keep, "grte", interpret=True)
