"""Continuous-batching scheduler + masked step engine: admission order,
slot reuse, mid-flight joins, decode budgets, metrics."""
import dataclasses

import numpy as np
import pytest
import jax

from repro.configs import get_smoke_config
from repro.core.policy import NATIVE_F32
from repro.models import build_model
from repro.serve import Request, Scheduler, ServeEngine
from repro.serve.scheduler import DECODE, DONE, PREFILL, WAITING


def _req(rid, n=4, max_new=4, vocab=64, seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    return Request(prompt=rng.integers(0, vocab, n).astype(np.int32),
                   max_new=max_new, rid=rid)


class TestScheduler:
    def test_fifo_admission_under_contention(self):
        s = Scheduler(slots=2, max_len=32)
        for i in range(5):
            s.submit(_req(i))
        first = s.admit()
        assert [t.rid for _, t in first] == [0, 1]
        assert s.n_waiting == 3 and not s.free
        # nothing admits while slots are occupied
        assert s.admit() == []
        s.complete(0)
        assert [t.rid for _, t in s.admit()] == [2]
        s.complete(1)
        s.complete(2)
        assert sorted(t.rid for _, t in s.admit()) == [3, 4]

    def test_slot_reuse_after_completion(self):
        s = Scheduler(slots=1, max_len=32)
        s.submit(_req(0))
        s.submit(_req(1))
        (slot0, t0), = s.admit()
        s.complete(0)
        (slot1, t1), = s.admit()
        assert slot0 == slot1  # the freed slot is handed to the next request
        assert t1.rid == 1

    def test_lifecycle_states(self):
        s = Scheduler(slots=1, max_len=32)
        s.submit(_req(0))
        assert s.tickets[0].state == WAITING
        (_, t), = s.admit()
        assert t.state == PREFILL
        s.start_decode(0)
        assert t.state == DECODE
        s.complete(0)
        assert t.state == DONE and t.slot == -1
        assert not s.has_work()

    def test_budget_clamped_to_max_len(self):
        # eviction on max_len: prompt 10 + budget must fit a 12-slot cache;
        # prefill writes 10 rows, each decode step past the first token one
        # more -> 3 tokens fit (12 - 10 + 1)
        s = Scheduler(slots=1, max_len=12)
        s.submit(_req(0, n=10, max_new=50))
        assert s.tickets[0].budget == 3
        # a request that already fits is untouched
        s.submit(_req(1, n=4, max_new=5))
        assert s.tickets[1].budget == 5

    def test_zero_budget_completes_even_without_free_slot(self):
        # a zero-budget request consumes no slot, so it must not wait
        # behind slot contention: admit() drains it as (-1, ticket) while
        # every slot is occupied
        s = Scheduler(slots=1, max_len=32)
        s.submit(_req(0))
        (_, t0), = s.admit()
        s.submit(Request(prompt=np.arange(4, dtype=np.int32), max_new=0, rid=1))
        out = s.admit()
        assert out == [(-1, s.tickets[1])]
        assert s.tickets[1].done and not t0.done
        assert s.completed == [1]

    def test_submit_validation(self):
        s = Scheduler(slots=1, max_len=8)
        with pytest.raises(ValueError, match="exceeds max_len"):
            s.submit(_req(0, n=9))
        with pytest.raises(ValueError, match="empty prompt"):
            s.submit(Request(prompt=np.zeros((0,), np.int32), rid=1))
        s.submit(_req(2))
        with pytest.raises(ValueError, match="already submitted"):
            s.submit(_req(2))


# ---------------------------------------------------------------------------
# Engine-level: the masked step must be indistinguishable from solo decode
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_smoke_config("qwen1.5-0.5b").with_policy(NATIVE_F32)
    cfg = dataclasses.replace(cfg, n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _solo(model, params, req, max_len=48):
    eng = ServeEngine(model, params, batch_slots=1, max_len=max_len)
    return eng.generate_batch([req])[req.rid]


class TestContinuousBatching:
    def test_ragged_batch_matches_solo(self, tiny_model):
        # satellite regression: mixed prompt lengths through one slot array
        # must produce exactly the tokens each request gets alone at batch=1
        cfg, model, params = tiny_model
        reqs = [_req(0, n=3, max_new=5, vocab=cfg.vocab),
                _req(1, n=9, max_new=3, vocab=cfg.vocab),
                _req(2, n=6, max_new=4, vocab=cfg.vocab)]
        eng = ServeEngine(model, params, batch_slots=3, max_len=48)
        outs = eng.generate_batch(reqs)
        for r in reqs:
            assert outs[r.rid] == _solo(model, params, r), f"rid {r.rid}"

    def test_no_tokens_past_budget(self, tiny_model):
        # satellite regression: pre-refactor, every request decoded until
        # max(max_new); now lengths must equal each request's own budget
        cfg, model, params = tiny_model
        reqs = [_req(0, n=4, max_new=2, vocab=cfg.vocab),
                _req(1, n=4, max_new=9, vocab=cfg.vocab),
                _req(2, n=14, max_new=50, vocab=cfg.vocab)]
        eng = ServeEngine(model, params, batch_slots=3, max_len=16)
        outs = eng.generate_batch(reqs)
        assert len(outs[0]) == 2
        assert len(outs[1]) == 9
        assert len(outs[2]) == 3  # evicted at max_len: 16 - 14 + 1

    def test_mid_flight_join_matches_solo(self, tiny_model):
        cfg, model, params = tiny_model
        a = _req(0, n=7, max_new=8, vocab=cfg.vocab)
        b = _req(1, n=4, max_new=5, vocab=cfg.vocab)
        eng = ServeEngine(model, params, batch_slots=2, max_len=48)
        eng.submit(a)
        for _ in range(3):
            eng.step()  # a is 4 tokens deep when b arrives
        eng.submit(b)
        done = eng.drain()
        assert done[0] == _solo(model, params, a)
        assert done[1] == _solo(model, params, b)

    def test_more_requests_than_slots_reuses_slots(self, tiny_model):
        cfg, model, params = tiny_model
        reqs = [_req(i, n=3 + i, max_new=3, vocab=cfg.vocab) for i in range(5)]
        eng = ServeEngine(model, params, batch_slots=2, max_len=32)
        outs = eng.generate_batch(reqs)
        assert sorted(outs) == [0, 1, 2, 3, 4]
        for r in reqs:
            assert outs[r.rid] == _solo(model, params, r, max_len=32)
        # every slot was recycled: 5 requests through 2 slots
        assert eng.scheduler.free and len(eng.scheduler.free) == 2

    def test_streaming_events_order_and_content(self, tiny_model):
        cfg, model, params = tiny_model
        r = _req(0, n=5, max_new=4, vocab=cfg.vocab)
        eng = ServeEngine(model, params, batch_slots=1, max_len=32)
        eng.submit(r)
        events = []
        while eng.scheduler.has_work():
            events.extend(eng.step())
        assert [rid for rid, _ in events] == [0, 0, 0, 0]
        assert [t for _, t in events] == _solo(model, params, r, max_len=32)

    def test_metrics_counters(self, tiny_model):
        cfg, model, params = tiny_model
        reqs = [_req(i, n=4, max_new=4, vocab=cfg.vocab) for i in range(4)]
        eng = ServeEngine(model, params, batch_slots=2, max_len=32)
        outs = eng.generate_batch(reqs)
        s = eng.metrics.summary()
        assert s["tokens_out"] == sum(len(v) for v in outs.values()) == 16
        assert s["requests"] == s["completed"] == 4
        assert s["decode_steps"] > 0
        # 4 x 4-token requests through 2 slots: the array stays saturated
        assert 0.8 < s["occupancy"] <= 1.0
        assert s["ttft_mean_s"] is not None and s["ttft_mean_s"] > 0
        assert s["latency_mean_s"] >= s["ttft_mean_s"]
        for rid in outs:
            assert eng.metrics.ttft(rid) is not None
            assert eng.metrics.latency(rid) is not None
        assert set(s["plan_cache"]) == {"hits", "misses", "entries"}

    def test_per_phase_modes_split_on_boundary(self, tiny_model):
        # --accuracy spanning a mode boundary: prefill and decode phases
        # must report different planned modes (run-time reconfiguration
        # between phases of one workload)
        cfg, model, params = tiny_model
        eng = ServeEngine(model, params, batch_slots=2, max_len=32,
                          accuracy=2.0**-5)
        pre = eng.phase_plans["prefill"]["mlp_up"].mode
        dec = eng.phase_plans["decode"]["mlp_up"].mode
        assert pre != dec
        assert "prefill/mlp_up" in eng.describe_plans()
        r = _req(0, n=4, max_new=3, vocab=cfg.vocab)
        outs = eng.generate_batch([r])
        assert len(outs[0]) == 3
