"""Self-speculative decoding (repro.spec): exact output equivalence to the
baseline greedy engine across model families, rollback under rejection
(including sliding-window ring buffers), budget-clamped bursts, the
acceptance-driven draft-shift controller, and the zero-retrace property."""
import dataclasses

import numpy as np
import pytest
import jax

from hypothesis_compat import given, settings, st  # property tests skip w/o hypothesis

from repro.adapt import SLO
from repro.adapt.workload import conditioned_model
from repro.configs import get_smoke_config
from repro.core.policy import NATIVE_F32
from repro.core.precision import Mode
from repro.models import build_model
from repro.serve import Request, ServeEngine
from repro.spec import AcceptanceController, SpecConfig


def _tiny(arch="qwen1.5-0.5b", n_layers=2, seed=0, **over):
    cfg = get_smoke_config(arch).with_policy(NATIVE_F32)
    cfg = dataclasses.replace(cfg, n_layers=n_layers, **over)
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    return cfg, model, params


def _ragged(vocab, n, rng, max_prompt=10, max_new=9):
    return [
        Request(
            prompt=rng.integers(0, vocab, int(rng.integers(3, max_prompt))).astype(np.int32),
            max_new=int(rng.integers(3, max_new)), rid=i)
        for i in range(n)
    ]


def _drain_with_join(eng, reqs, join_after=2):
    """Submit some requests, step, submit the rest mid-flight, drain."""
    for r in reqs[:3]:
        eng.submit(dataclasses.replace(r))
    for _ in range(join_after):
        eng.step()
    for r in reqs[3:]:
        eng.submit(dataclasses.replace(r))
    return eng.drain()


class TestSpecEquivalence:
    """drain() must be token-for-token identical to the PR-2 baseline."""

    @pytest.mark.parametrize(
        "arch", ["qwen1.5-0.5b", "mamba2-2.7b", "recurrentgemma-9b"])
    def test_families_with_mid_flight_join(self, arch):
        cfg, model, params = _tiny(arch, n_layers=3)
        rng = np.random.default_rng(1)
        reqs = _ragged(cfg.vocab, 5, rng)
        base = ServeEngine(model, params, batch_slots=2, max_len=32)
        spec = ServeEngine(model, params, batch_slots=2, max_len=32,
                           speculate=SpecConfig(k=3, draft_shift=1))
        out_b = _drain_with_join(base, reqs)
        out_s = _drain_with_join(spec, reqs)
        assert out_b == out_s
        assert spec.metrics.acceptance_rate is not None

    def test_int8_kv_cache(self):
        cfg, model, params = _tiny(kv_cache_dtype="int8")
        rng = np.random.default_rng(2)
        reqs = _ragged(cfg.vocab, 4, rng)
        base = ServeEngine(model, params, batch_slots=2, max_len=32)
        spec = ServeEngine(model, params, batch_slots=2, max_len=32,
                           speculate=SpecConfig(k=2, draft_shift=1))
        assert _drain_with_join(base, reqs) == _drain_with_join(spec, reqs)

    def test_exact_under_heavy_rejection(self):
        # the conditioned workload's hot requests make the M8 draft disagree
        # with the M24 verify — the per-slot rollback-select must restore the
        # exact baseline KV positions/lengths on every rejection
        wl = conditioned_model(mode=Mode.M24, width=128)
        rng = np.random.default_rng(0)
        reqs = wl.requests(8, hot=set(range(8)), rng=rng, max_new=10)
        base = ServeEngine(wl.model, wl.params, batch_slots=3, max_len=24)
        spec = ServeEngine(wl.model, wl.params, batch_slots=3, max_len=24,
                           speculate=SpecConfig(k=3, draft_shift=2, adapt=False))
        for i, r in enumerate(reqs):
            base.submit(dataclasses.replace(r, rid=i))
            spec.submit(dataclasses.replace(r, rid=i))
        assert base.drain() == spec.drain()
        m = spec.metrics
        assert m.spec_drafted - m.spec_accepted > 0, "no rejection exercised"
        assert m.verify_steps_per_token < 1.0

    def test_sliding_window_ring_rollback(self):
        # hybrid local attention with a tiny window: rejected verify writes
        # land on top of still-live old-window ring rows, which the pos-mask
        # select must restore (length arithmetic alone would corrupt them)
        cfg, model, params = _tiny("recurrentgemma-9b", n_layers=6, seed=2,
                                   local_window=6)
        params = jax.tree.map(lambda p: p * 1.6, params)  # chaotic logits
        rng = np.random.default_rng(3)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                            int(rng.integers(3, 8))).astype(np.int32),
                        max_new=18, rid=i) for i in range(4)]
        base = ServeEngine(model, params, batch_slots=2, max_len=32)
        spec = ServeEngine(model, params, batch_slots=2, max_len=32,
                           speculate=SpecConfig(k=3, draft_shift=2, adapt=False))
        for r in reqs:
            base.submit(dataclasses.replace(r))
            spec.submit(dataclasses.replace(r))
        assert base.drain() == spec.drain()
        m = spec.metrics
        assert m.spec_drafted - m.spec_accepted > 0, "no ring-wrap rejection"

    def test_slo_adaptive_verify_matches_modal_baseline(self):
        # with slo= the baseline is the modal step; the speculative verify
        # must bind the same live table (monitor mode pins it in place)
        cfg, model, params = _tiny()
        rng = np.random.default_rng(4)
        reqs = _ragged(cfg.vocab, 4, rng)
        kw = dict(batch_slots=2, max_len=32, slo=SLO(max_err=0.5), adapt=False)
        base = ServeEngine(model, params, **kw)
        spec = ServeEngine(model, params, speculate=SpecConfig(k=2, draft_shift=1),
                           **kw)
        assert spec._spec_table is spec.mode_table  # one table, SLO-owned
        assert _drain_with_join(base, reqs) == _drain_with_join(spec, reqs)

    @given(st.integers(0, 2**31 - 1), st.integers(1, 4))
    @settings(max_examples=8, deadline=None)
    def test_property_random_workloads(self, seed, k):
        cfg, model, params = _tiny()
        rng = np.random.default_rng(seed)
        reqs = _ragged(cfg.vocab, 4, rng)
        base = ServeEngine(model, params, batch_slots=2, max_len=32)
        spec = ServeEngine(model, params, batch_slots=2, max_len=32,
                           speculate=SpecConfig(k=k, draft_shift=1))
        assert _drain_with_join(base, reqs) == _drain_with_join(spec, reqs)


class TestSpecMechanics:
    def test_compile_count_stable_across_shift_and_table(self):
        # shift and mode changes ride in as scalars: one compiled round
        cfg, model, params = _tiny()
        rng = np.random.default_rng(5)
        spec = ServeEngine(model, params, batch_slots=2, max_len=32,
                           speculate=SpecConfig(k=2, draft_shift=1, adapt=False))
        spec.generate_batch(_ragged(cfg.vocab, 3, rng))
        spec._draft_shift = 2  # manual run-time shift change
        reqs = [dataclasses.replace(r, rid=10 + r.rid)
                for r in _ragged(cfg.vocab, 3, rng)]
        for r in reqs:
            spec.submit(r)
        spec.drain()
        spec._spec_table.shift_all(-1, tag="test")  # mode-table change
        spec.submit(Request(prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                            max_new=4, rid=99))
        spec.drain()
        assert spec.spec_compile_count in (None, 1)

    def test_burst_clamped_to_budget(self):
        # k+1-token bursts must never emit past a request's decode budget
        cfg, model, params = _tiny()
        rng = np.random.default_rng(6)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                        max_new=m, rid=i) for i, m in enumerate([1, 2, 7])]
        spec = ServeEngine(model, params, batch_slots=3, max_len=32,
                           speculate=SpecConfig(k=4, draft_shift=1))
        outs = spec.generate_batch(reqs)
        assert [len(outs[i]) for i in range(3)] == [1, 2, 7]
        s = spec.metrics.summary()
        # budget-truncated draft tails are not credited as accepted
        assert s["spec_accepted"] <= s["spec_emitted"]

    def test_metrics_and_describe(self):
        cfg, model, params = _tiny()
        rng = np.random.default_rng(7)
        spec = ServeEngine(model, params, batch_slots=2, max_len=32,
                           speculate=SpecConfig(k=3, draft_shift=1))
        spec.generate_batch(_ragged(cfg.vocab, 4, rng))
        s = spec.metrics.summary()
        assert s["spec_rounds"] > 0
        assert s["spec_drafted"] == s["spec_accepted"] + s["spec_rejected"]
        assert 0.0 <= s["acceptance_rate"] <= 1.0
        assert 0.0 < s["verify_steps_per_token"] <= 1.0
        assert "acceptance" in spec.describe_speculation()
        assert "spec" in spec.metrics.format_summary()

    def test_latency_signal_normalized_per_token(self):
        # the SLO's target_ms is a per-decode-step budget: a speculative
        # round emits a burst per slot, so the controller must see the
        # per-token step equivalent, not the whole-round wall time (else
        # every round reads as a latency violation and the dead band dies)
        cfg, model, params = _tiny()
        rng = np.random.default_rng(9)
        spec = ServeEngine(
            model, params, batch_slots=2, max_len=48,
            slo=SLO(max_err=0.5, target_ms=1e9), adapt=False, adapt_every=1,
            speculate=SpecConfig(k=3, draft_shift=1, adapt=False))
        reqs = [Request(prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                        max_new=12, rid=i) for i in range(2)]
        spec.generate_batch(reqs)
        assert spec._last_step_tokens > 1.0  # bursts actually happened
        spec._active[0] = True  # re-arm one row for a manual probe tick
        spec._last_step_ms = 100.0
        spec._last_step_tokens = 4.0
        spec._adapt_tick()
        assert spec.controller.history[-1].step_ms == pytest.approx(25.0)

    def test_config_validation(self):
        with pytest.raises(ValueError, match="k must be >= 1"):
            SpecConfig(k=0)
        with pytest.raises(ValueError, match="draft_shift must be >= 1"):
            SpecConfig(draft_shift=0)
        with pytest.raises(ValueError, match="max_reject"):
            SpecConfig(max_reject=1.5)
        cfg, model, params = _tiny()
        with pytest.raises(TypeError, match="SpecConfig"):
            ServeEngine(model, params, batch_slots=1, max_len=16,
                        speculate={"k": 2})

    def test_speculate_requires_greedy(self):
        cfg, model, params = _tiny()
        with pytest.raises(NotImplementedError, match="greedy"):
            ServeEngine(model, params, batch_slots=1, max_len=16,
                        greedy=False, speculate=SpecConfig(k=2))


class TestAcceptanceController:
    def test_high_rejection_shallows_draft(self):
        c = AcceptanceController(SpecConfig(draft_shift=2, max_reject=0.4,
                                            cooldown=0), ladder=2)
        assert c.shift == 2
        c.observe(0, reject_rate=0.9)
        assert c.shift == 1  # shallower: one rung toward the verify modes
        c.observe(1, reject_rate=0.9)
        assert c.shift == 1  # clamped: draft never reaches the verify table

    def test_high_acceptance_deepens_draft(self):
        c = AcceptanceController(SpecConfig(draft_shift=1, max_reject=0.4,
                                            down_factor=0.25, cooldown=0),
                                 ladder=2)
        c.observe(0, reject_rate=0.0)
        assert c.shift == 2  # cheaper draft
        c.observe(1, reject_rate=0.0)
        assert c.shift == 2  # clamped at the ladder span

    def test_dead_band_holds(self):
        # between max_reject * down_factor and max_reject: no move
        c = AcceptanceController(SpecConfig(draft_shift=1, max_reject=0.4,
                                            down_factor=0.25, cooldown=0),
                                 ladder=2)
        for i in range(4):
            c.observe(i, reject_rate=0.2)
        assert c.shift == 1 and c.shallower_moves == c.deeper_moves == 0

    def test_cooldown_bounds_move_rate(self):
        c = AcceptanceController(SpecConfig(draft_shift=2, max_reject=0.4,
                                            cooldown=3), ladder=2)
        c.observe(0, reject_rate=0.9)
        assert c.shift == 1
        c2 = AcceptanceController(SpecConfig(draft_shift=1, max_reject=0.4,
                                             cooldown=3), ladder=2)
        c2.observe(0, reject_rate=0.0)
        assert c2.shift == 2
        c2.observe(1, reject_rate=0.9)  # within cooldown: held
        assert c2.shift == 2

    def test_engine_adapts_shift_from_acceptance(self):
        # the shift-1 (M16) draft fully agrees with M24 verify on this tiny
        # model, so the controller's first applied move deepens the draft —
        # and budget truncation at request tails must not read as rejection
        cfg, model, params = _tiny()
        rng = np.random.default_rng(8)
        spec = ServeEngine(
            model, params, batch_slots=2, max_len=48,
            speculate=SpecConfig(k=2, draft_shift=1, every=2, cooldown=0))
        reqs = [Request(prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                        max_new=20, rid=i) for i in range(4)]
        spec.generate_batch(reqs)
        assert spec.metrics.draft_shift_timeline
        assert spec.metrics.draft_shift_timeline[0][1] == 2  # first move: deeper
