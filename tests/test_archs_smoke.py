"""Per-architecture smoke tests: reduced configs (same family/topology),
one forward + one train step on CPU, asserting shapes and finiteness.
Full configs are exercised only by the dry-run (ShapeDtypeStruct)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import Mode
from repro.core.policy import NATIVE_F32, PrecisionPolicy
from repro.models import build_model
from repro.optim import adamw
from repro.train.step import TrainConfig, init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": labels}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, 16, cfg.d_model)).astype(np.float32) * 0.02
        )
    if cfg.family == "vlm":
        batch["pixel_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_vision_tokens, cfg.d_model)).astype(np.float32) * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch, rng):
        cfg = get_smoke_config(arch).with_policy(NATIVE_F32)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        logits, aux = jax.jit(model.apply)(params, _batch(cfg, rng))
        s_out = S if cfg.family != "vlm" else S
        assert logits.shape == (B, s_out, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        assert bool(jnp.isfinite(aux))

    def test_train_step_reduces_loss_shape(self, arch, rng):
        cfg = get_smoke_config(arch).with_policy(NATIVE_F32)
        model = build_model(cfg)
        tcfg = TrainConfig(
            optimizer=adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10),
            accum_steps=2,
        )
        step = jax.jit(make_train_step(model, tcfg))
        state = init_train_state(model, jax.random.key(1), tcfg)
        batch = _batch(cfg, rng)
        state, m1 = step(state, batch)
        state, m2 = step(state, batch)  # same batch twice: loss must drop
        assert np.isfinite(m1["loss"]) and np.isfinite(m2["loss"])
        assert float(m2["loss"]) < float(m1["loss"])
        assert float(m1["grad_norm"]) > 0

    def test_full_config_matches_assignment(self, arch, rng):
        cfg = get_config(arch)
        spec = {
            "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
            "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
            "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
            "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
            "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
            "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
            "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
            "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
            "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
            "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        }[arch]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
        assert got == spec, f"{arch}: {got} != {spec}"


class TestArchDetails:
    def test_qwen_has_qkv_bias(self):
        cfg = get_smoke_config("qwen1.5-4b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        seg = params["layers"]["seg0_dense"]
        assert "b" in seg["attn"]["wq"]

    def test_command_r_no_bias(self):
        cfg = get_smoke_config("command-r-plus-104b")
        params = build_model(cfg).init(jax.random.key(0))
        assert "b" not in params["layers"]["seg0_dense"]["attn"]["wq"]

    def test_moe_expert_counts(self):
        cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
        params = build_model(cfg).init(jax.random.key(0))
        moe = params["layers"]["seg0_moe"]["moe"]
        assert moe["gate"].shape[1] == cfg.moe_experts  # (L, E, D, F)

    def test_kimi_first_layer_dense_plus_shared_expert(self):
        cfg = get_smoke_config("kimi-k2-1t-a32b")
        model = build_model(cfg)
        assert model.segments[0] == ("dense", 1)
        params = model.init(jax.random.key(0))
        assert "shared" in params["layers"]["seg1_moe"]["moe"]

    def test_recurrentgemma_pattern(self):
        cfg = get_config("recurrentgemma-9b")
        model = build_model(cfg)
        kinds = [k for k, n in model.segments for _ in range(n)]
        assert kinds[:6] == ["rec", "rec", "attn_local", "rec", "rec", "attn_local"]
        assert len(kinds) == 38 and kinds[-2:] == ["rec", "rec"]

    def test_mamba2_is_attention_free(self):
        cfg = get_smoke_config("mamba2-2.7b")
        params = build_model(cfg).init(jax.random.key(0))
        flat = jax.tree_util.tree_leaves_with_path(params)
        assert not any("attn" in str(p) for p, _ in flat)

    def test_rmpm_policy_changes_results(self, rng):
        # the engine is live in the models: policy M8 vs M24 must differ
        cfg = get_smoke_config("qwen1.5-0.5b")
        model8 = build_model(cfg.with_policy(PrecisionPolicy(default=Mode.M8)))
        model24 = build_model(cfg.with_policy(PrecisionPolicy(default=Mode.M24)))
        params = model8.init(jax.random.key(0))
        batch = _batch(cfg, rng)
        l8, _ = jax.jit(model8.apply)(params, batch)
        l24, _ = jax.jit(model24.apply)(params, batch)
        diff = float(jnp.max(jnp.abs(l8 - l24)))
        assert 0 < diff < 1.0  # different rounding, same model
