"""Substrate tests: optimizer, checkpoint manager, data pipeline, train loop,
serving engine, gradient compression round-trip."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import PackedReader, Prefetcher, SyntheticLM
from repro.optim import adamw
from repro.distributed import compress


class TestAdamW:
    def _quad(self, quantize):
        cfg = adamw.AdamWConfig(
            lr=0.1, warmup_steps=0, total_steps=100, schedule="const",
            weight_decay=0.0, quantize_moments=quantize,
        )
        params = {"w": jnp.asarray([2.0, -3.0, 1.5])}
        state = adamw.init_state(params, cfg)

        @jax.jit
        def step(params, state):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            return adamw.apply_updates(params, grads, state, cfg)

        for _ in range(60):
            params, state, metrics = step(params, state)
        return params, metrics

    def test_converges(self):
        params, metrics = self._quad(False)
        assert float(jnp.abs(params["w"]).max()) < 0.15
        assert metrics["lr"] == pytest.approx(0.1)

    def test_quantized_moments_converge(self):
        params, _ = self._quad(True)
        assert float(jnp.abs(params["w"]).max()) < 0.3

    def test_quantized_state_is_int8(self):
        cfg = adamw.AdamWConfig(quantize_moments=True)
        params = {"w": jnp.zeros((4, 512))}
        st = adamw.init_state(params, cfg)
        q, scale = st["m"]["w"]
        assert q.dtype == jnp.int8 and q.shape == (4, 2, 256)
        assert scale.shape == (4, 2, 1)

    def test_schedule_warmup_cosine(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
        assert float(adamw.schedule_lr(cfg, jnp.int32(5))) == pytest.approx(0.5)
        assert float(adamw.schedule_lr(cfg, jnp.int32(10))) == pytest.approx(1.0)
        assert float(adamw.schedule_lr(cfg, jnp.int32(110))) == pytest.approx(0.0, abs=1e-6)

    def test_grad_clipping(self):
        cfg = adamw.AdamWConfig(grad_clip=1.0, warmup_steps=0, schedule="const")
        params = {"w": jnp.zeros(3)}
        state = adamw.init_state(params, cfg)
        huge = {"w": jnp.asarray([1e4, 1e4, 1e4])}
        _, _, m = adamw.apply_updates(params, huge, state, cfg)
        assert float(m["grad_norm"]) > 1e4  # reported pre-clip


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)}, "opt": {"step": jnp.int32(7)}}
        mgr.save(3, state)
        step, restored = mgr.restore()
        assert step == 3
        np.testing.assert_array_equal(restored["params"]["w"], np.arange(6.0).reshape(2, 3))
        assert int(restored["opt"]["step"]) == 7

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        st = {"x": jnp.zeros(1)}
        for s in (1, 2, 3, 4):
            mgr.save(s, st)
        assert mgr.all_steps() == [3, 4]

    def test_async_save_then_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
        mgr.save(1, {"x": jnp.ones(8)})
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_atomicity_no_partial_dirs(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(5, {"x": jnp.ones(2)})
        names = os.listdir(tmp_path)
        assert all(not n.startswith(".tmp") for n in names)

    def test_tuple_state_roundtrip(self, tmp_path):
        # quantized optimizer states contain tuples
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        st = {"m": {"w": (jnp.ones((2, 4), jnp.int8), jnp.ones((2, 1)))}}
        mgr.save(1, st)
        _, r = mgr.restore()
        assert isinstance(r["m"]["w"], tuple) and r["m"]["w"][0].dtype == np.int8


class TestData:
    def test_synthetic_deterministic_and_learnable(self):
        d1 = SyntheticLM(vocab=64, seq_len=16, batch=4, seed=1)
        d2 = SyntheticLM(vocab=64, seq_len=16, batch=4, seed=1)
        b1, b2 = d1.next_batch(), d2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        # labels shifted by one vs tokens
        np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])

    def test_skip_ahead_restart_consistency(self):
        d = SyntheticLM(vocab=64, seq_len=8, batch=2, seed=3)
        batches = [d.next_batch() for _ in range(5)]
        d2 = SyntheticLM(vocab=64, seq_len=8, batch=2, seed=3)
        d2.skip_to(3)
        np.testing.assert_array_equal(d2.next_batch()["tokens"], batches[3]["tokens"])

    def test_packed_reader_roundtrip(self, tmp_path):
        path = str(tmp_path / "tokens.bin")
        recs = np.arange(20 * 9, dtype=np.uint32).reshape(20, 9)
        PackedReader.write(path, recs)
        r = PackedReader(path, batch=4, rank=0, world=2, seed=0)
        b = r.next_batch()
        assert b["tokens"].shape == (4, 8) and b["labels"].shape == (4, 8)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_packed_reader_rank_disjoint(self, tmp_path):
        path = str(tmp_path / "t.bin")
        recs = np.arange(16 * 5, dtype=np.uint32).reshape(16, 5)
        PackedReader.write(path, recs)
        r0 = PackedReader(path, batch=4, rank=0, world=2, seed=0)
        r1 = PackedReader(path, batch=4, rank=1, world=2, seed=0)
        t0 = r0.next_batch()["tokens"][:, 0]
        t1 = r1.next_batch()["tokens"][:, 0]
        assert set(t0.tolist()).isdisjoint(t1.tolist())

    def test_prefetcher(self):
        d = SyntheticLM(vocab=16, seq_len=4, batch=2, seed=0)
        ref = SyntheticLM(vocab=16, seq_len=4, batch=2, seed=0)
        pf = Prefetcher(d, depth=2)
        try:
            for _ in range(3):
                np.testing.assert_array_equal(next(pf)["tokens"], ref.next_batch()["tokens"])
        finally:
            pf.close()


class TestCompression:
    def test_roundtrip_error_bounded(self, rng):
        x = jnp.asarray(rng.standard_normal(3000).astype(np.float32))
        approx, resid = compress.compress_decompress(x)
        np.testing.assert_allclose(np.asarray(approx + resid), np.asarray(x), rtol=1e-6)
        block_max = np.abs(np.asarray(x)).max()
        assert float(jnp.abs(resid).max()) <= block_max / 127.0

    def test_error_feedback_accumulates(self, rng):
        # with EF, the *accumulated* quantization error stays bounded and the
        # mean of compressed gradients tracks the true mean over steps
        g = jnp.asarray(rng.standard_normal(512).astype(np.float32)) * 0.01
        resid = jnp.zeros_like(g)
        total_sent = jnp.zeros_like(g)
        for _ in range(20):
            approx, resid = compress.compress_decompress(g + resid)
            total_sent = total_sent + approx
        np.testing.assert_allclose(
            np.asarray(total_sent) / 20, np.asarray(g), atol=float(jnp.abs(g).max()) / 100
        )


class TestStragglerMonitor:
    def test_flags_outlier(self):
        from repro.train.loop import StragglerMonitor

        mon = StragglerMonitor(alpha=0.3, z_threshold=3.0)
        for i in range(20):
            mon.observe(i, 0.1 + 0.001 * (i % 3))
        assert not mon.flagged
        assert mon.observe(99, 1.5)  # 15x step time -> straggler
        assert mon.flagged and mon.flagged[-1][0] == 99
