"""Sharded Strassen under the production mesh: compute-roofline lever.

Compiles a large per-device-local Strassen matmul over the 16x16 mesh and
reports scan-corrected HLO flops vs the classical leaf — the paper's matrix-
level contribution measured in the dry-run methodology.

    PYTHONPATH=src python -m benchmarks.strassen_sharded
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core.strassen import strassen_matmul  # noqa: E402
from repro.launch.hlo_cost import PEAK_FLOPS, parse_hlo_cost  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def main() -> None:
    mesh = make_production_mesh()
    n = 16384
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    sh_a = jax.NamedSharding(mesh, P("data", None))
    sh_b = jax.NamedSharding(mesh, P(None, "model"))
    print("name,us_per_call,derived")
    base = None
    for depth in (0, 1, 2):
        def fn(x, y, d=depth):
            return strassen_matmul(x, y, depth=d, align=128)

        with jax.set_mesh(mesh):
            compiled = (
                jax.jit(fn, in_shardings=(sh_a, sh_b)).lower(a, a).compile()
            )
        cost = parse_hlo_cost(compiled.as_text())
        base = base or cost.flops
        t_c = cost.flops / PEAK_FLOPS
        print(
            f"strassen_sharded/depth{depth},0.0,"
            f"flops_per_dev={cost.flops:.4g};t_compute={t_c*1e3:.3f}ms;"
            f"ratio_vs_classical={cost.flops/base:.3f};"
            f"coll_bytes={cost.collective_bytes:.3g}"
        )


if __name__ == "__main__":
    main()
