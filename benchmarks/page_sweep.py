"""Paged-KV-cache sweep: exactness, concurrency-beyond-dense, sharing, tiers.

Drives ``repro.serve`` with ``CacheConfig(layout="paged")`` against the
dense ring layout on identical seeded workloads and records the paged
cache's four claims as machine-independent cells (``BENCH_page.json``):

  * **exact**: at full precision the paged layout is token-for-token
    identical to dense for each architecture family (dense attention, SSM,
    hybrid local-window — the hybrid cell decodes past its window so ring
    wrap + prefix-shared pages force copy-on-write forks mid-run);
  * **concurrency**: with a page pool holding fewer full rows than there
    are slots, admission gating + page-pressure eviction sustain strictly
    more concurrent in-flight requests than a dense layout of the same
    memory could admit at all — tokens still bit-identical;
  * **sharing**: requests with a common prompt prefix attach the same
    physical pages read-only (shared_hits > 0, sharing ratio > 0) and
    still match dense exactly;
  * **tiers**: precision-tiered pages (mantissa truncation of cold pages
    in place).  The open-loop cell demotes at full ladder depth and
    records the measured residual; the budgeted cell must keep the
    residual inside its budget (the closed loop from repro.adapt).

The gate (``check_regression --page-new``) asserts all of the above from
the JSON alone — no wall-clock cells, so it runs identically on any host.

    PYTHONPATH=src python -m benchmarks.page_sweep            # full sweep
    PYTHONPATH=src python -m benchmarks.page_sweep --quick    # CI subset
    PYTHONPATH=src python -m benchmarks.make_experiments_md --write

Emits ``BENCH_page.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import numpy as np

from benchmarks.serve_sweep import build_tiny
from repro.adapt import PageTierPolicy
from repro.configs import get_smoke_config
from repro.core.policy import NATIVE_F32
from repro.models import build_model
from repro.serve import CacheConfig, Request, ServeConfig, ServeEngine

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_page.json")

PAGE_SIZE = 4
#: one arch per KV-state family; the hybrid cell is the only one whose
#: local-window cache (cap = window < max_len) ring-wraps mid-decode, so it
#: is the cell that exercises wrap + COW (the scheduler's budget clamp keeps
#: the global cache from ever wrapping)
EXACT_ARCHS = ("qwen1.5-0.5b", "mamba2-2.7b", "recurrentgemma-9b")
QUICK_ARCHS = ("qwen1.5-0.5b", "recurrentgemma-9b")


def _requests(vocab: int, n: int, prompt_len: int, max_new: int,
              shared_prefix=None) -> list[Request]:
    rng = np.random.default_rng(0)
    out = []
    for i in range(n):
        if shared_prefix is not None:
            prompt = list(shared_prefix) + [i % vocab]
        else:
            prompt = rng.integers(0, vocab, size=prompt_len).tolist()
        out.append(Request(prompt, max_new, rid=i))
    return out


def _run(model, params, reqs, **cfg_kw):
    eng = ServeEngine(model, params, config=ServeConfig(**cfg_kw))
    return eng.generate_batch(reqs), eng


def exact_cell(arch: str) -> dict:
    """Paged vs dense on an identical workload; the hybrid arch decodes past
    its local window so the cell also covers wrap-into-shared-pages COW."""
    cfg, model, params = build_tiny(arch)
    hybrid = arch == "recurrentgemma-9b"
    mk = lambda: _requests(cfg.vocab, n=3, prompt_len=8,
                           max_new=30 if hybrid else 8,
                           shared_prefix=[7] * 8 if hybrid else None)
    max_len = 48 if hybrid else 24
    dense, _ = _run(model, params, mk(), batch_slots=3, max_len=max_len)
    paged, eng = _run(model, params, mk(), batch_slots=3, max_len=max_len,
                      cache=CacheConfig(layout="paged", page_size=PAGE_SIZE))
    s = eng.metrics.summary()["pages"]
    return {
        "arch": arch,
        "requests": len(dense),
        "exact_match": paged == dense,
        "wrap_cow": hybrid,
        "shared_hits": s["shared_hits"],
        "cow_copies": s["cow_copies"],
        "occupancy_peak": s["occupancy_peak"],
    }


def concurrency_cell() -> dict:
    """Pool of 8 pages / 3 pages-per-row = 2 dense-equivalent slots; 4 slots
    and 6 requests must still finish bit-identical, with real evictions and
    peak concurrency above what dense admission could grant."""
    cfg, model, params = build_tiny("qwen1.5-0.5b")
    mk = lambda: _requests(cfg.vocab, n=6, prompt_len=4, max_new=7)
    dense, _ = _run(model, params, mk(), batch_slots=4, max_len=12)
    paged, eng = _run(
        model, params, mk(), batch_slots=4, max_len=12,
        cache=CacheConfig(layout="paged", page_size=PAGE_SIZE, pool_pages=8,
                          prefix_sharing=False))
    s = eng.metrics.summary()
    return {
        "requests": len(dense),
        "exact_match": paged == dense,
        "slots": 4,
        "dense_equiv_slots": s["pages"]["dense_equiv_slots"],
        "peak_active": s["peak_active"],
        "page_evictions": s["pages"]["page_evictions"],
        "preemptions": s["preemptions"],
    }


def sharing_cell() -> dict:
    """Identical prompt prefixes attach the same physical pages."""
    cfg, model, params = build_tiny("qwen1.5-0.5b")
    mk = lambda: _requests(cfg.vocab, n=3, prompt_len=9, max_new=6,
                           shared_prefix=[7] * 8)
    dense, _ = _run(model, params, mk(), batch_slots=3, max_len=20)
    paged, eng = _run(model, params, mk(), batch_slots=3, max_len=20,
                      cache=CacheConfig(layout="paged", page_size=PAGE_SIZE))
    s = eng.metrics.summary()["pages"]
    return {
        "requests": 3,
        "exact_match": paged == dense,
        "shared_hits": s["shared_hits"],
        "sharing_peak": s["sharing_peak"],
    }


def tier_cell(label: str, policy: PageTierPolicy | None) -> dict:
    """One tier-policy endpoint on a long-decode workload: ``off`` must stay
    exact; ``open`` demotes at full depth (the memory-vs-accuracy
    endpoint); ``budgeted`` must hold the measured residual inside its
    budget."""
    cfg, model, params = build_tiny("qwen1.5-0.5b")
    mk = lambda: _requests(cfg.vocab, n=3, prompt_len=8, max_new=12)
    dense, _ = _run(model, params, mk(), batch_slots=3, max_len=28)
    paged, eng = _run(
        model, params, mk(), batch_slots=3, max_len=28,
        cache=CacheConfig(layout="paged", page_size=PAGE_SIZE,
                          tier_policy=policy))
    s = eng.metrics.summary()["pages"]
    changed = sum(1 for rid in dense if paged.get(rid) != dense[rid])
    budget = policy.budget if policy else None
    err = s["tier_err_max"]
    return {
        "label": label,
        "levels": list(policy.levels) if policy else None,
        "budget": budget,
        "exact_match": paged == dense,
        "tokens_changed": changed,
        "requests": len(dense),
        "tier_ticks": s["tier_ticks"],
        "tier_demoted": s["tier_demoted"],
        "tier_promoted": s["tier_promoted"],
        "err_max": err,
        "budget_met": budget is None or (err is not None and err <= budget),
        "tier_mix": s["tier_mix"],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI subset: dense + hybrid exact cells only")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()

    archs = QUICK_ARCHS if args.quick else EXACT_ARCHS
    doc = {
        "host_backend": jax.default_backend(),
        "page_size": PAGE_SIZE,
        "exact": [],
        "tiers": [],
    }
    for arch in archs:
        c = exact_cell(arch)
        doc["exact"].append(c)
        print(f"exact {arch}: match={c['exact_match']} "
              f"cow={c['cow_copies']} hits={c['shared_hits']}")
    c = concurrency_cell()
    doc["concurrency"] = c
    print(f"concurrency: match={c['exact_match']} "
          f"peak_active={c['peak_active']} > dense_equiv="
          f"{c['dense_equiv_slots']} evictions={c['page_evictions']}")
    c = sharing_cell()
    doc["sharing"] = c
    print(f"sharing: match={c['exact_match']} hits={c['shared_hits']} "
          f"peak={c['sharing_peak']:.3f}")
    tiers = [("off", None),
             ("open", PageTierPolicy(levels=(5, 3), cold_after=4, every=2)),
             ("budgeted", PageTierPolicy(levels=(6, 4), cold_after=4,
                                         every=2, budget=0.05))]
    for label, pol in tiers:
        c = tier_cell(label, pol)
        doc["tiers"].append(c)
        err = "-" if c["err_max"] is None else f"{c['err_max']:.2e}"
        print(f"tiers {label}: err_max={err} met={c['budget_met']} "
              f"demoted={c['tier_demoted']} mix={c['tier_mix']}")
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
