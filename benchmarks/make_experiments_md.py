"""Regenerate the EXPERIMENTS.md generated tables: the planner sweep from
BENCH_plan.json (benchmarks/plan_sweep.py), the tuner's measured-vs-modeled
comparison from BENCH_tune.json (benchmarks/tune_sweep.py), the serve sweep
from BENCH_serve.json (benchmarks/serve_sweep.py), the runtime-adaptation
sweep from BENCH_adapt.json (benchmarks/adapt_sweep.py), the tile-kernel
sweep from BENCH_tile.json (benchmarks/tile_sweep.py), the paged-KV-cache
sweep from BENCH_page.json (benchmarks/page_sweep.py) and, when present,
the dry-run + roofline tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.plan_sweep          # produce BENCH_plan.json
    PYTHONPATH=src python -m benchmarks.serve_sweep         # produce BENCH_serve.json
    PYTHONPATH=src python -m benchmarks.make_experiments_md --write
    #   ^ refreshes the generated block of EXPERIMENTS.md in place
    PYTHONPATH=src python -m benchmarks.make_experiments_md --check
    #   ^ exit 1 if the generated block is stale vs the committed BENCH_*.json
    PYTHONPATH=src python -m benchmarks.make_experiments_md > tables.md  # stdout only
"""
from __future__ import annotations

import glob
import json
import os
import sys

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
BENCH_PLAN = os.path.join(os.path.dirname(__file__), "..", "BENCH_plan.json")
BENCH_TUNE = os.path.join(os.path.dirname(__file__), "..", "BENCH_tune.json")
BENCH_SERVE = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
BENCH_ADAPT = os.path.join(os.path.dirname(__file__), "..", "BENCH_adapt.json")
BENCH_SPEC = os.path.join(os.path.dirname(__file__), "..", "BENCH_spec.json")
BENCH_TENANT = os.path.join(os.path.dirname(__file__), "..", "BENCH_tenant.json")
BENCH_TILE = os.path.join(os.path.dirname(__file__), "..", "BENCH_tile.json")
BENCH_PAGE = os.path.join(os.path.dirname(__file__), "..", "BENCH_page.json")
EXPERIMENTS_MD = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
BEGIN_MARK = "<!-- BEGIN GENERATED (benchmarks/make_experiments_md.py) -->"
END_MARK = "<!-- END GENERATED -->"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "qwen1.5-4b", "command-r-plus-104b", "phi3-mini-3.8b", "qwen1.5-0.5b",
    "internvl2-1b", "phi3.5-moe-42b-a6.6b", "kimi-k2-1t-a32b",
    "whisper-medium", "mamba2-2.7b", "recurrentgemma-9b",
]


def load(policy: str = "paper_baseline") -> dict:
    recs = {}
    for path in glob.glob(os.path.join(DRYRUN_DIR, f"*__{policy}.json")):
        with open(path) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"], r["mesh"], r["policy"])] = r
    return recs


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def dryrun_table(recs, policy="paper_baseline") -> list[str]:
    out = ["| arch | shape | mesh | status | compile | args/dev | temp/dev | HLO flops/dev | coll bytes/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("pod16x16", "pod2x16x16"):
                r = recs.get((arch, shape, mesh, policy))
                if r is None:
                    continue
                if r["status"] != "ok":
                    reason = r.get("reason", r.get("error", ""))[:60]
                    out.append(f"| {arch} | {shape} | {mesh} | {r['status']}: {reason} | - | - | - | - | - |")
                    continue
                rl = r["roofline"]
                out.append(
                    f"| {arch} | {shape} | {mesh} | ok | {r['t_compile_s']}s "
                    f"| {fmt_bytes(r['memory']['argument_bytes'])} "
                    f"| {fmt_bytes(r['memory']['temp_bytes'])} "
                    f"| {rl['flops_per_device']:.3g} "
                    f"| {rl['collective_bytes_per_device']:.3g} |"
                )
    return out


def roofline_table(recs, policy="paper_baseline") -> list[str]:
    out = ["| arch | shape | t_compute | t_memory | t_collective | dominant | MODEL_FLOPS | useful-ratio | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, "pod16x16", policy))
            if r is None:
                continue
            if r["status"] == "n/a":
                out.append(f"| {arch} | {shape} | - | - | - | - | - | - | {r['reason'][:50]} |")
                continue
            if r["status"] != "ok":
                out.append(f"| {arch} | {shape} | FAIL | | | | | | |")
                continue
            rl = r["roofline"]
            note = _move_note(r)
            out.append(
                f"| {arch} | {shape} | {fmt_s(rl['t_compute_s'])} | {fmt_s(rl['t_memory_s'])} "
                f"| {fmt_s(rl['t_collective_s'])} | **{rl['dominant']}** "
                f"| {r['model_flops_global']:.3g} | {r['useful_flops_ratio']:.3f} | {note} |"
            )
    return out


def _move_note(r) -> str:
    dom = r["roofline"]["dominant"]
    if dom == "compute":
        return "fewer limb passes (policy) or Strassen depth"
    if dom == "memory":
        return "fused limb extraction (Pallas) / bf16 residuals"
    return "grad compression / EP-local dispatch / larger per-pod batch"


# --------------------------------------------------------------------------
# Planner sweep tables (BENCH_plan.json, benchmarks/plan_sweep.py)
# --------------------------------------------------------------------------


def load_bench_plan(path: str = BENCH_PLAN) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def plan_measured_table(doc: dict) -> list[str]:
    out = ["| n | impl | mode | depth | wall | rel err | est t | dominant |",
           "|---|---|---|---|---|---|---|---|"]
    for r in doc.get("measured", []):
        out.append(
            f"| {r['n']} | {r['impl']} | {r['mode']} | {r['depth']} "
            f"| {fmt_s(r['wall_us'] * 1e-6)} | {r['rel_err']:.1e} "
            f"| {fmt_s(r['est_t_us'] * 1e-6)} | {r['est_dominant']} |"
        )
    return out


def plan_selection_table(doc: dict) -> list[str]:
    out = ["| backend | n | accuracy | mode | impl | depth | est t | bound |",
           "|---|---|---|---|---|---|---|---|"]
    for backend, recs in doc.get("planner", {}).items():
        for r in recs:
            out.append(
                f"| {backend} | {r['n']} | {r['accuracy']:.1e} | {r['mode']} "
                f"| {r['impl']} | {r['depth']} | {fmt_s(r['est_t_us'] * 1e-6)} "
                f"| {r['dominant']} |"
            )
    return out


# --------------------------------------------------------------------------
# Tuner tables (BENCH_tune.json, benchmarks/tune_sweep.py)
# --------------------------------------------------------------------------


def load_bench_tune(path: str = BENCH_TUNE) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _fmt_pick(p: dict) -> str:
    blk = ""
    if p.get("block"):
        blk = " b" + "x".join(str(x) for x in p["block"])
    return f"{p['mode']}/{p['impl']}/d{p['depth']}{blk}"


def tune_comparison_table(doc: dict) -> list[str]:
    out = ["| n | accuracy | modeled pick | modeled t | tuned pick | tuned t | source | agree |",
           "|---|---|---|---|---|---|---|---|"]
    for r in doc.get("comparison", []):
        mo, tu = r["modeled"], r["tuned"]
        out.append(
            f"| {r['n']} | {r['accuracy']:.1e} | {_fmt_pick(mo)} "
            f"| {fmt_s(mo['t_us'] * 1e-6)} | {_fmt_pick(tu)} "
            f"| {fmt_s(tu['t_us'] * 1e-6)} | {tu['source']} "
            f"| {'yes' if r['agree'] else '**no**'} |"
        )
    return out


def tune_section() -> list[str]:
    doc = load_bench_tune()
    if doc is None:
        return ["### Measured vs modeled\n",
                "_BENCH_tune.json not found — run "
                "`python -m benchmarks.tune_sweep` first._\n"]
    bal = doc["balance"]
    n_disagree = sum(1 for r in doc.get("comparison", []) if not r["agree"])
    parts = [
        f"### Measured vs modeled (BENCH_tune.json, host={doc['host_backend']}, "
        f"table={doc['table_backend']}@{doc['table_fingerprint'][:8]}, "
        f"{doc['n_records']} records)\n",
        "Autotuner (`repro.tune`) measurements vs the static roofline: what "
        "`plan_matmul` picks pure-roofline vs pointed at the measured table "
        f"({n_disagree} disagreement(s) — the cells the roofline gets wrong "
        "on this host).  Fitted machine balance "
        f"peak={bal['fitted_peak_flops']:.3g} FLOP/s, "
        f"bw={bal['fitted_hbm_bw']:.3g} B/s "
        f"(hand-entered defaults: {bal['default_peak_flops']:.3g} / "
        f"{bal['default_hbm_bw']:.3g}):\n",
        "\n".join(tune_comparison_table(doc)),
        "",
    ]
    return parts


def load_bench_serve(path: str = BENCH_SERVE) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def serve_table(doc: dict) -> list[str]:
    out = ["| slots | accuracy | modes (prefill/decode) | tok/s | TTFT | latency | occupancy | steps | switches | mode occupancy |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in doc.get("cells", []):
        acc = f"{r['accuracy']:.1e}" if r["accuracy"] else "unplanned"
        mocc = " ".join(f"{m}:{f:.2f}"
                        for m, f in r.get("mode_occupancy", {}).items()) or "-"
        out.append(
            f"| {r['slots']} | {acc} | {r['mode_prefill']}/{r['mode_decode']} "
            f"| {r['tok_s']:.1f} | {fmt_s(r['ttft_mean_s'])} "
            f"| {fmt_s(r['latency_mean_s'])} | {r['occupancy']:.2f} "
            f"| {r['decode_steps']} | {r.get('mode_switches', 0)} | {mocc} |"
        )
    return out


def serve_section() -> list[str]:
    doc = load_bench_serve()
    if doc is None:
        return ["### Serve sweep\n",
                "_BENCH_serve.json not found — run "
                "`python -m benchmarks.serve_sweep` first._\n"]
    parts = [
        f"### Serve sweep (BENCH_serve.json, host={doc['host_backend']}, "
        f"arch={doc['arch']}, {doc['requests']} ragged requests)\n",
        "Continuous-batching engine (`repro.serve`): throughput / TTFT / "
        "slot occupancy vs (slots x accuracy); modes column shows the "
        "per-phase planned RMPM mode (prefill vs decode — the run-time "
        "reconfiguration inside one workload):\n",
        "\n".join(serve_table(doc)),
        "",
    ]
    return parts


def load_bench_adapt(path: str = BENCH_ADAPT) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def adapt_table(doc: dict) -> list[str]:
    out = ["| slo (max err) | run | tok/s | err mean | err max | SLO hit rate | switches | mode occupancy |",
           "|---|---|---|---|---|---|---|---|"]
    for r in doc.get("cells", []):
        mocc = " ".join(f"{m}:{f:.2f}"
                        for m, f in r.get("mode_occupancy", {}).items()) or "-"
        hit = (f"{r['slo_hit_rate']:.2f}" if r.get("slo_hit_rate") is not None
               else "-")
        meets = "yes" if r.get("meets_slo") else "**no**"
        out.append(
            f"| {r['slo_err']:g} | {r['label']} | {r['tok_s']:.1f} "
            f"| {r['err_mean']:.3g} | {r['err_max']:.3g} | {hit} ({meets}) "
            f"| {r['mode_switches']} | {mocc} |"
        )
    return out


def adapt_section() -> list[str]:
    doc = load_bench_adapt()
    if doc is None:
        return ["### Adapt sweep\n",
                "_BENCH_adapt.json not found — run "
                "`python -m benchmarks.adapt_sweep` first._\n"]
    return [
        f"### Adapt sweep (BENCH_adapt.json, host={doc['host_backend']}, "
        f"{doc['requests']} requests over normal/hot/normal phases)\n",
        "Closed-loop runtime precision adaptation (`repro.adapt`) vs the "
        "static plans on the conditioned workload: the adapted run starts "
        "at the cheap plan's modes, shifts up for the ill-conditioned "
        "burst and back down after — inside one compiled step:\n",
        "\n".join(adapt_table(doc)),
        "",
    ]


def load_bench_spec(path: str = BENCH_SPEC) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def spec_table(doc: dict) -> list[str]:
    out = ["| k | draft shift | accuracy | exact | acceptance | verify-steps/token | spec tok/s | baseline tok/s | shift moves |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in doc.get("cells", []):
        acc = f"{r['accuracy']:.1e}" if r["accuracy"] else "unplanned"
        shift = (f"adaptive ({r['final_draft_shift']})"
                 if r.get("adaptive_shift") else str(r["draft_shift"]))
        rate = (f"{r['acceptance_rate']:.2f}"
                if r.get("acceptance_rate") is not None else "-")
        vspt = (f"{r['verify_steps_per_token']:.2f}"
                if r.get("verify_steps_per_token") is not None else "-")
        out.append(
            f"| {r['k']} | {shift} | {acc} "
            f"| {'yes' if r['exact_match'] else '**no**'} | {rate} | {vspt} "
            f"| {r['tok_s']:.1f} | {r['baseline_tok_s']:.1f} "
            f"| {r.get('draft_shift_moves', 0)} |"
        )
    return out


def spec_section() -> list[str]:
    doc = load_bench_spec()
    if doc is None:
        return ["### Spec sweep\n",
                "_BENCH_spec.json not found — run "
                "`python -m benchmarks.spec_sweep` first._\n"]
    return [
        f"### Spec sweep (BENCH_spec.json, host={doc['host_backend']}, "
        f"arch={doc['arch']}, {doc['requests']} ragged requests)\n",
        "Self-speculative decoding (`repro.spec`): the cheap mode of the "
        "same compiled step drafts k tokens, the exact baseline step "
        "verifies — outputs stay token-identical while expensive-mode "
        "verify steps per emitted token drop below 1:\n",
        "\n".join(spec_table(doc)),
        "",
    ]


def load_bench_tenant(path: str = BENCH_TENANT) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def tenant_table(doc: dict) -> list[str]:
    out = ["| arch | policy | tenant | done | attainment | p50 | p99 | share (entitled) | preempts | exact |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for c in doc.get("cells", []):
        exact = ("yes" if c.get("all_exact")
                 else f"**{c.get('n_exact')}/{c.get('requests')}**")
        for name in sorted(c.get("tenants", {})):
            t = c["tenants"][name]
            att = (f"{t['attainment']:.0%}" if t["attainment"] is not None
                   else "-")
            out.append(
                f"| {c['arch']} | {c['policy']} | {name} "
                f"| {t['completed']}/{t['submitted']} | {att} "
                f"| {fmt_s(t['latency_p50_s'])} | {fmt_s(t['latency_p99_s'])} "
                f"| {t['slot_share']:.2f} ({t['entitlement']:.2f}) "
                f"| {t['preemptions']} | {exact} |"
            )
    return out


def tenant_section() -> list[str]:
    doc = load_bench_tenant()
    if doc is None:
        return ["### Tenant sweep\n",
                "_BENCH_tenant.json not found — run "
                "`python -m benchmarks.tenant_sweep` first._\n"]
    hp = doc.get("high_priority_tenant", "interactive")
    return [
        f"### Tenant sweep (BENCH_tenant.json, host={doc['host_backend']}, "
        f"{doc['slots']} slots, seeded Poisson arrivals)\n",
        "Multi-tenant scheduling (`repro.serve` tenancy): identical mixed "
        "traffic — bulk batch decodes flooding the slots first, then "
        "interactive chat and audio-length prompts with step-unit deadlines "
        "— under pure FIFO vs the priority+EDF+aging scheduler.  Deadlines "
        "and attainment are measured in engine steps (machine-independent); "
        f"the gate requires the `{hp}` tenant's attainment to beat FIFO "
        "while every request stays bit-identical to its solo run "
        "(preemption parks and resumes exact state rows):\n",
        "\n".join(tenant_table(doc)),
        "",
    ]


def load_bench_tile(path: str = BENCH_TILE) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def tile_table(doc: dict) -> list[str]:
    out = ["| n | cell | detail | dispatch | cost | tile wall | ref wall |",
           "|---|---|---|---|---|---|---|"]
    for c in doc.get("cells", []):
        if c["kind"] == "uniform":
            eq = "bitwise" if c["bitwise_equal"] else "**diverged**"
            out.append(
                f"| {c['n']} | uniform {c['mode']} | {eq} vs pallas | 1 fused "
                f"| = | {fmt_s(c['tile_wall_us'] * 1e-6)} "
                f"| {fmt_s(c['pallas_wall_us'] * 1e-6)} |"
            )
        elif c["kind"] == "runtime":
            eq = "bitwise" if c["modes_equal_switch"] else "**diverged**"
            disp = (f"{c['tile_pallas_calls']} fused / "
                    f"{c['tile_switches']} switch "
                    f"(vs {c['switch_switches']}x"
                    f"{c['switch_pallas_calls']} branches)")
            out.append(
                f"| {c['n']} | runtime mode | {eq}, "
                f"compile x{c['tile_compile_count']} | {disp} | = "
                f"| {fmt_s(c['tile_wall_us'] * 1e-6)} "
                f"| {fmt_s(c['switch_wall_us'] * 1e-6)} |"
            )
        elif c["kind"] == "magnitude":
            hist = " ".join(f"{m}:{n}" for m, n in c["mode_histogram"].items())
            met = "yes" if c["budget_met"] else "**no**"
            out.append(
                f"| {c['n']} | magnitude map | {hist}, "
                f"err/S={c['rel_err_vs_envelope']:.1e} (met: {met}) "
                f"| 1 fused | passes x{c['pass_ratio']:.2f} "
                f"| {fmt_s(c['tile_wall_us'] * 1e-6)} "
                f"| {fmt_s(c['uniform_max_wall_us'] * 1e-6)} |"
            )
    return out


def tile_section() -> list[str]:
    doc = load_bench_tile()
    if doc is None:
        return ["### Tile sweep\n",
                "_BENCH_tile.json not found — run "
                "`python -m benchmarks.tile_sweep` first._\n"]
    blk = "x".join(str(x) for x in doc.get("block", []))
    return [
        f"### Tile sweep (BENCH_tile.json, host={doc['host_backend']}, "
        f"block={blk}, budget={doc['budget']:.1e})\n",
        "Partitioned-SIMD tile kernel (`repro.kernels.tile_matmul`): one "
        "fused dispatch reads a per-tile mode map instead of branching "
        "through `lax.switch` — uniform maps stay bitwise-equal to the "
        "pallas kernel, runtime mode changes hit one compiled executable, "
        "and the magnitude map spends expensive limbs only on hot tiles "
        "(`cost` = MXU passes vs uniform-max; ref wall = the switch-path / "
        "forced-expensive equivalent):\n",
        "\n".join(tile_table(doc)),
        "",
    ]


def load_bench_page(path: str = BENCH_PAGE) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def page_table(doc: dict) -> list[str]:
    out = ["| cell | exact | detail |",
           "|---|---|---|"]
    for c in doc.get("exact", []):
        wrap = " +wrap/COW" if c.get("wrap_cow") else ""
        out.append(
            f"| exact {c['arch']}{wrap} "
            f"| {'yes' if c['exact_match'] else '**no**'} "
            f"| shared_hits={c['shared_hits']} cow={c['cow_copies']} "
            f"occ_peak={c['occupancy_peak']:.2f} |")
    c = doc.get("concurrency")
    if c:
        out.append(
            f"| concurrency | {'yes' if c['exact_match'] else '**no**'} "
            f"| peak_active={c['peak_active']} > "
            f"dense_equiv={c['dense_equiv_slots']} "
            f"({c['slots']} slots), evictions={c['page_evictions']} |")
    c = doc.get("sharing")
    if c:
        out.append(
            f"| sharing | {'yes' if c['exact_match'] else '**no**'} "
            f"| shared_hits={c['shared_hits']} "
            f"peak_ratio={c['sharing_peak']:.2f} |")
    for c in doc.get("tiers", []):
        err = "-" if c["err_max"] is None else f"{c['err_max']:.1e}"
        bud = "-" if c["budget"] is None else f"{c['budget']:.1e}"
        exact = ("yes" if c["exact_match"]
                 else f"{c['tokens_changed']}/{c['requests']} changed")
        out.append(
            f"| tiers {c['label']} | {exact} "
            f"| levels={c['levels']} err_max={err} budget={bud} "
            f"(met: {'yes' if c['budget_met'] else '**no**'}) "
            f"demoted={c['tier_demoted']} mix={c['tier_mix']} |")
    return out


def page_section() -> list[str]:
    doc = load_bench_page()
    if doc is None:
        return ["### Page sweep\n",
                "_BENCH_page.json not found — run "
                "`python -m benchmarks.page_sweep` first._\n"]
    return [
        f"### Page sweep (BENCH_page.json, host={doc['host_backend']}, "
        f"page_size={doc['page_size']})\n",
        "Paged KV cache (`repro.serve.paged`): page-table pools with "
        "admission gating, page-pressure eviction, prompt-prefix sharing "
        "(copy-on-write forks) and precision-tiered cold pages.  At full "
        "precision every cell is token-identical to the dense ring layout "
        "— the hybrid cell decodes past its local window so ring wrap "
        "forces COW mid-run — while a pool smaller than the slot array "
        "sustains more in-flight requests than dense admission allows:\n",
        "\n".join(page_table(doc)),
        "",
    ]


BENCH_OBS = os.path.join(os.path.dirname(__file__), "..", "BENCH_obs.json")


def load_bench_obs(path: str = BENCH_OBS) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def obs_table(doc: dict) -> list[str]:
    out = ["| cell | tokens equal | compiles (decode/spec) | overhead "
           "| events | chrome | replay |",
           "|---|---|---|---|---|---|---|"]
    for c in doc.get("cells", []):
        comp = "/".join("-" if v is None else str(v)
                        for v in c["compiles_traced"])
        comp_ok = "" if c["compiles_equal"] else " (**≠ untraced**)"
        out.append(
            f"| {c['cell']} "
            f"| {'yes' if c['tokens_equal'] else '**no**'} "
            f"| {comp}{comp_ok} "
            f"| {c['overhead_ratio']:.3f}x "
            f"| {c['n_events']} ({c['dropped']} dropped) "
            f"| {'valid' if c['chrome_valid'] else '**invalid**'} "
            f"| {'ok' if c['replay_ok'] else '**fail**'} |")
    return out


def obs_section() -> list[str]:
    doc = load_bench_obs()
    if doc is None:
        return ["### Obs sweep\n",
                "_BENCH_obs.json not found — run "
                "`python -m benchmarks.obs_sweep` first._\n"]
    return [
        f"### Obs sweep (BENCH_obs.json, host={doc['host_backend']}, "
        f"median overhead {doc['overhead_ratio_median']:.3f}x)\n",
        "Tracing (`repro.obs`): each serving configuration runs untraced "
        "(NULL_TRACER) and traced on identical workloads.  The traced arm "
        "must emit bit-identical tokens with identical compile counts "
        "(tracing is host-side only — nothing reaches jit), and its event "
        "stream must be lossless, export a schema-valid Chrome trace, and "
        "replay through the scheduler invariant harness "
        "(tests/scheduler_model.py consumer mode).  Overhead is the "
        "median of per-rep paired wall ratios, gated at 1.05x:\n",
        "\n".join(obs_table(doc)),
        "",
    ]


def generated_sections() -> str:
    parts: list[str] = []
    doc = load_bench_plan()
    if doc is not None:
        parts.append(
            f"### Plan sweep (BENCH_plan.json, host={doc['host_backend']}, "
            f"sizes={list(doc['sizes'])})\n"
        )
        if doc.get("measured"):
            parts.append("Measured (size x mode x depth x impl), wall-clock on "
                         "this host vs cost-model estimate:\n")
            parts.append("\n".join(plan_measured_table(doc)))
            parts.append("")
        parts.append("Planner selections (what `plan_matmul` picks per "
                      "(backend, size, accuracy)):\n")
        parts.append("\n".join(plan_selection_table(doc)))
        parts.append("")
    else:
        parts.append("### Plan sweep\n")
        parts.append("_BENCH_plan.json not found — run "
                     "`python -m benchmarks.plan_sweep` first._\n")
    parts.extend(tune_section())
    parts.extend(serve_section())
    parts.extend(adapt_section())
    parts.extend(spec_section())
    parts.extend(tenant_section())
    parts.extend(tile_section())
    parts.extend(page_section())
    parts.extend(obs_section())
    recs = load("paper_baseline")
    if recs:
        n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
        n_na = sum(1 for r in recs.values() if r["status"] == "n/a")
        n_fail = len(recs) - n_ok - n_na
        parts.append(f"### Dry-run sweep (paper_baseline): {n_ok} ok / "
                     f"{n_na} n-a / {n_fail} fail\n")
        parts.append("\n".join(dryrun_table(recs)))
        parts.append("\n### Roofline (single-pod 16x16, paper_baseline)\n")
        parts.append("\n".join(roofline_table(recs)))
    else:
        parts.append("### Dry-run sweep\n")
        parts.append("_experiments/dryrun/ is empty — run "
                     "`python -m repro.launch.dryrun --all` on a machine with "
                     "spare RAM to populate the roofline tables._")
    return "\n".join(parts).rstrip() + "\n"


def _rendered(path: str = EXPERIMENTS_MD) -> tuple[str, str]:
    """(current file text, text with a freshly generated block)."""
    with open(path) as f:
        text = f.read()
    if BEGIN_MARK not in text or END_MARK not in text:
        raise SystemExit(f"{path} has no generated-block markers")
    head, rest = text.split(BEGIN_MARK, 1)
    _, tail = rest.split(END_MARK, 1)
    new = head + BEGIN_MARK + "\n" + generated_sections() + END_MARK + tail
    return text, new


def write_experiments_md(path: str = EXPERIMENTS_MD) -> None:
    """Replace the marked generated block of EXPERIMENTS.md in place."""
    _, new = _rendered(path)
    with open(path, "w") as f:
        f.write(new)
    print(f"refreshed generated block of {path}")


def check_experiments_md(path: str = EXPERIMENTS_MD) -> bool:
    """True iff the generated block matches the committed BENCH_*.json —
    the CI docs-drift gate (exit 1 via main when stale)."""
    current, fresh = _rendered(path)
    if current == fresh:
        print(f"{path} generated block is up to date")
        return True
    cur_lines = current.splitlines()
    new_lines = fresh.splitlines()
    n_diff = sum(1 for a, b in zip(cur_lines, new_lines) if a != b)
    n_diff += abs(len(cur_lines) - len(new_lines))
    print(
        f"{path} generated block is STALE ({n_diff} line(s) differ): run "
        "`python -m benchmarks.make_experiments_md --write` and commit"
    )
    return False


def main() -> None:
    argv = [a for a in sys.argv[1:]]
    if "--write" in argv:
        write_experiments_md()
        return
    if "--check" in argv:
        sys.exit(0 if check_experiments_md() else 1)
    policy = argv[0] if argv else "paper_baseline"
    doc = load_bench_plan()
    if doc is not None:
        print(f"### Plan sweep (host={doc['host_backend']})\n")
        if doc.get("measured"):
            print("\n".join(plan_measured_table(doc)) + "\n")
        print("\n".join(plan_selection_table(doc)) + "\n")
    print("\n".join(tune_section()) + "\n")
    print("\n".join(serve_section()) + "\n")
    print("\n".join(adapt_section()) + "\n")
    print("\n".join(spec_section()) + "\n")
    print("\n".join(tenant_section()) + "\n")
    print("\n".join(tile_section()) + "\n")
    recs = load(policy)
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_na = sum(1 for r in recs.values() if r["status"] == "n/a")
    n_fail = len(recs) - n_ok - n_na
    print(f"### Dry-run sweep ({policy}): {n_ok} ok / {n_na} n-a / {n_fail} fail\n")
    print("\n".join(dryrun_table(recs, policy)))
    print(f"\n### Roofline (single-pod 16x16, {policy})\n")
    print("\n".join(roofline_table(recs, policy)))


if __name__ == "__main__":
    main()
