"""Regenerate the EXPERIMENTS.md dry-run + roofline tables from
experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.make_experiments_md > EXPERIMENTS.tables.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "qwen1.5-4b", "command-r-plus-104b", "phi3-mini-3.8b", "qwen1.5-0.5b",
    "internvl2-1b", "phi3.5-moe-42b-a6.6b", "kimi-k2-1t-a32b",
    "whisper-medium", "mamba2-2.7b", "recurrentgemma-9b",
]


def load(policy: str = "paper_baseline") -> dict:
    recs = {}
    for path in glob.glob(os.path.join(DRYRUN_DIR, f"*__{policy}.json")):
        with open(path) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"], r["mesh"], r["policy"])] = r
    return recs


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def dryrun_table(recs, policy="paper_baseline") -> list[str]:
    out = ["| arch | shape | mesh | status | compile | args/dev | temp/dev | HLO flops/dev | coll bytes/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("pod16x16", "pod2x16x16"):
                r = recs.get((arch, shape, mesh, policy))
                if r is None:
                    continue
                if r["status"] != "ok":
                    reason = r.get("reason", r.get("error", ""))[:60]
                    out.append(f"| {arch} | {shape} | {mesh} | {r['status']}: {reason} | - | - | - | - | - |")
                    continue
                rl = r["roofline"]
                out.append(
                    f"| {arch} | {shape} | {mesh} | ok | {r['t_compile_s']}s "
                    f"| {fmt_bytes(r['memory']['argument_bytes'])} "
                    f"| {fmt_bytes(r['memory']['temp_bytes'])} "
                    f"| {rl['flops_per_device']:.3g} "
                    f"| {rl['collective_bytes_per_device']:.3g} |"
                )
    return out


def roofline_table(recs, policy="paper_baseline") -> list[str]:
    out = ["| arch | shape | t_compute | t_memory | t_collective | dominant | MODEL_FLOPS | useful-ratio | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, "pod16x16", policy))
            if r is None:
                continue
            if r["status"] == "n/a":
                out.append(f"| {arch} | {shape} | - | - | - | - | - | - | {r['reason'][:50]} |")
                continue
            if r["status"] != "ok":
                out.append(f"| {arch} | {shape} | FAIL | | | | | | |")
                continue
            rl = r["roofline"]
            note = _move_note(r)
            out.append(
                f"| {arch} | {shape} | {fmt_s(rl['t_compute_s'])} | {fmt_s(rl['t_memory_s'])} "
                f"| {fmt_s(rl['t_collective_s'])} | **{rl['dominant']}** "
                f"| {r['model_flops_global']:.3g} | {r['useful_flops_ratio']:.3f} | {note} |"
            )
    return out


def _move_note(r) -> str:
    dom = r["roofline"]["dominant"]
    if dom == "compute":
        return "fewer limb passes (policy) or Strassen depth"
    if dom == "memory":
        return "fused limb extraction (Pallas) / bf16 residuals"
    return "grad compression / EP-local dispatch / larger per-pod batch"


def main() -> None:
    policy = sys.argv[1] if len(sys.argv) > 1 else "paper_baseline"
    recs = load(policy)
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_na = sum(1 for r in recs.values() if r["status"] == "n/a")
    n_fail = len(recs) - n_ok - n_na
    print(f"### Dry-run sweep ({policy}): {n_ok} ok / {n_na} n-a / {n_fail} fail\n")
    print("\n".join(dryrun_table(recs, policy)))
    print(f"\n### Roofline (single-pod 16x16, {policy})\n")
    print("\n".join(roofline_table(recs, policy)))


if __name__ == "__main__":
    main()
