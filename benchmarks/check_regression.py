"""CI perf-regression gate: fresh sweep results vs committed BENCH baselines.

Compares a freshly generated ``BENCH_plan.json`` / ``BENCH_serve.json``
against the committed baselines and fails (exit 1) when any overlapping
cell regresses by more than the tolerance.  Three comparison layers, by
noise profile:

* **plan selections** (``--plan-mode selections``, the CI default): the
  planner's estimated time for its own pick per (backend, size, accuracy)
  cell.  Deterministic — a regression here means the planner or cost model
  got worse, not that the runner was busy — so the 25% tolerance is exact.
* **serve throughput** (tok/s per (slots, accuracy) cell): a multi-second
  aggregate over thousands of decode steps; stable enough on shared
  runners to gate wall clock at 25%.
* **plan measured** (``--plan-mode measured``): per-cell kernel
  microbenchmarks (~ms).  Too contention-sensitive for hosted CI at tight
  tolerances — meant for same-machine, before/after comparisons (pair with
  ``plan_sweep --stat min``).
* **page** (``--page-new``): machine-independent *semantic* invariants of
  the paged-KV-cache sweep (paged bit-identical to dense at full precision
  including the ring-wrap/COW cell, strictly more in-flight concurrency
  than the dense-equivalent pool admits, prefix pages shared, tiered
  residual inside its budget) — no wall-clock cells at all.
* **obs** (``--obs-new``): machine-independent *semantic* invariants of
  the observability sweep (traced arms emit bit-identical tokens with
  identical compile counts, traces are lossless + Chrome-schema-valid +
  replayable through the scheduler invariant harness, median steady-state
  overhead under 5%) — the only wall-clock number is the overhead *ratio*
  of two arms on the same host, so it travels.
* **adapt** (``--adapt-new``): machine-independent *semantic* invariants of
  the runtime-adaptation sweep (adapted meets its SLO, the cheap static
  plan violates it, reconfiguration happened with zero recompiles) — the
  CI layer.  The serve-style normalized tok/s ratio gate
  (``--adapt-baseline``) is same-machine only: adapt cells are sub-second
  spans that swing ~2x between identical runs on a busy host (the Cell G
  finding again).

CI runners are not the machine the baselines were measured on, so
wall-clock comparisons are **normalized**: each cell's cost ratio
``new / baseline`` is computed (cost = 1/tok_s for serve, wall for plan
measured), the median ratio is the machine-speed factor, and a cell
regresses when its ratio exceeds ``median * (1 + tolerance)``.  A
uniformly slower machine passes; a *relative* regression survives
normalization.  ``--absolute`` disables normalization (same-machine use).

    python -m benchmarks.check_regression \\
        --plan-baseline BENCH_plan.json --plan-new /tmp/BENCH_plan.json \\
        --serve-baseline BENCH_serve.json --serve-new /tmp/BENCH_serve.json \\
        --tolerance 0.25
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def plan_cells(doc: dict) -> dict[tuple, float]:
    """Measured plan-sweep cells -> wall_us, keyed (n, impl, mode, depth)."""
    return {
        (r["n"], r["impl"], r["mode"], r["depth"]): float(r["wall_us"])
        for r in doc.get("measured", [])
        if r.get("wall_us", 0) > 0
    }


def plan_selection_cells(doc: dict) -> dict[tuple, float]:
    """Planner-selection cells -> the pick's estimated time in us, keyed
    (backend, n, accuracy).  Deterministic model output: any drift is a
    code change, not machine noise."""
    out = {}
    for backend, recs in doc.get("planner", {}).items():
        for r in recs:
            out[(backend, r["n"], f"{r['accuracy']:.3e}")] = float(r["est_t_us"])
    return out


def serve_cells(doc: dict) -> dict[tuple, float]:
    """Serve-sweep cells -> seconds-per-token, keyed (slots, accuracy)."""
    out = {}
    for c in doc.get("cells", []):
        if c.get("tok_s", 0) <= 0:
            continue
        acc = "unplanned" if c["accuracy"] is None else f"{c['accuracy']:.3e}"
        out[(c["slots"], acc)] = 1.0 / float(c["tok_s"])
    return out


def adapt_cells(doc: dict) -> dict[tuple, float]:
    """Adapt-sweep cells -> seconds-per-token, keyed (label, slo)."""
    return {
        (c["label"], f"{c['slo_err']:.3e}"): 1.0 / float(c["tok_s"])
        for c in doc.get("cells", [])
        if c.get("tok_s", 0) > 0
    }


def adapt_semantics(doc: dict, *, check_throughput: bool = False) -> list[str]:
    """Machine-independent invariants of a fresh BENCH_adapt.json — the
    run-time-reconfiguration claim itself, not a wall-clock ratio:

      * the adapted run meets every SLO it was given (probe hit rate);
      * the cheapest static plan violates at least one of those SLOs
        (otherwise the sweep isn't exercising the adaptation at all);
      * the adapted run actually reconfigured (mode switches > 0) inside a
        single compiled step.

    ``check_throughput`` adds "adapted tok/s >= 0.9x static-safe" (the loop
    must not cost more than just planning safe statically).  That one IS a
    wall-clock comparison of sub-second spans, so it is same-machine only
    (baseline-generation time, ``--adapt-strict``) — on hosted CI it is
    reported as a warning, not a failure.

    Returns a list of violation strings (empty = pass).
    """
    problems = []
    by_slo: dict[float, dict[str, dict]] = {}
    for c in doc.get("cells", []):
        by_slo.setdefault(c["slo_err"], {})[c["label"]] = c
    if not by_slo:
        return ["no adapt cells found"]
    cheap_violates_somewhere = False
    for slo, cells in sorted(by_slo.items()):
        adapted = cells.get("adapted")
        cheap = cells.get("static-cheap")
        safe = cells.get("static-safe")
        if adapted is None:
            problems.append(f"slo={slo}: no adapted cell")
            continue
        if not adapted.get("meets_slo"):
            problems.append(
                f"slo={slo}: adapted run misses the SLO "
                f"(hit rate {adapted.get('slo_hit_rate')})")
        if adapted.get("mode_switches", 0) < 1:
            problems.append(f"slo={slo}: adapted run never reconfigured")
        if adapted.get("compiled_steps") not in (None, 1):
            problems.append(
                f"slo={slo}: adapted run recompiled "
                f"({adapted['compiled_steps']} step variants)")
        if cheap is not None and not cheap.get("meets_slo"):
            cheap_violates_somewhere = True
        if safe is not None and adapted["tok_s"] < 0.9 * safe["tok_s"]:
            msg = (f"slo={slo}: adapted {adapted['tok_s']} tok/s fell below "
                   f"static-safe {safe['tok_s']} tok/s")
            if check_throughput:
                problems.append(msg)
            else:
                print(f"adapt (semantics): WARN {msg} (wall-clock; gate "
                      "with --adapt-strict on a quiet same machine)")
    if not cheap_violates_somewhere:
        problems.append(
            "static-cheap meets every SLO in the sweep: the workload is not "
            "exercising adaptation")
    return problems


def tenant_semantics(doc: dict) -> list[str]:
    """Machine-independent invariants of a fresh BENCH_tenant.json — the
    multi-tenant scheduling claims, all measured in engine steps (never
    wall clock), so they gate identically on any host:

      * every cell's outputs are bit-identical to each request's solo run
        (``all_exact``) — scheduling, preemption and resume must never
        change tokens;
      * every submitted request completed in every cell (no starvation
        under either policy — aging must make the priority policy drain);
      * per arch, the high-priority tenant's SLO attainment under the
        priority policy is >= its FIFO attainment, and strictly better in
        at least one arch (otherwise the scheduler buys nothing);
      * the priority cells actually preempted somewhere (the contention in
        the workload is real, not vacuously satisfied).

    Returns a list of violation strings (empty = pass).
    """
    problems = []
    cells = doc.get("cells", [])
    if not cells:
        return ["no tenant cells found"]
    hp = doc.get("high_priority_tenant", "interactive")
    by_arch: dict[str, dict[str, dict]] = {}
    for c in cells:
        key = f"{c.get('arch')}/{c.get('policy')}"
        if not c.get("all_exact"):
            problems.append(
                f"{key}: outputs diverged from solo runs "
                f"({c.get('n_exact')}/{c.get('requests')} exact)")
        if c.get("completed") != c.get("requests"):
            problems.append(
                f"{key}: {c.get('completed')}/{c.get('requests')} completed "
                "(starvation)")
        by_arch.setdefault(c.get("arch"), {})[c.get("policy")] = c
    strictly_better = False
    any_preempt = False
    for arch, pols in sorted(by_arch.items()):
        fifo, prio = pols.get("fifo"), pols.get("priority")
        if fifo is None or prio is None:
            problems.append(f"{arch}: missing a policy cell")
            continue
        any_preempt |= prio.get("preemptions", 0) > 0
        att_f = (fifo.get("tenants", {}).get(hp) or {}).get("attainment")
        att_p = (prio.get("tenants", {}).get(hp) or {}).get("attainment")
        if att_f is None or att_p is None:
            problems.append(f"{arch}: no {hp} attainment measured")
            continue
        if att_p < att_f:
            problems.append(
                f"{arch}: priority attainment {att_p} below FIFO {att_f} "
                f"for {hp}")
        elif att_p > att_f:
            strictly_better = True
    if not strictly_better:
        problems.append(
            f"{hp} attainment never strictly beat FIFO: the workload is not "
            "exercising the priority scheduler")
    if not any_preempt:
        problems.append(
            "no priority cell preempted: contention is vacuous")
    return problems


def spec_semantics(doc: dict) -> list[str]:
    """Machine-independent invariants of a fresh BENCH_spec.json — the
    self-speculative-decoding claim itself, not a wall-clock ratio:

      * every cell's drain() was token-for-token identical to the baseline
        engine (``exact_match``) — speculation must never change outputs;
      * every cell measured an acceptance rate (the draft actually ran) and
        at least one cell accepted drafts (acceptance > 0), so the measured
        verify-step *dispatches* per emitted token — decode's sequential-
        latency unit, 1.0/token for the baseline engine by construction —
        drop below 1.0 somewhere (an inert draft sits exactly at 1.0);
      * no cell's verify-steps-per-token exceeds 1.0 (the baseline cost);
      * the compiled round count stayed 1 in every cell — draft shift, k
        grid position and mode tables must never retrace.

    Returns a list of violation strings (empty = pass).
    """
    problems = []
    cells = doc.get("cells", [])
    if not cells:
        return ["no spec cells found"]
    best_vspt = None
    any_accept = False
    for c in cells:
        key = (f"k={c.get('k')} shift={c.get('draft_shift')} "
               f"adapt={c.get('adaptive_shift')} acc={c.get('accuracy')}")
        if not c.get("exact_match"):
            problems.append(f"{key}: output diverged from the baseline engine")
        acc = c.get("acceptance_rate")
        vspt = c.get("verify_steps_per_token")
        if acc is None or vspt is None:
            problems.append(f"{key}: no acceptance/verify-steps measured")
            continue
        if acc > 0:
            any_accept = True
        if vspt > 1.0:
            problems.append(
                f"{key}: verify-steps/token {vspt} above the baseline cost")
        best_vspt = vspt if best_vspt is None else min(best_vspt, vspt)
        if c.get("spec_compile_count") not in (None, 1):
            problems.append(
                f"{key}: {c['spec_compile_count']} compiled round variants "
                "(draft shift / mode changes must not retrace)")
    if not any_accept:
        problems.append("no cell accepted any draft: speculation is inert")
    elif best_vspt is not None and best_vspt >= 1.0:
        problems.append(
            f"best verify-steps/token {best_vspt} never dropped below 1.0")
    return problems


def tile_semantics(doc: dict) -> list[str]:
    """Machine-independent invariants of a fresh BENCH_tile.json — the
    partitioned-SIMD kernel's contract, never a wall-clock ratio:

      * every uniform-map cell is BIT-identical to ``mp_matmul`` on the
        ``impl='pallas'`` switch-branch kernel at the same blocks;
      * the runtime tile path traces to 0 ``lax.switch`` equations and
        exactly 1 fused ``pallas_call`` (the switch path must show >= 1
        switch, or the comparison is vacuous), stays bit-identical to the
        switch path at every mode value, and compiles exactly once across
        all mode values (zero-recompile reconfiguration);
      * every magnitude cell meets its error budget, uses >= 2 distinct
        modes (one mode means the outlier workload isn't exercising the
        map), and its per-tile MXU pass count is strictly below the
        uniform-max cost the switch path would pay (``pass_ratio < 1``).

    Returns a list of violation strings (empty = pass).
    """
    problems = []
    cells = doc.get("cells", [])
    if not cells:
        return ["no tile cells found"]
    kinds = {c.get("kind") for c in cells}
    for want in ("uniform", "runtime", "magnitude"):
        if want not in kinds:
            problems.append(f"no {want} cells found")
    for c in cells:
        kind = c.get("kind")
        if kind == "uniform":
            key = f"uniform n={c.get('n')} {c.get('mode')}"
            if not c.get("bitwise_equal"):
                problems.append(f"{key}: tile output not bitwise-equal to "
                                "the pallas switch-branch kernel")
        elif kind == "runtime":
            key = f"runtime n={c.get('n')}"
            if not c.get("modes_equal_switch"):
                problems.append(
                    f"{key}: tile output diverged from the switch path")
            if c.get("tile_switches") != 0 or c.get("tile_pallas_calls") != 1:
                problems.append(
                    f"{key}: tile path traced {c.get('tile_switches')} "
                    f"switches x {c.get('tile_pallas_calls')} pallas calls "
                    "(want 0 x 1: one fused dispatch)")
            if c.get("switch_switches", 0) < 1:
                problems.append(
                    f"{key}: switch path shows no lax.switch — the "
                    "comparison is vacuous")
            if c.get("tile_compile_count") != 1:
                problems.append(
                    f"{key}: {c.get('tile_compile_count')} compiled "
                    "executables across mode values (mode changes retrace)")
        elif kind == "magnitude":
            key = f"magnitude n={c.get('n')}"
            if not c.get("budget_met"):
                problems.append(
                    f"{key}: error {c.get('rel_err_vs_envelope')} over "
                    f"budget {c.get('budget')}")
            if c.get("modes_used", 0) < 2:
                problems.append(
                    f"{key}: magnitude map used {c.get('modes_used')} mode "
                    "(outlier workload not exercising the map)")
            if not c.get("pass_ratio", 1.0) < 1.0:
                problems.append(
                    f"{key}: pass_ratio {c.get('pass_ratio')} not below the "
                    "uniform-max cost")
    return problems


def page_semantics(doc: dict) -> list[str]:
    """Machine-independent invariants of a fresh BENCH_page.json — the
    paged-KV-cache contract (repro.serve.paged), never a wall-clock ratio:

      * every full-precision exact cell is token-for-token identical to the
        dense ring layout, and the hybrid wrap cell actually forked pages
        (cow_copies > 0) — otherwise ring wrap into shared pages went
        unexercised;
      * the concurrency cell stays exact while sustaining strictly more
        concurrent in-flight requests than a dense layout of the same
        memory admits (``peak_active > dense_equiv_slots``) with real
        page-pressure evictions;
      * the sharing cell stays exact with shared_hits > 0 and a nonzero
        peak sharing ratio;
      * tier cells: ``off`` stays exact; ``open`` demotes pages and
        measures a nonzero residual; ``budgeted`` holds the measured
        residual inside its budget (``budget_met``).

    Returns a list of violation strings (empty = pass).
    """
    problems = []
    exact = doc.get("exact", [])
    if not exact:
        return ["no page exact cells found"]
    for c in exact:
        if not c.get("exact_match"):
            problems.append(
                f"exact {c.get('arch')}: paged output diverged from dense "
                "at full precision")
    wrap = [c for c in exact if c.get("wrap_cow")]
    if not wrap:
        problems.append("no exact cell covers ring wrap (hybrid arch)")
    elif not any(c.get("cow_copies", 0) > 0 for c in wrap):
        problems.append(
            "wrap cell never forked a page: copy-on-write unexercised")
    conc = doc.get("concurrency")
    if not conc:
        problems.append("no concurrency cell found")
    else:
        if not conc.get("exact_match"):
            problems.append("concurrency: output diverged under page "
                            "pressure (eviction corrupted state)")
        if not (conc.get("peak_active", 0)
                > conc.get("dense_equiv_slots", 1 << 30)):
            problems.append(
                f"concurrency: peak_active {conc.get('peak_active')} not "
                f"above dense-equivalent {conc.get('dense_equiv_slots')} — "
                "paging buys no concurrency")
        if conc.get("page_evictions", 0) < 1:
            problems.append("concurrency: no page-pressure eviction "
                            "happened (the pool is not actually small)")
    sh = doc.get("sharing")
    if not sh:
        problems.append("no sharing cell found")
    else:
        if not sh.get("exact_match"):
            problems.append("sharing: output diverged with shared prefixes")
        if sh.get("shared_hits", 0) < 1 or not sh.get("sharing_peak", 0) > 0:
            problems.append("sharing: no prefix pages were actually shared")
    tiers = {c.get("label"): c for c in doc.get("tiers", [])}
    for want in ("off", "open", "budgeted"):
        if want not in tiers:
            problems.append(f"no {want} tier cell found")
    off, open_, bud = (tiers.get(k) for k in ("off", "open", "budgeted"))
    if off is not None and not off.get("exact_match"):
        problems.append("tiers off: output diverged without any demotion")
    if open_ is not None:
        if open_.get("tier_demoted", 0) < 1:
            problems.append("tiers open: no page was demoted")
        if not (open_.get("err_max") or 0) > 0:
            problems.append("tiers open: demotion left no measured residual "
                            "(truncation is inert)")
    if bud is not None:
        if bud.get("budget") is None:
            problems.append("tiers budgeted: cell carries no budget")
        if not bud.get("budget_met"):
            problems.append(
                f"tiers budgeted: residual {bud.get('err_max')} over "
                f"budget {bud.get('budget')}")
    return problems


#: allowed median steady-state wall ratio, traced / untraced — tracing is
#: host-side dict appends against multi-ms jit dispatches, so anything
#: above 5% means an emit site leaked into the hot path
OBS_OVERHEAD_LIMIT = 1.05


def obs_semantics(doc: dict) -> list[str]:
    """Machine-independent invariants of a fresh BENCH_obs.json — the
    tracing contract (repro.obs):

      * every cell's traced arm emits bit-identical tokens and compiles
        exactly as many step variants as the untraced arm (tracing must be
        invisible to jit), with zero decode/spec recompiles mid-run
        (prefill recompiles are legitimate: one variant per ragged prompt
        length);
      * every trace is lossless (0 dropped), non-empty, exports a
        schema-valid Chrome document, and replays through the scheduler
        invariant harness (tests/scheduler_model.py consumer mode);
      * the median steady-state overhead ratio across cells stays under
        ``OBS_OVERHEAD_LIMIT`` (each cell's ratio is a median of paired
        per-rep ratios; the median across cells absorbs single-cell
        timing noise).

    Returns a list of violation strings (empty = pass).
    """
    problems = []
    cells = doc.get("cells", [])
    if not cells:
        return ["no obs cells found"]
    for want in ("plain", "spec", "full"):
        if not any(c.get("cell") == want for c in cells):
            problems.append(f"no {want} obs cell found")
    for c in cells:
        key = f"obs {c.get('cell')}"
        if not c.get("tokens_equal"):
            problems.append(f"{key}: traced tokens diverged from untraced "
                            "(tracing changed the computation)")
        if not c.get("compiles_equal"):
            problems.append(
                f"{key}: compile counts differ traced vs untraced "
                f"({c.get('compiles_traced')} vs "
                f"{c.get('compiles_untraced')}) — tracing is jit-visible")
        if c.get("steady_recompiles", 0) != 0:
            problems.append(
                f"{key}: {c.get('steady_recompiles')} mid-run decode/spec "
                f"recompiles detected ({c.get('recompiles')})")
        if c.get("n_events", 0) < 1:
            problems.append(f"{key}: empty trace")
        if c.get("dropped", 0) != 0:
            problems.append(f"{key}: {c.get('dropped')} events dropped "
                            "(ring too small — trace not replayable)")
        if not c.get("chrome_valid"):
            problems.append(f"{key}: Chrome export failed validation: "
                            f"{c.get('chrome_problems')}")
        if not c.get("replay_ok"):
            problems.append(f"{key}: event stream failed the scheduler "
                            "invariant replay")
    ratios = sorted(c.get("overhead_ratio", 0.0) for c in cells)
    median = ratios[len(ratios) // 2] if len(ratios) % 2 else (
        ratios[len(ratios) // 2 - 1] + ratios[len(ratios) // 2]) / 2
    if median > OBS_OVERHEAD_LIMIT:
        problems.append(
            f"median tracing overhead {median:.3f} above "
            f"{OBS_OVERHEAD_LIMIT} (per-cell ratios {ratios})")
    return problems


def compare(
    baseline: dict[tuple, float],
    new: dict[tuple, float],
    *,
    tolerance: float,
    absolute: bool = False,
    min_cells: int = 2,
) -> dict:
    """Compare cost dicts (lower is better).  Returns a report dict with
    ``violations``; raises ValueError on insufficient overlap."""
    common = sorted(set(baseline) & set(new))
    if len(common) < min_cells:
        raise ValueError(
            f"only {len(common)} overlapping cells (need >= {min_cells}); "
            "baseline and new sweep grids do not overlap enough to gate on"
        )
    ratios = {key: new[key] / baseline[key] for key in common}
    ordered = sorted(ratios.values())
    mid = len(ordered) // 2
    if absolute:
        speed_factor = 1.0
    elif len(ordered) % 2:
        speed_factor = ordered[mid]
    else:
        speed_factor = 0.5 * (ordered[mid - 1] + ordered[mid])
    limit = speed_factor * (1.0 + tolerance)
    violations = [
        {"cell": list(key), "ratio": ratios[key], "limit": limit}
        for key in common
        if ratios[key] > limit
    ]
    violations.sort(key=lambda v: -v["ratio"])
    return {
        "n_cells": len(common),
        "speed_factor": speed_factor,
        "limit": limit,
        "violations": violations,
    }


def _gate(name: str, baseline_cells, new_cells, args, absolute=None) -> bool:
    try:
        report = compare(
            baseline_cells,
            new_cells,
            tolerance=args.tolerance,
            absolute=args.absolute if absolute is None else absolute,
            min_cells=args.min_cells,
        )
    except ValueError as e:
        print(f"{name}: ERROR {e}")
        return False
    print(
        f"{name}: {report['n_cells']} cells, machine-speed factor "
        f"{report['speed_factor']:.3f}, per-cell limit {report['limit']:.3f}"
    )
    for v in report["violations"]:
        print(
            f"  REGRESSION {v['cell']}: cost ratio {v['ratio']:.3f} "
            f"> {v['limit']:.3f}"
        )
    if not report["violations"]:
        print(f"  ok (worst within {args.tolerance:.0%} of the median ratio)")
    return not report["violations"]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--plan-baseline", default="")
    ap.add_argument("--plan-new", default="")
    ap.add_argument(
        "--plan-mode",
        default="selections",
        choices=("selections", "measured"),
        help="plan comparison layer: deterministic planner selections "
        "(CI) or wall-clock kernel cells (same-machine)",
    )
    ap.add_argument("--serve-baseline", default="")
    ap.add_argument("--serve-new", default="")
    ap.add_argument("--adapt-baseline", default="")
    ap.add_argument(
        "--adapt-new",
        default="",
        help="fresh BENCH_adapt.json; always checked for the machine-"
        "independent adaptation invariants, and ratio-gated against "
        "--adapt-baseline when one is given",
    )
    ap.add_argument(
        "--spec-new",
        default="",
        help="fresh BENCH_spec.json; checked for the machine-independent "
        "speculative-decoding invariants (exact output equivalence, "
        "acceptance > 0 with verify-steps/token < 1, one compiled round)",
    )
    ap.add_argument(
        "--tenant-new",
        default="",
        help="fresh BENCH_tenant.json; checked for the machine-independent "
        "multi-tenant invariants (all outputs exact vs solo, no starvation, "
        "priority attainment >= FIFO for the high-priority tenant and "
        "strictly better somewhere, real preemption)",
    )
    ap.add_argument(
        "--tile-new",
        default="",
        help="fresh BENCH_tile.json; checked for the machine-independent "
        "partitioned-SIMD invariants (uniform maps bitwise-equal to the "
        "pallas kernel, one fused dispatch with zero switches and zero "
        "recompiles, magnitude maps inside budget with pass_ratio < 1)",
    )
    ap.add_argument(
        "--page-new",
        default="",
        help="fresh BENCH_page.json; checked for the machine-independent "
        "paged-KV-cache invariants (paged bit-identical to dense at full "
        "precision incl. the wrap+COW cell, in-flight concurrency above "
        "the dense-equivalent admission with real evictions, prefix pages "
        "shared, tiered residual inside budget)",
    )
    ap.add_argument(
        "--obs-new",
        default="",
        help="fresh BENCH_obs.json; checked for the machine-independent "
        "tracing invariants (traced arm bit-identical tokens and compile "
        "counts, lossless schema-valid replayable traces, median overhead "
        "inside the 5% gate)",
    )
    ap.add_argument(
        "--adapt-strict",
        action="store_true",
        help="also fail on the adapted-vs-safe throughput invariant "
        "(wall-clock: same-machine use only)",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed per-cell cost-ratio excess over the median ratio",
    )
    ap.add_argument(
        "--absolute",
        action="store_true",
        help="skip machine-speed normalization (same-machine comparisons)",
    )
    ap.add_argument("--min-cells", type=int, default=2)
    args = ap.parse_args(argv)

    ran = False
    ok = True
    if args.plan_baseline and args.plan_new:
        ran = True
        selections = args.plan_mode == "selections"
        cells = plan_selection_cells if selections else plan_cells
        ok &= _gate(
            f"plan ({args.plan_mode})",
            cells(load(args.plan_baseline)),
            cells(load(args.plan_new)),
            args,
            # model output vs model output: no machine-speed factor to cancel
            absolute=True if selections else None,
        )
    if args.serve_baseline and args.serve_new:
        ran = True
        ok &= _gate(
            "serve",
            serve_cells(load(args.serve_baseline)),
            serve_cells(load(args.serve_new)),
            args,
        )
    if args.adapt_new:
        ran = True
        doc = load(args.adapt_new)
        problems = adapt_semantics(doc, check_throughput=args.adapt_strict)
        for p in problems:
            print(f"adapt (semantics): FAIL {p}")
        if not problems:
            print("adapt (semantics): ok (adapted meets SLO, cheap static "
                  "violates, reconfigured with zero recompiles)")
        ok &= not problems
        if args.adapt_baseline:
            ok &= _gate(
                "adapt",
                adapt_cells(load(args.adapt_baseline)),
                adapt_cells(doc),
                args,
            )
    if args.tenant_new:
        ran = True
        problems = tenant_semantics(load(args.tenant_new))
        for p in problems:
            print(f"tenant (semantics): FAIL {p}")
        if not problems:
            print("tenant (semantics): ok (outputs exact, no starvation, "
                  "priority attainment beats FIFO, preemption exercised)")
        ok &= not problems
    if args.tile_new:
        ran = True
        problems = tile_semantics(load(args.tile_new))
        for p in problems:
            print(f"tile (semantics): FAIL {p}")
        if not problems:
            print("tile (semantics): ok (uniform maps bitwise-equal, one "
                  "fused dispatch with zero switches/recompiles, magnitude "
                  "maps inside budget at pass_ratio < 1)")
        ok &= not problems
    if args.page_new:
        ran = True
        problems = page_semantics(load(args.page_new))
        for p in problems:
            print(f"page (semantics): FAIL {p}")
        if not problems:
            print("page (semantics): ok (paged bit-identical to dense incl. "
                  "wrap+COW, concurrency beats dense-equivalent admission "
                  "under eviction, prefixes shared, tiers inside budget)")
        ok &= not problems
    if args.obs_new:
        ran = True
        problems = obs_semantics(load(args.obs_new))
        for p in problems:
            print(f"obs (semantics): FAIL {p}")
        if not problems:
            print("obs (semantics): ok (traced arms bit-identical with "
                  "equal compile counts, traces lossless + schema-valid + "
                  "replayable, median overhead inside the gate)")
        ok &= not problems
    if args.spec_new:
        ran = True
        problems = spec_semantics(load(args.spec_new))
        for p in problems:
            print(f"spec (semantics): FAIL {p}")
        if not problems:
            print("spec (semantics): ok (outputs exact, drafts accepted with "
                  "verify-steps/token < 1, one compiled round)")
        ok &= not problems
    if not ran:
        print("nothing to compare: pass --plan-baseline/--plan-new and/or --serve-*")
        return 2
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
