"""Benchmark driver: one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table9     # substring filter

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys

from benchmarks import paper_tables


def main() -> None:
    pattern = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    for fn in paper_tables.ALL:
        if pattern and pattern not in fn.__name__:
            continue
        try:
            fn()
        except Exception as e:  # keep the harness running; report the failure
            print(f"{fn.__name__},-1,FAILED:{type(e).__name__}:{e}", flush=True)
            raise


if __name__ == "__main__":
    main()
