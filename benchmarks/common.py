"""Shared benchmark utilities: timing, CSV emission."""
from __future__ import annotations

import time

import jax
import numpy as np

ROWS: list[tuple] = []


def timeit(fn, *args, warmup: int = 2, iters: int = 5, stat: str = "median") -> float:
    """Wall-time in microseconds for jitted fn(*args).

    ``stat='median'`` is the historical default; ``stat='min'`` (the least-
    contended observation) is far more stable on shared machines and is what
    the CI perf-gate sweeps use (benchmarks/check_regression.py)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.min(ts) if stat == "min" else np.median(ts))


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def hlo_flops(fn, *arg_shapes) -> float:
    """Scan-corrected HLO flops (repro.launch.hlo_cost parser)."""
    from repro.launch.hlo_cost import parse_hlo_cost

    compiled = jax.jit(fn).lower(*arg_shapes).compile()
    return parse_hlo_cost(compiled.as_text()).flops
