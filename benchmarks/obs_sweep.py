"""Observability sweep: the cost and the fidelity of tracing (repro.obs).

Runs each serving configuration twice on identical seeded workloads — once
on the no-op NULL_TRACER, once with ``ServeConfig(trace=TraceConfig())`` —
and records the two tentpole contracts as machine-checkable cells
(``BENCH_obs.json``, gated by ``check_regression --obs-new``):

  * **zero jit-visible cost**: the traced arm must emit bit-identical
    tokens and compile exactly as many step variants as the untraced arm
    (tracing is host-side Python; nothing it does may reach jit), and its
    steady-state wall time must stay within the overhead gate (median
    overhead_ratio <= 1.05 across cells; each cell's ratio is the median
    of per-rep PAIRED ratios, so host-load drift cancels);
  * **fidelity**: the recorded stream must be lossless (0 dropped), export
    a schema-valid Chrome trace (``validate_chrome``), and replay through
    the scheduler invariant harness (tests/scheduler_model.py consumer
    mode, ``check_replay``) — the trace is a checkable artifact, not a
    best-effort log.

Cells: ``plain`` (continuous batching only), ``spec`` (self-speculative
rounds), ``full`` (multi-tenant priority scheduling + paged KV cache +
speculation — the acceptance-criterion combination; no slo=, which the
engine refuses alongside speculate= and tenants=).

    PYTHONPATH=src python -m benchmarks.obs_sweep            # full sweep
    PYTHONPATH=src python -m benchmarks.obs_sweep --quick    # CI subset
    PYTHONPATH=src python -m benchmarks.make_experiments_md --write

Emits ``BENCH_obs.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import statistics
import sys
import time

import jax
import numpy as np

from benchmarks.serve_sweep import build_tiny
from repro.obs import TraceConfig, validate_chrome
from repro.serve import (
    CacheConfig,
    RequestClass,
    SchedulingConfig,
    ServeConfig,
    ServeEngine,
    Tenant,
    class_requests,
    ragged_requests,
)
from repro.spec import SpecConfig

# the replay harness lives with the tests, not the package
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from scheduler_model import check_replay  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_obs.json")

TENANTS = (Tenant("interactive", priority=0, share=2.0),
           Tenant("bulk", priority=2, share=1.0))
CLASSES = (RequestClass("chat", slo_steps=10, prompt_len=6, max_new=5),
           RequestClass("batch", prompt_len=10, max_new=8))


def _cell_configs(vocab: int):
    """(name, base ServeConfig, request factory) per cell.  The factory
    takes a rid base so repeated batches on one engine stay replayable
    (every rid's lifecycle must be fresh)."""
    def plain_reqs(base: int):
        rng = np.random.default_rng(0)
        return [dataclasses.replace(r, rid=base + r.rid)
                for r in ragged_requests(6, vocab, 10, 8, rng)]

    def tenant_reqs(base: int):
        rng = np.random.default_rng(0)
        reqs = class_requests(CLASSES[1], TENANTS[1], 3, vocab, rng,
                              rid_base=base)
        reqs += class_requests(CLASSES[0], TENANTS[0], 3, vocab, rng,
                               rid_base=base + 100)
        return reqs

    plain = ServeConfig(batch_slots=2, max_len=26)
    spec = ServeConfig(batch_slots=2, max_len=26,
                       spec=SpecConfig(k=2, draft_shift=1))
    full = ServeConfig(
        batch_slots=3, max_len=26,
        scheduling=SchedulingConfig(tenants=TENANTS, classes=CLASSES),
        spec=SpecConfig(k=2, draft_shift=1),
        cache=CacheConfig(layout="paged", page_size=4))
    return [("plain", plain, plain_reqs),
            ("spec", spec, plain_reqs),
            ("full", full, tenant_reqs)]


def _timed_batch(eng: ServeEngine, reqs) -> float:
    t0 = time.perf_counter()
    eng.generate_batch(reqs)
    return time.perf_counter() - t0


def sweep_cell(model, params, name: str, cfg: ServeConfig, mk_reqs,
               reps: int) -> dict:
    e_off = ServeEngine(model, params, config=cfg)
    e_on = ServeEngine(model, params, config=dataclasses.replace(
        cfg, trace=TraceConfig()))
    # warm batches: compiles + the token-identity comparison
    outs_off = e_off.generate_batch(mk_reqs(0))
    outs_on = e_on.generate_batch(mk_reqs(0))
    # timed reps are PAIRED: each rep times the two arms back to back on
    # the identical batch, and the cell's overhead is the median of the
    # per-rep ratios — host-load drift moves both walls of a pair together
    # and cancels in the ratio, where a ratio of two independent
    # best-of-reps walls would keep it
    walls_off, walls_on = [], []
    for rep in range(1, reps + 1):
        walls_off.append(_timed_batch(e_off, mk_reqs(rep * 1000)))
        walls_on.append(_timed_batch(e_on, mk_reqs(rep * 1000)))
    wall_off, wall_on = min(walls_off), min(walls_on)
    ratio = statistics.median(on / off
                              for on, off in zip(walls_on, walls_off))

    chrome_problems = validate_chrome(e_on.tracer.chrome())
    try:
        check_replay(e_on)
        replay_ok = True
    except AssertionError:
        replay_ok = False
    compiles_off = [e_off.decode_compile_count, e_off.spec_compile_count]
    compiles_on = [e_on.decode_compile_count, e_on.spec_compile_count]
    # recompiles by cause: prefill ones are legitimate (the prefill jit
    # specializes per ragged prompt length); decode/spec-round growth
    # mid-run would mean tracing perturbed the compiled step
    recompiles: dict[str, int] = {}
    for e in e_on.tracer.events:
        if e.kind == "recompile":
            sizes = e.data["sizes"]
            recompiles[e.cause] = (recompiles.get(e.cause, 0)
                                   + sizes["after"] - sizes["before"])
    return {
        "cell": name,
        "requests": len(outs_off),
        "tokens": sum(len(v) for v in outs_off.values()),
        "tokens_equal": outs_off == outs_on,
        "compiles_untraced": compiles_off,
        "compiles_traced": compiles_on,
        "compiles_equal": compiles_off == compiles_on,
        "wall_untraced_s": round(wall_off, 4),
        "wall_traced_s": round(wall_on, 4),
        "overhead_ratio": round(ratio, 4),
        "n_events": len(e_on.tracer.events),
        "dropped": e_on.tracer.dropped,
        "chrome_valid": chrome_problems == [],
        "chrome_problems": chrome_problems[:5],
        "replay_ok": replay_ok,
        "recompiles": recompiles,
        "steady_recompiles": sum(v for k, v in recompiles.items()
                                 if k != "prefill"),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--quick", action="store_true",
                    help="CI subset: fewer timed reps per arm")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()

    cfg, model, params = build_tiny(args.arch)
    reps = 3 if args.quick else 5
    cells = []
    for name, scfg, mk in _cell_configs(cfg.vocab):
        c = sweep_cell(model, params, name, scfg, mk, reps)
        cells.append(c)
        print(f"{name}: tokens_equal={c['tokens_equal']} "
              f"compiles={c['compiles_traced']} "
              f"overhead={c['overhead_ratio']:.3f} "
              f"events={c['n_events']} dropped={c['dropped']} "
              f"chrome_valid={c['chrome_valid']} replay_ok={c['replay_ok']}")
    doc = {
        "host_backend": jax.default_backend(),
        "arch": args.arch,
        "reps": reps,
        "overhead_ratio_median": round(statistics.median(
            c["overhead_ratio"] for c in cells), 4),
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out} (median overhead "
          f"{doc['overhead_ratio_median']:.3f})")


if __name__ == "__main__":
    main()
