"""Multi-tenant mixed-traffic sweep: priority+EDF scheduling vs pure FIFO.

Drives the continuous-batching engine (``repro.serve``) with a heterogeneous
request mix — the traffic a real deployment of the paper's reconfigurable
core actually sees — and compares the two scheduler policies on identical,
seeded workloads:

  * **interactive** tenant (priority 0, 2x entitlement): short ``chat``
    turns with a tight step-unit deadline, plus ``audio`` requests with
    Whisper-scale prompt lengths and a looser deadline;
  * **bulk** tenant (priority 2): long ``batch`` decodes, no deadline —
    submitted first so it saturates every slot before the urgent traffic
    arrives (open-loop Poisson arrivals, measured in engine steps so the
    whole sweep is machine-independent).

Each (arch, policy) cell records per-tenant SLO attainment, latency
percentiles, decode-slot share vs entitlement, preemption counts — and
verifies every request's tokens are bit-identical to a solo run of the same
prompt (the engines run unplanned NATIVE_F32, so exactness is exact).  The
gate (``check_regression --tenant-new``) then asserts the semantic claims:
all outputs exact, nobody starves, and the priority scheduler's
high-priority attainment beats FIFO's on the same workload.

    PYTHONPATH=src python -m benchmarks.tenant_sweep           # full sweep
    PYTHONPATH=src python -m benchmarks.tenant_sweep --quick   # CI: one arch
    PYTHONPATH=src python -m benchmarks.make_experiments_md --write

Emits ``BENCH_tenant.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.serve_sweep import build_tiny
from repro.serve import Request, ServeEngine
from repro.serve.tenancy import RequestClass, Tenant, class_requests

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_tenant.json")

ARCHS = ("qwen1.5-0.5b", "mamba2-2.7b")  # dense chat + SSM batch families
SLOTS = 2
MAX_LEN = 40
HIGH_PRIORITY_TENANT = "interactive"

TENANTS = [
    Tenant("interactive", priority=0, share=2.0),
    Tenant("bulk", priority=2, share=1.0),
]
CLASSES = [
    RequestClass("chat", slo_steps=10, prompt_len=6, max_new=4),
    RequestClass("audio", slo_steps=20, prompt_len=18, max_new=4),
    RequestClass("batch", prompt_len=8, max_new=14),
]
#: (tenant, class, n requests, Poisson arrival rate in requests/step, first
#: possible arrival step, rid base).  Bulk floods from step 0; the urgent
#: streams arrive open-loop while every slot is already busy.
STREAMS = [
    ("bulk", "batch", 4, 2.0, 0, 0),
    ("interactive", "chat", 3, 0.4, 2, 100),
    ("interactive", "audio", 2, 0.25, 4, 200),
]


def build_workload(vocab: int, seed: int = 0):
    """The per-step submission schedule: seeded Poisson arrivals measured
    in *engine steps* (machine-independent), identical for every policy
    cell of one arch."""
    rng = np.random.default_rng(seed)
    tenants = {t.name: t for t in TENANTS}
    classes = {c.name: c for c in CLASSES}
    arrivals: list[tuple[int, Request]] = []
    for tname, cname, n, rate, start, rid_base in STREAMS:
        reqs = class_requests(classes[cname], tenants[tname], n, vocab, rng,
                              rid_base=rid_base)
        t = float(start)
        for r in reqs:
            t += rng.exponential(1.0 / rate)
            arrivals.append((int(t), r))
    arrivals.sort(key=lambda a: (a[0], a[1].rid))
    horizon = max(step for step, _ in arrivals) + 1
    schedule: list[list[Request]] = [[] for _ in range(horizon)]
    for step, r in arrivals:
        schedule[step].append(r)
    return schedule


def solo_reference(model, params, schedule) -> dict[int, list[int]]:
    """Every request served alone at batch_slots=1 — the exactness oracle
    (one engine reused; rids offset to stay unique)."""
    eng = ServeEngine(model, params, batch_slots=1, max_len=MAX_LEN)
    out = {}
    for step_reqs in schedule:
        for r in step_reqs:
            clone = Request(prompt=r.prompt, max_new=r.max_new,
                            rid=r.rid + 10_000)
            out[r.rid] = eng.generate_batch([clone])[clone.rid]
    return out


def run_cell(model, params, schedule, solo, policy: str) -> dict:
    eng = ServeEngine(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                      tenants=TENANTS, classes=CLASSES,
                      scheduler_policy=policy, aging_steps=8, min_quantum=1)
    t0 = time.perf_counter()
    for step_reqs in schedule:
        for r in step_reqs:
            eng.submit(r)
        eng.step()
    outs = eng.drain()
    wall = time.perf_counter() - t0
    s = eng.metrics.summary()
    exact = {rid: outs.get(rid) == solo[rid] for rid in solo}
    tenants = {
        name: {
            "submitted": t["submitted"],
            "completed": t["completed"],
            "tokens": t["tokens"],
            "preemptions": t["preemptions"],
            "classes": t["classes"],
            "attainment": t["attainment"],
            "latency_p50_s": (round(t["latency_p50_s"], 4)
                              if t["latency_p50_s"] is not None else None),
            "latency_p99_s": (round(t["latency_p99_s"], 4)
                              if t["latency_p99_s"] is not None else None),
            "slot_share": round(t["slot_share"], 3),
            "entitlement": round(t["entitlement"], 3),
        }
        for name, t in s["tenants"].items() if t["submitted"]
    }
    return {
        "policy": policy,
        "slots": SLOTS,
        "requests": s["requests"],
        "completed": s["completed"],
        "tokens_out": s["tokens_out"],
        "tok_s": round(s["tok_s"], 2),
        "wall_s": round(wall, 3),
        "decode_steps": s["decode_steps"],
        "engine_steps": eng.scheduler.clock,
        "occupancy": round(s["occupancy"], 3),
        "preemptions": s["preemptions"],
        "max_wait_steps": eng.scheduler.max_wait_steps,
        "all_exact": all(exact.values()),
        "n_exact": sum(exact.values()),
        "tenants": tenants,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="one arch only (the CI gate configuration)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()

    archs = ARCHS[:1] if args.quick else ARCHS
    cells = []
    for arch in archs:
        cfg, model, params = build_tiny(arch)
        schedule = build_workload(cfg.vocab, args.seed)
        solo = solo_reference(model, params, schedule)
        for policy in ("fifo", "priority"):
            cell = run_cell(model, params, schedule, solo, policy)
            cell["arch"] = arch
            cells.append(cell)
            hp = cell["tenants"][HIGH_PRIORITY_TENANT]
            att = (f"{hp['attainment']:.0%}"
                   if hp["attainment"] is not None else "-")
            print(f"{arch} {policy}: {cell['completed']}/{cell['requests']} "
                  f"done, {HIGH_PRIORITY_TENANT} attainment {att}, "
                  f"{cell['preemptions']} preemptions, "
                  f"exact {cell['n_exact']}/{cell['requests']}")
    doc = {
        "host_backend": jax.default_backend(),
        "slots": SLOTS,
        "max_len": MAX_LEN,
        "seed": args.seed,
        "high_priority_tenant": HIGH_PRIORITY_TENANT,
        "tenants": {t.name: {"priority": t.priority, "share": t.share}
                    for t in TENANTS},
        "classes": {c.name: {"slo_steps": c.slo_steps,
                             "prompt_len": c.prompt_len,
                             "max_new": c.max_new}
                    for c in CLASSES},
        "streams": [
            {"tenant": tn, "class": cn, "n": n, "rate_per_step": rate,
             "start_step": start}
            for tn, cn, n, rate, start, _ in STREAMS
        ],
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
