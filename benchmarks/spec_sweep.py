"""Speculative-decoding sweep: k x draft-shift x accuracy vs the baseline
engine (EXPERIMENTS.md Cell I is generated from this output).

For every (k, draft_shift, accuracy) cell the same ragged workload runs
through the PR-2 baseline engine and the speculative engine
(``ServeEngine(speculate=SpecConfig(...))``) over the same params, and the
cell records

  * **exact_match** — drain() token-for-token equality (the speculative
    engine's defining invariant: the verify chain replays the exact
    baseline step, so acceptance only changes the cost, never the output);
  * **acceptance rate** and **verify-steps-per-token** — expensive-mode
    verify executions per emitted token, the machine-independent payoff
    (< 1.0 whenever anything is accepted; the baseline is 1.0 by
    construction);
  * **tok/s** both ways (CPU wall clock: machine-local, trend-only);
  * **spec_compile_count** — compiled round variants (must stay 1: draft
    shift and mode tables ride in as jit scalars).

One extra row per accuracy runs the acceptance *controller* live
(``adapt=True``) and records its draft-shift moves.

    PYTHONPATH=src python -m benchmarks.spec_sweep            # full sweep
    PYTHONPATH=src python -m benchmarks.spec_sweep --quick    # CI-sized

Emits ``BENCH_spec.json``; gated machine-independently by
``benchmarks.check_regression --spec-new``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.serve import ServeEngine, ragged_requests
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Scheduler
from repro.spec import SpecConfig

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_spec.json")

ACCURACIES = (None, 2.0**-12)  # None = unplanned native_f32 policy table
KS = (2, 4)
SHIFTS = (1, 2)


def build_tiny(arch: str):
    from benchmarks.serve_sweep import build_tiny as _bt

    return _bt(arch)


def _reset(eng: ServeEngine) -> None:
    """Fresh metrics/scheduler — and, for adaptive cells, a fresh
    acceptance controller back at the configured initial shift — between
    the warmup and the measured run, so the recorded draft-shift moves are
    the measured workload's own.  Compiled executables (step, prefill,
    spec round) are kept, which is the point of the warmup."""
    from repro.spec import AcceptanceController

    eng.metrics = ServeMetrics(eng.slots)
    eng.scheduler = Scheduler(eng.slots, eng.max_len)
    if eng.spec is not None:
        eng._spec_window = [0, 0]
        if eng._accept_ctrl is not None:
            eng._accept_ctrl = AcceptanceController(
                eng.spec, eng._accept_ctrl.ladder)


def _run(eng: ServeEngine, reqs) -> tuple[dict, dict, float]:
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(dataclasses.replace(r))
    outs = eng.drain()
    wall = time.perf_counter() - t0
    return outs, eng.metrics.summary(), wall


def _warmup(eng: ServeEngine, reqs) -> None:
    _run(eng, [dataclasses.replace(reqs[0], rid=10_000)])
    _reset(eng)


def sweep_cell(model, params, baseline_out, base_s, *, slots, max_len,
               accuracy, k, shift, adapt, reqs) -> dict:
    eng = ServeEngine(
        model, params, batch_slots=slots, max_len=max_len,
        accuracy=accuracy, tune_table=False,
        speculate=SpecConfig(k=k, draft_shift=shift, adapt=adapt),
    )
    _warmup(eng, reqs)
    outs, s, wall = _run(eng, reqs)
    return {
        "k": k,
        "draft_shift": shift,
        "adaptive_shift": adapt,
        "accuracy": accuracy,
        "requests": len(reqs),
        "exact_match": outs == baseline_out,
        "tokens_out": s["tokens_out"],
        "tok_s": round(s["tok_s"], 2),
        "baseline_tok_s": round(base_s["tok_s"], 2),
        "wall_s": round(wall, 3),
        "acceptance_rate": (round(s["acceptance_rate"], 4)
                            if s["acceptance_rate"] is not None else None),
        "verify_steps_per_token": (round(s["verify_steps_per_token"], 4)
                                   if s["verify_steps_per_token"] is not None
                                   else None),
        "spec_rounds": s["spec_rounds"],
        "spec_rejected": s["spec_rejected"],
        "draft_shift_moves": s["draft_shift_moves"],
        "final_draft_shift": eng.draft_shift,
        "spec_compile_count": eng.spec_compile_count,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: one accuracy, shift=1 grid plus the "
                         "adaptive row (cells stay key-comparable to the "
                         "committed full-sweep baseline)")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()

    cfg, model, params = build_tiny(args.arch)
    max_len = args.prompt_len + args.max_new + 8
    rng = np.random.default_rng(0)
    reqs = ragged_requests(args.requests, cfg.vocab, args.prompt_len,
                           args.max_new, rng)
    accuracies = (None,) if args.quick else ACCURACIES
    grid = [(k, s) for k in KS for s in (SHIFTS[:1] if args.quick else SHIFTS)]

    cells = []
    for accuracy in accuracies:
        base = ServeEngine(model, params, batch_slots=args.slots,
                           max_len=max_len, accuracy=accuracy,
                           tune_table=False)
        _warmup(base, reqs)
        baseline_out, base_s, _ = _run(base, reqs)
        acc_s = "unplanned" if accuracy is None else f"{accuracy:.1e}"
        for k, shift in grid:
            cell = sweep_cell(
                model, params, baseline_out, base_s, slots=args.slots,
                max_len=max_len, accuracy=accuracy, k=k, shift=shift,
                adapt=False, reqs=reqs)
            cells.append(cell)
            print(f"k={k} shift={shift} acc={acc_s}: "
                  f"exact={cell['exact_match']} "
                  f"acceptance={cell['acceptance_rate']} "
                  f"verify/token={cell['verify_steps_per_token']} "
                  f"{cell['tok_s']} vs base {cell['baseline_tok_s']} tok/s")
        # the live acceptance controller (draft_shift is its initial rung)
        cell = sweep_cell(
            model, params, baseline_out, base_s, slots=args.slots,
            max_len=max_len, accuracy=accuracy, k=KS[-1], shift=1,
            adapt=True, reqs=reqs)
        cells.append(cell)
        print(f"k={KS[-1]} adaptive: exact={cell['exact_match']} "
              f"final_shift={cell['final_draft_shift']} "
              f"({cell['draft_shift_moves']} moves)")
    doc = {
        "host_backend": jax.default_backend(),
        "arch": args.arch,
        "slots": args.slots,
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "max_new": args.max_new,
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
