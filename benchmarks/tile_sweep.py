"""Tile-kernel sweep: the partitioned-SIMD path vs the switch path
(EXPERIMENTS.md section Tile sweep is generated from this output).

Three cell kinds per shape:

  * **uniform** — one cell per f32-ladder mode: the tile kernel under a
    uniform map must be BIT-identical to ``mp_matmul(impl='pallas')`` at the
    same blocks (the exactness contract), with wall time for both.
  * **runtime** — the zero-recompile dispatch comparison: the tile path must
    trace to 0 ``lax.switch`` equations and exactly 1 fused ``pallas_call``
    where the switch path traces N branches; one compiled executable across
    every mode value; median step wall both ways.
  * **magnitude** — an outlier-heavy workload (background tiles ~1e-3 of the
    hot tile): the magnitude map must use >= 2 distinct modes, stay inside
    its error budget, and cut MXU passes vs forcing the whole matmul to the
    expensive mode (``pass_ratio`` — the machine-independent cost win; wall
    time recorded alongside).

Wall times are CPU-interpret-mode numbers on CI — machine-local, trend-only;
every gate in ``check_regression --tile-new`` is machine-independent.

    PYTHONPATH=src python -m benchmarks.tile_sweep            # full sweep
    PYTHONPATH=src python -m benchmarks.tile_sweep --quick    # CI-sized

Emits ``BENCH_tile.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import F32_MODES, MODE_LIMBS, Mode
from repro.core.rmpm import mp_matmul, mp_matmul_runtime
from repro.kernels.tile_matmul.ops import tile_grid, tile_matmul_auto
from repro.kernels.tile_matmul.tile_policy import dispatch_stats, magnitude_map

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_tile.json")

SIZES = (128, 256)
QUICK_SIZES = (128,)
BLOCK = (64, 64, 64)
BUDGET = 2.0**-12


def _wall_us(fn, *args, iters: int) -> float:
    jax.block_until_ready(fn(*args))  # compile/warm
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _operands(rng, n: int, outlier: bool = False):
    a = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    if outlier:
        # background rows 1e-3 of the hot row-tile: the switch path would
        # pay the expensive mode everywhere for the sake of one tile
        a = (a * 1e-3).at[: BLOCK[0]].set(a[: BLOCK[0]])
    return a, b


def uniform_cells(rng, n: int, iters: int) -> list[dict]:
    a, b = _operands(rng, n)
    cells = []
    for mode in F32_MODES:
        def tile(a_, b_, mode=mode):
            return mp_matmul(a_, b_, mode, impl="tile", block=BLOCK)

        def pallas(a_, b_, mode=mode):
            return mp_matmul(a_, b_, mode, impl="pallas", block=BLOCK)

        t_out = np.asarray(tile(a, b))
        p_out = np.asarray(pallas(a, b))
        cells.append({
            "kind": "uniform",
            "n": n,
            "mode": mode.name,
            "bitwise_equal": bool((t_out == p_out).all()),
            "tile_wall_us": round(_wall_us(jax.jit(tile), a, b, iters=iters), 1),
            "pallas_wall_us": round(_wall_us(jax.jit(pallas), a, b, iters=iters), 1),
        })
    return cells


def runtime_cell(rng, n: int, iters: int) -> dict:
    a, b = _operands(rng, n)

    def tile_fn(a_, b_, s):
        return mp_matmul_runtime(a_, b_, s, impl="tile", block=BLOCK,
                                 allow_auto=False)

    def switch_fn(a_, b_, s):
        return mp_matmul_runtime(a_, b_, s, impl="pallas", block=BLOCK,
                                 allow_auto=False)

    t_stats = dispatch_stats(tile_fn, a, b, jnp.int32(2))
    s_stats = dispatch_stats(switch_fn, a, b, jnp.int32(2))
    tile_jit, switch_jit = jax.jit(tile_fn), jax.jit(switch_fn)
    match = True
    for mv in (1, 2, 3):
        s = jnp.int32(mv)
        match &= bool((np.asarray(tile_jit(a, b, s))
                       == np.asarray(switch_jit(a, b, s))).all())
    return {
        "kind": "runtime",
        "n": n,
        "modes_equal_switch": match,
        "tile_switches": t_stats["switches"],
        "tile_pallas_calls": t_stats["pallas_calls"],
        "switch_switches": s_stats["switches"],
        "switch_pallas_calls": s_stats["pallas_calls"],
        "tile_compile_count": tile_jit._cache_size(),
        "switch_compile_count": switch_jit._cache_size(),
        "tile_wall_us": round(_wall_us(tile_jit, a, b, jnp.int32(3), iters=iters), 1),
        "switch_wall_us": round(
            _wall_us(switch_jit, a, b, jnp.int32(3), iters=iters), 1),
    }


def magnitude_cell(rng, n: int, iters: int) -> dict:
    a, b = _operands(rng, n, outlier=True)
    bm, bn, bk = BLOCK
    mm = np.asarray(magnitude_map(a, b, BUDGET, bm=bm, bn=bn, bk=bk))
    grid, _ = tile_grid(n, n, n, bm=bm, bn=bn, bk=bk)
    gk = grid[2]
    kmax = MODE_LIMBS[Mode.M24]
    # retained Karatsuba passes per tile: k(k+1)/2; uniform-max pays kmax
    # everywhere — the cost the switch path is forced into by one hot tile
    def passes(k):
        return k * (k + 1) // 2

    tile_passes = int(sum(passes(int(k)) for k in mm.ravel()) * gk)
    max_passes = int(passes(kmax) * mm.size * gk)
    out = np.asarray(
        tile_matmul_auto(a, b, BUDGET, bm=bm, bn=bn, bk=bk), np.float64)
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    # the budget is relative to the magnitude envelope S = amax*bmax*K
    scale = float(np.abs(a).max()) * float(np.abs(b).max()) * n
    err = float(np.abs(out - ref).max())
    hist = {Mode(int(v)).name: int(c)
            for v, c in zip(*np.unique(mm, return_counts=True))}
    def auto(a_, b_):
        return tile_matmul_auto(a_, b_, BUDGET, bm=bm, bn=bn, bk=bk)

    def forced(a_, b_):
        return mp_matmul(a_, b_, Mode.M24, impl="tile", block=BLOCK)
    return {
        "kind": "magnitude",
        "n": n,
        "budget": BUDGET,
        "rel_err_vs_envelope": err / scale,
        "budget_met": err <= BUDGET * scale,
        "mode_histogram": hist,
        "modes_used": len(hist),
        "tile_passes": tile_passes,
        "uniform_max_passes": max_passes,
        "pass_ratio": round(tile_passes / max_passes, 4),
        "tile_wall_us": round(_wall_us(jax.jit(auto), a, b, iters=iters), 1),
        "uniform_max_wall_us": round(_wall_us(jax.jit(forced), a, b, iters=iters), 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default=None,
                    help="comma-separated square sizes (default 128,256)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: smallest shape, 1 timing iter")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()

    if args.sizes:
        sizes = tuple(int(s) for s in args.sizes.split(","))
    else:
        sizes = QUICK_SIZES if args.quick else SIZES
    iters = 1 if args.quick else args.iters

    rng = np.random.default_rng(0)
    cells = []
    for n in sizes:
        for cell in uniform_cells(rng, n, iters):
            cells.append(cell)
            print(f"n={n} uniform {cell['mode']}: bitwise={cell['bitwise_equal']} "
                  f"tile {cell['tile_wall_us']}us vs pallas {cell['pallas_wall_us']}us")
        cell = runtime_cell(rng, n, iters)
        cells.append(cell)
        print(f"n={n} runtime: dispatches {cell['tile_pallas_calls']} fused / "
              f"{cell['tile_switches']} switches (switch path: "
              f"{cell['switch_switches']} switch x {cell['switch_pallas_calls']} "
              f"branches), compile x{cell['tile_compile_count']}, "
              f"{cell['tile_wall_us']}us vs {cell['switch_wall_us']}us")
        cell = magnitude_cell(rng, n, iters)
        cells.append(cell)
        print(f"n={n} magnitude: modes={cell['mode_histogram']} "
              f"pass_ratio={cell['pass_ratio']} "
              f"err/envelope={cell['rel_err_vs_envelope']:.1e} "
              f"(budget {cell['budget']:.1e}) "
              f"{cell['tile_wall_us']}us vs forced-M24 {cell['uniform_max_wall_us']}us")
    doc = {
        "host_backend": jax.default_backend(),
        "block": list(BLOCK),
        "budget": BUDGET,
        "iters": iters,
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
