"""Planner sweep: measure (size x mode x depth x impl) and record what the
planner would have picked — the repo's perf trajectory seed (EXPERIMENTS.md
section Plan sweep is generated from this file's output).

    PYTHONPATH=src python -m benchmarks.plan_sweep                 # full sweep
    PYTHONPATH=src python -m benchmarks.plan_sweep --sizes 256,512 --iters 3
    PYTHONPATH=src python -m benchmarks.make_experiments_md        # render

Emits ``BENCH_plan.json``: one record per measured cell with wall time,
cost-model estimate, and the planner's own selection for that (size,
accuracy) so estimate-vs-measured drift is visible in one file.

Wall times here are CPU (this container); the cost model is TPU-balance.
The *ordering* within a lever (fewer passes faster; depth crossover at large
n; limb-copy traffic visible) is what the sweep validates — absolute
microseconds are machine-local.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core.precision import MODE_PASSES, Mode
from repro.plan import estimate, plan_matmul

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_plan.json")

MODES = (Mode.M8, Mode.M16, Mode.M24)
IMPLS = ("native", "xla")  # pallas interpret-mode timing is not meaningful
DEPTHS = (0, 1, 2)
ACCURACIES = (2.0**-4, 2.0**-12, 2.0**-20)


def sweep_cell(n: int, mode: Mode, impl: str, depth: int, iters: int,
               rng: np.random.Generator, stat: str = "median") -> dict:
    a = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))

    def run(x, y):
        from repro.core.rmpm import mp_matmul

        return mp_matmul(x, y, mode, impl=impl, strassen_depth=depth)

    fn = jax.jit(run)
    us = timeit(fn, a, b, warmup=1, iters=iters, stat=stat)
    out = np.asarray(fn(a, b), np.float64)
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    rel = float(np.abs(out - ref).max() / np.abs(ref).max())
    est = estimate(n, n, n, mode, impl, depth)
    return {
        "n": n,
        "mode": mode.name,
        "impl": impl,
        "depth": depth,
        "passes": MODE_PASSES[mode],
        "wall_us": us,
        "rel_err": rel,
        "est_t_us": est.t_total_s * 1e6,
        "est_flops": est.flops,
        "est_hbm_bytes": est.hbm_bytes,
        "est_dominant": est.dominant,
    }


def planner_selections(sizes, backend: str) -> list[dict]:
    recs = []
    for n in sizes:
        for acc in ACCURACIES:
            # tune_table=False: the baseline must be the pure cost model —
            # an ambient TUNE_TABLE env var must not leak into the committed
            # BENCH_plan.json the CI perf-gate compares against
            p = plan_matmul((n, n), (n, n), accuracy=acc, backend=backend,
                            max_depth=2, tune_table=False)
            recs.append({
                "n": n,
                "accuracy": acc,
                "backend": backend,
                "mode": p.mode.name,
                "impl": p.impl,
                "depth": p.strassen_depth,
                "est_t_us": p.cost.t_total_s * 1e6,
                "dominant": p.cost.dominant,
                "reason": p.reason,
            })
    return recs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="256,512,1024")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--skip-measure", action="store_true",
                    help="planner selections only (fast)")
    ap.add_argument("--stat", default="median", choices=("median", "min"),
                    help="per-cell statistic; 'min' is load-robust (CI gate)")
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    rng = np.random.default_rng(0)

    measured = []
    if not args.skip_measure:
        for n in sizes:
            for impl in IMPLS:
                for mode in MODES:
                    if impl == "native" and mode != Mode.M24:
                        continue  # native ignores mode; measure once as ~M24
                    for depth in DEPTHS:
                        if n // (2**depth) < 64:
                            continue
                        rec = sweep_cell(n, mode, impl, depth, args.iters, rng,
                                         stat=args.stat)
                        measured.append(rec)
                        print(
                            f"n={n} {impl}/{mode.name}/d{depth}: "
                            f"{rec['wall_us']:.0f}us rel={rec['rel_err']:.1e}",
                            flush=True,
                        )

    doc = {
        "host_backend": jax.default_backend(),
        "stat": args.stat,
        "sizes": sizes,
        "measured": measured,
        "planner": {
            bk: planner_selections(sizes + (4096, 16384), bk)
            for bk in ("cpu", "tpu")
        },
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}: {len(measured)} measured cells, "
          f"{sum(len(v) for v in doc['planner'].values())} planner selections")


if __name__ == "__main__":
    main()
