"""Benchmarks reproducing the paper's tables/figures (TPU-adapted units).

Paper table -> bench mapping (DESIGN.md section 8):
  Table 2     bench_table2_multiplier_widths   cost vs mantissa width
  Tables 3-6  bench_tables3_6_vs_baselines     vs conventional multipliers
  Table 7     bench_table7_fp_units            full FP unit per mode
  Table 8     bench_table8_single_precision    M24 vs native f32
  Table 9     bench_table9_accuracy            result variation per mode
  Fig 15/16   bench_fig15_16_cost_scaling      relative cost growth
  Fig 17      bench_fig17_precision_variation  error ladder + roundings
  Fig 18      bench_fig18_mode_cost_reduction  cost collapse at low modes
  section 3.1 bench_strassen                   7 vs 8 multiplications
  Fig 7       bench_auto_mode                  auto-mode selection
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    MODE_LIMBS,
    MODE_PASSES,
    Mode,
    auto_mode,
    df32_from_f32,
    mp_matmul,
    mp_matmul_runtime,
    quantize_mantissa,
)
from repro.core.strassen import strassen_matmul
from benchmarks.common import emit, hlo_flops, timeit

_N = 256  # benchmark matmul size (CPU container; structure not speed is the point)


def _ab(seed=0, n=_N):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    return a, b


def _err_vs_f64(out, a, b):
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    o = np.asarray(out, np.float64)
    return float(np.abs(o - ref).max() / np.abs(ref).max())


def bench_table2_multiplier_widths():
    """Paper Table 2: binary multiplier cost vs word length.
    TPU analogue: MXU passes + HLO flops + wall time vs limb count."""
    a, b = _ab()
    base = None
    for mode in (Mode.M8, Mode.M16, Mode.M24, Mode.M32, Mode.M48):
        if mode in (Mode.M32, Mode.M48):
            A, B = df32_from_f32(a), df32_from_f32(b)
        else:
            A, B = a, b
        fn = jax.jit(lambda x, y, m=mode: mp_matmul(x, y, m))
        us = timeit(fn, A, B)
        flops = hlo_flops(lambda x, y, m=mode: mp_matmul(x, y, m), A, B)
        base = base or us
        emit(
            f"table2/multiplier_{8*MODE_LIMBS[mode]}bit",
            us,
            f"passes={MODE_PASSES[mode]};hlo_flops={flops:.3g};rel_cost={us/base:.2f}",
        )


def bench_tables3_6_vs_baselines():
    """Tables 3-6: proposed multiplier vs prior multipliers.
    TPU analogue: RMPM modes vs the conventional units available in XLA —
    f32 dot (DEFAULT) and HIGHEST-precision dot."""
    a, b = _ab(1)
    cases = {
        "baseline_f32_dot": jax.jit(lambda x, y: jnp.dot(x, y)),
        "baseline_f32_highest": jax.jit(
            lambda x, y: jnp.dot(x, y, precision=jax.lax.Precision.HIGHEST)
        ),
        "proposed_M8": jax.jit(lambda x, y: mp_matmul(x, y, Mode.M8)),
        "proposed_M16": jax.jit(lambda x, y: mp_matmul(x, y, Mode.M16)),
        "proposed_M24": jax.jit(lambda x, y: mp_matmul(x, y, Mode.M24)),
    }
    for name, fn in cases.items():
        us = timeit(fn, a, b)
        err = _err_vs_f64(fn(a, b), a, b)
        emit(f"tables3_6/{name}", us, f"max_rel_err={err:.2e}")


def bench_table7_fp_units():
    """Table 7: the full floating-point unit at each precision mode
    (delay grows sub-linearly with precision — the paper's headline)."""
    a, b = _ab(2)
    rows = []
    for mode in (Mode.M8, Mode.M16, Mode.M24):
        fn = jax.jit(lambda x, y, m=mode: mp_matmul(x, y, m))
        us = timeit(fn, a, b)
        err = _err_vs_f64(fn(a, b), a, b)
        rows.append((mode, us, err))
        emit(f"table7/fp_unit_{mode.name}", us, f"max_rel_err={err:.2e}")
    # sub-linearity check: cost ratio between modes < passes ratio
    r_cost = rows[-1][1] / rows[0][1]
    r_passes = MODE_PASSES[Mode.M24] / MODE_PASSES[Mode.M8]
    emit("table7/sublinearity", 0.0, f"cost_ratio={r_cost:.2f};passes_ratio={r_passes:.1f}")


def bench_table8_single_precision():
    """Table 8: proposed single-precision unit vs reference f32 units."""
    a, b = _ab(3)
    ours = jax.jit(lambda x, y: mp_matmul(x, y, Mode.M24))
    ref = jax.jit(lambda x, y: jnp.dot(x, y))
    emit("table8/proposed_M24", timeit(ours, a, b), f"max_rel_err={_err_vs_f64(ours(a,b),a,b):.2e}")
    emit("table8/reference_f32", timeit(ref, a, b), f"max_rel_err={_err_vs_f64(ref(a,b),a,b):.2e}")


def bench_table9_accuracy():
    """Table 9: multiply the paper's own operand (1.605759317 x 2^7, i.e.
    0x4069B130AE804118) by itself in every mode; report the mantissa
    variation vs the exact double product."""
    from repro.core.precision import DoubleF32

    x64 = np.frombuffer(bytes.fromhex("4069b130ae804118"), ">f8")[0].astype(np.float64)
    exact = x64 * x64
    a = jnp.full((8, 8), np.float32(x64))
    # full 52-bit operand as a DoubleF32 (hi/lo split done in numpy f64)
    hi = np.float32(x64)
    lo = np.float32(x64 - np.float64(hi))
    A = DoubleF32(jnp.full((8, 8), hi), jnp.full((8, 8), lo))
    for mode in (Mode.M8, Mode.M16, Mode.M24, Mode.M32, Mode.M48):
        if mode in (Mode.M32, Mode.M48):
            out = mp_matmul(A, A, mode)
            val = float(np.asarray(out.hi, np.float64)[0, 0] + np.asarray(out.lo, np.float64)[0, 0]) / 8
        else:
            val = float(np.asarray(mp_matmul(a, a, mode), np.float64)[0, 0]) / 8
        variation = abs(val - exact) / exact
        paper = {Mode.M8: 2.52915e-4, Mode.M16: 1.58495e-4, Mode.M24: 8.7e-8,
                 Mode.M32: 0.0, Mode.M48: 0.0}[mode]
        emit(f"table9/mode_{mode.name}", 0.0,
             f"mantissa_variation={variation:.3e};paper_reported={paper:.3e}")


def bench_fig15_16_cost_scaling():
    """Figs 15/16: relative change in cost when width doubles —
    the paper's claim: growth is sub-quadratic thanks to Karatsuba."""
    a, b = _ab(4)
    prev = None
    for mode in (Mode.M8, Mode.M16, Mode.M24, Mode.M48):
        A, B = (df32_from_f32(a), df32_from_f32(b)) if mode == Mode.M48 else (a, b)
        us = timeit(jax.jit(lambda x, y, m=mode: mp_matmul(x, y, m)), A, B)
        bits = 8 * MODE_LIMBS[mode]
        if prev is not None:
            emit(f"fig15/{prev[0]}to{bits}bit", us,
                 f"cost_ratio={us/prev[1]:.2f};naive_quadratic_ratio={(bits/prev[0])**2:.2f}")
        prev = (bits, us)


def bench_fig17_precision_variation():
    """Fig 17 + section 3.3.4: error ladder across modes and rounding schemes."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    for keep, label in ((7, "8bit"), (15, "16bit"), (22, "23bit")):
        errs = {}
        for r in ("trunc", "rne", "grte"):
            q = quantize_mantissa(x, keep, r)
            errs[r] = float(jnp.max(jnp.abs((q - x) / x)))
        emit(f"fig17/round_{label}", 0.0,
             f"trunc={errs['trunc']:.2e};rne={errs['rne']:.2e};grte={errs['grte']:.2e}")


def bench_fig18_mode_cost_reduction():
    """Fig 18: cost collapse when a low-precision mode is selected at run
    time — HLO flops of one transformer block per policy mode vs the
    conventional double(-ish) unit (M48)."""
    d = 512
    x = jax.ShapeDtypeStruct((64, d), jnp.float32)
    w = jax.ShapeDtypeStruct((d, d), jnp.float32)
    # conventional always-max-precision unit = M48 pass count (21 passes)
    m24 = hlo_flops(lambda a, b: mp_matmul(a, b, Mode.M24), x, w)
    base = m24 / 6 * 21
    for mode in (Mode.M8, Mode.M16, Mode.M24):
        fl = hlo_flops(lambda a, b, m=mode: mp_matmul(a, b, m), x, w)
        emit(f"fig18/{mode.name}", 0.0,
             f"hlo_flops={fl:.3g};reduction_vs_M48={100*(1-fl/base):.1f}%")


def bench_strassen():
    """Section 3.1: Strassen needs 7 multiplications per 2x2 block level."""
    n = 512
    a, b = _ab(6, n)
    flops_c = hlo_flops(lambda x, y: jnp.dot(x, y), a, b)
    t_c = timeit(jax.jit(lambda x, y: jnp.dot(x, y)), a, b)
    emit("strassen/classical", t_c, f"hlo_flops={flops_c:.4g};leaf_mults=1")
    for depth in (1, 2):
        fn = jax.jit(lambda x, y, d=depth: strassen_matmul(x, y, depth=d, align=64))
        fl = hlo_flops(lambda x, y, d=depth: strassen_matmul(x, y, depth=d, align=64), a, b)
        err = _err_vs_f64(fn(a, b), a, b)
        emit(f"strassen/depth{depth}", timeit(fn, a, b),
             f"hlo_flops={fl:.4g};flops_ratio={fl/flops_c:.3f};leaf_mults={7**depth};max_rel_err={err:.1e}")


def bench_auto_mode():
    """Fig 7: auto-mode picks the cheapest adequate precision at run time."""
    rng = np.random.default_rng(7)
    a_int = jnp.asarray(rng.integers(0, 100, (_N, _N)).astype(np.float32))
    a_f = jnp.asarray(rng.standard_normal((_N, _N)).astype(np.float32))
    m_int = int(auto_mode(a_int, a_int))
    m_f = int(auto_mode(a_f, a_f))
    fn = jax.jit(mp_matmul_runtime)
    us_int = timeit(fn, a_int, a_int, jnp.int32(0))
    us_f = timeit(fn, a_f, a_f, jnp.int32(0))
    exact = np.array_equal(
        np.asarray(fn(a_int, a_int, jnp.int32(0)), np.float64),
        np.asarray(a_int, np.float64) @ np.asarray(a_int, np.float64),
    )
    emit("auto_mode/int_inputs", us_int, f"selected=M{8*m_int};exact_int_product={exact}")
    emit("auto_mode/float_inputs", us_f, f"selected=M{8*m_f}")


ALL = [
    bench_table2_multiplier_widths,
    bench_tables3_6_vs_baselines,
    bench_table7_fp_units,
    bench_table8_single_precision,
    bench_table9_accuracy,
    bench_fig15_16_cost_scaling,
    bench_fig17_precision_variation,
    bench_fig18_mode_cost_reduction,
    bench_strassen,
    bench_auto_mode,
]
