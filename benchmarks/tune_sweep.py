"""Autotuner sweep: measure the candidate space, fit the machine balance,
and record where measurement disagrees with the roofline model.

For every (size, accuracy) cell the sweep plans the matmul twice — pure
roofline (``tune_table=False``) and against the measured table — and records
both picks, the resolution source, and whether they agree.  The output,
``BENCH_tune.json``, feeds the "Measured vs modeled" table in EXPERIMENTS.md
(via ``python -m benchmarks.make_experiments_md --write``).

    PYTHONPATH=src python -m benchmarks.tune_sweep                  # measure fresh
    PYTHONPATH=src python -m benchmarks.tune_sweep --table tuning/cpu.json
    #   ^ reuse a committed table instead of re-measuring
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from repro.plan import DEFAULT_BALANCE, plan_matmul
from repro.tune import TuneTable, tune

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_tune.json")

ACCURACIES = (2.0**-4, 2.0**-12, 2.0**-20)


def _pick(plan) -> dict:
    return {
        "mode": plan.mode.name,
        "impl": plan.impl,
        "depth": plan.strassen_depth,
        "source": plan.source,
        "t_us": round(plan.t_resolved_s * 1e6, 2),
        "block": list(plan.block) if plan.block else None,
    }


def comparison(table: TuneTable, sizes, backend: str, max_depth: int) -> list[dict]:
    rows = []
    for n in sizes:
        for acc in ACCURACIES:
            kwargs = dict(accuracy=acc, backend=backend, max_depth=max_depth)
            modeled = plan_matmul((n, n), (n, n), tune_table=False, **kwargs)
            tuned = plan_matmul((n, n), (n, n), tune_table=table, **kwargs)
            rows.append(
                {
                    "n": n,
                    "accuracy": acc,
                    "modeled": _pick(modeled),
                    "tuned": _pick(tuned),
                    "agree": (modeled.mode, modeled.impl, modeled.strassen_depth)
                    == (tuned.mode, tuned.impl, tuned.strassen_depth),
                }
            )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="128,256,512")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--max-depth", type=int, default=1)
    ap.add_argument(
        "--table",
        default="",
        help="reuse an existing tuning table instead of measuring",
    )
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))

    if args.table:
        table = TuneTable.load(args.table)
        sizes = tuple(sorted({r.m for r in table.records}))
    else:
        table = tune(
            sizes,
            max_depth=args.max_depth,
            iters=args.iters,
            progress=lambda line: print(line, flush=True),
        )
    bal = table.balance
    rows = comparison(table, sizes, table.backend, args.max_depth)
    n_disagree = sum(1 for r in rows if not r["agree"])
    doc = {
        "host_backend": jax.default_backend(),
        "table_backend": table.backend,
        "table_fingerprint": table.fingerprint,
        "sizes": list(sizes),
        "n_records": len(table.records),
        "balance": {
            "fitted_peak_flops": bal.peak_flops,
            "fitted_hbm_bw": bal.hbm_bw,
            "default_peak_flops": DEFAULT_BALANCE.peak_flops,
            "default_hbm_bw": DEFAULT_BALANCE.hbm_bw,
        },
        "records": [r.to_json() for r in table.records],
        "comparison": rows,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(
        f"wrote {args.out}: {len(table.records)} records, "
        f"{len(rows)} comparison cells, {n_disagree} measured-vs-modeled "
        "disagreements"
    )


if __name__ == "__main__":
    main()
