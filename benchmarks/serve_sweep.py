"""Serve sweep: throughput / TTFT / occupancy vs (slots x accuracy mode).

Drives the continuous-batching engine (``repro.serve``) over a saturating
ragged workload for every (slots, accuracy) cell and records steady-state
decode throughput, mean TTFT, slot occupancy, and the per-phase planned
modes — the serving-level view of the paper's run-time precision lever
(EXPERIMENTS.md section Serve sweep is generated from this file's output).

    PYTHONPATH=src python -m benchmarks.serve_sweep                # full sweep
    PYTHONPATH=src python -m benchmarks.serve_sweep --slots 2,4 --requests 8
    PYTHONPATH=src python -m benchmarks.make_experiments_md --write  # render

Emits ``BENCH_serve.json``.  Wall times are CPU (this container): absolute
tok/s is machine-local, but the *trends* — occupancy staying high as slots
grow, the accuracy ladder trading mode passes for throughput — are the
sweep's payload.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.policy import NATIVE_F32
from repro.models import build_model
from repro.serve import ServeEngine, ragged_requests

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

ACCURACIES = (None, 2.0**-4, 2.0**-12)  # None = unplanned native_f32 baseline


def build_tiny(arch: str):
    cfg = get_smoke_config(arch).with_policy(NATIVE_F32)
    cfg = dataclasses.replace(cfg, n_layers=2)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def sweep_cell(model, params, slots: int, accuracy: float | None,
               requests: int, prompt_len: int, max_new: int,
               vocab: int) -> dict:
    rng = np.random.default_rng(0)
    reqs = ragged_requests(requests, vocab, prompt_len, max_new, rng)
    eng = ServeEngine(
        model, params, batch_slots=slots,
        max_len=prompt_len + max_new + 8,
        accuracy=accuracy, prefill_tokens=max(prompt_len // 2, 1),
        # pure-roofline plans: BENCH_serve.json is a CI baseline and must not
        # depend on whether a TUNE_TABLE env var happened to be set
        tune_table=False,
    )
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    outs = eng.drain()
    wall = time.perf_counter() - t0
    s = eng.metrics.summary()
    modes = {
        phase: plans["mlp_up"].mode.name
        for phase, plans in eng.phase_plans.items()
    } or {"prefill": model.cfg.policy.default.name,
          "decode": model.cfg.policy.default.name}
    return {
        "slots": slots,
        "accuracy": accuracy,
        "requests": requests,
        "tokens_out": s["tokens_out"],
        "tok_s": round(s["tok_s"], 2),
        "wall_s": round(wall, 3),
        "ttft_mean_s": round(s["ttft_mean_s"], 4) if s["ttft_mean_s"] else None,
        "latency_mean_s": (round(s["latency_mean_s"], 4)
                           if s["latency_mean_s"] else None),
        "occupancy": round(s["occupancy"], 3),
        "decode_steps": s["decode_steps"],
        "mode_prefill": modes.get("prefill"),
        "mode_decode": modes.get("decode"),
        # runtime-adaptation observability (repro.adapt): static engines
        # report 0 switches and all steps under the planned decode mode
        "mode_switches": s["mode_switches"],
        "mode_occupancy": {k: round(v, 3) for k, v in s["mode_occupancy"].items()},
        "n_ok": len([r for r in reqs if outs.get(r.rid)]),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--slots", default="1,2,4")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()

    cfg, model, params = build_tiny(args.arch)
    slots_list = [int(s) for s in args.slots.split(",")]
    cells = []
    for slots in slots_list:
        for acc in ACCURACIES:
            cell = sweep_cell(model, params, slots, acc, args.requests,
                              args.prompt_len, args.max_new, cfg.vocab)
            cells.append(cell)
            acc_s = f"{acc:.1e}" if acc else "unplanned"
            print(f"slots={slots} accuracy={acc_s}: {cell['tok_s']} tok/s, "
                  f"occupancy {cell['occupancy']}, "
                  f"modes {cell['mode_prefill']}/{cell['mode_decode']}")
    doc = {
        "host_backend": jax.default_backend(),
        "arch": args.arch,
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "max_new": args.max_new,
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
