"""Adapt sweep: static-plan vs closed-loop adapted serving across an
accuracy-SLO sweep (EXPERIMENTS.md Cell H is generated from this output).

For every SLO in the sweep, the same conditioned workload (normal traffic
with an ill-conditioned burst in the middle — repro.adapt.workload) runs
three ways over the same doctored model parameters:

  * ``static-cheap`` — every decode GEMM pinned at M8, no adaptation: the
    fastest static plan, which the hot burst pushes over the error SLO;
  * ``static-safe``  — pinned at M24: meets any SLO by construction, pays
    ~6x the MXU passes for every token including the tame ones;
  * ``adapted``      — starts at M8 under ``ServeEngine(slo=...)``: the
    probe/controller loop shifts the mode table up for the burst and back
    down after, inside one compiled step.

The workload model is widened (``conditioned_model(width=...)``) until
limb-pass count — not host dispatch — dominates the step wall: that is the
regime the paper's delay numbers live in, and the regime where a mode
shift has a measurable price.  Throughput cells are measured on plain
engines (no probe overhead) for the static rows and on the live adaptive
engine (probes included — they are part of the system cost) for the
adapted row.  Error cells come from monitor-mode engines (probes on,
shifts off); static plans never adapt, so their observed errors are
SLO-independent and each static row is measured once and re-scored per
SLO.

    PYTHONPATH=src python -m benchmarks.adapt_sweep            # full sweep
    PYTHONPATH=src python -m benchmarks.adapt_sweep --quick    # CI-sized

Emits ``BENCH_adapt.json``.  Wall times are CPU; the payload is the shape:
adapted err under the SLO that static-cheap violates, at a tok/s between
static-cheap and >= static-safe, with the mode-switch counts showing the
reconfiguration actually happened.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.adapt import SLO, HysteresisController
from repro.adapt.workload import conditioned_model
from repro.core.precision import Mode
from repro.serve import ServeEngine
from repro.serve.metrics import ServeMetrics

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_adapt.json")

SLO_SWEEP = (0.15, 0.1)
#: a run "meets" its SLO when at least this fraction of probe windows do —
#: the adapted run's reaction transient (one probe window per burst onset)
#: is the gap below 1.0 the closed loop inherently pays
MEETS_SLO_RATE = 0.8


def _run_phases(eng: ServeEngine, wl, *, requests: int,
                max_new: int, seed: int) -> dict:
    """Normal -> hot burst -> normal, drained per phase; returns summary."""
    rng = np.random.default_rng(seed)
    n_third = max(requests // 3, 2)
    rid = 0

    def submit(n, hot):
        nonlocal rid
        for r in wl.requests(n, hot=set(range(n)) if hot else set(),
                             rng=rng, max_new=max_new):
            eng.submit(dataclasses.replace(r, rid=rid))
            rid += 1

    t0 = time.perf_counter()
    submit(n_third, hot=False)
    eng.drain()
    submit(n_third, hot=True)
    eng.drain()
    submit(n_third, hot=False)
    eng.drain()
    wall = time.perf_counter() - t0
    s = eng.metrics.summary()
    s["wall_s"] = wall
    s["probe_errs"] = [e for _, e in eng.metrics.probe_errs]
    return s


def _reset(eng: ServeEngine, slo: SLO | None = None) -> None:
    """Fresh metrics/scheduler (and, for probing engines, a fresh controller
    + the table back at its planner initial condition) between measured runs
    — compiled executables are kept, which is the whole point of reuse."""
    from repro.serve.scheduler import Scheduler

    eng.metrics = ServeMetrics(eng.slots)
    eng.scheduler = Scheduler(eng.slots, eng.max_len)
    if eng.mode_table is not None:
        eng.mode_table.reset()
        eng.mode_table.switches = 0
        eng.mode_table.history.clear()
    if slo is not None and eng.controller is not None:
        eng.slo = slo
        eng.controller = HysteresisController(slo)


def _warmup(eng: ServeEngine, wl, seed: int = 99) -> None:
    """One request through the engine to compile prefill/step/probe (long
    enough that a probe actually fires)."""
    rng = np.random.default_rng(seed)
    req = wl.requests(1, hot=set(), rng=rng,
                      max_new=2 * getattr(eng, "adapt_every", 4))[0]
    eng.submit(dataclasses.replace(req, rid=10_000))
    eng.drain()
    _reset(eng)


def _hit_rate(errs: list[float], slo_err: float) -> float | None:
    if not errs:
        return None
    return sum(1 for e in errs if e <= slo_err) / len(errs)


def _cell(label: str, slo_err: float, *, tok_s: float, tokens: int,
          wall: float, errs: list[float], switches: int, occupancy: dict,
          compiled=None) -> dict:
    hit = _hit_rate(errs, slo_err)
    return {
        "label": label,
        "slo_err": slo_err,
        "tok_s": round(tok_s, 2),
        "tokens_out": tokens,
        "wall_s": round(wall, 3),
        "err_mean": round(sum(errs) / len(errs), 5) if errs else None,
        "err_max": round(max(errs), 5) if errs else None,
        "slo_hit_rate": round(hit, 3) if hit is not None else None,
        "meets_slo": hit is not None and hit >= MEETS_SLO_RATE,
        "mode_switches": switches,
        "mode_occupancy": {k: round(v, 3) for k, v in occupancy.items()},
        "compiled_steps": compiled,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=6)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--adapt-every", type=int, default=8)
    ap.add_argument("--width", type=int, default=384,
                    help="conditioned-model d_model (limb passes must "
                         "dominate the step wall for tok/s to respond to "
                         "mode shifts; the hot-cancellation calibration is "
                         "validated at 128 and 384)")
    ap.add_argument("--slos", default="")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: sweep a single SLO.  The workload "
                         "itself is unchanged, so quick cells stay "
                         "ratio-comparable to the committed full-sweep "
                         "baseline")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()

    slos = ([float(s) for s in args.slos.split(",")] if args.slos
            else [SLO_SWEEP[0]] if args.quick else list(SLO_SWEEP))
    # phases are equal thirds: round to what _run_phases will actually submit
    # so the recorded request count matches the workload
    requests = 3 * max(args.requests // 3, 2)
    width = args.width
    run_kw = dict(requests=requests, max_new=args.max_new, seed=args.seed)
    common = dict(batch_slots=args.slots, max_len=6 + args.max_new + 8)
    slo0 = SLO(max_err=slos[0])

    wl8 = conditioned_model(mode=Mode.M8, width=width)
    wl24 = conditioned_model(mode=Mode.M24, width=width)

    # static rows: one throughput run (plain engine) + one monitor run
    # (probes on, shifts off) each — SLO-independent, re-scored per SLO
    static = {}
    for label, wl in (("static-cheap", wl8), ("static-safe", wl24)):
        eng = ServeEngine(wl.model, wl.params, **common)
        _warmup(eng, wl)
        s = _run_phases(eng, wl, **run_kw)
        mon = ServeEngine(wl.model, wl.params, slo=slo0, adapt=False,
                          adapt_every=args.adapt_every, **common)
        _warmup(mon, wl)
        m = _run_phases(mon, wl, **run_kw)
        static[label] = (s, m)
        print(f"{label}: {s['tok_s']:.1f} tok/s, err mean "
              f"{np.mean(m['probe_errs'] or [0]):.4f} max "
              f"{np.max(m['probe_errs'] or [0]):.4f}")

    adapted = ServeEngine(wl8.model, wl8.params, slo=slo0,
                          adapt_every=args.adapt_every, **common)
    _warmup(adapted, wl8)

    cells = []
    for slo_err in slos:
        for label in ("static-cheap", "static-safe"):
            s, m = static[label]
            cells.append(_cell(
                label, slo_err, tok_s=s["tok_s"], tokens=s["tokens_out"],
                wall=s["wall_s"], errs=m["probe_errs"], switches=0,
                occupancy=m["mode_occupancy"]))
        _reset(adapted, SLO(max_err=slo_err))
        s = _run_phases(adapted, wl8, **run_kw)
        cells.append(_cell(
            "adapted", slo_err, tok_s=s["tok_s"], tokens=s["tokens_out"],
            wall=s["wall_s"], errs=s["probe_errs"],
            switches=s["mode_switches"], occupancy=s["mode_occupancy"],
            compiled=adapted.decode_compile_count))
        for c in cells[-3:]:
            print(f"slo={slo_err} {c['label']}: {c['tok_s']} tok/s, "
                  f"err mean {c['err_mean']} max {c['err_max']}, "
                  f"hit rate {c['slo_hit_rate']}, "
                  f"{c['mode_switches']} switches, "
                  f"meets_slo={c['meets_slo']}")
    doc = {
        "host_backend": jax.default_backend(),
        "workload": "repro.adapt.workload.conditioned_model",
        "width": width,
        "slots": args.slots,
        "requests": requests,
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
