"""Hysteresis mode controller: observed error + latency headroom -> shifts.

Decision rule (DESIGN.md section Runtime adaptation, invariants i-iv):

  i.   **Up** when the observed error at the current modes exceeds
       ``slo.max_err``.  Accuracy always beats latency: an up-shift is never
       suppressed by a latency target.
  ii.  **Down** only when the *measured would-be* error one mode down
       (``err_down``, from the probe's one-down shadow) sits inside the dead
       band — below ``slo.max_err * down_factor``.  Because the decision is
       based on the measured error of the configuration being entered (not
       the one being left), a down-shift can never immediately violate the
       SLO it just checked: no up/down thrash at a boundary.
  iii. ``down_factor < 1`` strictly — the dead band
       ``[max_err * down_factor, max_err]`` is where the controller holds.
       A latency violation (``step_ms > slo.target_ms``) relaxes the down
       threshold from ``max_err * down_factor`` to ``max_err`` itself: under
       latency pressure the controller trades the accuracy *margin*, never
       the SLO.
  iv.  **Cooldown**: at least ``cooldown`` probe observations between
       shifts, bounding the reconfiguration rate.

The controller is engine-agnostic: ``observe`` takes scalars, returns a
shift in {-1, 0, +1}; the caller applies it to its
:class:`~repro.adapt.runtime_policy.ModeTable`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.adapt.probe import GradDriftProbe
from repro.adapt.runtime_policy import ModeTable
from repro.obs import NULL_TRACER


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-call-site service-level objective the controller enforces.

    ``max_err``: ceiling on the probe's observed relative error (for serving,
    the normalized logit residual vs the max-mode reference; for training,
    the grad-norm drift).  ``target_ms``: optional per-step latency target —
    overshooting it applies downward pressure within the accuracy SLO.
    """

    max_err: float
    target_ms: float | None = None
    down_factor: float = 0.25

    def __post_init__(self):
        if self.max_err <= 0:
            raise ValueError(f"max_err must be positive, got {self.max_err}")
        if not (0.0 < self.down_factor < 1.0):
            raise ValueError(
                f"down_factor must be in (0, 1) for hysteresis, got "
                f"{self.down_factor}"
            )


@dataclasses.dataclass
class Observation:
    step: Any
    err: float
    err_down: float
    step_ms: float | None
    decision: int


class HysteresisController:
    #: trace sink + instance label (repro.obs) — the engine swaps in its
    #: live tracer and names each controller ("adapt", "adapt/<tenant>",
    #: "accept"); the class defaults keep standalone controllers emit-free
    tracer = NULL_TRACER
    name = "adapt"

    def __init__(self, slo: SLO, cooldown: int = 2):
        self.slo = slo
        self.cooldown = max(int(cooldown), 0)
        self.history: list[Observation] = []
        self._since_shift = self.cooldown  # first observation may act
        #: why the last observation decided what it did — the cause stamp
        #: the engine copies onto mode_switch / draft_shift trace events
        self.last_cause: str | None = None

    @property
    def up_shifts(self) -> int:
        return sum(1 for o in self.history if o.decision > 0)

    @property
    def down_shifts(self) -> int:
        return sum(1 for o in self.history if o.decision < 0)

    def observe(self, step: Any, err: float, err_down: float | None = None,
                step_ms: float | None = None, *, can_up: bool = True,
                can_down: bool = True) -> int:
        """One probe observation -> shift in {-1, 0, +1}.

        ``err``: observed error at the current modes (vs the max-mode
        reference).  ``err_down``: measured would-be error one mode down
        (None -> ``err``, the conservative degenerate form used when no
        down-shadow ran).  ``step_ms``: decode-step wall time for the
        latency term.  ``can_up``/``can_down``: ladder headroom — a clamped
        table cannot shift, so the decision is suppressed rather than
        recorded as a phantom switch.
        """
        if err_down is None:
            err_down = err
        decision = 0
        cause = "hold"
        if self._since_shift < self.cooldown:
            cause = "cooldown"
        else:
            down_limit = self.slo.max_err * self.slo.down_factor
            relaxed = (self.slo.target_ms is not None and step_ms is not None
                       and step_ms > self.slo.target_ms)
            if relaxed:
                # latency pressure: spend accuracy margin, never the SLO (iii)
                down_limit = self.slo.max_err
            if err > self.slo.max_err and can_up:
                decision = +1
                cause = "err_violation"
            elif err_down <= down_limit and can_down:
                decision = -1
                # distinguish "the dead band cleared on its own" from "the
                # latency term spent the margin" — the Why of the trace
                cause = ("latency_pressure"
                         if relaxed and err_down > self.slo.max_err
                         * self.slo.down_factor else "clean_streak")
        self.last_cause = cause
        if self.tracer.enabled:
            self.tracer.emit(
                "adapt_decision", cause=cause, controller=self.name,
                decision=decision, err=float(err), err_down=float(err_down),
                step_ms=step_ms, can_up=can_up, can_down=can_down)
        self.history.append(Observation(step, float(err), float(err_down),
                                        step_ms, decision))
        if decision:
            self._since_shift = 0
        else:
            self._since_shift += 1
        return decision


class TrainPrecisionSchedule:
    """Grad-norm-drift-driven precision schedule for the training loop.

    Wraps a :class:`ModeTable` + :class:`HysteresisController` +
    :class:`GradDriftProbe` behind the two calls ``train_loop`` makes:
    ``mode_scalars()`` (the extra jit argument of the modal train step) and
    ``observe(step, metrics, dt)``.  Natural dynamics: warmup drift holds
    precision up, a stabilized grad norm lets the schedule relax down the
    ladder, and a drift spike (loss-scale trouble, data shift) shifts it
    back up within ``cooldown`` observations.
    """

    def __init__(self, table: ModeTable, slo: SLO, *,
                 controller: HysteresisController | None = None,
                 probe: GradDriftProbe | None = None, every: int = 1):
        self.table = table
        self.controller = controller or HysteresisController(slo)
        self.probe = probe or GradDriftProbe()
        self.every = max(int(every), 1)

    def mode_scalars(self) -> dict:
        return self.table.scalars()

    def observe(self, step: int, metrics: dict, dt_s: float | None = None) -> int:
        """Feed one step's metrics; returns the applied shift (0 off-probe).

        The drift probe updates every step (EWMA continuity); the controller
        only acts every ``self.every`` steps.
        """
        drift = self.probe.update(float(metrics["grad_norm"]))
        if step % self.every:
            return 0
        decision = self.controller.observe(
            step, err=drift, err_down=drift,
            step_ms=None if dt_s is None else dt_s * 1e3,
            # ladder headroom: a clamped table must not consume the cooldown
            # with phantom decisions (that would delay a genuine up-shift)
            can_up=not self.table.at_max, can_down=not self.table.at_min,
        )
        if decision:
            self.table.shift_all(decision, tag=step)
        return decision
