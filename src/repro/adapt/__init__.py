"""repro.adapt — closed-loop run-time precision adaptation.

The paper's headline property — "adjust the power and delay requirements
according to different accuracy requirements by reconfiguring itself during
run time" — as a feedback loop over the RMPM engine:

    runtime_policy.py  mutable site->mode table + trace-time mode binding
                       (the mode-select bits as jit arguments: zero recompiles)
    probe.py           online error probes (shadow-forward logit residual,
                       sampled-row matmul residual, grad-norm drift)
    controller.py      SLO + dual-threshold hysteresis controller, and the
                       training-loop precision schedule
    workload.py        the synthetic ill-conditioned serving workload that
                       exercises the loop end to end (tests + adapt_sweep)

The planner's static pick (repro.plan) is the mode table's initial
condition; `ServeEngine(slo=...)` and `train_loop(adapt=...)` close the
loop.  See DESIGN.md section Runtime adaptation.
"""
from repro.adapt.controller import (  # noqa: F401
    SLO,
    HysteresisController,
    TrainPrecisionSchedule,
)
from repro.adapt.pages import (  # noqa: F401
    PageTierController,
    PageTierPolicy,
)
from repro.adapt.probe import (  # noqa: F401
    GradDriftProbe,
    logit_residual,
    sampled_matmul_residual,
    softmax_tv,
)
from repro.adapt.runtime_policy import (  # noqa: F401
    DEFAULT_SITES,
    ModeTable,
    bind_modes,
    runtime_mode_for,
)
