"""Precision-tiered KV pages: demotion/promotion policy for the paged cache.

The paper's run-time precision reconfiguration applied to decode *memory*
(DESIGN.md section Paged KV cache): cold pages — pages whose newest token
sits far enough behind the row's decode head — are mantissa-truncated in
place by the ``quantize_mantissa`` Pallas kernel, one tier at a time down a
keep-bits ladder.  The closed loop reuses the hysteresis machinery from
repro.adapt.controller verbatim:

  * ``err``      — the relative residual actually introduced by this tick's
                   demotions at the current tier depth;
  * ``err_down`` — the *measured would-be* residual of truncating the same
                   cold pages one tier deeper (computed, never applied);
  * decision +1  — promote: the allowed depth retreats one tier and every
                   page below the new floor is re-labelled at the floor.

**Tier invariant (lossy demotion, label promotion):** truncation is
in-place, so the dropped mantissa bits are gone; "promotion" restores the
*floor* — it stops further loss, re-labels over-demoted pages, and every new
append lands at full precision — it does not resurrect lost bits.  At
``budget=None`` the ladder runs open loop at full depth (the benchmark's
memory-vs-accuracy endpoint); with a budget the controller holds the
measured residual inside ``[budget * down_factor, budget]``.
"""
from __future__ import annotations

import dataclasses

from repro.adapt.controller import SLO, HysteresisController

#: tier label for a page that has never been demoted (keep-bits sentinel
#: larger than any real mantissa width — bf16 has 7 explicit bits)
HOT = 99


@dataclasses.dataclass(frozen=True)
class PageTierPolicy:
    """Demotion policy for precision-tiered KV pages.

    ``levels``: the keep-bits ladder, shallowest first (bf16 pools have 7
    explicit mantissa bits, so levels below 7 truncate).  ``cold_after``:
    tokens a page's newest entry must trail the row head before the page is
    demotion-eligible.  ``every``: engine decode steps between tier ticks.
    ``budget``: closed-loop residual ceiling (None = open loop at full
    depth).  ``rounding``: quantize_mantissa rounding mode.
    """

    levels: tuple[int, ...] = (5, 3)
    cold_after: int = 32
    every: int = 8
    budget: float | None = None
    rounding: str = "trunc"
    cooldown: int = 2

    def __post_init__(self):
        if not self.levels:
            raise ValueError("levels must name at least one keep-bits tier")
        if any(b < 1 for b in self.levels):
            raise ValueError(f"keep bits must be >= 1, got {self.levels}")
        if list(self.levels) != sorted(self.levels, reverse=True):
            raise ValueError(
                f"levels must descend (shallowest tier first), got "
                f"{self.levels}")
        if self.cold_after < 1:
            raise ValueError("cold_after must be >= 1")
        if self.every < 1:
            raise ValueError("every must be >= 1")
        if self.budget is not None and self.budget <= 0:
            raise ValueError(f"budget must be positive, got {self.budget}")


class PageTierController:
    """Maps the page-residual probe onto a HysteresisController.

    ``depth`` is how far down the ladder demotion may reach (0 = tiering
    effectively off).  With a budget the controller starts at depth 0 and
    only deepens when the measured would-be residual one tier down sits in
    the dead band — the same never-enter-a-violating-config rule the mode
    controller enforces (controller invariant ii).  Without a budget the
    ladder runs open loop at full depth.
    """

    def __init__(self, policy: PageTierPolicy):
        self.policy = policy
        if policy.budget is None:
            self.depth = len(policy.levels)
            self.ctrl = None
        else:
            self.depth = 0
            self.ctrl = HysteresisController(
                SLO(max_err=policy.budget), cooldown=policy.cooldown)
        self.promotions = 0  # applied +1 decisions (floor retreats)
        self.demotions = 0  # applied -1 decisions (floor deepens)

    @property
    def target_keep(self) -> int | None:
        """Keep-bits demotion-eligible cold pages truncate to right now
        (None: depth 0, nothing demotes)."""
        if self.depth == 0:
            return None
        return self.policy.levels[self.depth - 1]

    @property
    def next_keep(self) -> int | None:
        """One tier deeper than the current floor (the err_down shadow);
        None when the ladder is exhausted."""
        if self.depth < len(self.policy.levels):
            return self.policy.levels[self.depth]
        return None

    def observe(self, step: int, err: float, err_down: float) -> int:
        """One tier tick's measured residuals -> depth move in {-1, 0, +1}.
        Open-loop controllers never move."""
        if self.ctrl is None:
            return 0
        decision = self.ctrl.observe(
            step, err, err_down,
            can_up=self.depth > 0,
            can_down=self.depth < len(self.policy.levels))
        if decision > 0:
            self.depth -= 1
            self.promotions += 1
        elif decision < 0:
            self.depth += 1
            self.demotions += 1
        return decision

    def describe(self) -> str:
        mode = ("open-loop" if self.ctrl is None
                else f"budget={self.policy.budget:g}")
        tgt = self.target_keep
        return (f"tiers {self.policy.levels} ({mode}) depth={self.depth} "
                f"keep={'hot' if tgt is None else tgt} | "
                f"{self.promotions} promotions / {self.demotions} deepenings")
