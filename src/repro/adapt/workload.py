"""Synthetic ill-conditioned serving workload for the adaptation loop.

The closed loop (probe -> controller -> mode table) is only demonstrable on
a workload whose numerical error genuinely depends on the *data*.  Floating
point is scale-invariant, so "big inputs" prove nothing; what low RMPM
modes actually lose is *cancellation* — sums whose true value is far
smaller than their terms.  This module doctors a 1-layer dense model so a
designated set of "hot" token ids manufactures exactly that inside the
decode step's attention, while ordinary tokens stay numerically tame:

  * queries are constant (``wq = 0``, bias-only along a slow-RoPE direction
    ``kappa_q``), keys respond only to a hot direction ``a`` that ordinary
    embeddings have projected out — ordinary traffic gets zero scores
    (uniform attention), hot tokens get distinct softmax weights
    ``w in {4, 1, 3, 2}`` solved from their embedding's ``a`` component;
  * values carry a payload ``±g1 * nu`` whose *weighted sum cancels
    exactly* (4 + 1 = 3 + 2 with opposite payload signs): the true
    attention output is ordinary-sized, but a low-mode step truncates the
    four distinct softmax weights independently, leaving an error of order
    ``payload * 2^-8`` at M8 (and ``* 2^-16`` at M16) that the widened
    output projection ``wo += Mo * outer(nu, rho)`` amplifies into the
    logits;
  * every natural signal path through attention is shrunk (``wv * 0.02``)
    so ordinary tokens' probe error stays near the model-wide M8 floor.

Result (validated in tests/test_adapt.py): the probe's logit residual at M8
sits ~an order of magnitude above the SLO while hot requests occupy slots
and falls back below the moment they drain — the data-dependent error
signal the paper's run-time reconfiguration story needs, with knobs
(``payload_gain``) to move it relative to an SLO.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.policy import PrecisionPolicy
from repro.core.precision import Mode
from repro.models import build_model
from repro.serve.scheduler import Request

#: softmax weight (w) and payload sign per hot token: sum(w+) == sum(w-)
_HOT_WEIGHTS = ((4.0, +1), (1.0, +1), (3.0, -1), (2.0, -1))


@dataclasses.dataclass
class ConditionedWorkload:
    """A doctored model + the token vocabulary split driving it."""

    cfg: object
    model: object
    params: dict
    hot_ids: tuple[int, ...]  # ids that manufacture cancellation
    safe_vocab: int  # ordinary prompts draw from [0, safe_vocab)

    def hot_prompt(self, rng: np.random.Generator, length: int = 6) -> np.ndarray:
        ids = list(self.hot_ids)
        pad = rng.integers(0, self.safe_vocab,
                           max(length - len(ids), 0)).tolist()
        return np.asarray(pad[:1] + ids + pad[1:], np.int32)

    def normal_prompt(self, rng: np.random.Generator, length: int = 6) -> np.ndarray:
        return rng.integers(0, self.safe_vocab, length).astype(np.int32)

    def requests(self, n: int, hot: set[int] | frozenset[int],
                 rng: np.random.Generator, *, prompt_len: int = 6,
                 max_new: int = 8) -> list[Request]:
        """n requests with rids 0..n-1; rids in ``hot`` get hot prompts."""
        return [
            Request(
                prompt=(self.hot_prompt(rng, prompt_len) if i in hot
                        else self.normal_prompt(rng, prompt_len)),
                max_new=max_new, rid=i,
            )
            for i in range(n)
        ]


def _unit(v: np.ndarray) -> np.ndarray:
    return v / np.linalg.norm(v)


def conditioned_model(
    arch: str = "qwen1.5-0.5b",
    *,
    mode: Mode = Mode.M8,
    payload_gain: float = 40.0,
    score_offset: float = 3.0,
    n_hot: int = 8,
    seed: int = 7,
    width: int | None = None,
    value_gain: float = 1.0,
) -> ConditionedWorkload:
    """Build the doctored 1-layer model (see module docstring).

    ``mode`` sets the model policy's default RMPM mode — the static
    operating point the adaptation loop starts from.  ``payload_gain`` (the
    ``Mo`` output-projection amplifier) scales the hot error signal
    relative to the ordinary-traffic floor.  ``width`` overrides d_model
    (d_ff = 2x, head_dim scaled to keep 4 heads): tests keep the fast smoke
    width, the adapt benchmark widens the GEMMs until limb-pass count —
    not host dispatch — dominates the step wall (the regime the paper's
    delay numbers live in).
    """
    cfg = get_smoke_config(arch)
    if not cfg.qkv_bias:
        raise ValueError("conditioned_model needs an arch with qkv_bias "
                         "(the constant-query construction uses b_q)")
    # huge rope_theta: the slow-dim key direction is position-invariant, so
    # all hot keys coincide and their softmax weights come out exactly as
    # solved below
    over = {}
    if width is not None:
        over = dict(d_model=width, d_ff=2 * width, n_heads=4, n_kv_heads=2,
                    head_dim=width // 4)
    cfg = dataclasses.replace(
        cfg, n_layers=1, rope_theta=1e9,
        policy=PrecisionPolicy(default=Mode(mode)), **over,
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    d, hkv, hq, hd = cfg.d_model, cfg.n_kv_heads, cfg.n_heads, cfg.head_dim
    half = hd // 2
    rng = np.random.default_rng(seed)

    a = _unit(rng.normal(size=(d,)))  # key-exciting direction (hot only)
    c = rng.normal(size=(d,)); c -= (c @ a) * a; c = _unit(c)  # payload sign
    kap = np.zeros((hkv, hd)); kap[:, half - 1] = 1.0; kap[:, hd - 1] = 1.0
    kappa = _unit(kap.reshape(-1))  # slow-RoPE dims of every kv head
    kq = np.zeros((hq, hd)); kq[:, half - 1] = 1.0; kq[:, hd - 1] = 1.0
    kappa_q = _unit(kq.reshape(-1))
    nu = _unit(rng.normal(size=(hkv * hd,)))  # value payload direction

    seg = next(iter(params["layers"]))
    attn = params["layers"][seg]["attn"]
    mq = mk = 2.7
    g0, g1 = 0.3, float(value_gain)
    attn["wq"]["w"] = jnp.zeros_like(attn["wq"]["w"])
    bq = np.asarray(attn["wq"]["b"]).copy()
    bq[0] = mq * kappa_q
    attn["wq"]["b"] = jnp.asarray(bq.astype(np.float32))
    attn["wk"]["w"] = jnp.asarray(
        (mk * np.outer(a, kappa))[None].astype(np.float32))
    wv0 = np.asarray(attn["wv"]["w"])[0]
    attn["wv"]["w"] = jnp.asarray(
        (0.02 * wv0 + g1 * np.outer(c, nu))[None].astype(np.float32))
    rho = rng.normal(size=(d,))
    rho -= (rho @ a) * a; rho -= (rho @ c) * c; rho = _unit(rho)
    nu_q = np.broadcast_to(
        nu.reshape(hkv, hd), (hkv, hq // hkv, hd)).reshape(-1)
    wo = np.asarray(attn["wo"]["w"]).copy()
    wo[0] += payload_gain * np.outer(nu_q, rho)
    attn["wo"]["w"] = jnp.asarray(wo.astype(np.float32))

    emb = np.asarray(params["embed"]["w"]).copy()
    emb = emb - np.outer(emb @ a, a) - np.outer(emb @ c, c)
    hot_ids = tuple(range(cfg.vocab - n_hot, cfg.vocab))
    # score per unit of embedding a-component (two slow dims per head, rms
    # norm maps a unit embedding onto a sqrt(d)-length direction)
    k_score = (mq * mk / (np.sqrt(hd) * np.sqrt(2 * hq) * np.sqrt(2 * hkv))
               * 2) * np.sqrt(d)
    for i, t in enumerate(hot_ids):
        w, sgn = _HOT_WEIGHTS[i % len(_HOT_WEIGHTS)]
        f = (score_offset + np.log(w)) / k_score
        h = np.sqrt(max(1.0 - f * f - g0 * g0, 1e-4))
        b = rng.normal(size=(d,))
        b -= (b @ a) * a; b -= (b @ c) * c
        emb[t] = f * a + sgn * g0 * c + h * _unit(b)
    params["embed"]["w"] = jnp.asarray(emb.astype(np.float32))

    return ConditionedWorkload(
        cfg=cfg, model=model, params=params, hot_ids=hot_ids,
        safe_vocab=cfg.vocab - n_hot,
    )
