"""Cheap online error probes for the runtime adaptation loop.

The controller needs a live estimate of the numerical error the *current*
mode table inflicts.  Truth is unavailable online, so the probes compare
against the most precise runtime-switchable configuration — the mode table
shifted to its max (M24), run through the SAME compiled step with different
mode scalars (zero recompiles; that shared executable is the point of
`repro.adapt.runtime_policy`).  Three signals, cheapest first:

  * :func:`logit_residual` — normalized max-abs logit deviation between a
    low-mode and reference forward, masked to active slots.  Scale-
    normalized by the reference logit spread so one SLO threshold works
    across workloads; softmax-space total variation (:func:`softmax_tv`) is
    available when the caller cares about sampling fidelity rather than raw
    numerics.
  * :func:`sampled_matmul_residual` — the ISSUE's "sampled-row residual vs
    a one-mode-up shadow matmul": re-multiplies a row sample of one GEMM at
    ``mode`` and ``mode+1`` and reports the relative gap.  O(sample·K·N)
    instead of O(M·K·N) — a per-call-site probe for hosts that cannot
    afford shadow forwards.
  * :class:`GradDriftProbe` — EWMA drift of the gradient norm, the training
    loop's error surrogate (loss-scale blowups and underflow both announce
    themselves as grad-norm drift long before the loss diverges).

Probe cost is budgeted by the caller (`ServeEngine(adapt_every=N)` probes
every N decode steps: two shadow forwards per probe, amortized 2/N of a
step).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.precision import Mode
from repro.core.rmpm import mp_matmul

Array = jax.Array

_EPS = 1e-9


def logit_residual(logits_lo: Array, logits_ref: Array,
                   active: Array | None = None) -> Array:
    """max over active rows of max-abs logit deviation, normalized by the
    reference row's logit spread (std): a scale-free observed-error metric.

    Args:
      logits_lo / logits_ref: (B, V) last-position logits of the probed and
        reference forwards.
      active: (B,) bool — rows currently serving a request; inactive rows
        are frozen state and carry no meaningful logits.
    """
    diff = jnp.max(jnp.abs(logits_lo - logits_ref), axis=-1)  # (B,)
    spread = jnp.std(logits_ref, axis=-1) + _EPS
    err = diff / spread
    if active is not None:
        err = jnp.where(active, err, 0.0)
    return jnp.max(err)


def softmax_tv(logits_lo: Array, logits_ref: Array,
               active: Array | None = None) -> Array:
    """Total-variation distance between next-token distributions (max over
    active rows) — the sampling-fidelity view of the same residual."""
    tv = 0.5 * jnp.sum(
        jnp.abs(jax.nn.softmax(logits_lo, axis=-1)
                - jax.nn.softmax(logits_ref, axis=-1)),
        axis=-1,
    )
    if active is not None:
        tv = jnp.where(active, tv, 0.0)
    return jnp.max(tv)


def sampled_matmul_residual(
    x: Array,
    w: Array,
    mode: Mode | int,
    *,
    sample_rows: int = 4,
    key: Array | None = None,
    rounding: str = "rne",
) -> Array:
    """Relative error of ``x @ w`` at ``mode`` vs one mode up, on a row
    sample of ``x``.  Returns a scalar: max-abs deviation / max-abs of the
    shadow result.  ``mode`` at the top of the f32 ladder returns 0 (there
    is no switchable mode above to shadow with)."""
    mode = Mode(mode)
    up = Mode(min(int(mode) + 1, int(Mode.M24)))
    n = x.shape[0]
    k = min(sample_rows, n)
    if key is None:
        rows = jnp.arange(k)
    else:
        rows = jax.random.choice(key, n, shape=(k,), replace=False)
    xs = x[rows]
    lo = mp_matmul(xs, w, mode, rounding=rounding)
    hi = mp_matmul(xs, w, up, rounding=rounding)
    return jnp.max(jnp.abs(lo - hi)) / (jnp.max(jnp.abs(hi)) + _EPS)


@dataclasses.dataclass
class GradDriftProbe:
    """EWMA drift of the gradient norm: ``drift = |gn - ewma| / ewma``.

    Warmup steps (the first ``warmup`` observations) return 0 — compile-step
    and init transients must not trigger mode shifts.
    """

    alpha: float = 0.1
    warmup: int = 3
    ewma: float = 0.0
    n: int = 0

    def update(self, grad_norm: float) -> float:
        self.n += 1
        if self.n <= self.warmup:
            self.ewma = grad_norm
            return 0.0
        drift = abs(grad_norm - self.ewma) / (self.ewma + _EPS)
        self.ewma += self.alpha * (grad_norm - self.ewma)
        return drift
