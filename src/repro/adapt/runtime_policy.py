"""Mutable runtime mode table + the trace-time binding that layers read.

This is the half of `repro.adapt` that touches the compiled step.  The
paper's mode-select bits are *runtime inputs* of the multiplier — no
re-synthesis when they change.  The TPU translation (DESIGN.md section
Runtime adaptation): the decode/train step is compiled ONCE with one int32
mode scalar per call-site as a traced argument; `models/layers.pmm`/`pein`
route bound sites through ``mp_matmul_runtime``/``mp_einsum_runtime``'s
``lax.switch``, so changing a mode between steps changes which branch runs,
never what is compiled.

Two pieces:

  * :class:`ModeTable` — host-side mutable ``site -> Mode`` map over the
    runtime-switchable f32 ladder {M8, M16, M24}.  The planner's static pick
    (``ModeTable.from_plans``) is merely the table's initial condition; the
    controller (`repro.adapt.controller`) shifts it afterwards.
  * :func:`bind_modes` — a trace-time context manager installing the
    table's scalars for the duration of one traced step.  ``pmm``/``pein``
    consult :func:`runtime_mode_for` at trace time; unbound sites keep their
    static plan, so a model traced outside any binding is bit-identical to
    the pre-adaptation dispatch.
"""
from __future__ import annotations

import contextlib
from typing import Any, Iterable, Mapping

import jax.numpy as jnp

from repro.core.precision import F32_MODES, Mode

#: call-sites every transformer-family model routes through pmm/pein
#: (models/layers.py); moe adds router/moe_expert via plan_model_policy.
DEFAULT_SITES = (
    "qkv", "out", "mlp_up", "mlp_down", "logits", "attn_qk", "attn_av",
)

# Stack of bound {site: int32 scalar} dicts.  Tracing is single-threaded per
# jit call and the binding wraps the traced region, so a plain module-level
# stack is sufficient (and survives nested bindings: innermost wins).
_BOUND: list[dict[str, Any]] = []


@contextlib.contextmanager
def bind_modes(modes: Mapping[str, Any]):
    """Install runtime mode scalars for the enclosed trace.

    ``modes`` maps call-site names to int32 scalars (typically traced jit
    arguments — that is the zero-recompile property).  A ``"*"`` key acts as
    the default for sites not named explicitly.
    """
    _BOUND.append(dict(modes))
    try:
        yield
    finally:
        _BOUND.pop()


def runtime_mode_for(op: str):
    """The bound mode scalar for ``op``, or None when ``op`` is not adapted
    (static-plan dispatch).  Called by pmm/pein at trace time."""
    if not _BOUND:
        return None
    top = _BOUND[-1]
    return top.get(op, top.get("*"))


class ModeTable:
    """Mutable per-call-site RMPM mode table over the f32 ladder.

    The table is host state: reading it (``scalars()``) yields the int32
    device scalars fed to the compiled step each call, mutating it
    (``shift_all``/``set``) changes what the *next* step's ``lax.switch``
    selects.  Modes are clamped to ``[min_mode, max_mode]`` — the runtime-
    switchable branches that exist in the executable.
    """

    def __init__(self, sites: Mapping[str, Mode | int],
                 min_mode: Mode = Mode.M8, max_mode: Mode = Mode.M24):
        if not sites:
            raise ValueError("ModeTable needs at least one call-site")
        self.min_mode = Mode(min_mode)
        self.max_mode = Mode(max_mode)
        for m in (self.min_mode, self.max_mode):
            if m not in F32_MODES:
                raise ValueError(
                    f"{m.name} is not runtime-switchable (f32 ladder only)")
        self._baseline = {k: self._clamp(Mode(v)) for k, v in sites.items()}
        self._modes = dict(self._baseline)
        self.switches = 0
        #: list of (decode_step_or_tag, {site: Mode}) snapshots, one per change
        self.history: list[tuple[Any, dict[str, Mode]]] = []
        # device-scalar cache: rebuilt only on mutation, so the per-step cost
        # of feeding the compiled step is a dict of already-committed arrays
        self._scalar_cache: dict[int, dict[str, Any]] = {}

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_plans(cls, plans: Mapping[str, Any], **kw) -> "ModeTable":
        """Initial condition from the planner's per-op plans (repro.plan):
        only runtime-switchable plans join the table — DF32 / pinned-exotic
        sites keep their static execution path."""
        sites = {
            op: p.mode for op, p in plans.items()
            if p.mode in F32_MODES and getattr(p, "dtype", "float32") == "float32"
        }
        if not sites:
            raise ValueError("no runtime-switchable sites among the plans")
        return cls(sites, **kw)

    @classmethod
    def from_policy(cls, policy: Any,
                    sites: Iterable[str] = DEFAULT_SITES, **kw) -> "ModeTable":
        picked = {
            op: policy.mode_for(op) for op in sites
            if policy.mode_for(op) in F32_MODES
        }
        if not picked:
            raise ValueError("policy has no runtime-switchable sites")
        return cls(picked, **kw)

    # -- reads ---------------------------------------------------------------

    def modes(self) -> dict[str, Mode]:
        return dict(self._modes)

    def scalars(self) -> dict[str, Any]:
        """The table as int32 device scalars — the jit arguments whose values
        change between steps without retracing.  Cached until the table
        mutates (the common case is thousands of steps per shift)."""
        return self.scalars_shifted(0)

    def scalars_shifted(self, delta: int) -> dict[str, Any]:
        """Shadow scalars at every site shifted by ``delta`` (clamped) — the
        probe's one-mode-down / reference views.  Cached like ``scalars``."""
        cached = self._scalar_cache.get(delta)
        if cached is None:
            cached = {
                k: jnp.asarray(int(self._clamp(int(v) + delta)), jnp.int32)
                for k, v in self._modes.items()
            }
            self._scalar_cache[delta] = cached
        return cached

    def label(self) -> str:
        names = sorted({m.name for m in self._modes.values()})
        return names[0] if len(names) == 1 else "/".join(names)

    @property
    def at_max(self) -> bool:
        return all(m == self.max_mode for m in self._modes.values())

    @property
    def at_min(self) -> bool:
        return all(m == self.min_mode for m in self._modes.values())

    # -- mutations -----------------------------------------------------------

    def _clamp(self, mode: Mode | int) -> Mode:
        return Mode(min(max(int(mode), int(self.min_mode)), int(self.max_mode)))

    def set(self, site: str, mode: Mode | int, tag: Any = None) -> bool:
        new = self._clamp(mode)
        if self._modes[site] == new:
            return False
        self._modes[site] = new
        self._scalar_cache.clear()
        self.switches += 1
        self.history.append((tag, self.modes()))
        return True

    def shift(self, site: str, delta: int, tag: Any = None) -> bool:
        return self.set(site, int(self._modes[site]) + delta, tag)

    def shift_all(self, delta: int, tag: Any = None) -> bool:
        """Shift every site by ``delta`` rungs (clamped per site), keeping the
        planner's relative stagger — e.g. an attn_qk planned one rung above
        mlp_up stays one rung above until both hit a clamp.  Counts as one
        switch event when anything moved."""
        if delta == 0:
            return False
        changed = False
        for site, m in self._modes.items():
            new = self._clamp(int(m) + delta)
            if new != m:
                self._modes[site] = new
                changed = True
        if changed:
            self._scalar_cache.clear()
            self.switches += 1
            self.history.append((tag, self.modes()))
        return changed

    def reset(self) -> None:
        self._modes = dict(self._baseline)
        self._scalar_cache.clear()

    def describe(self) -> str:
        return ", ".join(f"{k}={v.name}" for k, v in sorted(self._modes.items()))
