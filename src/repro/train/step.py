"""Training step: loss, gradient accumulation, optimizer, compression hooks.

Gradient accumulation (scan over microbatches) bounds activation memory —
at kimi-k2 scale the 1M-token global batch cannot keep 61 layers of
residuals live; accumulation over ``accum_steps`` microbatches divides the
live set accordingly (DESIGN.md section 4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import BATCH_AXES, constrain
from repro.models.lm import LanguageModel
from repro.optim import adamw

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)
    accum_steps: int = 1
    aux_loss_weight: float = 0.01
    z_loss_weight: float = 1e-4
    grad_compression: bool = False  # int8 EF compression over the pod axis


def cross_entropy(
    logits: Array, labels: Array, z_loss: float, seq_sharded: bool = False
) -> Array:
    """Mean next-token CE in f32 with optional z-loss (logit drift control).

    Vocab-parallel formulation: the label log-prob is a masked reduction over
    the (model-sharded) vocab axis, NOT a take_along_axis gather — a gather
    would force GSPMD to all-gather the full (B, S, V) logits to every device
    (~20 GB/buffer at 152k vocab; measured 226 GB/device before this fix).
    ``seq_sharded``: SP archs shard the sequence (not vocab) over 'model'."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    # iota has no operands for GSPMD to propagate from — without this
    # constraint it replicates, which transitively all-gathers the logits.
    if seq_sharded:
        vocab_iota = constrain(vocab_iota, BATCH_AXES, "model", None)
    else:
        vocab_iota = constrain(vocab_iota, BATCH_AXES, None, "model")
    onehot = vocab_iota == labels[..., None]
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse**2)
    return loss


def make_loss_fn(model: LanguageModel, tcfg: TrainConfig) -> Callable:
    seq_sharded = model.cfg.attn_shard == "sequence"

    def loss_fn(params, batch):
        logits, aux = model.apply(params, batch)
        loss = cross_entropy(logits, batch["labels"], tcfg.z_loss_weight, seq_sharded)
        total = loss + tcfg.aux_loss_weight * aux
        return total, {"loss": loss, "aux_loss": aux}

    return loss_fn


def _split_microbatches(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for scan-based accumulation."""
    return jax.tree.map(lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_train_step(
    model: LanguageModel,
    tcfg: TrainConfig,
    mesh=None,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {'params', 'opt', 'residual' (optional EF residuals)}.
    """
    loss_fn = make_loss_fn(model, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.accum_steps == 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        micro = _split_microbatches(batch, tcfg.accum_steps)

        def body(carry, mb):
            acc, _ = carry
            (_, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, metrics), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, metrics), _ = jax.lax.scan(
            body, (zeros, {"loss": jnp.float32(0), "aux_loss": jnp.float32(0)}), micro
        )
        grads = jax.tree.map(lambda g: g / tcfg.accum_steps, acc)
        return grads, metrics

    def compress_on() -> bool:
        return tcfg.grad_compression and mesh is not None and "pod" in mesh.axis_names

    def train_step(state, batch):
        params = state["params"]
        if compress_on():
            # Manual over 'pod': backward computes PER-POD gradients (no f32
            # cross-pod all-reduce); the explicit int8 error-feedback
            # reduction is the only traffic on the pod axis.
            from jax.sharding import PartitionSpec as P

            from repro.distributed.compress import ef_reduce_tree

            def per_pod(params_, residual_, batch_):
                grads_, metrics_ = compute_grads(params_, batch_)
                grads_, new_res_ = ef_reduce_tree(grads_, residual_)
                metrics_ = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics_)
                return grads_, new_res_, metrics_

            grads, new_res, metrics = jax.shard_map(
                per_pod,
                mesh=mesh,
                in_specs=(P(), P(), P("pod")),
                out_specs=(P(), P(), P()),
                axis_names={"pod"},
                check_vma=False,
            )(params, state["residual"], batch)
        else:
            grads, metrics = compute_grads(params, batch)
            new_res = state.get("residual")
        params, opt, om = adamw.apply_updates(params, grads, state["opt"], tcfg.optimizer)
        metrics = dict(metrics, **om)
        new_state = {"params": params, "opt": opt}
        if new_res is not None:
            new_state["residual"] = new_res
        return new_state, metrics

    return train_step


def init_train_state(model: LanguageModel, key, tcfg: TrainConfig) -> dict:
    params = model.init(key)
    state = {"params": params, "opt": adamw.init_state(params, tcfg.optimizer)}
    if tcfg.grad_compression:
        state["residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return state
