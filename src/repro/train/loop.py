"""Training loop with fault tolerance and straggler monitoring.

Fault tolerance model (1000+-node posture):
  * periodic async checkpoints + atomic commit (checkpoint.manager)
  * SIGTERM emergency save (preemption)
  * resume: restore latest checkpoint, reshard onto the CURRENT mesh
    (elastic — device count may have changed), deterministic data skip-ahead
  * straggler monitor: per-step wall-time EWMA; steps beyond
    ``straggler_z`` sigma are logged with the step index — on a real fleet
    this feeds the scheduler's replace-worker decision
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    straggler_z: float = 3.0
    ewma_alpha: float = 0.1


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.1
    z_threshold: float = 3.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= 3:  # warmup: compile steps are expected outliers
            self.mean = dt
            self.var = 0.0
            return False
        z = (dt - self.mean) / (self.var**0.5 + 1e-9) if self.var > 0 else 0.0
        is_straggler = self.n > 8 and z > self.z_threshold
        if is_straggler:
            self.flagged.append((step, dt, z))
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler


def train_loop(
    train_step: Callable,
    state: Any,
    data: Iterator[dict[str, np.ndarray]] | Any,
    loop_cfg: LoopConfig,
    *,
    ckpt_manager=None,
    start_step: int = 0,
    put_batch: Callable | None = None,
    on_metrics: Callable | None = None,
    adapt=None,
) -> tuple[Any, list[dict]]:
    """Generic loop; ``data`` provides ``next_batch()`` or is an iterator.

    ``adapt`` (repro.adapt.TrainPrecisionSchedule) turns on the grad-norm-
    drift precision schedule: the step is then called as
    ``train_step(state, batch, mode_scalars)`` — a *modal* step whose GEMM
    call-sites read the scalars through ``bind_modes`` (one executable, the
    scalars select the live ``lax.switch`` branches) — and the schedule
    observes each step's metrics to shift the mode table between steps.
    """
    monitor = StragglerMonitor(alpha=loop_cfg.ewma_alpha, z_threshold=loop_cfg.straggler_z)
    history: list[dict] = []
    step = start_step
    if ckpt_manager is not None:
        latest = {"step": step, "state": state}
        ckpt_manager.install_sigterm_handler(lambda: (latest["step"], latest["state"]))

    while step < loop_cfg.total_steps:
        batch = data.next_batch() if hasattr(data, "next_batch") else next(data)
        if put_batch is not None:
            batch = put_batch(batch)
        t0 = time.perf_counter()
        if adapt is not None:
            state, metrics = train_step(state, batch, adapt.mode_scalars())
        else:
            state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        step += 1
        straggle = monitor.observe(step, dt)
        rec = {
            "step": step,
            "dt": dt,
            "straggler": straggle,
            **{k: float(v) for k, v in metrics.items()},
        }
        if adapt is not None:
            shift = adapt.observe(step, rec, dt)
            rec["mode"] = adapt.table.label()
            rec["mode_shift"] = shift
        history.append(rec)
        if on_metrics is not None and step % loop_cfg.log_every == 0:
            on_metrics(rec)
        if ckpt_manager is not None:
            latest = {"step": step, "state": state}
            if step % loop_cfg.checkpoint_every == 0 or step == loop_cfg.total_steps:
                ckpt_manager.save(step, state)
    if ckpt_manager is not None:
        ckpt_manager.wait()
    return state, history


def resume_or_init(
    ckpt_manager, init_fn: Callable[[], Any], shardings: Any = None
) -> tuple[int, Any]:
    """Elastic resume: restore latest (resharding onto the current mesh) or
    initialize fresh."""
    if ckpt_manager is not None and ckpt_manager.latest_step() is not None:
        step, state = ckpt_manager.restore(shardings=shardings)
        return step, state
    return 0, init_fn()
