"""The matmul planner: one place where (mode, Strassen depth, impl) is chosen.

Before this subsystem the three run-time levers the paper exposes — RMPM
precision mode (C1/C2), Strassen depth (C4) and execution impl — were
hard-coded at every call site.  ``plan_matmul`` turns a *shape + accuracy*
request into an executable ``Plan`` via the roofline cost model in
``repro.plan.cost``; ``execute`` runs a plan on concrete operands.  Plans are
cached per static key, so tracing a model re-plans each distinct GEMM shape
exactly once (DESIGN.md section Planner).

    plan_matmul(shape_a, shape_b, accuracy=..., backend=...) -> Plan
    execute(plan, a, b) -> Array

Example (doctested)::

    >>> from repro.plan import plan_matmul
    >>> p = plan_matmul((4096, 4096), (4096, 4096), accuracy=2**-12,
    ...                 backend="tpu")
    >>> p.mode.name, p.impl, p.strassen_depth >= 1
    ('M16', 'pallas', True)
    >>> tiny = plan_matmul((8, 16), (16, 8), accuracy=2**-12, backend="tpu")
    >>> tiny.strassen_depth
    0
"""
from __future__ import annotations

import dataclasses
import functools
import glob
import math
import os
from typing import Any

import jax

from repro.core.precision import DF32_MODES, MODE_LIMBS, DoubleF32, Mode
from repro.plan import cost as cost_lib
from repro.plan.cost import CostEstimate, NATIVE_REL_ERROR

Array = jax.Array

_DF32 = "df32"
_MAX_DEPTH_DEFAULT = 2


@dataclasses.dataclass(frozen=True)
class Plan:
    """An executable matmul decision: every lever pinned, costs attached."""

    shape_a: tuple[int, ...]  # (..., M, K)
    shape_b: tuple[int, int]  # (K, N)
    dtype: str  # 'float32' | 'df32'
    mode: Mode
    impl: str  # 'xla' | 'pallas' | 'native' | 'tile'
    strassen_depth: int
    rounding: str
    backend: str
    cost: CostEstimate
    reason: str
    accuracy: float | None = None
    align: int = 128
    #: how the winning candidate's cost was resolved (DESIGN.md Autotuner):
    #: 'measured' (exact tuning-table hit), 'interpolated' (flops-scaled
    #: nearest neighbor), or 'roofline' (model fallback — the only source
    #: when no tuning table is active).
    source: str = "roofline"
    #: resolved execution time ranked against the other candidates —
    #: measured/scaled seconds under a tuning table, cost.t_total_s otherwise.
    t_resolved_s: float | None = None
    #: Pallas (bm, bn, bk) tile override carried from the winning tuning
    #: record; None = kernel defaults.  Meaningful for impl='pallas'/'tile'.
    block: tuple[int, int, int] | None = None
    #: how the tile kernel's per-tile mode map is built (impl='tile' only):
    #: 'uniform' (one mode everywhere — bit-exact with impl='pallas') or
    #: 'magnitude' (per-tile operand abs-max picks the cheapest mode meeting
    #: the plan's accuracy budget; see kernels/tile_matmul/tile_policy.py).
    map_source: str = "uniform"

    @property
    def tile_eligible(self) -> bool:
        """True when a runtime-bound call site (models.layers.pmm) may route
        this plan through the partitioned tile kernel: the fused single-
        dispatch path covers exactly what impl='pallas' covers (f32 ladder),
        and a uniform map is bit-identical to the pallas branch — so any
        pallas-or-tile plan is eligible."""
        return self.impl in ("pallas", "tile") and self.dtype == "float32"

    @property
    def batch(self) -> int:
        return math.prod(self.shape_a[:-2]) if len(self.shape_a) > 2 else 1

    @property
    def mkn(self) -> tuple[int, int, int]:
        return (self.shape_a[-2], self.shape_a[-1], self.shape_b[1])

    @property
    def out_shape(self) -> tuple[int, ...]:
        return self.shape_a[:-1] + (self.shape_b[1],)

    def describe(self) -> str:
        m, k, n = self.mkn
        t = self.cost.t_total_s if self.t_resolved_s is None else self.t_resolved_s
        return (
            f"[{self.batch}x]({m}x{k})@({k}x{n}) -> mode={self.mode.name} "
            f"impl={self.impl} depth={self.strassen_depth} "
            f"({self.cost.dominant}-bound, ~{t*1e6:.1f}us {self.source}) "
            f"| {self.reason}"
        )


# ---------------------------------------------------------------------------
# Plan cache — keyed on the full static request; hit == no re-planning.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0

    @property
    def entries(self) -> int:
        return len(_PLAN_CACHE)


_PLAN_CACHE: dict[tuple, Plan] = {}
_STATS = CacheStats()


def plan_cache_stats() -> CacheStats:
    return _STATS


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _STATS.hits = 0
    _STATS.misses = 0


# ---------------------------------------------------------------------------
# Tuning tables (repro.tune) — measured costs override the roofline model.
# ---------------------------------------------------------------------------

#: env var naming a tuning-table JSON file, or a directory of
#: ``<backend>.json`` tables (the layout ``python -m repro.tune`` writes).
TUNE_TABLE_ENV = "TUNE_TABLE"

_TABLES_UNSET = object()
_GLOBAL_TABLES: Any = _TABLES_UNSET  # dict[backend -> TuneTable] once resolved


def _load_tables(src) -> dict:
    """Normalize a table source (TuneTable | file path | dir path) to a
    backend-keyed dict — tables never apply across backends."""
    from repro.tune.table import TuneTable

    if hasattr(src, "records"):  # an in-memory TuneTable
        return {src.backend: src}
    tables = {}
    if os.path.isdir(src):
        for path in sorted(glob.glob(os.path.join(src, "*.json"))):
            t = TuneTable.load(path)
            tables[t.backend] = t
    else:
        t = TuneTable.load(src)
        tables[t.backend] = t
    return tables


def _table_cache_key(path: str) -> tuple:
    """(path, mtime_ns, size) of the table file(s): rewriting a table on disk
    — e.g. re-running ``python -m repro.tune`` under a live server — must
    invalidate the load cache, or the stale table's stale fingerprint would
    keep the plan cache serving superseded plans."""
    paths = sorted(glob.glob(os.path.join(path, "*.json"))) if os.path.isdir(path) else [path]
    stats = []
    for p in paths:
        try:
            st = os.stat(p)
            stats.append((p, st.st_mtime_ns, st.st_size))
        except OSError:
            stats.append((p, 0, 0))
    return (path, tuple(stats))


@functools.lru_cache(maxsize=16)
def _load_tables_for_key(key: tuple) -> dict:
    return _load_tables(key[0])


def _load_tables_cached(path: str) -> dict:
    return _load_tables_for_key(_table_cache_key(path))


def set_tune_table(table) -> None:
    """Install the process-global tuning table(s) the planner resolves
    against: a TuneTable, a table-file path, or a directory of per-backend
    tables.  ``None`` clears the explicit setting, so the ``TUNE_TABLE`` env
    var is consulted (lazily) again.  Cached plans are keyed by table
    fingerprint, so swapping tables never returns a stale plan."""
    global _GLOBAL_TABLES
    _GLOBAL_TABLES = _TABLES_UNSET if table is None else _load_tables(table)


def active_tune_table(backend: str | None = None):
    """The tuning table the planner would use for ``backend`` (None -> host
    backend), or None when running pure-roofline."""
    global _GLOBAL_TABLES
    if _GLOBAL_TABLES is _TABLES_UNSET:
        path = os.environ.get(TUNE_TABLE_ENV, "")
        _GLOBAL_TABLES = _load_tables(path) if path else {}
    if backend is None:
        backend = jax.default_backend()
    return _GLOBAL_TABLES.get(backend)


def _resolve_tune_table(tune_table, backend: str):
    """Per-call table resolution: explicit arg beats the global/env setting;
    ``False`` forces pure roofline; a table only applies to its own
    backend."""
    if tune_table is False:
        return None
    if tune_table is None:
        return active_tune_table(backend)
    if isinstance(tune_table, str):
        return _load_tables_cached(tune_table).get(backend)
    return tune_table if tune_table.backend == backend else None


def _candidate_time(table, m, k, n, mode, impl, depth, est):
    """Resolve one candidate's cost in the three-level order (DESIGN.md
    section Autotuner): exact tuning-table hit -> flops-scaled nearest
    neighbor -> roofline estimate.  Returns (seconds, source, block)."""
    if table is not None:
        rec = table.lookup(m, k, n, mode, impl, depth)
        if rec is not None:
            return rec.wall_s, "measured", rec.block
        near = table.nearest(m, k, n, mode, impl, depth)
        if near is not None:
            rec, ratio = near
            return rec.wall_s * ratio, "interpolated", rec.block
    return est.t_total_s, "roofline", None


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------


def _impl_candidates(
    mode: Mode, impl: str | None, backend: str, accuracy: float | None,
    mode_pinned: bool, rounding: str,
) -> list[str]:
    if impl is not None:
        return [impl]
    if mode in DF32_MODES:
        # Validation-grade extended precision: the Neumaier scan path
        # (core/rmpm._limb_matmul_dd) — see DESIGN.md changed-assumption #8
        # for why the Pallas DD kernel saturates near 26-28 bits.
        return ["xla"]
    cands = []
    # 'native' (plain f32 dot, fidelity ~= M24) is only eligible when the
    # caller asked for an accuracy target that f32 meets — never when a
    # specific mode was pinned (mode semantics, e.g. quantization studies,
    # must be honoured) and never for non-RNE roundings (C3 runs in limbs).
    # On TPU there is no 1-pass f32 unit (XLA emulates HIGHEST-precision f32
    # dots with bf16 passes, i.e. the limb engine IS the native path), so
    # 'native' is only a candidate on cpu/gpu backends.
    if (
        backend != "tpu"
        and not mode_pinned
        and rounding == "rne"
        and accuracy is not None
        and NATIVE_REL_ERROR <= accuracy
    ):
        cands.append("native")
    cands.append("xla")
    if backend == "tpu":
        # Fused limb extraction only pays off with >= 2 limbs resident.
        if MODE_LIMBS[mode] >= 2:
            cands.append("pallas")
        # The partitioned tile kernel shares the pallas roofline (same fused
        # blocks; the map is O(grid) int32), so on ties the earlier 'pallas'
        # candidate wins and committed plan baselines stay stable — 'tile'
        # is selected when a tuning table measures it faster, when pinned,
        # or by the runtime-dispatch layer (Plan.tile_eligible).
        cands.append("tile")
    return cands


def _depth_candidates(m: int, k: int, n: int, mode: Mode, max_depth: int,
                      align: int) -> list[int]:
    if mode in DF32_MODES:
        return [0]  # DoubleF32 leaves cannot flow through the block adds
    out = [0]
    for d in range(1, max_depth + 1):
        # every leaf must still be at least one MXU tile per side
        if min(m, k, n) >= align * (2**d):
            out.append(d)
    return out


def plan_matmul(
    shape_a: tuple[int, ...],
    shape_b: tuple[int, int],
    *,
    dtype: str = "float32",
    accuracy: float | None = None,
    mode: Mode | int | None = None,
    impl: str | None = None,
    backend: str | None = None,
    rounding: str = "rne",
    max_depth: int = _MAX_DEPTH_DEFAULT,
    align: int = 128,
    tune_table: Any = None,
    map_source: str = "uniform",
) -> Plan:
    """Choose (mode, Strassen depth, impl) for ``a @ b`` from the cost model.

    Args:
      shape_a: operand A shape ``(..., M, K)`` (leading dims are batch).
      shape_b: operand B shape ``(K, N)``.
      dtype: ``'float32'`` or ``'df32'`` (DoubleF32 hi/lo operand pairs).
      accuracy: max acceptable relative error; the cheapest adequate RMPM
        mode is selected (None -> single-precision fidelity, M24).
      mode: pin the RMPM mode instead of deriving it from ``accuracy``.
      impl: pin the execution impl ('xla' | 'pallas' | 'native' | 'tile').
      map_source: tile-map construction for impl='tile' — 'uniform'
        (default; bit-exact with 'pallas') or 'magnitude' (per-tile operand
        statistics pick the cheapest mode within the accuracy budget;
        requires ``accuracy`` and forces impl='tile', the plan's mode being
        the per-tile ceiling).
      backend: 'cpu' | 'tpu' | 'gpu'; None -> ``jax.default_backend()``.
      rounding: limb-extraction rounding ('rne' | 'grte' | 'trunc').
      max_depth: largest Strassen depth the cost model may choose.
      align: leaf tile alignment (MXU tile side).
      tune_table: measured-cost table (repro.tune) candidate costs resolve
        against — a TuneTable, a path, ``None`` (use the global/env setting,
        see ``set_tune_table``), or ``False`` (force pure roofline).  A
        table only applies when its backend matches ``backend``.

    Returns a cached :class:`Plan`; identical static requests return the
    identical object (see ``plan_cache_stats``).
    """
    shape_a = tuple(int(d) for d in shape_a)
    shape_b = tuple(int(d) for d in shape_b)
    if len(shape_a) < 2 or len(shape_b) != 2:
        raise ValueError(f"need A (..., M, K) and B (K, N); got {shape_a} @ {shape_b}")
    if shape_a[-1] != shape_b[0]:
        raise ValueError(f"contraction mismatch {shape_a} @ {shape_b}")
    if impl is not None and impl not in ("xla", "pallas", "native", "tile"):
        raise ValueError(
            f"unknown impl {impl!r}: want 'xla' | 'pallas' | 'native' | 'tile'"
        )
    if dtype not in ("float32", _DF32):
        raise ValueError(f"unknown dtype {dtype!r}: want 'float32' | 'df32'")
    if map_source not in ("uniform", "magnitude"):
        raise ValueError(
            f"unknown map_source {map_source!r}: want 'uniform' | 'magnitude'"
        )
    if map_source == "magnitude":
        if impl is None:
            impl = "tile"  # per-tile maps exist only in the tile kernel
        elif impl != "tile":
            raise ValueError(f"map_source='magnitude' requires impl='tile', got {impl!r}")
        if accuracy is None:
            raise ValueError("map_source='magnitude' needs an accuracy budget")
        if dtype == _DF32:
            raise ValueError("map_source='magnitude' covers the f32 ladder only")
    if backend is None:
        backend = jax.default_backend()
    table = _resolve_tune_table(tune_table, backend)
    key = (shape_a, shape_b, dtype, accuracy, mode if mode is None else int(mode),
           impl, backend, rounding, max_depth, align, map_source,
           table.fingerprint if table is not None else None)
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        _STATS.hits += 1
        return cached
    _STATS.misses += 1

    mode_pinned = mode is not None
    if mode_pinned:
        mode = Mode(mode)
        if mode == Mode.AUTO:
            raise ValueError(
                "Mode.AUTO is a runtime operand probe (core.rmpm."
                "mp_matmul_runtime); the planner needs a static mode or an "
                "accuracy target"
            )
    else:
        mode = cost_lib.cheapest_mode(accuracy)
    if dtype == _DF32 and mode not in DF32_MODES:
        if mode_pinned:
            # pinned-mode semantics must be honoured, and f32 modes reject
            # DoubleF32 operands at execution (core.rmpm._check_mode_operands)
            raise ValueError(
                f"mode {mode.name} pinned but dtype='df32': DoubleF32 "
                f"operands need M32/M48"
            )
        mode = Mode.M32  # DoubleF32 operands need an extended-precision mode
    # DF32 modes on plain f32 operands are legal (core.rmpm accepts them: the
    # product of the given f32 values is computed past 2^-24 and returned as
    # a DoubleF32 pair) — callers asking for accuracy < 2^-21 opt into the
    # wider result type.

    batch = math.prod(shape_a[:-2]) if len(shape_a) > 2 else 1
    m, k = shape_a[-2], shape_a[-1]
    n = shape_b[1]

    # With a tuning table active, the roofline fallback runs on the table's
    # re-fit machine constants (cost.fit_balance) so measured and modeled
    # candidate times stay commensurable in one ranking; without one, the
    # hand-entered TPU-balance defaults apply.
    balance = table.balance if table is not None else cost_lib.DEFAULT_BALANCE
    best: tuple[tuple, CostEstimate, str, int, str, Any] | None = None
    # Magnitude maps are defined on the whole GEMM's tile grid; Strassen's
    # block adds/subtracts would scramble the per-tile magnitudes the map
    # was derived from, so the recursion is disabled for that source.
    depths = ([0] if map_source == "magnitude"
              else _depth_candidates(m, k, n, mode, max_depth, align))
    for cand_impl in _impl_candidates(mode, impl, backend, accuracy,
                                      mode_pinned, rounding):
        for depth in depths:
            est = cost_lib.estimate(
                m, k, n, mode, cand_impl, depth, align=align,
                peak_flops=balance.peak_flops, hbm_bw=balance.hbm_bw,
            )
            t_cand, source, block = _candidate_time(
                table, m, k, n, mode, cand_impl, depth, est)
            if batch > 1:
                est = CostEstimate(
                    flops=est.flops * batch,
                    hbm_bytes=est.hbm_bytes * batch,
                    t_compute_s=est.t_compute_s * batch,
                    t_memory_s=est.t_memory_s * batch,
                )
                t_cand *= batch
            # Resolved-time ties are common when compute-bound: break them
            # toward less HBM traffic (headroom for everything co-scheduled),
            # then fewer flops.
            rank = (t_cand, est.hbm_bytes, est.flops)
            if best is None or rank < best[0]:
                best = (rank, est, cand_impl, depth, source, block)
    assert best is not None
    rank, est, chosen_impl, chosen_depth, source, block = best
    why = []
    why.append(
        f"mode {mode.name} pinned" if mode_pinned
        else f"mode {mode.name} cheapest for accuracy<={accuracy:.1e}"
        if accuracy is not None else f"mode {mode.name} (single-precision default)"
    )
    why.append(f"impl {chosen_impl}" + (" pinned" if impl is not None else " by cost"))
    why.append(f"depth {chosen_depth} by cost" if chosen_depth or max_depth
               else "depth 0 (disabled)")
    why.append(
        f"cost {source}" + (f" (table {table.fingerprint[:8]})"
                            if table is not None else "")
    )
    plan = Plan(
        shape_a=shape_a,
        shape_b=shape_b,
        dtype=dtype,
        mode=mode,
        impl=chosen_impl,
        strassen_depth=chosen_depth,
        rounding=rounding,
        backend=backend,
        cost=est,
        reason="; ".join(why),
        accuracy=accuracy,
        align=align,
        source=source,
        t_resolved_s=rank[0],
        block=block if chosen_impl in ("pallas", "tile") else None,
        map_source=map_source,
    )
    _PLAN_CACHE[key] = plan
    return plan


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------


def execute(plan: Plan, a, b):
    """Run a :class:`Plan` on concrete operands.

    Leading batch dims of ``a`` are handled vmap-style (no flattening — a
    reshape would merge differently-sharded dims; see core/rmpm.py and
    EXPERIMENTS.md section Perf cell A), so ``execute`` itself is safe to
    call under ``jax.vmap``.
    """
    from repro.core import rmpm, strassen

    a_shape = a.hi.shape if isinstance(a, DoubleF32) else a.shape
    if tuple(a_shape) != plan.shape_a or tuple(b.shape if not isinstance(b, DoubleF32) else b.hi.shape) != plan.shape_b:
        raise ValueError(
            f"operands {tuple(a_shape)} @ "
            f"{tuple(b.shape if not isinstance(b, DoubleF32) else b.hi.shape)} "
            f"do not match plan {plan.shape_a} @ {plan.shape_b}"
        )
    if plan.map_source == "magnitude":
        from repro.kernels.tile_matmul import ops as tile_ops

        bm, bn, bk = plan.block if plan.block is not None else tile_ops.DEFAULT_BLOCK
        return tile_ops.tile_matmul_auto(
            a, b, plan.accuracy, max_mode=plan.mode, rounding=plan.rounding,
            bm=bm, bn=bn, bk=bk,
        )
    mm = functools.partial(
        rmpm.mp_matmul, mode=plan.mode, rounding=plan.rounding, impl=plan.impl,
        block=plan.block,
    )
    if plan.strassen_depth == 0:
        return mm(a, b)
    leaf = mm

    def mm2d(x, y):
        return strassen.strassen_matmul(
            x, y, depth=plan.strassen_depth, leaf_fn=leaf, align=plan.align
        )

    fn = mm2d
    for _ in range(len(plan.shape_a) - 2):
        fn = jax.vmap(fn, in_axes=(0, None))
    return fn(a, b)


def matmul(
    a,
    b,
    *,
    accuracy: float | None = None,
    mode: Mode | int | None = None,
    impl: str | None = None,
    backend: str | None = None,
    rounding: str = "rne",
    max_depth: int = _MAX_DEPTH_DEFAULT,
    tune_table: Any = None,
    map_source: str = "uniform",
) -> Array:
    """Plan-and-execute convenience: ``matmul(a, b, accuracy=2**-12)``."""
    dtype = _DF32 if isinstance(a, DoubleF32) or isinstance(b, DoubleF32) else "float32"
    shape_a = a.hi.shape if isinstance(a, DoubleF32) else a.shape
    shape_b = b.hi.shape if isinstance(b, DoubleF32) else b.shape
    plan = plan_matmul(
        tuple(shape_a),
        tuple(shape_b),
        dtype=dtype,
        accuracy=accuracy,
        mode=mode,
        impl=impl,
        backend=backend,
        rounding=rounding,
        max_depth=max_depth,
        tune_table=tune_table,
        map_source=map_source,
    )
    return execute(plan, a, b)


# ---------------------------------------------------------------------------
# Model-level bridge: derive a PrecisionPolicy from planned GEMMs
# ---------------------------------------------------------------------------

# Per-op tightening factors applied to the caller's bulk accuracy budget.
# Numerically sensitive contractions demand more bits — the beyond-paper
# MIXED policy's structure, now cost-derived instead of hand-tuned.
_OP_ACCURACY_SCALE = {
    "attn_qk": 2.0**-4,  # softmax logits: tight
    "logits": 2.0**-4,
    "router": 2.0**-6,  # MoE routing: tightest (top-k flips)
}


def plan_model_policy(cfg: Any, tokens: int, *, accuracy: float,
                      backend: str | None = None, max_depth: int = 0,
                      rounding: str = "rne", tune_table: Any = None):
    """Plan the dominant GEMMs of an ArchConfig-like model and fold the
    decisions into a PrecisionPolicy (+ the per-op plans, for reporting).

    ``accuracy`` is the bulk-GEMM relative-error budget; numerically
    sensitive op classes are planned at a tightened budget (see
    ``_OP_ACCURACY_SCALE``).  ``tokens`` is the expected batch*seq of one
    step — it sets the M dim the cost model sees.
    """
    from repro.core.policy import PrecisionPolicy

    d, ff, vocab = cfg.d_model, cfg.d_ff, cfg.vocab
    qkv_out = cfg.n_heads * cfg.head_dim if cfg.n_heads else d
    gemms = {
        "qkv": (d, qkv_out),
        "out": (qkv_out, d),
        "mlp_up": (d, ff),
        "mlp_down": (ff, d),
        "logits": (d, vocab),
        "attn_qk": (d, d),
        "attn_av": (d, d),
    }
    if getattr(cfg, "moe_experts", 0):
        gemms["router"] = (d, cfg.moe_experts)
        gemms["moe_expert"] = (d, ff)
    plans = {}
    for op, (din, dout) in gemms.items():
        acc = accuracy * _OP_ACCURACY_SCALE.get(op, 1.0)
        plans[op] = plan_matmul(
            (max(tokens, 1), din), (din, dout),
            accuracy=acc, backend=backend, max_depth=max_depth,
            rounding=rounding, tune_table=tune_table,
        )
    default_mode = plans["mlp_up"].mode
    overrides = tuple(
        (op, p.mode) for op, p in plans.items() if p.mode != default_mode
    )
    # one impl for the whole policy: what the planner chose for the largest
    # GEMM (the vocab head dominates the step cost)
    impl = plans["logits"].impl
    depth = max(p.strassen_depth for p in plans.values())
    policy = PrecisionPolicy(
        default=default_mode,
        overrides=overrides,
        rounding=rounding,
        impl=impl,
        max_strassen_depth=depth if max_depth else 0,
    )
    return policy, plans
