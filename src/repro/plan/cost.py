"""Roofline cost model for the matmul planner (DESIGN.md section Planner).

One estimator, three levers — the same levers the paper exposes as run-time
reconfiguration, lifted to the block-algorithm level:

  * **RMPM precision mode** — a k-limb mode runs ``MODE_PASSES[mode]`` =
    k(k+1)/2 bf16 MXU passes per leaf matmul (compute term scales with
    passes) and, on the ``xla`` impl, materializes k bf16 limb copies of each
    operand in HBM (memory term scales with limbs).  The ``pallas`` impl
    (kernels/limb_matmul) reads the f32 operands once per block and extracts
    limbs in VMEM, collapsing the limb memory factor back to ~1.
  * **Strassen depth** — each level multiplies leaf matmul FLOPs by 7/8 in
    exchange for O(n^2) block adds and zero-padding to ``align * 2^depth``
    multiples (core/strassen.py).  The cost model charges the padded leaf
    FLOPs, the add FLOPs, and the add memory traffic explicitly, so depth
    only wins when the (7/8)^depth saving beats the pad + add overhead at
    the machine balance point.
  * **impl** — 'native' (plain f32 dot: 1 pass, no limb traffic, fidelity
    ~= M24), 'xla' (limb algebra in HBM), 'pallas' (fused limb extraction).

The machine-balance constants are the same ones the dry-run roofline uses
(repro.launch.hlo_cost: TPU v5e peak FLOPs / HBM BW) — the planner and the
HLO-derived roofline read from one set of numbers, per the fold-the-
heuristics-into-one-place goal of the planner PR.
"""
from __future__ import annotations

import dataclasses

# Machine balance: folded in from the dry-run roofline (launch/hlo_cost.py).
from repro.launch.hlo_cost import HBM_BW, PEAK_FLOPS

from repro.core.precision import MODE_PASSES, Mode

F32_BYTES = 4
BF16_BYTES = 2

# Relative-error ceiling per mode on well-conditioned operands — the ladder
# validated by tests/test_core_precision.py (TestModeLadder) and the paper's
# Table 9 / Fig 17.  M24 is f32-accumulation limited, not 2^-24.
MODE_REL_ERROR: dict[Mode, float] = {
    Mode.M8: 2.0**-7,
    Mode.M16: 2.0**-15,
    Mode.M24: 2.0**-21,
    Mode.M32: 2.0**-28,
    Mode.M48: 2.0**-35,
}

# 'native' executes jnp.dot in f32: numerically ~= M24 (see core/rmpm.py).
NATIVE_REL_ERROR = MODE_REL_ERROR[Mode.M24]


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class MachineBalance:
    """The roofline's machine constants: peak FLOP/s and HBM bandwidth.

    The defaults are the hand-entered TPU-balance numbers shared with the
    dry-run roofline (launch/hlo_cost.py).  ``fit_balance`` re-fits both from
    a measured tuning table (repro.tune) so the planner can rank candidates
    against the machine it actually runs on (DESIGN.md section Autotuner).
    """

    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    source: str = "default"


DEFAULT_BALANCE = MachineBalance()


def fit_balance(samples, *, source: str = "fit") -> MachineBalance:
    """Re-fit the roofline constants from measured (CostEstimate, wall_s) pairs.

    Under the roofline ``t = max(flops/P, bytes/B)`` every sample is a lower
    bound ``P >= flops/t`` and ``B >= bytes/t``; the tightest machine
    consistent with all samples is the max over each bound — the achieved-
    rate envelope.  Compute-bound samples pin P, memory-bound samples pin B;
    with only one regime sampled the other constant stays a (loose) envelope
    too, which only shrinks the estimated time of candidates the measurements
    never contradicted.  Empty/degenerate input falls back to the defaults.
    """
    peak = 0.0
    bw = 0.0
    for est, wall_s in samples:
        if wall_s <= 0:
            continue
        peak = max(peak, est.flops / wall_s)
        bw = max(bw, est.hbm_bytes / wall_s)
    if peak <= 0 or bw <= 0:
        return DEFAULT_BALANCE
    return MachineBalance(peak_flops=peak, hbm_bw=bw, source=source)


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Roofline terms for one (mode, impl, depth) candidate."""

    flops: float  # MXU + add flops
    hbm_bytes: float  # operand/limb/add/result traffic
    t_compute_s: float
    t_memory_s: float

    @property
    def t_total_s(self) -> float:
        # Roofline: compute and memory overlap; the slower term binds.  Using
        # max() (not sum) matches roofline_terms() in launch/hlo_cost.py.
        return max(self.t_compute_s, self.t_memory_s)

    @property
    def dominant(self) -> str:
        return "compute" if self.t_compute_s >= self.t_memory_s else "memory"


def limb_factors(mode: Mode, impl: str) -> tuple[int, float]:
    """(MXU passes per leaf, operand-read multiplier) for a mode x impl.

    'native' runs one f32 pass and reads each operand once.  'xla' runs
    k(k+1)/2 bf16 passes and materializes k bf16 limb tensors per operand
    (k * 2 bytes = k/2 the f32 footprint per read, but each pass re-reads its
    two limb operands — we charge one bf16 read per pass operand, the
    schedule XLA actually emits for the unfused formulation).  'pallas' reads
    the f32 block once and keeps limbs in VMEM (limb_matmul.py docstring).
    """
    if impl == "native":
        return 1, 1.0
    passes = MODE_PASSES[mode]
    if impl in ("pallas", "tile"):
        # 'tile' shares the fused-kernel roofline: a uniform map runs the
        # same passes over the same once-read blocks; the per-tile mode map
        # itself is O(grid) int32 — negligible traffic.
        return passes, 1.0
    # xla: each of the `passes` bf16 dots reads one limb of A and one of B.
    return passes, passes * (BF16_BYTES / F32_BYTES)


def strassen_overhead(m: int, k: int, n: int, depth: int, align: int) -> tuple[
    tuple[int, int, int], float, float
]:
    """Padded leaf dims + (add flops, add bytes) for a depth-level recursion.

    Per level on an (M, K) x (K, N) node: 10 operand pre-adds (quarter A/B
    size) and 8 combination adds (quarter C size); each add element is 1 flop
    and 3 f32 transfers (2 reads + 1 write).  Level l has 7^(l-1) nodes of
    1/4^(l-1) the area — the O(n^2) term that caps useful depth.
    """
    if depth == 0:
        return (m, k, n), 0.0, 0.0
    unit = align * (2**depth)
    mp_, kp, np_ = _ceil_to(m, unit), _ceil_to(k, unit), _ceil_to(n, unit)
    add_elems = 0.0
    nodes = 1.0
    a_area, b_area, c_area = mp_ * kp, kp * np_, mp_ * np_
    for _ in range(depth):
        a_area /= 4.0
        b_area /= 4.0
        c_area /= 4.0
        add_elems += nodes * (10.0 * max(a_area, b_area) + 8.0 * c_area)
        nodes *= 7.0
    return (mp_, kp, np_), add_elems, 3.0 * F32_BYTES * add_elems


def estimate(
    m: int,
    k: int,
    n: int,
    mode: Mode,
    impl: str,
    depth: int,
    *,
    align: int = 128,
    peak_flops: float = PEAK_FLOPS,
    hbm_bw: float = HBM_BW,
) -> CostEstimate:
    """Roofline estimate for C = A (m, k) @ B (k, n) under one candidate."""
    (mp_, kp, np_), add_flops, add_bytes = strassen_overhead(m, k, n, depth, align)
    passes, read_mult = limb_factors(mode, impl)
    leaf_ratio = (7.0 / 8.0) ** depth
    mxu_flops = leaf_ratio * 2.0 * mp_ * kp * np_ * passes
    operand_bytes = read_mult * F32_BYTES * (mp_ * kp + kp * np_)
    result_bytes = F32_BYTES * mp_ * np_
    if mode in (Mode.M32, Mode.M48):
        operand_bytes *= 2.0  # DoubleF32 (hi, lo) operands
        result_bytes *= 2.0
    flops = mxu_flops + add_flops
    hbm = operand_bytes + result_bytes + add_bytes
    return CostEstimate(
        flops=flops,
        hbm_bytes=hbm,
        t_compute_s=flops / peak_flops,
        t_memory_s=hbm / hbm_bw,
    )


def cheapest_mode(accuracy: float | None) -> Mode:
    """Smallest mode whose error ceiling meets ``accuracy`` (max rel error).

    ``None`` means "single-precision fidelity" -> M24, the paper-baseline
    default (a conventional FP32 unit's behaviour).
    """
    if accuracy is None:
        return Mode.M24
    for mode in (Mode.M8, Mode.M16, Mode.M24, Mode.M32, Mode.M48):
        if MODE_REL_ERROR[mode] <= accuracy:
            return mode
    return Mode.M48
