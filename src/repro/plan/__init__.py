"""repro.plan — cost-model-driven matmul planner/executor.

Unifies the paper's three run-time levers (RMPM precision mode, Strassen
depth, execution impl) behind one shape- and accuracy-aware API:

    plan  = plan_matmul(shape_a, shape_b, accuracy=2**-12, backend='tpu')
    out   = execute(plan, a, b)          # or: matmul(a, b, accuracy=2**-12)

See DESIGN.md section Planner for the cost model.
"""
from repro.plan.cost import (  # noqa: F401
    DEFAULT_BALANCE,
    MODE_REL_ERROR,
    NATIVE_REL_ERROR,
    CostEstimate,
    MachineBalance,
    cheapest_mode,
    estimate,
    fit_balance,
    limb_factors,
    strassen_overhead,
)
from repro.plan.planner import (  # noqa: F401
    TUNE_TABLE_ENV,
    Plan,
    active_tune_table,
    clear_plan_cache,
    execute,
    matmul,
    plan_cache_stats,
    plan_matmul,
    plan_model_policy,
    set_tune_table,
)
