"""Pallas TPU kernel: partitioned-SIMD limb matmul with a per-tile mode map.

The paper's core trick is ONE wide multiplier that dynamically partitions
into many narrow ones at run time.  ``limb_matmul_pallas`` reproduces the
multi-pass limb datapath but at whole-matmul granularity: every output tile
runs the same k limb passes, and run-time mode switching happens OUTSIDE the
kernel as an N-branch ``lax.switch``.  This kernel moves the partitioning
inside the dispatch: a per-tile int32 **mode map** rides along as a
scalar-prefetch operand (SMEM), and each (bm, bn) output tile runs exactly
``map[i, j]`` limb passes — a tile at M8 does 1 MXU pass while its neighbor
at M24 does 6, inside one fused kernel launch.

Key properties (pinned by tests/test_tile.py):

* **Uniform-map exactness** — for a constant map at mode m, the retained
  Karatsuba terms executed per tile are exactly ``limb_product_terms(m)`` in
  the same order (``limb_product_terms`` sorts high-order-first with a stable
  sort, so filtering kmax's term list by ``i + j < m`` preserves both the
  set and the order), the first m limbs of a kmax-limb extraction equal an
  m-limb extraction, and the block/grid walk is identical — so the output is
  bit-identical to ``limb_matmul_pallas(k=m)`` by construction.
* **Zero-recompile reconfiguration** — the map is a traced runtime argument;
  changing tile modes (or the whole map) reuses the compiled executable,
  exactly like the traced mode scalar in ``mp_matmul_runtime``.
* **Mode values ARE limb counts** on the f32 ladder (Mode.M8=1, M16=2,
  M24=3), so a mode map doubles as the limb-count map with no translation.

The map is ``(M/bm, N/bn)`` int32 (one mode per output tile) or
``(M/bm, N/bn, K/bk)`` (additionally per K-slab, for contraction-dim
partitioning).  Entries must lie in [1, kmax].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.limb import limb_product_terms
from repro.kernels.limb_matmul.limb_matmul import _extract_limbs


def _tile_matmul_kernel(
    mode_ref, a_ref, b_ref, out_ref, acc_ref, *, kmax: int, n_k_tiles: int, map_ndim: int
):
    """One (bm, bn) output tile x one bk slab, at the tile's mapped mode."""
    i, j, kk = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # SMEM scalar read: limb count for this tile (== its Mode value).
    k_tile = mode_ref[i, j, kk] if map_ndim == 3 else mode_ref[i, j]

    a_limbs = _extract_limbs(a_ref[...], kmax)
    b_limbs = _extract_limbs(b_ref[...], kmax)

    # Same static term order as the uniform kernel (high-order first); each
    # pass is predicated on the tile's mode so cheap tiles skip MXU passes.
    for ti, tj in limb_product_terms(kmax):

        @pl.when(ti + tj < k_tile)
        def _pass(ti=ti, tj=tj):
            acc_ref[...] += jax.lax.dot_general(
                a_limbs[ti],
                b_limbs[tj],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

    @pl.when(kk == n_k_tiles - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("kmax", "bm", "bn", "bk", "interpret")
)
def tile_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    mode_map: jax.Array,
    *,
    kmax: int = 3,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """a (M, K) f32 @ b (K, N) f32 -> (M, N) f32, per-tile limb counts.

    Shapes must be multiples of the block sizes (ops.py pads); ``mode_map``
    is int32 of shape (M/bm, N/bn) or (M/bm, N/bn, K/bk) with entries in
    [1, kmax].
    """
    m, kdim = a.shape
    _, n = b.shape
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (a.shape, b.shape, bm, bn, bk)
    n_k_tiles = kdim // bk
    grid = (m // bm, n // bn, n_k_tiles)
    map_ndim = mode_map.ndim
    assert map_ndim in (2, 3), mode_map.shape
    expect = grid[:2] if map_ndim == 2 else grid
    assert mode_map.shape == expect, (mode_map.shape, expect)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        # Under scalar prefetch the index maps receive the SMEM ref(s) as
        # extra trailing args.
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk, mref: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk, mref: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, mref: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _tile_matmul_kernel, kmax=kmax, n_k_tiles=n_k_tiles, map_ndim=map_ndim
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(mode_map.astype(jnp.int32), a, b)
