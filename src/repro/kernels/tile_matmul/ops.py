"""Public wrappers for the partitioned tile_matmul Pallas kernel.

Entry points (all accept arbitrary leading batch dims on ``a``):

* ``tile_matmul(a, b, mode_map)``      — explicit per-tile map
* ``tile_matmul_mode(a, b, mode)``     — static uniform map from a Mode
  (bit-identical to ``limb_matmul`` at the same blocks, by construction)
* ``tile_matmul_runtime(a, b, scalar)``— traced mode scalar broadcast into a
  uniform map: the single-dispatch replacement for the ``lax.switch`` in
  ``mp_matmul_runtime`` (zero-recompile across mode changes)
* ``tile_matmul_auto(a, b, budget)``   — magnitude-statistics map (see
  ``tile_policy.magnitude_map``)

``interpret=None`` resolves backend-aware at call time (interpret on CPU,
compiled Mosaic elsewhere); the resolution lives OUTSIDE the jit boundary so
it is never frozen into a cached trace.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.precision import F32_MODES, MODE_LIMBS, Mode
from repro.kernels.blocking import ceil_to, clamp_block, pad_to_block, resolve_interpret
from repro.kernels.tile_matmul.tile_matmul import tile_matmul_pallas

DEFAULT_BLOCK = (128, 128, 512)
F32_KMAX = max(MODE_LIMBS[m] for m in F32_MODES)  # 3 limbs (M24)


def tile_grid(
    m: int, n: int, kdim: int, *, bm: int = 128, bn: int = 128, bk: int = 512
) -> tuple[tuple[int, int, int], tuple[int, int, int]]:
    """Clamp blocks to the (flattened) problem shape and return
    ``((gm, gn, gk), (bm, bn, bk))`` — the mode-map grid is ``(gm, gn)`` or
    ``(gm, gn, gk)``.  This is the single source of truth for map shapes;
    ``tile_policy`` builds maps against it and ``tile_matmul`` validates
    against it.
    """
    bm_, bn_, bk_ = clamp_block(bm, m), clamp_block(bn, n), clamp_block(bk, kdim)
    grid = (ceil_to(m, bm_) // bm_, ceil_to(n, bn_) // bn_, ceil_to(kdim, bk_) // bk_)
    return grid, (bm_, bn_, bk_)


@functools.partial(
    jax.jit, static_argnames=("kmax", "rounding", "bm", "bn", "bk", "interpret")
)
def _tile_matmul(a, b, mode_map, *, kmax, rounding, bm, bn, bk, interpret):
    if rounding != "rne":
        from repro.kernels.quantize_mantissa.ops import quantize_mantissa_op

        # GRTE applies at the coarsest retained limb width (kmax), matching
        # limb_matmul's pre-pass for uniform maps; identity for kmax >= 3.
        keep = 8 * kmax - 1
        a = quantize_mantissa_op(a, keep, rounding, interpret=interpret)
        b = quantize_mantissa_op(b, keep, rounding, interpret=interpret)
    lead = a.shape[:-1]
    kdim = a.shape[-1]
    n = b.shape[-1]
    a2 = a.reshape(-1, kdim).astype(jnp.float32)
    m = a2.shape[0]
    grid, (bm_, bn_, bk_) = tile_grid(m, n, kdim, bm=bm, bn=bn, bk=bk)
    expect = grid[:2] if mode_map.ndim == 2 else grid
    if mode_map.ndim not in (2, 3) or mode_map.shape != expect:
        raise ValueError(
            f"mode_map shape {mode_map.shape} != tile grid {expect} for "
            f"flattened matmul ({m}, {kdim}) @ ({kdim}, {n}) at blocks "
            f"({bm_}, {bn_}, {bk_})"
        )
    a2 = pad_to_block(a2, bm_, bk_)
    b2 = pad_to_block(b.astype(jnp.float32), bk_, bn_)
    out = tile_matmul_pallas(
        a2, b2, mode_map, kmax=kmax, bm=bm_, bn=bn_, bk=bk_, interpret=interpret
    )
    return out[:m, :n].reshape(*lead, n)


def tile_matmul(
    a: jax.Array,
    b: jax.Array,
    mode_map: jax.Array,
    *,
    kmax: int = F32_KMAX,
    rounding: str = "rne",
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Multi-precision matmul a (..., K) @ b (K, N) with a per-tile mode map.

    ``mode_map`` entries are f32-ladder Mode values (== limb counts, in
    [1, kmax]); shape must match ``tile_grid`` for the flattened problem.
    The map is a traced argument: new maps reuse the compiled kernel.
    """
    return _tile_matmul(
        a,
        b,
        mode_map,
        kmax=kmax,
        rounding=rounding,
        bm=bm,
        bn=bn,
        bk=bk,
        interpret=resolve_interpret(interpret),
    )


def tile_matmul_mode(
    a: jax.Array,
    b: jax.Array,
    mode: Mode,
    *,
    rounding: str = "rne",
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Uniform static-mode tile matmul — bit-identical to
    ``limb_matmul(k=MODE_LIMBS[mode])`` at the same blocks (kmax is set to
    the mode's limb count, so the executed passes, their order, and the GRTE
    pre-pass width all coincide with the uniform kernel)."""
    mode = Mode(mode)
    if mode not in F32_MODES:
        raise ValueError(f"tile impl supports the f32 ladder {F32_MODES}, got {mode!r}")
    k = MODE_LIMBS[mode]
    lead_m = 1
    for d in a.shape[:-1]:
        lead_m *= d
    grid, _ = tile_grid(lead_m, b.shape[-1], a.shape[-1], bm=bm, bn=bn, bk=bk)
    mode_map = jnp.full(grid[:2], k, dtype=jnp.int32)
    return tile_matmul(
        a, b, mode_map, kmax=k, rounding=rounding, bm=bm, bn=bn, bk=bk,
        interpret=interpret,
    )


def tile_matmul_runtime(
    a: jax.Array,
    b: jax.Array,
    mode_scalar: jax.Array,
    *,
    rounding: str = "rne",
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Run-time reconfigurable tile matmul: a TRACED f32-ladder mode scalar
    (e.g. from ``repro.adapt``'s ModeTable) broadcast into a uniform map.

    One fused dispatch at every mode — this is what replaces the N-branch
    ``lax.switch`` of ``mp_matmul_runtime``; mode changes touch only the map
    values, never the compiled executable.
    """
    lead_m = 1
    for d in a.shape[:-1]:
        lead_m *= d
    grid, _ = tile_grid(lead_m, b.shape[-1], a.shape[-1], bm=bm, bn=bn, bk=bk)
    k = jnp.clip(jnp.asarray(mode_scalar, jnp.int32), 1, F32_KMAX)
    mode_map = jnp.full(grid[:2], 1, dtype=jnp.int32) * k
    return tile_matmul(
        a, b, mode_map, kmax=F32_KMAX, rounding=rounding, bm=bm, bn=bn, bk=bk,
        interpret=interpret,
    )


def tile_matmul_auto(
    a: jax.Array,
    b: jax.Array,
    budget: float,
    *,
    relative: bool = True,
    per_k: bool = False,
    max_mode: Mode = Mode.M24,
    rounding: str = "rne",
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Magnitude-statistics tile matmul: per-tile operand abs-max picks the
    cheapest mode meeting the per-tile error ``budget`` (see
    ``tile_policy.magnitude_map``), then one fused dispatch runs the map."""
    from repro.kernels.tile_matmul.tile_policy import magnitude_map

    mode_map = magnitude_map(
        a, b, budget, relative=relative, per_k=per_k, max_mode=max_mode,
        bm=bm, bn=bn, bk=bk,
    )
    return tile_matmul(
        a, b, mode_map, kmax=MODE_LIMBS[Mode(max_mode)], rounding=rounding,
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
