"""Pure-jnp oracle for the tile_matmul Pallas kernel.

Deliberately written from scratch (NOT importing the kernel or core.rmpm):
it materializes each (bm, bn) output tile independently at its mapped limb
count, against the full (padded) contraction split into bk slabs in the same
K-innermost order as the kernel grid — an independent formulation of the
same per-tile arithmetic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tile_matmul_ref(
    a: jax.Array, b: jax.Array, mode_map, *, bm: int, bn: int, bk: int
) -> jax.Array:
    """a (M, K) f32 @ b (K, N) f32 (block multiples) with per-tile limb
    counts from ``mode_map`` ((gm, gn) or (gm, gn, gk) ints)."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    mode_map = np.asarray(mode_map)
    m, kdim = a.shape
    n = b.shape[1]
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    gk = kdim // bk

    def limbs(x, k):
        out, r = [], jnp.asarray(x)
        for _ in range(k):
            li = r.astype(jnp.bfloat16)
            out.append(li)
            r = r - li.astype(jnp.float32)
        return out

    out = np.zeros((m, n), np.float32)
    for i in range(m // bm):
        for j in range(n // bn):
            acc = jnp.zeros((bm, bn), jnp.float32)
            for kk in range(gk):
                k_tile = int(
                    mode_map[i, j, kk] if mode_map.ndim == 3 else mode_map[i, j]
                )
                at = a[i * bm : (i + 1) * bm, kk * bk : (kk + 1) * bk]
                bt = b[kk * bk : (kk + 1) * bk, j * bn : (j + 1) * bn]
                al, bl = limbs(at, k_tile), limbs(bt, k_tile)
                terms = sorted(
                    [
                        (ti, tj)
                        for ti in range(k_tile)
                        for tj in range(k_tile)
                        if ti + tj < k_tile
                    ],
                    key=lambda ij: -(ij[0] + ij[1]),
                )
                for ti, tj in terms:
                    acc = acc + jnp.dot(
                        al[ti], bl[tj], preferred_element_type=jnp.float32
                    )
            out[i * bm : (i + 1) * bm, j * bn : (j + 1) * bn] = np.asarray(acc)
    return jnp.asarray(out)
