"""Mode-map construction for the partitioned tile_matmul kernel.

Three map sources, coarsest to finest:

* ``uniform_map``   — constant map from a static Mode: reproduces today's
  whole-matmul granularity, bit-exact with ``mp_matmul(impl="pallas")``.
* ``table_map``     — a (possibly traced) per-site mode scalar — e.g. from
  ``repro.adapt``'s ModeTable — broadcast into a map.  This is the bridge
  that lets the hysteresis controller steer the tile kernel today and
  individual tiles later: the map is a runtime argument, so per-tile values
  need no new compilation.
* ``magnitude_map`` — per-tile operand abs-max statistics pick the cheapest
  mode meeting a per-tile error budget, so one outlier-heavy tile no longer
  forces the entire matmul to the expensive mode.

``magnitude_map`` budget semantics: the worst-case absolute error of a tile
computed at mode m is bounded by ``eps_m * amax_tile(A) * amax_tile(B) * K``
(eps_m = the mode's relative-error ceiling from ``repro.plan.cost``; every
one of the K products errs by at most eps_m relative to its operands).  Each
tile takes the cheapest mode whose bound fits the budget; ``relative=True``
(default) expresses the budget as a fraction of the global magnitude
envelope ``S = max_tile(amax_A) * max_tile(amax_B) * K`` — so tiles whose
operands are small relative to the matmul's dominant tiles get cheap modes.
The bound is conservative (random-sign accumulation does not attain it), so
the measured error sits well inside the budget (gated in
``check_regression --tile-new``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.precision import F32_MODES, MODE_LIMBS, Mode
from repro.kernels.blocking import pad_to_block
from repro.kernels.tile_matmul.ops import tile_grid


def _f32_ladder_eps() -> list[tuple[int, float]]:
    """(limb count, relative-error ceiling) for the f32 ladder, cheap first."""
    from repro.plan.cost import MODE_REL_ERROR  # lazy: avoid kernels<->plan cycle

    return sorted((MODE_LIMBS[m], MODE_REL_ERROR[m]) for m in F32_MODES)


def uniform_map(
    shape_a: tuple[int, ...],
    shape_b: tuple[int, int],
    mode: Mode,
    *,
    per_k: bool = False,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
) -> jax.Array:
    """Constant mode map for ``a @ b`` — the bit-exact-with-today source."""
    mode = Mode(mode)
    if mode not in F32_MODES:
        raise ValueError(f"tile maps cover the f32 ladder {F32_MODES}, got {mode!r}")
    grid = _grid_for(shape_a, shape_b, bm, bn, bk)
    shape = grid if per_k else grid[:2]
    return jnp.full(shape, MODE_LIMBS[mode], dtype=jnp.int32)


def table_map(
    shape_a: tuple[int, ...],
    shape_b: tuple[int, int],
    mode_scalar,
    *,
    per_k: bool = False,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
) -> jax.Array:
    """Broadcast a per-site mode scalar (static or TRACED, e.g. one entry of
    ``repro.adapt``'s ModeTable) into a tile map.  Values are clipped to the
    f32 ladder's limb range [1, 3]."""
    grid = _grid_for(shape_a, shape_b, bm, bn, bk)
    shape = grid if per_k else grid[:2]
    kmax = max(MODE_LIMBS[m] for m in F32_MODES)
    k = jnp.clip(jnp.asarray(mode_scalar, jnp.int32), 1, kmax)
    return jnp.full(shape, 1, dtype=jnp.int32) * k


def magnitude_map(
    a: jax.Array,
    b: jax.Array,
    budget: float,
    *,
    relative: bool = True,
    per_k: bool = False,
    max_mode: Mode = Mode.M24,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
) -> jax.Array:
    """Per-tile cheapest mode meeting the error budget (see module docs).

    Returns an int32 map of limb counts in [1, limbs(max_mode)]; tiles whose
    bound fits no cheaper mode fall back to ``max_mode``.
    """
    max_mode = Mode(max_mode)
    if max_mode not in F32_MODES:
        raise ValueError(f"max_mode must be on the f32 ladder, got {max_mode!r}")
    kdim = a.shape[-1]
    n = b.shape[-1]
    a2 = jnp.abs(a.reshape(-1, kdim).astype(jnp.float32))
    b2 = jnp.abs(b.astype(jnp.float32))
    m = a2.shape[0]
    grid, (bm_, bn_, bk_) = tile_grid(m, n, kdim, bm=bm, bn=bn, bk=bk)
    gm, gn, gk = grid
    # Per-(row-tile, k-slab) and per-(k-slab, col-tile) operand maxima.
    amax = pad_to_block(a2, bm_, bk_).reshape(gm, bm_, gk, bk_).max(axis=(1, 3))
    bmax = pad_to_block(b2, bk_, bn_).reshape(gk, bk_, gn, bn_).max(axis=(1, 3))
    if per_k:
        mag = amax[:, None, :] * bmax.transpose(1, 0)[None, :, :] * bk_  # (gm, gn, gk)
    else:
        mag = amax.max(axis=1)[:, None] * bmax.max(axis=0)[None, :] * kdim  # (gm, gn)
    scale = amax.max() * bmax.max() * (bk_ if per_k else kdim)
    abs_budget = budget * scale if relative else jnp.asarray(budget, jnp.float32)
    kmax = MODE_LIMBS[max_mode]
    mode = jnp.full(mag.shape, kmax, dtype=jnp.int32)
    # Walk the ladder expensive -> cheap so the final value is the cheapest
    # mode whose worst-case bound eps * mag fits the budget.
    for limbs, eps in sorted(_f32_ladder_eps(), reverse=True):
        if limbs > kmax:
            continue
        mode = jnp.where(eps * mag <= abs_budget, jnp.int32(limbs), mode)
    return mode


def _grid_for(shape_a, shape_b, bm, bn, bk) -> tuple[int, int, int]:
    lead_m = 1
    for d in shape_a[:-1]:
        lead_m *= d
    grid, _ = tile_grid(lead_m, shape_b[-1], shape_a[-1], bm=bm, bn=bn, bk=bk)
    return grid


# The jaxpr walkers grew into a full static-analysis pass and moved to
# repro.analysis.dispatch (single implementation, version-portable
# duck-typing preserved); re-exported here for the existing call sites.
from repro.analysis.dispatch import (  # noqa: E402,F401
    _subjaxprs,
    _walk,
    dispatch_stats,
)
