"""Shared blocking helpers for the Pallas kernel wrappers.

Every kernel wrapper in ``repro.kernels`` does the same dance: clamp the
requested block to the actual dims, pad the operands up to block multiples,
run the kernel on the padded arrays, and strip the padding from the result.
This module is the single home for that logic (used by limb_matmul,
quantize_mantissa, and tile_matmul) plus the backend-aware ``interpret``
default shared by all kernel entry points.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# f32 sublane quantum on TPU: the second-to-last dim of a tile must be a
# multiple of 8 (the last dim quantum of 128 is handled by padding, not
# clamping — a 128-wide block on a 100-wide array just pads to 128).
BLOCK_QUANTUM = 8


def ceil_to(x: int, m: int) -> int:
    """Round ``x`` up to the next multiple of ``m``."""
    return -(-x // m) * m


def default_interpret() -> bool:
    """Backend-aware default for Pallas ``interpret``: interpret on CPU
    (no Mosaic lowering there), compile everywhere else.

    Called at Python time by the non-jit public wrappers, so tests can
    monkeypatch ``jax.default_backend`` and callers can still override
    explicitly via ``interpret=bool``.
    """
    return jax.default_backend() == "cpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` means "pick for the current backend"."""
    return default_interpret() if interpret is None else bool(interpret)


def clamp_block(block: int, dim: int, quantum: int = BLOCK_QUANTUM) -> int:
    """Largest useful block for a dim: the requested ``block`` when the dim
    fills it, else the dim rounded up to the tiling quantum.

    The naive ``min(block, dim)`` yields non-multiple-of-8 blocks for
    degenerate shapes (M=1 decode rows -> block 1), which violates the f32
    sublane quantum and pessimizes tiling; ``clamp_block(128, 1) == 8``.
    """
    if dim >= block:
        return block
    return ceil_to(max(dim, 1), quantum)


def pad_to_block(x: jax.Array, bm: int, bn: int) -> jax.Array:
    """Zero-pad a 2D array up to multiples of ``(bm, bn)``.

    Zero padding is exact for every op in this package: padded rows/cols
    contribute ``x + 0.0 == x`` to f32 accumulation and quantize to zero.
    """
    m, n = x.shape
    return jnp.pad(x, ((0, ceil_to(m, bm) - m), (0, ceil_to(n, bn) - n)))
