"""Jitted public wrapper for the limb_matmul Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.limb_matmul.limb_matmul import limb_matmul_pallas


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("k", "bm", "bn", "bk", "interpret", "rounding"))
def limb_matmul(
    a: jax.Array,
    b: jax.Array,
    k: int = 3,
    *,
    rounding: str = "rne",
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Multi-precision matmul a (..., K) @ b (K, N) via the fused Pallas
    kernel; pads to block multiples and strips the padding.

    ``interpret=True`` executes the kernel body on CPU (this container);
    on TPU pass interpret=False.  Only RNE limb extraction is fused; the
    paper's GRTE rounding runs through kernels/quantize_mantissa first.
    """
    if rounding != "rne":
        from repro.kernels.quantize_mantissa.ops import quantize_mantissa_op

        a = quantize_mantissa_op(a, 8 * k - 1, rounding, interpret=interpret)
        b = quantize_mantissa_op(b, 8 * k - 1, rounding, interpret=interpret)
    lead = a.shape[:-1]
    kdim = a.shape[-1]
    n = b.shape[-1]
    a2 = a.reshape(-1, kdim).astype(jnp.float32)
    m = a2.shape[0]
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, kdim)
    mp_, kp, np_ = _ceil_to(m, bm_), _ceil_to(kdim, bk_), _ceil_to(n, bn_)
    a2 = jnp.pad(a2, ((0, mp_ - m), (0, kp - kdim)))
    b2 = jnp.pad(b.astype(jnp.float32), ((0, kp - kdim), (0, np_ - n)))
    out = limb_matmul_pallas(a2, b2, k, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:m, :n].reshape(*lead, n)
