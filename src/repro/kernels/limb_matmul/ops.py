"""Public wrapper for the limb_matmul Pallas kernel.

``limb_matmul`` is a thin non-jit shell that resolves the backend-aware
``interpret`` default (interpret on CPU, compiled Mosaic elsewhere — see
``kernels.blocking.default_interpret``) and calls the jitted ``_limb_matmul``
body.  The resolution happens OUTSIDE the jit boundary so an explicit
override or a different backend is never frozen into a cached trace.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.blocking import clamp_block, pad_to_block, resolve_interpret
from repro.kernels.limb_matmul.limb_matmul import limb_matmul_pallas


def limb_matmul(
    a: jax.Array,
    b: jax.Array,
    k: int = 3,
    *,
    rounding: str = "rne",
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Multi-precision matmul a (..., K) @ b (K, N) via the fused Pallas
    kernel; pads to block multiples and strips the padding.

    ``interpret=None`` (default) interprets on CPU and compiles elsewhere;
    pass a bool to force either.  Only RNE limb extraction is fused; the
    paper's GRTE rounding runs through kernels/quantize_mantissa first.
    """
    return _limb_matmul(
        a, b, k, rounding=rounding, bm=bm, bn=bn, bk=bk,
        interpret=resolve_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("k", "bm", "bn", "bk", "interpret", "rounding"))
def _limb_matmul(
    a: jax.Array,
    b: jax.Array,
    k: int,
    *,
    rounding: str,
    bm: int,
    bn: int,
    bk: int,
    interpret: bool,
) -> jax.Array:
    if rounding != "rne":
        from repro.kernels.quantize_mantissa.ops import quantize_mantissa_op

        a = quantize_mantissa_op(a, 8 * k - 1, rounding, interpret=interpret)
        b = quantize_mantissa_op(b, 8 * k - 1, rounding, interpret=interpret)
    lead = a.shape[:-1]
    kdim = a.shape[-1]
    n = b.shape[-1]
    a2 = a.reshape(-1, kdim).astype(jnp.float32)
    m = a2.shape[0]
    bm_, bn_, bk_ = clamp_block(bm, m), clamp_block(bn, n), clamp_block(bk, kdim)
    a2 = pad_to_block(a2, bm_, bk_)
    b2 = pad_to_block(b.astype(jnp.float32), bk_, bn_)
    out = limb_matmul_pallas(a2, b2, k, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:m, :n].reshape(*lead, n)
