"""Pure-jnp oracle for the limb_matmul Pallas kernel.

Deliberately written from scratch (NOT importing core.rmpm) so kernel tests
check against an independent formulation of the same arithmetic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def limb_matmul_ref(a: jax.Array, b: jax.Array, k: int) -> jax.Array:
    """a (M, K) f32 @ b (K, N) f32 at k bf16-limb precision -> (M, N) f32."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)

    def limbs(x):
        out, r = [], x
        for _ in range(k):
            li = r.astype(jnp.bfloat16)
            out.append(li)
            r = r - li.astype(jnp.float32)
        return out

    al, bl = limbs(a), limbs(b)
    terms = sorted(
        [(i, j) for i in range(k) for j in range(k) if i + j < k],
        key=lambda ij: -(ij[0] + ij[1]),
    )
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
    for i, j in terms:
        acc = acc + jnp.dot(al[i], bl[j], preferred_element_type=jnp.float32)
    return acc
