"""Pallas TPU kernel: fused limb-extraction + k-limb multi-pass matmul.

This is the MXU-native form of the paper's reconfigurable multiplier (C1).
The naive XLA formulation materializes k bf16 limb tensors per operand in HBM
(k x the read traffic); this kernel reads the f32 operands ONCE per block,
extracts the limbs in VMEM, and runs the k(k+1)/2 retained Karatsuba passes
on the MXU while the block is resident — the memory-roofline optimization
recorded in EXPERIMENTS.md section Perf.

Grid: (M/bm, N/bn, K/bk), K innermost so the f32 accumulator tile stays
resident in VMEM across the contraction (revisited output block pattern).

VMEM budget per step (f32 words): bm*bk (A) + bk*bn (B) + bm*bn (acc)
 + bf16 limb copies k*(bm*bk + bk*bn)/2.  With bm=bn=128, bk=512, k=3:
 128*512*4 + 512*128*4 + 128*128*4 + 3*(128*512+512*128)*2 = ~1.3 MiB << 16 MiB VMEM.

High modes (k >= 4) additionally carry a Neumaier compensation tile so the
accumulation is double-f32 across K-tiles (see core.rmpm._limb_matmul_dd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.limb import limb_product_terms


def _extract_limbs(x, k: int):
    """Split an f32 tile into k bf16 limbs (in VMEM / registers)."""
    limbs = []
    r = x
    for _ in range(k):
        li = r.astype(jnp.bfloat16)
        limbs.append(li)
        r = r - li.astype(jnp.float32)
    return limbs


def _limb_matmul_kernel(a_ref, b_ref, out_ref, acc_ref, *, k: int, n_k_tiles: int):
    """One (bm, bn) output tile x one bk slab of the contraction."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_tile = a_ref[...]  # (bm, bk) f32 — read once; limbs live in VMEM only
    b_tile = b_ref[...]  # (bk, bn) f32
    a_limbs = _extract_limbs(a_tile, k)
    b_limbs = _extract_limbs(b_tile, k)

    acc = acc_ref[...]
    # High-order (small-magnitude) terms first minimizes accumulation error.
    for i, j in limb_product_terms(k):
        acc = acc + jax.lax.dot_general(
            a_limbs[i],
            b_limbs[j],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    acc_ref[...] = acc

    @pl.when(pl.program_id(2) == n_k_tiles - 1)
    def _done():
        out_ref[...] = acc_ref[...]


def _limb_matmul_dd_kernel(
    a_ref, b_ref, hi_ref, lo_ref, acc_ref, comp_ref, *, k: int, n_k_tiles: int
):
    """High-precision variant: double-f32 accumulation across K-tiles.

    Two f32 VMEM accumulators (sum, compensation) are carried across the K
    grid; each retained Karatsuba pass is folded in with a TwoSum, removing
    the cross-tile accumulation error.  NOTE the honest hardware limit: each
    MXU pass itself accumulates bk products in a plain f32 tree (the paper's
    FPGA builds arbitrary-width accumulators; the MXU cannot), so the
    effective precision of this kernel saturates near 26-28 bits.  Full
    M32/M48 fidelity uses core.rmpm._limb_matmul_dd (exact per-element
    products + Neumaier scan) — the validation-grade path.  Recorded as
    changed-assumption #8 in DESIGN.md.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        comp_ref[...] = jnp.zeros_like(comp_ref)

    a_limbs = _extract_limbs(a_ref[...], k)
    b_limbs = _extract_limbs(b_ref[...], k)
    s = acc_ref[...]
    comp = comp_ref[...]
    for i, j in limb_product_terms(k):
        p = jax.lax.dot_general(
            a_limbs[i],
            b_limbs[j],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        t = s + p
        bb = t - s
        comp = comp + ((s - (t - bb)) + (p - bb))  # Knuth TwoSum error term
        s = t
    acc_ref[...] = s
    comp_ref[...] = comp

    @pl.when(pl.program_id(2) == n_k_tiles - 1)
    def _done():
        s_f = acc_ref[...]
        c_f = comp_ref[...]
        t = s_f + c_f
        bb = t - s_f
        hi_ref[...] = t
        lo_ref[...] = (s_f - (t - bb)) + (c_f - bb)


@functools.partial(
    jax.jit, static_argnames=("k", "bm", "bn", "bk", "interpret")
)
def limb_matmul_dd_pallas(
    a: jax.Array,
    b: jax.Array,
    k: int = 4,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """High-precision k-limb matmul returning a (hi, lo) DoubleF32 pair."""
    m, kdim = a.shape
    _, n = b.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    n_k_tiles = kdim // bk
    return pl.pallas_call(
        functools.partial(_limb_matmul_dd_kernel, k=k, n_k_tiles=n_k_tiles),
        grid=(m // bm, n // bn, n_k_tiles),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(a, b)


@functools.partial(
    jax.jit, static_argnames=("k", "bm", "bn", "bk", "interpret")
)
def limb_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    k: int = 3,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """a (M, K) f32 @ b (K, N) f32 -> (M, N) f32 at k-limb precision.

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    m, kdim = a.shape
    _, n = b.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (a.shape, b.shape, bm, bn, bk)
    n_k_tiles = kdim // bk
    grid = (m // bm, n // bn, n_k_tiles)
    return pl.pallas_call(
        functools.partial(_limb_matmul_kernel, k=k, n_k_tiles=n_k_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
