"""Pallas TPU kernel: mantissa truncation + rounding (paper C3).

Bit-exact implementation of the paper's rounding scheme on the int32 view of
f32 data:

    G = first dropped bit, R = second, E = third, T = OR of the rest
    rnd = G & (R | T | E)        -> added to the kept-mantissa LSB  (Eq. 10)

plus round-to-nearest-even and plain truncation for the Table 9 comparison.
Elementwise over 2D blocks — integer ALU work on the VPU, one pass over HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_MANT = 23  # explicit mantissa bits of f32


def _quantize_block(x, keep: int, rounding: str):
    drop = _MANT - keep
    xi = jax.lax.bitcast_convert_type(x, jnp.uint32)
    one = jnp.uint32(1)
    lsb_unit = one << drop
    kept = xi & ~(lsb_unit - one)
    if rounding == "trunc":
        qi = kept
    elif rounding == "grte":
        g = (xi >> (drop - 1)) & one
        r = (xi >> (drop - 2)) & one if drop >= 2 else jnp.zeros_like(xi)
        e = (xi >> (drop - 3)) & one if drop >= 3 else jnp.zeros_like(xi)
        if drop >= 4:
            t = ((xi & ((one << (drop - 3)) - one)) != 0).astype(jnp.uint32)
        else:
            t = jnp.zeros_like(xi)
        qi = kept + (g & (r | t | e)) * lsb_unit
    elif rounding == "rne":
        g = (xi >> (drop - 1)) & one
        rest = ((xi & ((one << (drop - 1)) - one)) != 0).astype(jnp.uint32)
        lsb = (xi >> drop) & one
        qi = kept + (g & (rest | lsb)) * lsb_unit
    else:
        raise ValueError(rounding)
    q = jax.lax.bitcast_convert_type(qi, jnp.float32)
    return jnp.where(jnp.isfinite(x), q, x)


def _kernel(x_ref, o_ref, *, keep: int, rounding: str):
    o_ref[...] = _quantize_block(x_ref[...], keep, rounding)


@functools.partial(jax.jit, static_argnames=("keep", "rounding", "block", "interpret"))
def quantize_mantissa_pallas(
    x: jax.Array,
    keep: int,
    rounding: str = "grte",
    *,
    block: tuple[int, int] = (256, 256),
    interpret: bool = False,
) -> jax.Array:
    """x: (M, N) f32, M/N multiples of block dims (ops.py pads)."""
    if keep < 1:
        # mirror the jnp oracle: keep <= 0 makes drop > 23 and the integer
        # mask/carry corrupt the exponent and sign fields
        raise ValueError(f"keep must be >= 1, got {keep}")
    if keep >= _MANT:
        return x
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        functools.partial(_kernel, keep=keep, rounding=rounding),
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x)
