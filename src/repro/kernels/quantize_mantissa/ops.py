"""Public wrapper for the quantize_mantissa Pallas kernel.

Non-jit shell (backend-aware ``interpret`` resolution, ``keep`` validation)
around the jitted ``_quantize_mantissa`` body — same structure as
``limb_matmul``; see ``kernels.blocking``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.blocking import clamp_block, pad_to_block, resolve_interpret
from repro.kernels.quantize_mantissa.quantize_mantissa import quantize_mantissa_pallas


def quantize_mantissa_op(
    x: jax.Array,
    keep: int,
    rounding: str = "grte",
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Quantize the mantissa of an arbitrary-shape f32 array to ``keep``
    explicit bits with the selected rounding (trunc | rne | grte).
    ``keep`` must be >= 1 (the kernel rejects values that would reach into
    the exponent/sign fields, matching the jnp oracle); ``keep >= 23`` is
    the identity.  ``interpret=None`` interprets on CPU, compiles elsewhere."""
    if keep < 1:
        raise ValueError(f"keep must be >= 1, got {keep}")
    if keep >= 23:
        return x
    return _quantize_mantissa(x, keep, rounding, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("keep", "rounding", "interpret"))
def _quantize_mantissa(
    x: jax.Array,
    keep: int,
    rounding: str,
    *,
    interpret: bool,
) -> jax.Array:
    shape = x.shape
    flat = x.reshape(1, -1) if x.ndim < 2 else x.reshape(-1, shape[-1])
    m, n = flat.shape
    bm, bn = clamp_block(256, m), clamp_block(256, n)
    padded = pad_to_block(flat, bm, bn)
    out = quantize_mantissa_pallas(
        padded, keep, rounding, block=(bm, bn), interpret=interpret
    )
    return out[:m, :n].reshape(shape)
