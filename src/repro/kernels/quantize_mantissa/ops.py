"""Jitted public wrapper for the quantize_mantissa Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quantize_mantissa.quantize_mantissa import quantize_mantissa_pallas


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("keep", "rounding", "interpret"))
def quantize_mantissa_op(
    x: jax.Array,
    keep: int,
    rounding: str = "grte",
    *,
    interpret: bool = True,
) -> jax.Array:
    """Quantize the mantissa of an arbitrary-shape f32 array to ``keep``
    explicit bits with the selected rounding (trunc | rne | grte).
    ``keep`` must be >= 1 (the kernel rejects values that would reach into
    the exponent/sign fields, matching the jnp oracle)."""
    if keep < 1:
        raise ValueError(f"keep must be >= 1, got {keep}")
    if keep >= 23:
        return x
    shape = x.shape
    flat = x.reshape(1, -1) if x.ndim < 2 else x.reshape(-1, shape[-1])
    m, n = flat.shape
    bm, bn = min(256, m), min(256, n)
    mp_, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    padded = jnp.pad(flat, ((0, mp_ - m), (0, np_ - n)))
    out = quantize_mantissa_pallas(
        padded, keep, rounding, block=(bm, bn), interpret=interpret
    )
    return out[:m, :n].reshape(shape)
