"""Pure-jnp oracle for the quantize_mantissa kernel (independent of core)."""
from __future__ import annotations

import numpy as np


def quantize_mantissa_ref(x: np.ndarray, keep: int, rounding: str = "grte") -> np.ndarray:
    """NumPy bit-level reference (scalar loop semantics, vectorized)."""
    x = np.asarray(x, np.float32)
    if keep >= 23:
        return x
    drop = 23 - keep
    xi = x.view(np.uint32)
    lsb_unit = np.uint32(1 << drop)
    kept = xi & ~np.uint32(lsb_unit - 1)
    if rounding == "trunc":
        qi = kept
    elif rounding == "grte":
        g = (xi >> (drop - 1)) & 1
        r = (xi >> (drop - 2)) & 1 if drop >= 2 else np.zeros_like(xi)
        e = (xi >> (drop - 3)) & 1 if drop >= 3 else np.zeros_like(xi)
        t = (
            ((xi & np.uint32((1 << (drop - 3)) - 1)) != 0).astype(np.uint32)
            if drop >= 4
            else np.zeros_like(xi)
        )
        qi = kept + (g & (r | t | e)) * lsb_unit
    elif rounding == "rne":
        g = (xi >> (drop - 1)) & 1
        rest = ((xi & np.uint32((1 << (drop - 1)) - 1)) != 0).astype(np.uint32)
        lsb = (xi >> drop) & 1
        qi = kept + (g & (rest | lsb)) * lsb_unit
    else:
        raise ValueError(rounding)
    q = qi.astype(np.uint32).view(np.float32)
    return np.where(np.isfinite(x), q, x)
