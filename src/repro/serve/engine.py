"""Masked step engine: true continuous batching over a fixed slot array.

``ServeEngine`` runs the streaming API

    rid = engine.submit(Request(prompt, max_new, rid))   # any time
    events = engine.step()                               # [(rid, token), ...]
    outputs = engine.drain()                             # run to completion

over one compiled decode step.  The slot array is fixed at ``batch_slots``;
per-slot state (KV positions, lengths, decode position) lives in the
per-slot ``DecodeState`` layout (``models.lm.init_decode_state(per_slot=
True)``), so slots at *different* sequence depths — and empty slots — share
the same ``jax.jit`` step: finished rows are masked out (their state is
frozen by a per-row select), new requests join mid-flight by scattering a
solo-prefilled row into their slot.  This is the ReservationStations fan-in/
fan-out shape from the ieee754fpu pipeline (SNIPPETS.md section 1) applied
to decode: the step function is the shared pipeline, the scheduler is the
fan-in.

Prefill runs per-request at the prompt's true length (batch=1) — no padded
positions ever enter the KV cache — then the resulting row is written into
the request's slot (one ``dynamic_update_slice`` per state leaf).

Precision phases: with ``accuracy=...`` the engine plans *two* policies via
``repro.plan.plan_model_policy`` — one for prefill GEMMs (prompt_tokens x d)
and one for decode GEMMs (slots x d) — and compiles each phase under its own
policy.  That is the paper's run-time mode switch exercised inside a single
workload: the mode bits flip between phases while the params and the KV
cache stream through unchanged (DESIGN.md section Serving).

With ``speculate=SpecConfig(...)`` the decode phase runs self-speculative
rounds (repro.spec): the cheap end of the mode ladder drafts ``k`` tokens
per slot, the exact baseline step verifies all ``k+1`` positions, and a
compiled rollback-select restores each slot to its accepted prefix —
outputs stay bit-identical to this engine's plain greedy decode while
expensive-mode steps per emitted token drop below 1 (DESIGN.md section
Speculative decoding).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LanguageModel
from repro.obs import NULL_TRACER, PhaseProfiler, TraceConfig, Tracer
from repro.serve.config import ServeConfig
from repro.serve.metrics import ServeMetrics
from repro.serve.paged import make_layout
from repro.serve.scheduler import Request, Scheduler, Ticket
from repro.serve.tenancy import RequestClass, Tenant

__all__ = ["Request", "RequestClass", "ServeConfig", "ServeEngine", "Tenant"]


def _plan_phase(model: LanguageModel, tokens: int, accuracy: float,
                backend: str | None, tune_table=None):
    """Plan one phase's policy (prefill or decode) for its GEMM M-dim."""
    from repro.core.precision import DF32_MODES
    from repro.plan import plan_model_policy

    base = model.cfg.policy
    policy, plans = plan_model_policy(
        model.cfg, tokens=tokens, accuracy=accuracy,
        backend=backend, rounding=base.rounding, tune_table=tune_table,
    )
    if (
        base.impl == "native"
        and policy.impl == "xla"
        and not any(p.mode in DF32_MODES for p in plans.values())
    ):
        # keep the fast CPU execution path when the base policy chose it and
        # the planner has no better limb impl to offer — but never for DF32
        # modes, where 'xla' IS the limb engine and 'native' (plain f32)
        # would break the accuracy budget
        policy = policy.with_impl("native")
    return LanguageModel(model.cfg.with_policy(policy)), plans


def row_select(ax: int, new, old, active):
    """Per-row select along a state leaf's batch axis ``ax``: rows where
    ``active`` is False keep ``old`` exactly — the masking invariant shared
    by the masked steps and the speculative rollback (repro.spec).  Leaves
    with no batch axis (``repro.serve.paged.SHARED`` — the paged pools) keep
    ``new``: per-row isolation for them is the page table's job (inactive
    rows' cleared tables redirect their writes to the scratch page)."""
    if ax < 0:
        return new
    shape = [1] * new.ndim
    shape[ax] = active.shape[0]
    return jnp.where(active.reshape(shape), new, old)


class ServeEngine:
    #: decode-phase accuracy tightening: autoregressive decode feeds its
    #: rounding errors back (every generated token conditions the rest),
    #: while prefill errors are one-shot, so the decode phase plans at
    #: ``accuracy * DECODE_ACCURACY_SCALE`` — a budget near a mode boundary
    #: therefore flips the RMPM mode bits between the phases of one workload.
    DECODE_ACCURACY_SCALE = 2.0**-4

    def __init__(self, model: LanguageModel, params,
                 batch_slots: int | None = None, max_len: int | None = None,
                 greedy: bool = True, accuracy: float | None = None,
                 plan_backend: str | None = None,
                 prefill_tokens: int | None = None,
                 decode_accuracy_scale: float | None = None,
                 tune_table=None,
                 slo=None, adapt_every: int = 4, adapt: bool = True,
                 controller=None, speculate=None,
                 tenants=None, classes=None,
                 scheduler_policy: str = "priority",
                 preempt: bool = True, aging_steps: int = 8,
                 min_quantum: int = 2, cache=None,
                 config: ServeConfig | None = None):
        """``config=ServeConfig(...)`` is the documented construction path —
        one frozen value grouping the scheduling / adaptation / speculation /
        cache surfaces (repro.serve.config).  The flat kwargs remain as a
        deprecation shim: they are regrouped through
        ``ServeConfig.from_kwargs`` and must not be mixed with ``config=``.

        ``slo`` (repro.adapt.SLO) turns on closed-loop runtime precision
        adaptation of the decode phase: the planner's decode modes become a
        mutable ModeTable whose int32 scalars feed one compiled masked step
        (``lax.switch`` branch select — zero recompiles across mode changes);
        every ``adapt_every`` decode steps a probe runs the same executable
        at the max-mode reference and one mode down, and the hysteresis
        controller shifts the table against the SLO.  ``adapt=False`` keeps
        the probes and mode timeline (monitoring) but never shifts — the
        instrumented static baseline the adapt benchmark compares against.

        ``speculate`` (repro.spec.SpecConfig) turns on self-speculative
        decoding: each round drafts ``k`` tokens per slot under a cheap mode
        table, verifies all ``k+1`` positions with the exact baseline step,
        and rolls every slot back to its accepted prefix inside one compiled
        round — outputs stay bit-identical to the non-speculative greedy
        engine while expensive-mode steps per emitted token drop below 1
        (DESIGN.md section Speculative decoding).  Requires ``greedy=True``.

        ``tenants`` / ``classes`` (repro.serve.tenancy) declare the request
        streams multiplexed onto the slot array: the scheduler admits by
        (aged priority, deadline, seq) instead of FIFO, preempting running
        low-priority work by *parking its exact state row* (gather, requeue,
        scatter back at re-admission — never a re-prefill, so a preempted
        request's token stream stays bit-identical to an uncontended run).
        With ``slo=`` set, each tenant also gets its own ModeTable and
        hysteresis controller (its ``accuracy`` overrides ``slo.max_err``)
        so one tenant's hot workload cannot drag another tenant's modes;
        each step binds the per-site *most precise* mode across tenants
        with active slots.  ``scheduler_policy="fifo"`` restores the pure
        submission-order baseline (the tenant sweep's comparison point).
        """
        if config is not None:
            if batch_slots is not None or max_len is not None:
                raise ValueError(
                    "pass either config=ServeConfig(...) or the legacy flat "
                    "kwargs, not both")
            cfg = config
        else:
            if batch_slots is None or max_len is None:
                raise TypeError(
                    "ServeEngine requires batch_slots and max_len (or a "
                    "config=ServeConfig(...))")
            cfg = ServeConfig.from_kwargs(
                batch_slots, max_len, greedy=greedy, accuracy=accuracy,
                plan_backend=plan_backend, prefill_tokens=prefill_tokens,
                decode_accuracy_scale=decode_accuracy_scale,
                tune_table=tune_table, slo=slo, adapt_every=adapt_every,
                adapt=adapt, controller=controller, speculate=speculate,
                tenants=tenants, classes=classes,
                scheduler_policy=scheduler_policy, preempt=preempt,
                aging_steps=aging_steps, min_quantum=min_quantum,
                cache=cache)
        self.config = cfg
        batch_slots, max_len = cfg.batch_slots, cfg.max_len
        greedy, accuracy = cfg.greedy, cfg.accuracy
        plan_backend, prefill_tokens = cfg.plan_backend, cfg.prefill_tokens
        decode_accuracy_scale = cfg.decode_accuracy_scale
        tune_table = cfg.tune_table
        sch = cfg.scheduling
        tenants, classes = sch.tenants, sch.classes
        scheduler_policy, preempt = sch.policy, sch.preempt
        aging_steps, min_quantum = sch.aging_steps, sch.min_quantum
        slo, adapt_every = cfg.adapt.slo, cfg.adapt.adapt_every
        adapt, controller = cfg.adapt.adapt, cfg.adapt.controller
        speculate = cfg.spec
        if not greedy:
            # the masked step and the solo prefill take argmax; pretending
            # to honour a sampling flag would silently return greedy tokens
            # (and speculative verify is only exact against greedy decode)
            raise NotImplementedError(
                "ServeEngine only implements greedy decoding: temperature "
                "sampling is not wired into the masked step / prefill, and "
                "speculative verify requires greedy argmax. Pass greedy=True."
            )
        # metrics first: its plan-cache snapshot must predate phase planning
        # so plan_cache_delta() counts the plans this engine triggers
        self.metrics = ServeMetrics(batch_slots)
        # -- tracing (repro.obs): fixed at construction.  Off means the
        # shared no-op NULL_TRACER everywhere — every emit site is guarded
        # on ``tracer.enabled`` and never touches jit arguments, so traced
        # and untraced engines compile and dispatch identically (pinned by
        # tests/test_obs.py and the obs_sweep overhead gate)
        if cfg.trace:
            self.tracer = Tracer(cfg.trace if isinstance(cfg.trace, TraceConfig)
                                 else None)
        else:
            self.tracer = NULL_TRACER
        self.profiler = PhaseProfiler(self.tracer)
        if accuracy is not None:
            # Per-phase planning (DESIGN.md section Serving): decode GEMMs
            # see M = batch_slots at a tightened budget, prefill GEMMs see
            # M = prompt tokens at the caller's budget.  ``tune_table``
            # (TuneTable | path | None | False) routes both phases through
            # the measured-cost planner (DESIGN.md section Autotuner).
            scale = (self.DECODE_ACCURACY_SCALE if decode_accuracy_scale is None
                     else decode_accuracy_scale)
            self.model_decode, decode_plans = _plan_phase(
                model, batch_slots, accuracy * scale, plan_backend, tune_table)
            self.model_prefill, prefill_plans = _plan_phase(
                model, prefill_tokens or max_len, accuracy, plan_backend, tune_table)
            self.phase_plans = {"prefill": prefill_plans, "decode": decode_plans}
            # flat view kept for the PR-1 API (`engine.plans`)
            self.plans = {
                f"{phase}/{op}": p
                for phase, plans in self.phase_plans.items()
                for op, p in plans.items()
            }
        else:
            self.model_decode = self.model_prefill = model
            self.phase_plans = {}
            self.plans = {}
        self.model = self.model_decode
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        self.scheduler = Scheduler(
            batch_slots, max_len, tenants=tenants, classes=classes,
            policy=scheduler_policy, preempt=preempt,
            aging_steps=aging_steps, min_quantum=min_quantum)
        self.scheduler.tracer = self.tracer
        self.metrics.set_tenant_shares(
            {name: t.share for name, t in self.scheduler.tenants.items()})
        #: rid -> (parked per-slot state row (device pytree), cache length)
        #: of preempted requests, scattered back verbatim at re-admission
        self._parked: dict[int, tuple[object, int]] = {}
        #: the KV layout owns the decode state's shape, the per-row
        #: gather/scatter, and (paged) the page-pool bookkeeping — the
        #: engine never touches cache internals directly (repro.serve.paged)
        self.layout = make_layout(cfg.cache, self.model_decode,
                                  batch_slots, max_len)
        self.layout.tracer = self.tracer
        self.state = self.layout.init()
        # solo-prefill template: one per-slot row, reused for every prefill.
        # Always the *dense* layout — the batch-1 dense row is the exchange
        # format every layout's scatter_row/gather_row speaks.
        self._solo0 = self.model_prefill.init_decode_state(
            1, max_len, per_slot=True)
        self._axes = self.layout.axes
        self._prefill = jax.jit(self.model_prefill.decode_step)
        self._step = jax.jit(self._masked_step)
        # host-side slot mirrors
        self._active = np.zeros((batch_slots,), bool)
        self._last_tok = np.zeros((batch_slots,), np.int32)
        #: tokens currently in each slot's cache (virtual length) — drives
        #: paged allocation-on-append and the tier cold-page ages
        self._row_len = np.zeros((batch_slots,), np.int64)
        # -- runtime adaptation (repro.adapt) --------------------------------
        self.slo = slo
        self._adapt = bool(adapt)
        self._last_step_ms: float | None = None
        #: tokens each active slot emitted in the last measured step — the
        #: SLO's target_ms is a *per-decode-step* budget, so a speculative
        #: round (one dispatch emitting up to k+1 tokens per slot) must be
        #: normalized to its per-token step equivalent before the latency
        #: comparison, or every round would read as a latency violation and
        #: silently disable the controller's dead band (invariant iii)
        self._last_step_tokens = 1.0
        if self.phase_plans:
            self._static_decode_label = self.phase_plans["decode"]["mlp_up"].mode.name
        else:
            self._static_decode_label = model.cfg.policy.default.name
        #: per-tenant adaptation (tenants= with slo=): each tenant owns a
        #: private ModeTable + controller; the compiled step binds the
        #: per-site max across tenants with active slots (see
        #: ``_bound_scalars``) so a tenant needing precision always gets at
        #: least its own table's modes — isolation without a second compile
        self.tenant_tables: dict[str, object] = {}
        self.tenant_ctrl: dict[str, object] = {}
        self._combined_cache: dict[tuple, dict] = {}
        self._per_tenant_adapt = slo is not None and tenants is not None
        if slo is not None:
            from repro.adapt import SLO, HysteresisController, ModeTable

            def make_table():
                if self.phase_plans:
                    return ModeTable.from_plans(self.phase_plans["decode"])
                return ModeTable.from_policy(model.cfg.policy)

            if self._per_tenant_adapt:
                if controller is not None:
                    raise ValueError(
                        "controller= is a single shared instance; with "
                        "tenants= each tenant gets its own controller — "
                        "set per-tenant budgets via Tenant.accuracy instead")
                for name, ten in self.scheduler.tenants.items():
                    t_slo = SLO(
                        max_err=(ten.accuracy if ten.accuracy is not None
                                 else slo.max_err),
                        target_ms=slo.target_ms,
                        down_factor=slo.down_factor)
                    self.tenant_tables[name] = make_table()
                    ctrl = HysteresisController(t_slo)
                    ctrl.tracer, ctrl.name = self.tracer, f"adapt/{name}"
                    self.tenant_ctrl[name] = ctrl
                self.mode_table = None
                self.controller = None
            else:
                self.mode_table = make_table()
                self.controller = controller or HysteresisController(slo)
                self.controller.tracer = self.tracer
                self.controller.name = "adapt"
            self.adapt_every = max(int(adapt_every), 1)
            self._step_modal = jax.jit(self._masked_step_modal)
            self._probe = jax.jit(self._probe_fn)
        else:
            self.mode_table = None
            self.controller = None
        # -- self-speculative decoding (repro.spec) --------------------------
        self.spec = None
        if speculate is not None:
            self._init_spec(speculate)

    def _init_spec(self, spec) -> None:
        """Wire the speculative round: the verify table is the engine's live
        adaptive table when ``slo`` is set (so the PR-4 SLO controller keeps
        owning output quality) or the planner/policy decode modes otherwise;
        the draft table is that table shifted ``draft_shift`` rungs down,
        retuned at run time by the acceptance controller."""
        from repro.adapt import ModeTable
        from repro.spec import AcceptanceController, SpecConfig
        from repro.spec.rollout import build_spec_round

        if not isinstance(spec, SpecConfig):
            raise TypeError(
                f"speculate must be a repro.spec.SpecConfig, got {type(spec)}")
        if self.tenant_tables:
            # the speculative round binds ONE draft/verify table pair per
            # compiled round; per-tenant tables would need per-slot mode
            # binding inside the round — not built yet, so refuse loudly
            # rather than silently verifying tenant A under tenant B's modes
            raise NotImplementedError(
                "speculate= with per-tenant adaptation (tenants= and slo=) "
                "is not supported: the spec round verifies under one mode "
                "table. Drop slo= (static speculation works with tenants=) "
                "or drop speculate=.")
        self.spec = spec
        if self.mode_table is not None:
            self._spec_table = self.mode_table  # adaptive verify (slo path)
        elif self.phase_plans:
            self._spec_table = ModeTable.from_plans(self.phase_plans["decode"])
        else:
            self._spec_table = ModeTable.from_policy(self.model_decode.cfg.policy)
        ladder = int(self._spec_table.max_mode) - int(self._spec_table.min_mode)
        self._draft_shift = max(1, min(spec.draft_shift, max(ladder, 1)))
        self._accept_ctrl = (
            AcceptanceController(spec, ladder, shift=self._draft_shift)
            if spec.adapt and ladder > 0 else None)
        if self._accept_ctrl is not None:
            self._accept_ctrl.controller.tracer = self.tracer
            self._accept_ctrl.controller.name = "accept"
        self._spec_round = jax.jit(build_spec_round(
            self.model_decode, self._axes, spec.k,
            modal_verify=self.slo is not None))
        self._spec_window = [0, 0]  # (drafted, agreed) since last tick

    # -- compiled pieces -----------------------------------------------------

    def _masked_step(self, params, tokens, state, active):
        """One decode token for every slot; rows where ``active`` is False
        keep their exact prior state (cache, positions, lengths) — finished
        and empty slots are inert, so a freed slot can be re-filled at any
        step without touching the others."""
        logits, new_state = self.model_decode.decode_step(params, tokens, state)
        merged = jax.tree.map(
            lambda ax, new, old: row_select(ax, new, old, active),
            self._axes, new_state, state)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), merged

    def _masked_step_modal(self, params, tokens, state, active, modes):
        """The masked step with the mode table bound: ``modes`` is a dict of
        int32 scalars (jit arguments), so every table mutation between steps
        re-dispatches the ``lax.switch`` branches of one executable — the
        paper's run-time reconfiguration, no recompile."""
        from repro.adapt import bind_modes

        with bind_modes(modes):
            logits, new_state = self.model_decode.decode_step(
                params, tokens, state)
        merged = jax.tree.map(
            lambda ax, new, old: row_select(ax, new, old, active),
            self._axes, new_state, state)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), merged

    def _probe_fn(self, params, tokens, state, active, cur, ref, down):
        """Shadow-forward error probe: the decode step replayed at the
        current, max-mode-reference and one-mode-down tables (same compiled
        executable, different mode scalars; state discarded).  Returns
        (err_current, err_one_down) as the normalized logit residual over
        active slots (repro.adapt.probe)."""
        from repro.adapt import bind_modes, logit_residual

        def fwd(modes):
            with bind_modes(modes):
                logits, _ = self.model_decode.decode_step(params, tokens, state)
            return logits[:, -1]

        l_cur, l_ref, l_down = fwd(cur), fwd(ref), fwd(down)
        return (logit_residual(l_cur, l_ref, active),
                logit_residual(l_down, l_ref, active))

    # -- streaming API -------------------------------------------------------

    def submit(self, req: Request) -> int:
        """Enqueue a request; it joins a slot on the next ``step()`` with
        free capacity.  Returns the rid."""
        rid = self.scheduler.submit(req)
        t = self.scheduler.tickets[rid]
        rc = self.scheduler.classes[t.rclass]
        self.metrics.on_submit(
            rid, tenant=t.tenant, rclass=t.rclass,
            slo_steps=rc.slo_steps, slo_ms=rc.slo_ms, step=t.submit_step)
        if self.tracer.enabled:
            self.tracer.emit(
                "submit", rid=rid, step=t.submit_step, tenant=t.tenant,
                rclass=t.rclass, prompt_len=len(t.prompt), budget=t.budget)
            self.tracer.inc("submitted")
        return rid

    def step(self) -> list[tuple[int, int]]:
        """One engine step: advance the scheduler clock, park any
        preemption victims (exact state-row gather + requeue), admit
        waiting requests into free slots (fresh: one solo prefill emitting
        the first token; preempted: scatter the parked row back — no new
        token, the request just continues), then run one masked batched
        decode step for every active slot.  Returns this step's
        (rid, token) events in emission order."""
        events: list[tuple[int, int]] = []
        self.scheduler.tick()
        self.tracer.step = self.scheduler.clock
        for victim in self.scheduler.plan_preemptions():
            self._park_slot(victim, cause="priority")
        self.layout.begin_admission()
        for slot, ticket in self.scheduler.admit(can_admit=self._can_admit):
            if slot < 0:
                # zero-budget admission (nothing fits the cache): the
                # scheduler completed it without a slot — route the
                # completion through metrics so summary()["completed"]
                # agrees with drain()/scheduler.completed
                self.metrics.on_done(ticket.rid, step=self.scheduler.clock)
                if self.tracer.enabled:
                    self.tracer.emit("done", rid=ticket.rid, slot=-1,
                                     cause="zero_budget")
                continue
            if ticket.tokens:
                self._resume_slot(slot, ticket)
                continue
            if self.tracer.enabled:
                self.tracer.emit("admit", rid=ticket.rid, slot=slot,
                                 tenant=ticket.tenant, rclass=ticket.rclass)
            first = self._prefill_slot(slot, ticket)
            self.metrics.on_first_token(ticket.rid)
            events.append((ticket.rid, first))
            self._emit(ticket, slot, first)
        if self._active.any():
            self._prepare_pages()
        if self._active.any():
            if self.spec is not None:
                events.extend(self._spec_step())
            else:
                events.extend(self._decode_step())
            if (self.slo is not None
                    and self.metrics.decode_steps % self.adapt_every == 0
                    and self._active.any()):
                if self._per_tenant_adapt:
                    self._adapt_tick_tenants()
                else:
                    self._adapt_tick()
            self._page_tick()
        return events

    def _can_admit(self, ticket: Ticket) -> bool:
        """Admission gate handed to the scheduler: the layout says whether
        it can map this ticket's cache content (dense: always — the free
        slot IS the capacity; paged: free pages after prefix-sharing
        hits)."""
        if ticket.rid in self._parked:
            return self.layout.can_admit(self._parked[ticket.rid][1])
        return self.layout.can_admit(len(ticket.prompt),
                                     prompt=ticket.prompt)

    def _prepare_pages(self) -> None:
        """Paged allocation-on-append, before the decode dispatch: every
        active row gets pages covering the tokens this step will write
        (1 plain decode, k+1 speculative).  When the pool cannot serve a
        row, the scheduler names a page-pressure victim — lowest effective
        priority among running requests — and its exact state parks
        (gather + requeue, the same bit-exact preemption path tenancy
        uses), freeing its pages; repeat until the survivors fit.  Dense
        layouts return no failures and this is a no-op."""
        ahead = (self.spec.k + 1) if self.spec is not None else 1
        while self._active.any():
            lengths = {int(s): int(self._row_len[s])
                       for s in np.nonzero(self._active)[0]}
            self.state, failed = self.layout.prepare_step(
                self.state, lengths, ahead)
            if not failed:
                return
            victim = self.scheduler.page_victim()
            if victim is None or victim.slot is None:
                victim = self.scheduler.by_slot[failed[0]]
            self._park_slot(victim, cause="page_pressure")
            self.metrics.on_page_evict()
            if self.tracer.enabled:
                self.tracer.emit("page_evict", rid=victim.rid,
                                 cause="page_pressure")
                self.tracer.inc("page_evictions")

    def _page_tick(self) -> None:
        """Post-step page accounting: occupancy/sharing stats every step,
        one tier demotion/measurement pass every ``tier_policy.every``
        decode steps (repro.adapt.pages)."""
        stats = self.layout.page_stats()
        if stats is None:
            return
        self.metrics.on_page_stats(stats)
        tp = self.config.cache.tier_policy
        if tp is None or self.metrics.decode_steps % tp.every != 0:
            return
        lengths = {int(s): int(self._row_len[s])
                   for s in np.nonzero(self._active)[0]}
        self.state, tstats = self.layout.tier_tick(
            self.state, lengths, self.metrics.decode_steps)
        if tstats is not None:
            self.metrics.on_page_tier(self.metrics.decode_steps, tstats)
            if self.tracer.enabled:
                self.tracer.emit(
                    "tier_tick",
                    cause="budget" if tp.budget else "open_loop",
                    keep=tstats.get("keep"), depth=tstats.get("depth"),
                    demoted=tstats.get("demoted"),
                    promoted=tstats.get("promoted"),
                    err=tstats.get("err"))
                self.tracer.inc("tier_demotions", tstats.get("demoted", 0))

    def _park_slot(self, victim: Ticket, cause: str = "priority") -> None:
        """Preempt a running request: gather its exact per-slot state row
        off the device (as a dense batch-1 row, whatever the layout), free
        the slot — and, paged, the row's pages — and requeue the ticket.
        Nothing is recomputed at resume — ``_resume_slot`` scatters this
        row back, so the token stream continues bit-identically.  ``cause``
        stamps the trace event ("priority" scheduler preemption vs
        "page_pressure" eviction — the latter legally ignores the quantum,
        which the replay harness accounts for by cause)."""
        slot = victim.slot
        self._parked[victim.rid] = (
            self.layout.gather_row(self.state, slot),
            int(self._row_len[slot]))
        self.state = self.layout.free_row(self.state, slot)
        self._active[slot] = False
        self.scheduler.preempt(victim.rid)
        self.metrics.on_preempt(victim.rid)
        if self.tracer.enabled:
            self.tracer.emit("preempt", rid=victim.rid, slot=slot,
                             cause=cause)
            self.tracer.inc("preemptions")

    def _resume_slot(self, slot: int, ticket: Ticket) -> None:
        """Re-admit a preempted request: scatter its parked state row into
        the (possibly different) slot and rearm the host mirrors.  No token
        is emitted and no prefill runs — the next masked step continues
        from ``ticket.tokens[-1]`` exactly as if the gap never happened."""
        row, length = self._parked.pop(ticket.rid)
        self.state = self.layout.scatter_row(
            self.state, row, slot, length=length)
        self._row_len[slot] = length
        self._active[slot] = True
        self._last_tok[slot] = ticket.tokens[-1]
        if self.tracer.enabled:
            self.tracer.emit("resume", rid=ticket.rid, slot=slot,
                             cache_len=length)

    def _tenant_active(self) -> dict[str, int]:
        """Active slots per tenant right now — metrics attribution for the
        fairness report (share of decode-slot work actually consumed)."""
        counts: dict[str, int] = {}
        for slot in np.nonzero(self._active)[0]:
            name = self.scheduler.by_slot[int(slot)].tenant
            counts[name] = counts.get(name, 0) + 1
        return counts

    def _bound_scalars(self, tenant_active: dict[str, int]):
        """(scalars, label) to bind this step under per-tenant adaptation:
        the per-site *maximum* (most precise) mode across the tables of
        tenants with active slots.  Each tenant therefore always runs at
        least as precisely as its own table demands — its controller can
        only see errors at or below what it asked for — while tables stay
        isolated (a hot tenant shifting up never mutates a cold tenant's
        table, and costs the cold tenant nothing once the hot tenant's
        slots drain)."""
        names = [n for n in tenant_active if n in self.tenant_tables]
        if not names:  # no active slots: probe-only callers, bind any table
            names = list(self.tenant_tables)
        combined: dict[str, object] = {}
        for n in names:
            for site, m in self.tenant_tables[n].modes().items():
                cur = combined.get(site)
                if cur is None or int(m) > int(cur):
                    combined[site] = m
        key = tuple(sorted((s, int(m)) for s, m in combined.items()))
        cached = self._combined_cache.get(key)
        if cached is None:
            cached = {s: jnp.asarray(int(m), jnp.int32)
                      for s, m in combined.items()}
            self._combined_cache[key] = cached
        label_names = sorted({m.name for m in combined.values()})
        label = (label_names[0] if len(label_names) == 1
                 else "/".join(label_names))
        return cached, label

    def _decode_step(self) -> list[tuple[int, int]]:
        """One masked batched decode step (the non-speculative path)."""
        events: list[tuple[int, int]] = []
        tokens = jnp.asarray(self._last_tok[:, None])
        active = jnp.asarray(self._active)
        tenant_active = self._tenant_active()
        t0 = time.perf_counter()
        if self.slo is not None:
            if self._per_tenant_adapt:
                scalars, label = self._bound_scalars(tenant_active)
            else:
                scalars, label = self.mode_table.scalars(), self.mode_table.label()
            next_tok, self.state = self._step_modal(
                self.params, tokens, self.state, active, scalars)
        else:
            label = self._static_decode_label
            next_tok, self.state = self._step(
                self.params, tokens, self.state, active)
        produced = np.asarray(next_tok)  # syncs the step
        self._last_step_ms = (time.perf_counter() - t0) * 1e3
        n_active = int(self._active.sum())
        self.metrics.on_decode_step(
            n_active, mode=label, tenant_active=tenant_active)
        if self.tracer.enabled:
            self.tracer.emit("decode_step", dur_ms=self._last_step_ms,
                             n_active=n_active, mode=label)
            self.tracer.set_gauge("active_slots", n_active)
            self.profiler.record("decode", self._last_step_ms / 1e3,
                                 tokens=n_active)
            self.profiler.observe_cache("decode_step",
                                        self.decode_compile_count)
        for slot in np.nonzero(self._active)[0]:
            ticket = self.scheduler.by_slot[int(slot)]
            tok = int(produced[slot])
            self._row_len[slot] += 1  # this step appended one KV entry
            events.append((ticket.rid, tok))
            self._emit(ticket, int(slot), tok)
        return events

    def _spec_step(self) -> list[tuple[int, int]]:
        """One speculative round: draft k cheap tokens per slot, verify all
        k+1 positions with the exact baseline step, emit each slot's
        accepted prefix plus the correction token (clamped to its remaining
        decode budget), and roll the state back inside the compiled round."""
        events: list[tuple[int, int]] = []
        active_np = self._active.copy()
        tokens = jnp.asarray(self._last_tok[:, None])
        active = jnp.asarray(active_np)
        t0 = time.perf_counter()
        drafts, greedy, n_acc, self.state = self._spec_round(
            self.params, tokens, self.state, active,
            self._spec_table.scalars_shifted(-self.draft_shift),
            self._spec_table.scalars(),
        )
        drafts = np.asarray(drafts)  # (k, B)
        greedy = np.asarray(greedy)  # (k+1, B)
        n_acc = np.asarray(n_acc)  # (B,) — syncs the round
        self._last_step_ms = (time.perf_counter() - t0) * 1e3
        n_active = int(active_np.sum())
        self.metrics.on_decode_step(
            n_active,
            mode=(self.mode_table.label() if self.mode_table is not None
                  else self._static_decode_label),
            tenant_active=self._tenant_active(),
        )
        accepted = agreed = emitted = 0
        for slot in np.nonzero(active_np)[0]:
            ticket = self.scheduler.by_slot[int(slot)]
            j = int(n_acc[slot])
            # the rolled-back cache holds the accepted prefix + correction
            # (budget clamping truncates *emission*, not the cache)
            self._row_len[slot] += j + 1
            # two accounts: metrics credit only drafts that were *emitted*
            # (a budget-truncated tail did no useful work), while the
            # controller sees raw draft/verify *agreement* — truncation says
            # nothing about draft quality and must not read as rejection
            agreed += j
            accepted += min(j, ticket.remaining)
            burst = [int(drafts[i, slot]) for i in range(j)]
            burst.append(int(greedy[j, slot]))  # correction / bonus token
            for tok in burst[:ticket.remaining]:
                events.append((ticket.rid, tok))
                self._emit(ticket, int(slot), tok)
                emitted += 1
        self._last_step_tokens = emitted / n_active if n_active else 1.0
        self.metrics.on_spec_round(
            n_active, drafted=self.spec.k * n_active,
            accepted=accepted, emitted=emitted)
        if self.tracer.enabled:
            from repro.spec.rollout import trace_round

            trace_round(self.tracer, k=self.spec.k, n_active=n_active,
                        agreed=agreed, emitted=emitted,
                        dur_ms=self._last_step_ms)
            self.tracer.set_gauge("active_slots", n_active)
            self.profiler.record("spec", self._last_step_ms / 1e3,
                                 tokens=emitted)
            self.profiler.observe_cache("spec_round", self.spec_compile_count)
        self._spec_window[0] += self.spec.k * n_active
        self._spec_window[1] += agreed
        if (self._accept_ctrl is not None
                and self.metrics.spec_rounds % self.spec.every == 0):
            self._spec_adapt_tick()
        return events

    def _spec_adapt_tick(self) -> None:
        """Feed the windowed draft/verify disagreement rate to the
        acceptance controller; an applied decision moves ``draft_shift``
        one rung (repro.spec)."""
        drafted, agreed = self._spec_window
        if not drafted:
            return
        self._spec_window = [0, 0]
        before = self._accept_ctrl.shift
        self._accept_ctrl.observe(
            self.metrics.spec_rounds, 1.0 - agreed / drafted)
        if self._accept_ctrl.shift != before:
            self.metrics.on_draft_shift(
                self.metrics.spec_rounds, self._accept_ctrl.shift)
            if self.tracer.enabled:
                self.tracer.emit(
                    "draft_shift", shift=self._accept_ctrl.shift,
                    cause=self._accept_ctrl.controller.last_cause,
                    reject_rate=1.0 - agreed / drafted)
                self.tracer.inc("draft_shifts")

    @property
    def draft_shift(self) -> int:
        """Current rungs between the verify and draft tables (repro.spec)."""
        if self.spec is None:
            raise AttributeError("engine was built without speculate=")
        if self._accept_ctrl is not None:
            return self._accept_ctrl.shift
        return self._draft_shift

    def _adapt_tick(self) -> None:
        """One probe + controller observation; applies the shift when
        adaptation is enabled (monitor-only engines record but hold)."""
        table = self.mode_table
        ladder = int(table.max_mode) - int(table.min_mode)
        err_cur, err_down = self._probe(
            self.params,
            jnp.asarray(self._last_tok[:, None]),
            self.state,
            jnp.asarray(self._active),
            table.scalars(),
            table.scalars_shifted(ladder),  # clamps every site to max: ref
            table.scalars_shifted(-1),
        )
        err_cur, err_down = float(err_cur), float(err_down)
        self.metrics.on_probe(err_cur)
        step_ms = self._last_step_ms
        if step_ms is not None:
            step_ms /= max(self._last_step_tokens, 1.0)
        decision = self.controller.observe(
            self.metrics.decode_steps, err_cur, err_down,
            step_ms=step_ms,
            can_up=not table.at_max, can_down=not table.at_min)
        if self._adapt and decision:
            if table.shift_all(decision, tag=self.metrics.decode_steps):
                self.metrics.on_mode_switch()
                if self.tracer.enabled:
                    self.tracer.emit(
                        "mode_switch", cause=self.controller.last_cause,
                        direction=decision, mode=table.label(),
                        sites={s: m.name for s, m in table.modes().items()})
                    self.tracer.inc("mode_switches")

    def _adapt_tick_tenants(self) -> None:
        """One probe + controller observation *per tenant with active
        slots*, each against that tenant's own table and masked to that
        tenant's slots.  Isolation invariant (pinned by
        tests/test_tenancy.py): tenant A's residuals never reach tenant
        B's controller, so a hot workload shifting A's table up leaves B's
        table exactly where B's own traffic put it."""
        step_ms = self._last_step_ms
        if step_ms is not None:
            step_ms /= max(self._last_step_tokens, 1.0)
        tokens = jnp.asarray(self._last_tok[:, None])
        for name, table in self.tenant_tables.items():
            mask = np.zeros_like(self._active)
            for slot, t in self.scheduler.by_slot.items():
                if t.tenant == name and self._active[slot]:
                    mask[slot] = True
            if not mask.any():
                continue
            ladder = int(table.max_mode) - int(table.min_mode)
            err_cur, err_down = self._probe(
                self.params, tokens, self.state, jnp.asarray(mask),
                table.scalars(),
                table.scalars_shifted(ladder),
                table.scalars_shifted(-1),
            )
            err_cur, err_down = float(err_cur), float(err_down)
            self.metrics.on_probe(err_cur)
            decision = self.tenant_ctrl[name].observe(
                self.metrics.decode_steps, err_cur, err_down,
                step_ms=step_ms,
                can_up=not table.at_max, can_down=not table.at_min)
            if self._adapt and decision:
                if table.shift_all(decision, tag=self.metrics.decode_steps):
                    self.metrics.on_mode_switch()
                    if self.tracer.enabled:
                        self.tracer.emit(
                            "mode_switch",
                            cause=self.tenant_ctrl[name].last_cause,
                            direction=decision, tenant=name,
                            mode=table.label(),
                            sites={s: m.name
                                   for s, m in table.modes().items()})
                        self.tracer.inc("mode_switches")

    def drain(self) -> dict[int, list[int]]:
        """Step until queue and slots are empty; returns rid -> tokens for
        every request completed since construction."""
        while self.scheduler.has_work():
            self.step()
        return {rid: self.scheduler.tickets[rid].tokens
                for rid in self.scheduler.completed}

    # -- internals -----------------------------------------------------------

    def _prefill_slot(self, slot: int, ticket: Ticket) -> int:
        t0 = time.perf_counter()
        logits, solo = self._prefill(
            self.params, jnp.asarray(ticket.prompt)[None, :], self._solo0)
        self.state = self.layout.scatter_row(
            self.state, solo, slot, prompt=ticket.prompt)
        self._row_len[slot] = len(ticket.prompt)
        first = int(jnp.argmax(logits[0, -1]))  # syncs the prefill
        if self.tracer.enabled:
            dur_s = time.perf_counter() - t0
            self.tracer.emit("prefill", rid=ticket.rid, slot=slot,
                             dur_ms=dur_s * 1e3,
                             prompt_len=len(ticket.prompt))
            self.profiler.record("prefill", dur_s,
                                 tokens=len(ticket.prompt))
            cache_size = getattr(self._prefill, "_cache_size", None)
            self.profiler.observe_cache(
                "prefill", cache_size() if callable(cache_size) else None)
        return first

    def _emit(self, ticket: Ticket, slot: int, tok: int) -> None:
        ticket.tokens.append(tok)
        self.metrics.on_token(ticket.rid)
        if self.tracer.enabled:
            self.tracer.emit("token", rid=ticket.rid, slot=slot)
            self.tracer.inc("tokens_out")
        if len(ticket.tokens) >= ticket.budget:
            self.scheduler.complete(ticket.rid)
            self.metrics.on_done(ticket.rid, step=self.scheduler.clock)
            if self.tracer.enabled:
                self.tracer.emit("done", rid=ticket.rid, slot=slot,
                                 cause="budget")
                self.tracer.inc("completed")
            self._active[slot] = False
            # completion frees the row's pages back to the pool (dense: no-op)
            self.state = self.layout.free_row(self.state, slot)
        else:
            self.scheduler.start_decode(ticket.rid)
            self._active[slot] = True
            self._last_tok[slot] = tok

    # -- reporting / compat --------------------------------------------------

    def describe(self) -> dict[str, str]:
        """The consolidated reporting surface: one dict with every
        subsystem's description (plans / adaptation / speculation / tenancy
        / cache — plus tracing/profiling when tracing is on).  The
        ``describe_*`` helpers below are thin per-key wrappers kept for the
        pre-obs API; ``launch/serve`` prints :meth:`format_describe`."""
        out = {
            "plans": self._describe_plans(),
            "adaptation": self._describe_adaptation(),
            "speculation": self._describe_speculation(),
            "tenancy": self._describe_tenancy(),
            "cache": self._describe_cache(),
        }
        if self.tracer.enabled:
            out["trace"] = self.tracer.describe()
            out["profile"] = self.profiler.describe()
        return out

    def format_describe(self) -> str:
        """One coherent engine report block (headers + sections)."""
        return "\n".join(f"-- {key} --\n{body}"
                         for key, body in self.describe().items())

    def describe_plans(self) -> str:
        return self.describe()["plans"]

    def describe_speculation(self) -> str:
        return self.describe()["speculation"]

    def describe_tenancy(self) -> str:
        return self.describe()["tenancy"]

    def describe_adaptation(self) -> str:
        return self.describe()["adaptation"]

    def describe_cache(self) -> str:
        return self.describe()["cache"]

    def _describe_plans(self) -> str:
        if not self.plans:
            return "unplanned (explicit policy)"
        return "\n".join(f"{op}: {p.describe()}" for op, p in self.plans.items())

    @property
    def decode_compile_count(self) -> int | None:
        """Number of compiled decode-step variants (None when jax does not
        expose the cache).  Stays 1 across arbitrary mode-table changes —
        the zero-recompile property tests/test_adapt.py pins."""
        fn = self._step_modal if self.slo is not None else self._step
        cache_size = getattr(fn, "_cache_size", None)
        return cache_size() if callable(cache_size) else None

    @property
    def spec_compile_count(self) -> int | None:
        """Compiled speculative-round variants (None when jax does not
        expose the cache).  Stays 1 across draft-shift and mode-table
        changes — the shift rides in as mode scalars, never a retrace."""
        if self.spec is None:
            return None
        cache_size = getattr(self._spec_round, "_cache_size", None)
        return cache_size() if callable(cache_size) else None

    def _describe_speculation(self) -> str:
        if self.spec is None:
            return "speculation off (no speculate=)"
        s = self.metrics.summary()
        acc = s["acceptance_rate"]
        vspt = s["verify_steps_per_token"]
        ctrl = ""
        if self._accept_ctrl is not None:
            ctrl = (f" | {self._accept_ctrl.shallower_moves} shallower / "
                    f"{self._accept_ctrl.deeper_moves} deeper moves")
        return (
            f"k={self.spec.k} draft_shift={self.draft_shift} "
            f"(verify {self._spec_table.describe()}) | "
            f"{s['spec_rounds']} rounds | acceptance "
            + (f"{acc:.2f}" if acc is not None else "-")
            + " | verify-steps/token "
            + (f"{vspt:.2f}" if vspt is not None else "-")
            + ctrl
        )

    def _describe_tenancy(self) -> str:
        """Scheduler configuration + per-tenant fairness report."""
        sch = self.scheduler
        head = (
            f"policy={sch.policy} aging_steps={sch.aging_steps} "
            f"preempt={'on' if sch.preempt_enabled else 'off'} "
            f"(min_quantum={sch.min_quantum}) | "
            f"{len(sch.tenants)} tenants x {len(sch.classes)} classes | "
            f"{sch.preemptions} preemptions, max wait {sch.max_wait_steps} "
            f"steps"
        )
        body = self.metrics.format_tenants()
        return head + ("\n" + body if body else "")

    def _describe_adaptation(self) -> str:
        if self.tenant_tables:
            lines = []
            for name in sorted(self.tenant_tables):
                table = self.tenant_tables[name]
                ctrl = self.tenant_ctrl[name]
                lines.append(
                    f"tenant {name}: table {table.describe()} | "
                    f"{table.switches} switches ({ctrl.up_shifts} up / "
                    f"{ctrl.down_shifts} down)")
            return "per-tenant adaptation\n" + "\n".join(lines)
        if self.mode_table is None:
            return "adaptation off (no slo)"
        s = self.metrics.summary()
        occ = " ".join(f"{m}:{f:.2f}" for m, f in s["mode_occupancy"].items())
        timeline = " -> ".join(
            f"@{step}:{label}" for step, label in self.metrics.mode_timeline)
        return (
            f"slo max_err={self.slo.max_err:g}"
            + (f" target_ms={self.slo.target_ms:g}" if self.slo.target_ms else "")
            + f" | table {self.mode_table.describe()} | "
            f"{s['mode_switches']} switches ({self.controller.up_shifts} up / "
            f"{self.controller.down_shifts} down) | occupancy {occ} | "
            f"timeline {timeline}"
        )

    def _describe_cache(self) -> str:
        """One-line KV layout report (layout name, pools, tiers, sharing)."""
        return self.layout.describe()

    def generate_batch(self, requests: list[Request]) -> dict[int, list[int]]:
        """Offline batch API on top of the streaming engine: submit
        everything, drain, return each request's tokens.  Unlike the
        pre-refactor lockstep loop, no request decodes past its own budget
        and ragged prompts never pollute the KV cache (each prefill runs at
        true length)."""
        rids = [self.submit(r) for r in requests]
        done = self.drain()
        return {rid: done[rid] for rid in rids}
