"""Batched serving engine: continuous-batching decode over the KV cache.

``ServeEngine`` keeps a fixed-size slot array; requests join free slots, each
step decodes one token for every active slot (one compiled executable —
runtime-reconfigurable precision per step via the RMPM mode scalar if the
policy asks for it).  Slot completion frees capacity (continuous batching).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LanguageModel


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    rid: int = 0


class ServeEngine:
    def __init__(self, model: LanguageModel, params, batch_slots: int, max_len: int,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        self.state = model.init_decode_state(batch_slots, max_len)
        self._decode = jax.jit(model.decode_step)
        self.active: dict[int, dict] = {}

    def generate_batch(self, requests: list[Request]) -> dict[int, list[int]]:
        """Simple offline batch API: same-length prompts padded to the max,
        prefill once, then decode until every request hits max_new."""
        assert len(requests) <= self.slots
        s_max = max(len(r.prompt) for r in requests)
        prompts = np.zeros((self.slots, s_max), np.int32)
        for i, r in enumerate(requests):
            prompts[i, s_max - len(r.prompt):] = r.prompt  # left-pad
        logits, self.state = self._decode(self.params, jnp.asarray(prompts), self.state)
        outputs: dict[int, list[int]] = {r.rid: [] for r in requests}
        last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new for r in requests)
        for t in range(max_new):
            for i, r in enumerate(requests):
                if t < r.max_new:
                    outputs[r.rid].append(int(last[i]))
            logits, self.state = self._decode(self.params, last[:, None], self.state)
            last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return outputs
