"""Batched serving engine: continuous-batching decode over the KV cache.

``ServeEngine`` keeps a fixed-size slot array; requests join free slots, each
step decodes one token for every active slot (one compiled executable —
runtime-reconfigurable precision per step via the RMPM mode scalar if the
policy asks for it).  Slot completion frees capacity (continuous batching).

Precision dispatch routes through the matmul planner (``repro.plan``): pass
``accuracy`` and the engine re-plans the model's PrecisionPolicy for its own
decode shapes (batch_slots x model dims) before compiling — the paper's
application-program-set mode bits, set by a cost model instead of by hand.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LanguageModel


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    rid: int = 0


class ServeEngine:
    def __init__(self, model: LanguageModel, params, batch_slots: int, max_len: int,
                 greedy: bool = True, accuracy: float | None = None,
                 plan_backend: str | None = None):
        if accuracy is not None:
            # Plan (mode, impl, depth) for this engine's decode GEMMs and
            # rebuild the model under the planned policy (DESIGN.md section
            # Planner).  All matmuls inside decode_step then execute through
            # repro.plan.execute via models.layers.pmm.
            from repro.core.precision import DF32_MODES
            from repro.plan import plan_model_policy

            base = model.cfg.policy
            policy, self.plans = plan_model_policy(
                model.cfg, tokens=batch_slots, accuracy=accuracy,
                backend=plan_backend, rounding=base.rounding,
            )
            if (
                base.impl == "native"
                and policy.impl == "xla"
                and not any(p.mode in DF32_MODES for p in self.plans.values())
            ):
                # keep the fast CPU execution path when the base policy chose
                # it and the planner has no better limb impl to offer — but
                # never for DF32 modes, where 'xla' IS the limb engine and
                # 'native' (plain f32) would break the accuracy budget
                policy = policy.with_impl("native")
            model = LanguageModel(model.cfg.with_policy(policy))
        else:
            self.plans = {}
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        self.state = model.init_decode_state(batch_slots, max_len)
        self._decode = jax.jit(model.decode_step)
        self.active: dict[int, dict] = {}

    def describe_plans(self) -> str:
        if not self.plans:
            return "unplanned (explicit policy)"
        return "\n".join(f"{op}: {p.describe()}" for op, p in self.plans.items())

    def generate_batch(self, requests: list[Request]) -> dict[int, list[int]]:
        """Simple offline batch API: same-length prompts padded to the max,
        prefill once, then decode until every request hits max_new."""
        assert len(requests) <= self.slots
        s_max = max(len(r.prompt) for r in requests)
        prompts = np.zeros((self.slots, s_max), np.int32)
        for i, r in enumerate(requests):
            prompts[i, s_max - len(r.prompt):] = r.prompt  # left-pad
        logits, self.state = self._decode(self.params, jnp.asarray(prompts), self.state)
        outputs: dict[int, list[int]] = {r.rid: [] for r in requests}
        last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new for r in requests)
        for t in range(max_new):
            for i, r in enumerate(requests):
                if t < r.max_new:
                    outputs[r.rid].append(int(last[i]))
            logits, self.state = self._decode(self.params, last[:, None], self.state)
            last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return outputs
