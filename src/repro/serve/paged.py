"""KV-cache layouts behind one protocol: dense slot rows or paged pools.

The serve engine used to hard-code the dense per-slot ring cache: admission
counted free *slots*, park/resume moved dense rows with per-leaf
``dynamic_slice_in_dim``, and spec rollback assumed ``KVCache`` nodes.  This
module is the redesign seam: a :class:`KVLayout` protocol

    init / state_spec / gather_row / scatter_row / free_row /
    can_admit / prepare_step / tier_tick / describe

with two implementations —

* :class:`DenseLayout` — today's per-slot ring cache, bit-identical to the
  pre-paged engine (the default);
* :class:`PagedLayout` — every KV group becomes a
  :class:`~repro.models.layers.PagedKVCache`: fixed-size pages in a shared
  pool, per-row page tables, allocation on append and free on completion or
  eviction.  Virtual addressing preserves the dense ring semantics exactly,
  so at full precision paged serving is bit-identical to dense — while
  admission is gated on free *pages*, so short-lived requests stack far past
  the dense ``slots x max_len`` wall.

On top of the paged layout ride the two things a fixed layout cannot offer
(DESIGN.md section Paged KV cache):

* **precision-tiered pages** — cold pages are mantissa-truncated in place by
  the ``quantize_mantissa`` Pallas kernel under a
  :class:`~repro.adapt.pages.PageTierController` (demotion is lossy;
  promotion restores the floor);
* **radix-style prefix sharing** — page ``j`` of a prompt's KV depends only
  on ``prompt[:(j+1)*page_size]`` (causal attention), so that byte string
  keys a per-group index of read-only shared pages.  A row never writes a
  shared or index-held page: decode appends and ring wraps trigger
  copy-on-write forks in :meth:`PagePool.cow`.

The exchange format between layouts is the *dense solo row*: ``gather_row``
always returns the same per-slot batch-1 pytree the solo prefill produces,
so parking, resume, prefill and speculative rollback stay layout-agnostic.
"""
from __future__ import annotations

import collections
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.adapt.pages import HOT, PageTierController
from repro.obs import NULL_TRACER
from repro.models.layers import (
    KVCache,
    PagedKVCache,
    paged_cache_init,
    paged_view,
    stack_tree,
)
from repro.serve.config import CacheConfig

#: axes sentinel for pool leaves shared by every row (no batch axis): the
#: masked step's row_select keeps the *new* value — per-row isolation is
#: enforced by the page table (cleared tables redirect writes to scratch)
SHARED = -1


def _is_kv(x) -> bool:
    return isinstance(x, KVCache)


def _is_paged(x) -> bool:
    return isinstance(x, PagedKVCache)


def _is_cache(x) -> bool:
    return isinstance(x, (KVCache, PagedKVCache))


def compute_axes(spec_fn, slots: int):
    """Per-leaf batch-axis pytree found by diffing abstract shapes at two
    slot counts (``ServeEngine._batch_axes``, generalized): leaves whose
    shape does not depend on the slot count — the paged pools — get
    :data:`SHARED`."""
    a = spec_fn(slots)
    b = spec_fn(slots + 1)

    def axis(x, y):
        for i, (p, q) in enumerate(zip(x.shape, y.shape)):
            if p != q:
                return i
        return SHARED

    return jax.tree.map(axis, a, b)


# ---------------------------------------------------------------------------
# Device primitives on one PagedKVCache node (vmapped over the layer axis
# for stacked groups)
# ---------------------------------------------------------------------------


def paged_gather_row(c: PagedKVCache, slot) -> KVCache:
    """Materialize row ``slot`` as a dense batch-1 per-slot ``KVCache`` —
    the layout-agnostic exchange format (park/resume/rollback all speak
    dense rows).  Unmapped page-table entries read the scratch page; the
    garbage there is outside the row's valid positions, and scatter_row
    writes the same region back, so park -> resume round-trips bit-exactly."""
    cap = c.pos.shape[1]
    npg, ps = c.page_tbl.shape[1], c.k_pool.shape[1]
    tbl = jnp.maximum(jax.lax.dynamic_slice_in_dim(c.page_tbl, slot, 1, 0), 0)

    def g(pool):
        if pool is None:
            return None
        return pool[tbl].reshape(1, npg * ps, *pool.shape[2:])[:, :cap]

    return KVCache(
        g(c.k_pool), g(c.v_pool), g(c.k_scale), g(c.v_scale),
        jax.lax.dynamic_slice_in_dim(c.pos, slot, 1, 0),
        jax.lax.dynamic_slice_in_dim(c.length, slot, 1, 0),
    )


def paged_scatter_row(c: PagedKVCache, row: KVCache, slot,
                      write_tbl) -> PagedKVCache:
    """Write a dense batch-1 row into the pool through ``write_tbl`` — the
    per-page *write* table: entries of -1 (shared prefix pages, unmapped
    tail) redirect to the scratch page, so read-only pages are never
    touched.  ``pos``/``length`` are per-row leaves and always written."""
    cap = c.pos.shape[1]
    ps = c.k_pool.shape[1]
    vi = jnp.arange(cap, dtype=jnp.int32)
    pages = jnp.maximum(write_tbl[vi // ps], 0)
    off = vi % ps

    def put(pool, vals):
        if pool is None:
            return None
        return pool.at[pages, off].set(vals[0].astype(pool.dtype))

    return dataclasses.replace(
        c,
        k_pool=put(c.k_pool, row.k), v_pool=put(c.v_pool, row.v),
        k_scale=put(c.k_scale, row.k_scale),
        v_scale=put(c.v_scale, row.v_scale),
        pos=jax.lax.dynamic_update_slice_in_dim(c.pos, row.pos, slot, axis=0),
        length=jax.lax.dynamic_update_slice_in_dim(
            c.length, row.length, slot, axis=0),
    )


def copy_page_node(node: PagedKVCache, src, dst) -> PagedKVCache:
    """Device-side copy-on-write fork: duplicate pool page ``src`` into the
    freshly allocated ``dst`` (every pool leaf, all layers of a stacked
    group at once)."""
    ax = node.k_pool.ndim - 4  # page axis: 0 unstacked, 1 layer-stacked

    def cp(pool):
        if pool is None:
            return None
        page = jax.lax.dynamic_slice_in_dim(pool, src, 1, axis=ax)
        return jax.lax.dynamic_update_slice_in_dim(pool, page, dst, axis=ax)

    return dataclasses.replace(
        node, k_pool=cp(node.k_pool), v_pool=cp(node.v_pool),
        k_scale=cp(node.k_scale), v_scale=cp(node.v_scale))


@functools.partial(jax.jit, static_argnums=(3, 4, 5))
def tier_node(node: PagedKVCache, demote, shadow, keep, next_keep,
              rounding):
    """Demote pages in ``demote`` (bool (P,)) to ``keep`` mantissa bits in
    place via the quantize_mantissa kernel, and measure

      * ``err``      — max relative residual the applied demotions introduced;
      * ``err_down`` — would-be residual of truncating the ``shadow`` pages
        to ``next_keep`` (computed, never applied — controller invariant ii:
        the config being *entered* is measured before entering it).

    ``keep=None`` applies nothing (depth 0: the shadow still measures)."""
    from repro.kernels.quantize_mantissa.ops import quantize_mantissa_op

    def pool_err(pool, mask, bits, apply):
        if bits is None:
            return pool, jnp.float32(0.0)
        shape = [1] * pool.ndim
        shape[pool.ndim - 4] = mask.shape[0]
        m = mask.reshape(shape)
        f = pool.astype(jnp.float32)
        q = quantize_mantissa_op(f, bits, rounding=rounding)
        d = jnp.max(jnp.where(m, jnp.abs(f - q), 0.0))
        a = jnp.max(jnp.where(m, jnp.abs(f), 0.0))
        err = d / (a + 1e-30)
        if apply:
            pool = jnp.where(m, q, f).astype(pool.dtype)
        return pool, err

    k_pool, ek = pool_err(node.k_pool, demote, keep, apply=True)
    v_pool, ev = pool_err(node.v_pool, demote, keep, apply=True)
    _, ekd = pool_err(node.k_pool, shadow, next_keep, apply=False)
    _, evd = pool_err(node.v_pool, shadow, next_keep, apply=False)
    err = jnp.maximum(ek, ev)
    err_down = jnp.maximum(ekd, evd) if next_keep is not None else err
    return dataclasses.replace(node, k_pool=k_pool, v_pool=v_pool), err, err_down


# ---------------------------------------------------------------------------
# Host-side page-pool allocator (one per cache group)
# ---------------------------------------------------------------------------


class PagePool:
    """Free-list allocator + refcounts + prefix index for one cache group.

    Pool indices are 1-based: page 0 is the scratch page (-1 table entries
    clamp to it on device).  ``ref`` counts *row* references; pages whose
    refcount drops to zero while registered in the prefix index park in an
    LRU of ``cached`` pages — still shareable, reclaimed (index entry
    dropped) only when the free list runs dry.  A page is privately
    writable iff ``ref == 1`` and it is not index-held; everything else
    forks via :meth:`cow`.
    """

    def __init__(self, n_pages: int, page_size: int, cap: int, rows: int):
        self.ps = page_size
        self.cap = cap
        self.rows = rows
        self.per_row = -(-cap // page_size)
        if n_pages < self.per_row:
            raise ValueError(
                f"pool of {n_pages} pages cannot hold one full row "
                f"(cap={cap}, page_size={page_size})")
        self.n_pages = n_pages
        self.free: collections.deque[int] = collections.deque(
            range(1, n_pages + 1))
        self.ref = np.zeros(n_pages + 1, np.int32)
        self.tier = np.full(n_pages + 1, HOT, np.int32)  # keep-bits labels
        self.tbl = np.full((rows, self.per_row), -1, np.int32)
        self.index: dict[bytes, int] = {}  # prefix key -> shared page
        self.page_key: dict[int, bytes] = {}
        self.cached: dict[int, None] = {}  # ref==0 index-held pages (LRU)
        self.reserved = 0  # admission-gate reservations (reset each admit)
        self.shared_hits = 0
        self.cow_copies = 0
        self.index_evictions = 0

    # -- capacity ------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` of virtual content (ring-clamped)."""
        return -(-min(max(n_tokens, 1), self.cap) // self.ps)

    def available(self) -> int:
        return len(self.free) + len(self.cached) - self.reserved

    def _alloc(self) -> int | None:
        if self.free:
            p = self.free.popleft()
        elif self.cached:
            # reclaim the LRU prefix-cache page: drop its index entry
            p = next(iter(self.cached))
            del self.cached[p]
            key = self.page_key.pop(p)
            del self.index[key]
            self.index_evictions += 1
        else:
            return None
        self.ref[p] = 1
        self.tier[p] = HOT
        return p

    def _release(self, p: int) -> None:
        self.ref[p] -= 1
        if self.ref[p] == 0:
            if p in self.page_key:
                self.cached[p] = None  # shareable until reclaimed
            else:
                self.free.append(p)

    # -- row lifecycle -------------------------------------------------------

    def free_row(self, row: int) -> None:
        """Drop every page reference of ``row`` and clear its table — the
        engine calls this on completion and on eviction BEFORE the next
        device sync, so freed pages can never be written through a stale
        table."""
        for p in self.tbl[row]:
            if p >= 0:
                self._release(int(p))
        self.tbl[row] = -1

    def peek_needed(self, n_tokens: int, keys: list[bytes] | None) -> int:
        """Fresh pages a new row of ``n_tokens`` content (+1 append slot)
        would allocate after prefix-sharing hits — the admission gate."""
        target = self.pages_for(n_tokens + 1)
        hits = 0
        if keys is not None and n_tokens <= self.cap:
            for j in range(min(n_tokens // self.ps, target)):
                if keys[j] in self.index:
                    hits += 1
        return target - hits

    def attach(self, row: int, n_tokens: int,
               keys: list[bytes] | None) -> np.ndarray | None:
        """Map a new row covering ``n_tokens`` content (+1 append slot).
        Full prompt pages with an index hit attach read-only (refcount++);
        misses allocate and — when keyed — register in the prefix index.
        Returns the per-page *write* table (-1 = shared page, skip the
        write) or None when the pool is exhausted."""
        target = self.pages_for(n_tokens + 1)
        wt = np.full(self.per_row, -1, np.int32)
        shareable = keys is not None and n_tokens <= self.cap
        for j in range(target):
            key = (keys[j] if shareable and j < n_tokens // self.ps else None)
            if key is not None:
                p = self.index.get(key)
                if p is not None:
                    if self.ref[p] == 0:
                        self.cached.pop(p, None)
                    self.ref[p] += 1
                    self.tbl[row, j] = p
                    self.shared_hits += 1
                    continue
            p = self._alloc()
            if p is None:
                return None
            self.tbl[row, j] = p
            wt[j] = p
            if key is not None:
                self.index[key] = p
                self.page_key[p] = key
        return wt

    def ensure(self, row: int, upto_tokens: int) -> bool:
        """Extend ``row``'s mapping to cover ``upto_tokens`` of virtual
        content (the pre-step allocation-on-append)."""
        for j in range(self.pages_for(upto_tokens)):
            if self.tbl[row, j] < 0:
                p = self._alloc()
                if p is None:
                    return False
                self.tbl[row, j] = p
        return True

    def cow(self, row: int, lo: int, hi: int) -> list[tuple[int, int]] | None:
        """Make the pages overlapping virtual token range [lo, hi) privately
        writable: shared (ref > 1) or index-held pages fork into fresh
        allocations.  Returns (src, dst) device-copy pairs, or None on
        exhaustion."""
        pairs: list[tuple[int, int]] = []
        for j in sorted({(v % self.cap) // self.ps for v in range(lo, hi)}):
            p = int(self.tbl[row, j])
            if p < 0:
                continue  # unmapped: ensure() allocates fresh, nothing to fork
            if self.ref[p] == 1 and p not in self.page_key:
                continue  # exclusively owned: writable in place
            d = self._alloc()
            if d is None:
                return None
            self.tier[d] = self.tier[p]  # the fork inherits the tier label
            self._release(p)
            self.tbl[row, j] = d
            pairs.append((p, d))
            self.cow_copies += 1
        return pairs

    # -- tiering / stats -----------------------------------------------------

    def page_ages(self, lengths: dict[int, int]) -> dict[int, int]:
        """Per referenced page, the minimum over referencing rows of how far
        its newest token trails that row's head.  Ring-wrapped rows
        (length > cap) keep all their pages hot — a wrapped page mixes old
        and new tokens, so age is ill-defined for it.  Index-cached pages
        with no row reference are never demoted (future sharers expect the
        precision they were written at)."""
        ages: dict[int, int] = {}
        for row, ln in lengths.items():
            if ln > self.cap:
                continue
            for j in range(self.pages_for(ln)):
                p = int(self.tbl[row, j])
                if p < 0:
                    continue
                age = ln - min((j + 1) * self.ps, ln)
                ages[p] = min(ages.get(p, 1 << 30), age)
        return ages

    def stats(self) -> dict:
        used = int((self.ref > 0).sum())
        mapped_refs = int((self.tbl >= 0).sum())
        unique = len({int(p) for p in self.tbl.ravel() if p >= 0})
        mix: dict[str, int] = {}
        for p in range(1, self.n_pages + 1):
            if self.ref[p] > 0 or p in self.cached:
                t = int(self.tier[p])
                mix[str(t) if t != HOT else "hot"] = (
                    mix.get(str(t) if t != HOT else "hot", 0) + 1)
        return {
            "pages_total": self.n_pages,
            "pages_used": used,
            "pages_cached": len(self.cached),
            "mapped_refs": mapped_refs,
            "unique_pages": unique,
            "tier_mix": mix,
            "shared_hits": self.shared_hits,
            "cow_copies": self.cow_copies,
            "index_evictions": self.index_evictions,
        }


# ---------------------------------------------------------------------------
# Layouts
# ---------------------------------------------------------------------------


class KVLayout:
    """Protocol shared by :class:`DenseLayout` and :class:`PagedLayout`.

    ``gather_row``/``scatter_row`` always exchange *dense solo rows* (the
    per-slot batch-1 pytree the solo prefill produces), so the engine's
    prefill, park/resume and rollback never see the layout."""

    name = "abstract"
    axes = None
    #: trace sink (repro.obs) — the engine swaps in its live tracer
    tracer = NULL_TRACER

    def init(self):
        raise NotImplementedError

    def state_spec(self, batch: int):
        raise NotImplementedError

    def gather_row(self, state, slot: int):
        raise NotImplementedError

    def scatter_row(self, state, row, slot: int, *, prompt=None, length=None):
        raise NotImplementedError

    def free_row(self, state, slot: int):
        return state

    def begin_admission(self) -> None:
        pass

    def can_admit(self, n_tokens: int, prompt=None) -> bool:
        return True

    def prepare_step(self, state, lengths: dict[int, int], ahead: int):
        return state, []

    def tier_tick(self, state, lengths: dict[int, int], step: int):
        return state, None

    def page_stats(self) -> dict | None:
        return None

    def describe(self) -> str:
        raise NotImplementedError


class DenseLayout(KVLayout):
    """Today's per-slot ring cache — bit-identical to the pre-paged engine.
    Slots are the only resource: every row owns ``max_len`` rows of every
    cache up front, so all layout hooks are trivial."""

    name = "dense"

    def __init__(self, model, slots: int, max_len: int):
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.axes = compute_axes(
            lambda b: jax.eval_shape(
                lambda: model.init_decode_state(b, max_len, per_slot=True)),
            slots)
        self._gather = jax.jit(self._gather_fn)
        self._scatter = jax.jit(self._scatter_fn)

    def init(self):
        return self.model.init_decode_state(
            self.slots, self.max_len, per_slot=True)

    def state_spec(self, batch: int):
        return jax.eval_shape(
            lambda: self.model.init_decode_state(
                batch, self.max_len, per_slot=True))

    def _gather_fn(self, state, slot):
        return jax.tree.map(
            lambda ax, s: jax.lax.dynamic_slice_in_dim(s, slot, 1, axis=ax),
            self.axes, state)

    def _scatter_fn(self, state, row, slot):
        return jax.tree.map(
            lambda ax, s, r: jax.lax.dynamic_update_slice_in_dim(
                s, r.astype(s.dtype), slot, axis=ax),
            self.axes, state, row)

    def gather_row(self, state, slot: int):
        return self._gather(state, jnp.int32(slot))

    def scatter_row(self, state, row, slot: int, *, prompt=None, length=None):
        return self._scatter(state, row, jnp.int32(slot))

    def describe(self) -> str:
        return (f"dense ring cache: {self.slots} slots x {self.max_len} "
                f"rows (admission on free slots)")


@dataclasses.dataclass
class _Group:
    """One KV cache group of the decode state (one segment / hybrid layer
    kind), in pytree traversal order."""

    cap: int
    n_kv: int
    hd: int
    dtype: str
    stacked: bool
    layers: int
    pool: PagePool


class PagedLayout(KVLayout):
    """Page-table layout: every KV group shares a page pool; per-row page
    tables live on the host (numpy) and sync to the device page_tbl leaves
    lazily (before any decode/gather/scatter touches them)."""

    name = "paged"

    def __init__(self, model, slots: int, max_len: int, cfg: CacheConfig):
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.cfg = cfg
        ps = cfg.page_size
        spec = jax.eval_shape(
            lambda: model.init_decode_state(slots, max_len, per_slot=True))
        kv_nodes = [n for n in jax.tree.leaves(spec, is_leaf=_is_kv)
                    if _is_kv(n)]
        caps = [n.pos.shape[-1] for n in kv_nodes]
        ppr_max = max((-(-c // ps) for c in caps), default=1)
        if cfg.pool_pages is None:
            # memory-equivalent to the dense layout at this slot count
            self.dense_equiv_slots = slots
        else:
            self.dense_equiv_slots = cfg.pool_pages // ppr_max
            if self.dense_equiv_slots < 1:
                raise ValueError(
                    f"pool_pages={cfg.pool_pages} below one row of the "
                    f"largest group ({ppr_max} pages of {ps} tokens)")
        self.groups: list[_Group] = []
        for n in kv_nodes:
            stacked = n.length.ndim == 2
            cap = n.pos.shape[-1]
            dtype = "int8" if n.k.dtype == jnp.int8 else "bf16"
            if cfg.tier_policy is not None and dtype != "bf16":
                raise ValueError(
                    "tier_policy requires a bfloat16 KV cache "
                    "(mantissa truncation of int8 pages is meaningless)")
            per_row = -(-cap // ps)
            n_pages = max(self.dense_equiv_slots * per_row, per_row)
            self.groups.append(_Group(
                cap=cap, n_kv=n.k.shape[-2], hd=n.k.shape[-1], dtype=dtype,
                stacked=stacked, layers=n.length.shape[0] if stacked else 1,
                pool=PagePool(n_pages, ps, cap, slots)))
        self.tier_ctrl = (PageTierController(cfg.tier_policy)
                          if cfg.tier_policy is not None else None)
        self._dirty = True
        self.axes = compute_axes(
            lambda b: jax.eval_shape(lambda: self._build_state(b)), slots)
        self._gather = jax.jit(self._gather_fn)
        self._scatter = jax.jit(self._scatter_fn)
        self._copy = jax.jit(copy_page_node)

    # -- state construction --------------------------------------------------

    def _build_state(self, batch: int):
        """The dense per-slot state with every KV group replaced by its
        paged twin (traced: the dense zeros are dead code under jit)."""
        dense = self.model.init_decode_state(
            batch, self.max_len, per_slot=True)
        groups = iter(self.groups)

        def conv(node):
            if not _is_kv(node):
                return node
            g = next(groups)
            c = paged_cache_init(
                batch, g.cap, g.n_kv, g.hd,
                "int8" if g.dtype == "int8" else "bfloat16",
                g.pool.n_pages, g.pool.ps)
            if g.stacked:
                c = stack_tree(g.layers, c)
            return c

        return jax.tree.map(conv, dense, is_leaf=_is_kv)

    def init(self):
        return jax.jit(lambda: self._build_state(self.slots))()

    def state_spec(self, batch: int):
        return jax.eval_shape(lambda: self._build_state(batch))

    # -- device fns (jitted once) --------------------------------------------

    def _gather_fn(self, state, slot):
        def g(axn, node):
            if _is_paged(axn):
                if node.length.ndim == 2:
                    return jax.vmap(paged_gather_row, in_axes=(0, None))(
                        node, slot)
                return paged_gather_row(node, slot)
            return jax.lax.dynamic_slice_in_dim(node, slot, 1, axis=axn)

        return jax.tree.map(g, self.axes, state, is_leaf=_is_paged)

    def _scatter_fn(self, state, row, slot, write_tbls):
        tbls = iter(write_tbls)

        def s(axn, node, rnode):
            if _is_paged(axn):
                wt = next(tbls)
                if node.length.ndim == 2:
                    return jax.vmap(paged_scatter_row,
                                    in_axes=(0, 0, None, None))(
                        node, rnode, slot, wt)
                return paged_scatter_row(node, rnode, slot, wt)
            return jax.lax.dynamic_update_slice_in_dim(
                node, rnode.astype(node.dtype), slot, axis=axn)

        return jax.tree.map(s, self.axes, state, row, is_leaf=_is_paged)

    def _map_nodes(self, state, fn):
        """Apply ``fn(group_index, node)`` to every paged node of the state
        (pytree traversal order == ``self.groups`` order)."""
        idx = iter(range(len(self.groups)))

        def visit(axn, node):
            if _is_paged(axn):
                return fn(next(idx), node)
            return node

        return jax.tree.map(visit, self.axes, state, is_leaf=_is_paged)

    def _sync(self, state):
        """Push the host page tables into the device ``page_tbl`` leaves.
        Called before anything reads or writes through the tables, so a
        freed row's pages can never be touched via a stale device table."""
        if not self._dirty:
            return state

        def push(gi, node):
            tbl = jnp.asarray(self.groups[gi].pool.tbl)
            if node.page_tbl.ndim == 3:
                tbl = jnp.broadcast_to(tbl, node.page_tbl.shape)
            return dataclasses.replace(node, page_tbl=tbl)

        state = self._map_nodes(state, push)
        self._dirty = False
        return state

    # -- KVLayout hooks ------------------------------------------------------

    def _keys(self, prompt) -> list[bytes] | None:
        if not self.cfg.prefix_sharing or prompt is None:
            return None
        p = np.asarray(prompt, np.int32)
        ps = self.cfg.page_size
        return [p[:(j + 1) * ps].tobytes() for j in range(len(p) // ps)]

    def gather_row(self, state, slot: int):
        state = self._sync(state)
        return self._gather(state, jnp.int32(slot))

    def scatter_row(self, state, row, slot: int, *, prompt=None, length=None):
        n = len(prompt) if prompt is not None else int(length)
        keys = self._keys(prompt)
        write_tbls = []
        hits0 = (sum(g.pool.shared_hits for g in self.groups)
                 if self.tracer.enabled else 0)
        for g in self.groups:
            g.pool.free_row(slot)  # drop any stale mapping (defensive no-op)
            wt = g.pool.attach(slot, n, keys)
            if wt is None:
                raise RuntimeError(
                    "page pool exhausted inside scatter_row — the admission "
                    "gate should have reserved these pages")
            write_tbls.append(jnp.asarray(wt))
        if self.tracer.enabled:
            hits = sum(g.pool.shared_hits for g in self.groups) - hits0
            if hits:
                self.tracer.emit("prefix_share", slot=slot,
                                 cause="prompt_prefix", pages=hits)
                self.tracer.inc("prefix_shared_pages", hits)
        self._dirty = True
        state = self._sync(state)
        return self._scatter(state, row, jnp.int32(slot), tuple(write_tbls))

    def free_row(self, state, slot: int):
        for g in self.groups:
            g.pool.free_row(slot)
        self._dirty = True  # synced before the next table read/write
        return state

    def begin_admission(self) -> None:
        for g in self.groups:
            g.pool.reserved = 0

    def can_admit(self, n_tokens: int, prompt=None) -> bool:
        """Admission gated on free *pages*, not free slots: a ticket admits
        only when every group can map its content (+1 append slot) after
        prefix-sharing hits.  Approval reserves the pages so one admission
        round cannot over-commit the pool."""
        keys = self._keys(prompt)
        needed = []
        for g in self.groups:
            need = g.pool.peek_needed(n_tokens, keys)
            if g.pool.available() < need:
                if self.tracer.enabled:
                    self.tracer.emit(
                        "admit_refuse", cause="no_free_pages",
                        needed=need, available=g.pool.available())
                    self.tracer.inc("admit_refusals")
                return False
            needed.append(need)
        for g, need in zip(self.groups, needed):
            g.pool.reserved += need
        return True

    def prepare_step(self, state, lengths: dict[int, int], ahead: int):
        """Allocation-on-append + copy-on-write, before the decode step:
        every active row gets pages covering ``length + ahead`` tokens
        (``ahead`` = 1 plain decode, k+1 speculative) and private
        writability over the slots the step will write.  Rows the pool
        cannot serve are returned as ``failed`` — the engine parks a
        page-pressure victim and retries."""
        failed: list[int] = []
        copies: list[tuple[int, int, int]] = []  # (group, src, dst)
        for slot, ln in lengths.items():
            ok = True
            for gi, g in enumerate(self.groups):
                if not g.pool.ensure(slot, ln + ahead):
                    ok = False
                    break
                pairs = g.pool.cow(slot, ln, ln + ahead)
                if pairs is None:
                    ok = False
                    break
                copies.extend((gi, s, d) for s, d in pairs)
                if pairs and self.tracer.enabled:
                    for s, d in pairs:
                        self.tracer.emit(
                            "cow_fork", slot=slot, cause="shared_page_write",
                            group=gi, src=s, dst=d)
                    self.tracer.inc("cow_forks", len(pairs))
            if not ok:
                failed.append(slot)
        self._dirty = True
        state = self._sync(state)
        for gi, src, dst in copies:
            state = self._map_nodes(
                state,
                lambda i, node, gi=gi, src=src, dst=dst:
                self._copy(node, jnp.int32(src), jnp.int32(dst))
                if i == gi else node)
        return state, failed

    def tier_tick(self, state, lengths: dict[int, int], step: int):
        """One demotion/measurement pass of the precision-tier loop."""
        tc = self.tier_ctrl
        if tc is None or not lengths:
            return state, None
        pol = tc.policy
        target, nxt = tc.target_keep, tc.next_keep
        demote_masks, shadow_masks = [], []
        total_cold = 0
        for g in self.groups:
            ages = g.pool.page_ages(lengths)
            demote = np.zeros(g.pool.n_pages + 1, bool)
            shadow = np.zeros(g.pool.n_pages + 1, bool)
            for p, age in ages.items():
                if age < pol.cold_after:
                    continue
                total_cold += 1
                shadow[p] = True
                if target is not None and g.pool.tier[p] > target:
                    demote[p] = True
            demote_masks.append(demote)
            shadow_masks.append(shadow)
        if not total_cold:
            return state, None
        errs, errs_down = [], []

        def run(gi, node):
            node, err, err_down = tier_node(
                node, jnp.asarray(demote_masks[gi]),
                jnp.asarray(shadow_masks[gi]), target, nxt, pol.rounding)
            errs.append(err)
            errs_down.append(err_down)
            return node

        state = self._map_nodes(state, run)
        err = float(max(float(e) for e in errs))
        err_down = float(max(float(e) for e in errs_down))
        demoted = 0
        for g, mask in zip(self.groups, demote_masks):
            demoted += int(mask.sum())
            if target is not None:
                g.pool.tier[mask] = target
        decision = tc.observe(step, err, err_down)
        promoted = 0
        if decision > 0:
            # floor retreated: re-label every page demoted below it (lossy
            # demotion, label promotion — DESIGN.md tier invariant)
            floor = tc.target_keep if tc.target_keep is not None else HOT
            for g in self.groups:
                deep = g.pool.tier < floor
                promoted += int(deep.sum())
                g.pool.tier[deep] = floor
        return state, {
            "demoted": demoted, "promoted": promoted,
            "err": err, "err_down": err_down,
            "depth": tc.depth, "keep": target,
        }

    def page_stats(self) -> dict | None:
        total = used = cached = mapped = unique = 0
        shared_hits = cow = evic = 0
        mix: dict[str, int] = {}
        for g in self.groups:
            s = g.pool.stats()
            total += s["pages_total"]
            used += s["pages_used"]
            cached += s["pages_cached"]
            mapped += s["mapped_refs"]
            unique += s["unique_pages"]
            shared_hits += s["shared_hits"]
            cow += s["cow_copies"]
            evic += s["index_evictions"]
            for t, n in s["tier_mix"].items():
                mix[t] = mix.get(t, 0) + n
        return {
            "pages_total": total,
            "pages_used": used,
            "pages_cached": cached,
            "occupancy": used / total if total else 0.0,
            "sharing_ratio": 1.0 - unique / mapped if mapped else 0.0,
            "shared_hits": shared_hits,
            "cow_copies": cow,
            "index_evictions": evic,
            "tier_mix": mix,
            "dense_equiv_slots": self.dense_equiv_slots,
        }

    def describe(self) -> str:
        tiers = (self.tier_ctrl.describe() if self.tier_ctrl is not None
                 else "tiers off")
        pools = ", ".join(
            f"{g.pool.n_pages}p x {g.pool.ps}t (cap {g.cap})"
            for g in self.groups) or "no KV groups"
        return (f"paged cache: {self.slots} slots over pools [{pools}] "
                f"~= {self.dense_equiv_slots} dense slots of memory | "
                f"sharing {'on' if self.cfg.prefix_sharing else 'off'} | "
                f"{tiers}")


def make_layout(cfg: CacheConfig, model, slots: int, max_len: int) -> KVLayout:
    """Layout factory for the engine: ``CacheConfig.layout`` selects."""
    if cfg.layout == "paged":
        return PagedLayout(model, slots, max_len, cfg)
    return DenseLayout(model, slots, max_len)
