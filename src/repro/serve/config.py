"""ServeConfig: the grouped, frozen construction surface of ServeEngine.

``ServeEngine.__init__`` had grown to ~17 flat kwargs spanning four
subsystems.  This module groups them:

    ServeConfig(batch_slots, max_len,
                scheduling=SchedulingConfig(...),   # repro.serve.scheduler
                adapt=AdaptConfig(...),             # repro.adapt
                spec=SpecConfig(...),               # repro.spec
                cache=CacheConfig(...))             # repro.serve.paged

``ServeEngine(model, params, config=cfg)`` is the documented construction
path; the legacy flat kwargs remain as a deprecation shim that calls
:meth:`ServeConfig.from_kwargs`, and ``launch/serve.py`` builds its config
via :meth:`ServeConfig.from_flags`.  Everything is frozen: a config is a
value, shareable across engines and safe to put in test parametrizations.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from repro.adapt.pages import PageTierPolicy


@dataclasses.dataclass(frozen=True)
class SchedulingConfig:
    """Admission / preemption policy (repro.serve.scheduler)."""

    tenants: Sequence | None = None
    classes: Sequence | None = None
    policy: str = "priority"
    preempt: bool = True
    aging_steps: int = 8
    min_quantum: int = 2

    def __post_init__(self):
        if self.policy not in ("priority", "fifo"):
            raise ValueError(f"unknown scheduling policy {self.policy!r}")


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    """Closed-loop runtime precision adaptation (repro.adapt).  ``slo=None``
    disables the loop entirely; ``adapt=False`` keeps probes + timeline but
    never shifts (the monitored static baseline)."""

    slo: Any = None  # repro.adapt.SLO | None
    adapt_every: int = 4
    adapt: bool = True
    controller: Any = None

    def __post_init__(self):
        if self.adapt_every < 1:
            raise ValueError("adapt_every must be >= 1")


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """KV-cache layout selection (repro.serve.paged).

    ``layout="dense"`` is today's per-slot ring cache, bit-identical to the
    pre-paged engine.  ``layout="paged"`` switches every KV group to the
    page-table pool: ``page_size`` tokens per page; ``pool_pages`` sizes the
    pool of the largest-capacity group (other groups scale proportionally;
    None = memory-equivalent to dense at ``batch_slots`` slots);
    ``tier_policy`` turns on precision-tiered pages (bf16 caches only);
    ``prefix_sharing`` shares read-only prompt-prefix pages between requests
    with copy-on-write forks.
    """

    layout: str = "dense"
    page_size: int = 16
    pool_pages: int | None = None
    tier_policy: PageTierPolicy | None = None
    prefix_sharing: bool = True

    def __post_init__(self):
        if self.layout not in ("dense", "paged"):
            raise ValueError(f"unknown cache layout {self.layout!r}")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.pool_pages is not None and self.pool_pages < 1:
            raise ValueError("pool_pages must be >= 1")
        if self.tier_policy is not None and self.layout != "paged":
            raise ValueError("tier_policy requires layout='paged'")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Everything ServeEngine needs beyond (model, params)."""

    batch_slots: int
    max_len: int
    greedy: bool = True
    accuracy: float | None = None
    plan_backend: str | None = None
    prefill_tokens: int | None = None
    decode_accuracy_scale: float | None = None
    tune_table: Any = None
    scheduling: SchedulingConfig = dataclasses.field(
        default_factory=SchedulingConfig)
    adapt: AdaptConfig = dataclasses.field(default_factory=AdaptConfig)
    spec: Any = None  # repro.spec.SpecConfig | None
    cache: CacheConfig = dataclasses.field(default_factory=CacheConfig)
    #: repro.obs.TraceConfig | True | None — None keeps the engine on the
    #: no-op NULL_TRACER (zero jit-visible cost); True means default knobs
    trace: Any = None

    def __post_init__(self):
        if self.batch_slots < 1:
            raise ValueError("batch_slots must be >= 1")
        if self.max_len < 1:
            raise ValueError("max_len must be >= 1")

    @classmethod
    def from_kwargs(cls, batch_slots: int, max_len: int, *,
                    greedy: bool = True, accuracy: float | None = None,
                    plan_backend: str | None = None,
                    prefill_tokens: int | None = None,
                    decode_accuracy_scale: float | None = None,
                    tune_table=None, slo=None, adapt_every: int = 4,
                    adapt: bool = True, controller=None, speculate=None,
                    tenants=None, classes=None,
                    scheduler_policy: str = "priority", preempt: bool = True,
                    aging_steps: int = 8, min_quantum: int = 2,
                    cache: CacheConfig | None = None,
                    trace=None) -> "ServeConfig":
        """The deprecation shim: the flat pre-ServeConfig kwarg surface of
        ``ServeEngine.__init__``, regrouped.  Legacy call sites keep working
        through this mapping (the full pre-redesign test suite passes
        against it)."""
        return cls(
            batch_slots=batch_slots, max_len=max_len, greedy=greedy,
            accuracy=accuracy, plan_backend=plan_backend,
            prefill_tokens=prefill_tokens,
            decode_accuracy_scale=decode_accuracy_scale,
            tune_table=tune_table,
            scheduling=SchedulingConfig(
                tenants=tenants, classes=classes, policy=scheduler_policy,
                preempt=preempt, aging_steps=aging_steps,
                min_quantum=min_quantum),
            adapt=AdaptConfig(slo=slo, adapt_every=adapt_every, adapt=adapt,
                              controller=controller),
            spec=speculate,
            cache=cache or CacheConfig(),
            trace=trace,
        )

    @classmethod
    def from_flags(cls, args, *, tenants=None, classes=None) -> "ServeConfig":
        """Build a config from the ``repro.launch.serve`` argparse namespace
        (tenants/classes are constructed by the launcher for
        ``--multi-tenant`` and passed through)."""
        slo = None
        if args.adapt:
            from repro.adapt import SLO

            slo = SLO(max_err=args.slo_err, target_ms=args.slo_ms or None)
        speculate = None
        if args.speculate:
            from repro.spec import SpecConfig

            speculate = SpecConfig(k=args.draft_k,
                                   draft_shift=args.draft_shift)
        tier = None
        if getattr(args, "tier_levels", ""):
            levels = tuple(int(b) for b in args.tier_levels.split(","))
            tier = PageTierPolicy(
                levels=levels, cold_after=args.tier_cold_after,
                every=args.tier_every, budget=args.tier_budget or None)
        cache = CacheConfig(
            layout="paged" if getattr(args, "paged", False) else "dense",
            page_size=getattr(args, "page_size", 16),
            pool_pages=getattr(args, "pool_pages", 0) or None,
            tier_policy=tier,
            prefix_sharing=not getattr(args, "no_prefix_sharing", False),
        )
        trace = None
        if getattr(args, "trace", False) or getattr(args, "trace_out", ""):
            from repro.obs import TraceConfig

            trace = TraceConfig(out=getattr(args, "trace_out", "") or None)
        slots = args.slots or max(args.requests, 1)
        return cls(
            batch_slots=slots,
            max_len=args.prompt_len + args.max_new + 8,
            accuracy=args.accuracy,
            prefill_tokens=max(args.prompt_len // 2, 1),
            tune_table=args.tune_table or None,
            scheduling=SchedulingConfig(tenants=tenants, classes=classes,
                                        policy=args.scheduler_policy),
            adapt=AdaptConfig(slo=slo, adapt_every=args.adapt_every),
            spec=speculate,
            cache=cache,
            trace=trace,
        )
