"""Request admission and slot lifecycle for continuous batching.

The scheduler owns everything *per-request* and nothing *per-array*: requests
are submitted into a FIFO admission queue, admitted into free slots of the
fixed slot array as capacity opens up, and walk the lifecycle

    WAITING -> PREFILL -> DECODE -> DONE

Slot capacity is the only resource: a slot frees the moment its request
finishes (the masked step engine keeps the freed row inert), so a waiting
request joins mid-flight on the very next ``ServeEngine.step``.  The decode
budget is clamped against the KV-cache capacity at submit time (eviction on
``max_len``): a request whose prompt plus budget would overflow the cache is
truncated to the tokens that fit, never silently over-decoded.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

# lifecycle states
WAITING = "WAITING"
PREFILL = "PREFILL"
DECODE = "DECODE"
DONE = "DONE"


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    rid: int = 0


@dataclasses.dataclass
class Ticket:
    """Per-request scheduler record (request + lifecycle + emitted tokens)."""

    rid: int
    prompt: np.ndarray
    max_new: int
    budget: int  # max_new clamped to cache capacity (eviction on max_len)
    state: str = WAITING
    slot: int = -1
    tokens: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def remaining(self) -> int:
        """Decode budget left — the clamp for multi-token (speculative)
        emission bursts: a burst never emits past the budget mid-round."""
        return max(self.budget - len(self.tokens), 0)


def ragged_requests(n: int, vocab: int, prompt_len: int, max_new: int,
                    rng: np.random.Generator) -> list[Request]:
    """Ragged serving workload shared by the launcher and the serve sweep:
    prompt lengths U[prompt_len/4 .. prompt_len], decode budgets
    U[max_new/2 .. max_new], rids 0..n-1."""
    return [
        Request(
            prompt=rng.integers(0, vocab, int(rng.integers(
                max(prompt_len // 4, 1), prompt_len + 1))).astype(np.int32),
            max_new=int(rng.integers(max(max_new // 2, 1), max_new + 1)),
            rid=i,
        )
        for i in range(n)
    ]


class Scheduler:
    def __init__(self, slots: int, max_len: int):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        self.slots = slots
        self.max_len = max_len
        self.queue: collections.deque[Ticket] = collections.deque()  # FIFO
        self.free: collections.deque[int] = collections.deque(range(slots))
        self.tickets: dict[int, Ticket] = {}  # all rids ever submitted
        self.by_slot: dict[int, Ticket] = {}  # occupied slots only
        self.completed: list[int] = []  # rids in completion order

    # -- admission -----------------------------------------------------------

    def submit(self, req: Request) -> int:
        """Enqueue a request (WAITING).  The decode budget is
        ``min(max_new, max_len - len(prompt) + 1)``: prefill writes the
        prompt, each decode step past the first token writes one cache row,
        so this is exactly what fits without overflowing the slot's cache."""
        n = len(req.prompt)
        if n < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if n > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {n} exceeds max_len "
                f"{self.max_len}"
            )
        if req.rid in self.tickets:
            # rids are the keys of every per-request record (tickets,
            # metrics, drain() output): reuse would silently overwrite the
            # earlier request's history
            raise ValueError(f"rid {req.rid} already submitted")
        budget = max(min(req.max_new, self.max_len - n + 1), 0)
        t = Ticket(rid=req.rid, prompt=np.asarray(req.prompt, np.int32),
                   max_new=req.max_new, budget=budget)
        self.tickets[req.rid] = t
        self.queue.append(t)
        return req.rid

    def admit(self) -> list[tuple[int, Ticket]]:
        """Move waiting requests into free slots, FIFO, until either runs
        out.  Admitted tickets transition WAITING -> PREFILL.  Zero-budget
        tickets (nothing fits the cache) complete immediately without a
        slot and are returned as ``(-1, ticket)`` so the caller can route
        the completion event (the engine's metrics must agree with
        ``completed`` — completing them silently here undercounted
        ``ServeMetrics.summary()['completed']``)."""
        out = []
        while self.queue:
            if self.queue[0].budget == 0:
                # nothing fits: complete immediately — needs no slot, so it
                # must not wait behind slot contention either
                t = self.queue.popleft()
                self.complete(t.rid)
                out.append((-1, t))
                continue
            if not self.free:
                break
            t = self.queue.popleft()
            slot = self.free.popleft()
            t.slot = slot
            t.state = PREFILL
            self.by_slot[slot] = t
            out.append((slot, t))
        return out

    # -- lifecycle -----------------------------------------------------------

    def start_decode(self, rid: int) -> None:
        self.tickets[rid].state = DECODE

    def complete(self, rid: int) -> None:
        """DONE: release the slot for the next admission."""
        t = self.tickets[rid]
        if t.done:
            return
        t.state = DONE
        self.completed.append(rid)
        if t.slot >= 0:
            self.by_slot.pop(t.slot)
            self.free.append(t.slot)
            t.slot = -1

    # -- queries -------------------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.queue or self.by_slot)

    @property
    def n_active(self) -> int:
        return len(self.by_slot)

    @property
    def n_waiting(self) -> int:
        return len(self.queue)
