"""Request admission and slot lifecycle for continuous batching.

The scheduler owns everything *per-request* and nothing *per-array*:
requests are submitted into an admission queue, admitted into free slots of
the fixed slot array as capacity opens up, and walk the lifecycle

    WAITING -> PREFILL -> DECODE -> (PREEMPTED -> DECODE)* -> DONE

Admission order (``policy="priority"``, the default) is a total order over
the waiting queue by the key

    (effective priority, deadline step, submission seq)

where effective priority = ``tenant.priority - age // aging_steps`` (aging:
a waiting request gains one priority rung every ``aging_steps`` scheduler
ticks, so no request starves behind an endless stream of more-urgent
arrivals — the effective priority falls without bound until it wins), the
deadline is ``submit_step + class.slo_steps`` (earliest-deadline-first
within a priority level), and ``seq`` is the submission counter — the
stable tie-break that pins equal-priority equal-arrival requests to
submission order.  ``policy="fifo"`` ignores tenancy entirely (key =
``(seq,)``): the pure-FIFO baseline the tenant sweep compares against.

Preemption (``preempt=True`` with the priority policy): when a waiter's
*base* priority is strictly more urgent than a running ticket's base
priority, the scheduler names a victim (the worst-key active ticket that
has run at least ``min_quantum`` tokens since its last admission — the
quantum bounds thrash).  Base-vs-base deliberately: aging drives admission
order only, so equal-priority traffic never preempts itself (the default
single-tenant config stays exactly FIFO) and a victim can never preempt
its own preemptor back.  The *engine* owns the victim's device state: it
parks the slot's state row, then calls :meth:`Scheduler.preempt`, which
requeues the ticket through the same budget-clamp bookkeeping every
admission uses — ``Ticket.remaining`` already measures decode budget left,
so a resumed ticket simply continues its burst accounting where it stopped.
Aging restarts at preemption (``queued_step`` resets): the victim re-earns
its way back instead of instantly reclaiming the slot it just lost.

Slot capacity is the only resource: a slot frees the moment its request
finishes or is preempted.  The decode budget is clamped against the
KV-cache capacity at submit time (eviction on ``max_len``): a request whose
prompt plus budget would overflow the cache is truncated to the tokens that
fit, never silently over-decoded.
"""
from __future__ import annotations

import collections
import dataclasses
import math

import numpy as np

from repro.obs import NULL_TRACER
from repro.serve.tenancy import (RequestClass, Tenant, normalize_classes,
                                 normalize_tenants)

# lifecycle states
WAITING = "WAITING"
PREFILL = "PREFILL"
DECODE = "DECODE"
PREEMPTED = "PREEMPTED"
DONE = "DONE"


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    rid: int = 0
    tenant: str = "default"
    rclass: str = "default"


@dataclasses.dataclass
class Ticket:
    """Per-request scheduler record (request + lifecycle + emitted tokens)."""

    rid: int
    prompt: np.ndarray
    max_new: int
    budget: int  # max_new clamped to cache capacity (eviction on max_len)
    tenant: str = "default"
    rclass: str = "default"
    priority: int = 1  # tenant priority at submit (lower = more urgent)
    deadline: float = math.inf  # absolute step: submit_step + slo_steps
    seq: int = 0  # submission counter — the stable tie-break
    submit_step: int = 0
    queued_step: int = 0  # aging reference; resets on preemption
    tokens_at_admit: int = 0  # quantum reference for preemption eligibility
    preemptions: int = 0
    state: str = WAITING
    slot: int = -1
    tokens: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def remaining(self) -> int:
        """Decode budget left — the clamp for multi-token (speculative)
        emission bursts: a burst never emits past the budget mid-round.
        Preemption rides on the same account: a resumed ticket keeps its
        emitted tokens, so ``remaining`` already measures what is left."""
        return max(self.budget - len(self.tokens), 0)


def ragged_requests(n: int, vocab: int, prompt_len: int, max_new: int,
                    rng: np.random.Generator) -> list[Request]:
    """Ragged serving workload shared by the launcher and the serve sweep:
    prompt lengths U[prompt_len/4 .. prompt_len], decode budgets
    U[max_new/2 .. max_new], rids 0..n-1."""
    return [
        Request(
            prompt=rng.integers(0, vocab, int(rng.integers(
                max(prompt_len // 4, 1), prompt_len + 1))).astype(np.int32),
            max_new=int(rng.integers(max(max_new // 2, 1), max_new + 1)),
            rid=i,
        )
        for i in range(n)
    ]


class Scheduler:
    #: trace sink (repro.obs) — the engine swaps in its live tracer; the
    #: class default keeps a standalone Scheduler emit-free at no cost
    tracer = NULL_TRACER

    def __init__(self, slots: int, max_len: int, *,
                 tenants=None, classes=None, policy: str = "priority",
                 aging_steps: int = 8, preempt: bool = True,
                 min_quantum: int = 2):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        if policy not in ("priority", "fifo"):
            raise ValueError(f"unknown scheduling policy {policy!r}")
        if aging_steps < 0:
            raise ValueError("aging_steps must be >= 0 (0 disables aging)")
        if min_quantum < 1:
            raise ValueError("min_quantum must be >= 1")
        self.slots = slots
        self.max_len = max_len
        self.tenants: dict[str, Tenant] = normalize_tenants(tenants)
        self.classes: dict[str, RequestClass] = normalize_classes(classes)
        self.policy = policy
        self.aging_steps = aging_steps
        self.preempt_enabled = bool(preempt) and policy == "priority"
        self.min_quantum = min_quantum
        self.clock = 0  # engine steps; advanced by tick()
        self.queue: list[Ticket] = []  # waiting + preempted, sorted at admit
        self.free: collections.deque[int] = collections.deque(range(slots))
        self.tickets: dict[int, Ticket] = {}  # all rids ever submitted
        self.by_slot: dict[int, Ticket] = {}  # occupied slots only
        self.completed: list[int] = []  # rids in completion order
        self.preemptions = 0  # total preempt() calls
        self.max_wait_steps = 0  # worst queue wait seen at any admission
        self._seq = 0

    # -- admission -----------------------------------------------------------

    def tick(self) -> None:
        """Advance the step clock (the engine calls this once per step).
        Aging and deadlines are measured in these ticks — engine steps, not
        wall clock — so scheduling decisions and the attainment gate are
        machine-independent."""
        self.clock += 1

    def submit(self, req: Request) -> int:
        """Enqueue a request (WAITING).  The decode budget is
        ``min(max_new, max_len - len(prompt) + 1)``: prefill writes the
        prompt, each decode step past the first token writes one cache row,
        so this is exactly what fits without overflowing the slot's cache."""
        n = len(req.prompt)
        if n < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if n > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {n} exceeds max_len "
                f"{self.max_len}"
            )
        if req.rid in self.tickets:
            # rids are the keys of every per-request record (tickets,
            # metrics, drain() output): reuse would silently overwrite the
            # earlier request's history
            raise ValueError(f"rid {req.rid} already submitted")
        tenant = self.tenants.get(req.tenant)
        if tenant is None:
            raise ValueError(
                f"request {req.rid}: unknown tenant {req.tenant!r} "
                f"(declared: {sorted(self.tenants)})")
        rc = self.classes.get(req.rclass)
        if rc is None:
            raise ValueError(
                f"request {req.rid}: unknown request class {req.rclass!r} "
                f"(declared: {sorted(self.classes)})")
        budget = max(min(req.max_new, self.max_len - n + 1), 0)
        t = Ticket(
            rid=req.rid, prompt=np.asarray(req.prompt, np.int32),
            max_new=req.max_new, budget=budget,
            tenant=tenant.name, rclass=rc.name, priority=tenant.priority,
            deadline=(self.clock + rc.slo_steps if rc.slo_steps is not None
                      else math.inf),
            seq=self._seq, submit_step=self.clock, queued_step=self.clock,
        )
        self._seq += 1
        self.tickets[req.rid] = t
        self.queue.append(t)
        return req.rid

    def eff_priority(self, t: Ticket) -> int:
        """Priority after aging: one rung more urgent per ``aging_steps``
        ticks waited — falls without bound, so any waiter eventually
        out-ranks any fresh arrival (the no-starvation lever)."""
        if not self.aging_steps:
            return t.priority
        return t.priority - (self.clock - t.queued_step) // self.aging_steps

    def admission_key(self, t: Ticket):
        """Total order over the waiting queue.  The trailing ``seq`` makes
        every comparison deterministic: equal-priority, equal-deadline
        (hence equal-arrival) requests admit in submission order."""
        if self.policy == "fifo":
            return (t.seq,)
        return (self.eff_priority(t), t.deadline, t.seq)

    def admit(self, can_admit=None) -> list[tuple[int, Ticket]]:
        """Move waiting requests into free slots in admission-key order
        until either runs out.  Fresh tickets transition WAITING -> PREFILL;
        preempted tickets re-admit as DECODE (the engine restores their
        parked state row instead of prefilling).  Zero-budget tickets
        (nothing fits the cache) complete immediately without a slot and
        are returned as ``(-1, ticket)`` so the caller can route the
        completion event (the engine's metrics must agree with
        ``completed`` — completing them silently here undercounted
        ``ServeMetrics.summary()['completed']``).

        ``can_admit(ticket) -> bool`` is the engine's capacity gate beyond
        free slots (the paged layout's free-page check).  A refused ticket
        stays queued in place and the scan continues: a smaller request
        further back may still fit — slot order is a *preference* under
        memory pressure, not a barrier — while the refused ticket keeps its
        admission-key rank for the next step."""
        out: list[tuple[int, Ticket]] = []
        keep = []
        for t in self.queue:
            if t.budget == 0:
                # nothing fits: complete immediately — needs no slot, so it
                # must not wait behind slot contention either
                self.complete(t.rid)
                out.append((-1, t))
            else:
                keep.append(t)
        keep.sort(key=self.admission_key)
        self.queue[:] = keep
        i = 0
        while i < len(self.queue) and self.free:
            t = self.queue[i]
            if can_admit is not None and not can_admit(t):
                if self.tracer.enabled:
                    # the layout refused capacity: this ticket waits in rank
                    # while the scan continues — admissions behind it are
                    # legal reorderings the replay harness must not call
                    # FIFO violations
                    self.tracer.emit("admit_defer", rid=t.rid,
                                     cause="layout_refusal")
                    self.tracer.inc("admit_defers")
                i += 1
                continue
            self.queue.pop(i)
            slot = self.free.popleft()
            t.slot = slot
            t.state = DECODE if t.tokens else PREFILL
            t.tokens_at_admit = len(t.tokens)
            self.max_wait_steps = max(self.max_wait_steps,
                                      self.clock - t.queued_step)
            self.by_slot[slot] = t
            out.append((slot, t))
        return out

    # -- preemption ----------------------------------------------------------

    def plan_preemptions(self) -> list[Ticket]:
        """Victims to evict this step so more-urgent waiters can run.

        For each waiter (best admission key first) that no free slot can
        serve, pick the worst active ticket — largest (base priority,
        deadline, seq) — whose *base* priority is strictly less urgent than
        the waiter's *base* priority and which has emitted at least
        ``min_quantum`` tokens since its last admission.  Base-vs-base,
        never aged: preemption exists for genuinely-more-urgent arrivals,
        while an aged equal-or-lower-priority waiter gets the next natural
        slot turnover instead (budgets are finite, so turnover is bounded —
        aging still guarantees no starvation through admission order
        alone).  A victim can therefore never preempt its preemptor back
        (its base priority is strictly worse), and the quantum guarantees
        every admission makes progress — together they bound thrash.

        The caller (engine) must park each victim's state row and then call
        :meth:`preempt` — this method only *names* victims, it mutates
        nothing."""
        if not (self.preempt_enabled and self.queue):
            return []
        victims: list[Ticket] = []
        taken: set[int] = set()
        free_virtual = len(self.free)
        for w in sorted((t for t in self.queue if t.budget > 0),
                        key=self.admission_key):
            if free_virtual > 0:
                free_virtual -= 1
                continue
            cands = [
                t for t in self.by_slot.values()
                if t.state == DECODE and t.rid not in taken
                and t.priority > w.priority
                and len(t.tokens) - t.tokens_at_admit >= self.min_quantum
            ]
            if not cands:
                continue
            v = max(cands, key=lambda t: (t.priority, t.deadline, t.seq))
            victims.append(v)
            taken.add(v.rid)
            if self.tracer.enabled:
                self.tracer.emit("preempt_plan", rid=v.rid, slot=v.slot,
                                 cause="priority", waiter=w.rid)
        return victims

    def page_victim(self) -> Ticket | None:
        """Name the page-pressure eviction victim: the *least* urgent
        running DECODE ticket by (base priority, deadline, seq).  Unlike
        :meth:`plan_preemptions` this ignores ``preempt`` and the quantum —
        memory pressure is a correctness condition (the pool physically
        cannot hold every active row's next tokens), not a fairness policy,
        so some row must park regardless of configuration.  Mutates
        nothing; the engine parks the row and calls :meth:`preempt`."""
        cands = [t for t in self.by_slot.values() if t.state == DECODE]
        if not cands:
            return None
        return max(cands, key=lambda t: (t.priority, t.deadline, t.seq))

    def preempt(self, rid: int) -> None:
        """Evict a running ticket back to the queue (PREEMPTED): the slot
        frees for the next admission, the ticket keeps its emitted tokens
        and budget (``remaining`` keeps counting down across the gap), and
        its aging reference resets to now."""
        t = self.tickets[rid]
        if t.slot < 0 or t.done:
            raise ValueError(f"rid {rid} is not running (state {t.state})")
        self.by_slot.pop(t.slot)
        self.free.append(t.slot)
        t.slot = -1
        t.state = PREEMPTED
        t.queued_step = self.clock
        t.preemptions += 1
        self.preemptions += 1
        self.queue.append(t)

    # -- lifecycle -----------------------------------------------------------

    def start_decode(self, rid: int) -> None:
        self.tickets[rid].state = DECODE

    def complete(self, rid: int) -> None:
        """DONE: release the slot for the next admission."""
        t = self.tickets[rid]
        if t.done:
            return
        t.state = DONE
        self.completed.append(rid)
        if t.slot >= 0:
            self.by_slot.pop(t.slot)
            self.free.append(t.slot)
            t.slot = -1

    # -- queries -------------------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.queue or self.by_slot)

    @property
    def n_active(self) -> int:
        return len(self.by_slot)

    @property
    def n_waiting(self) -> int:
        return len(self.queue)
