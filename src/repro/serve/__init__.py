"""repro.serve — continuous-batching serving subsystem.

    scheduler.py  admission + slot lifecycle (WAITING/PREFILL/DECODE/
                  PREEMPTED/DONE): priority + earliest-deadline-first with
                  aging and preemption, or pure FIFO (policy="fifo")
    tenancy.py    Tenant / RequestClass — priority, entitlement share,
                  per-tenant accuracy budget, step-unit deadlines
    engine.py     masked compiled step over the fixed slot array + streaming
                  API; preemption parks/resumes exact state rows
    metrics.py    tok/s, TTFT, latency, slot occupancy, plan-cache hits,
                  speculative acceptance, per-tenant SLO attainment /
                  fairness (share vs entitlement)

``ServeEngine(slo=...)`` closes the runtime-precision loop (repro.adapt);
``ServeEngine(speculate=SpecConfig(...))`` runs self-speculative decode
rounds (repro.spec); ``ServeEngine(tenants=[...], classes=[...])`` turns on
multi-tenant priority scheduling (with ``slo=`` each tenant gets a private
mode table + controller).  See DESIGN.md sections Serving / Runtime
adaptation / Speculative decoding / Multi-tenant scheduling.
"""
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.metrics import ServeMetrics  # noqa: F401
from repro.serve.scheduler import Request, Scheduler, ragged_requests  # noqa: F401
from repro.serve.tenancy import (  # noqa: F401
    RequestClass,
    Tenant,
    class_requests,
)
