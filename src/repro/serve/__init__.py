"""repro.serve — continuous-batching serving subsystem.

    scheduler.py  admission + slot lifecycle (WAITING/PREFILL/DECODE/
                  PREEMPTED/DONE): priority + earliest-deadline-first with
                  aging and preemption, or pure FIFO (policy="fifo")
    tenancy.py    Tenant / RequestClass — priority, entitlement share,
                  per-tenant accuracy budget, step-unit deadlines
    engine.py     masked compiled step over the fixed slot array + streaming
                  API; preemption parks/resumes exact state rows
    config.py     ServeConfig — the frozen grouped construction surface
                  (SchedulingConfig / AdaptConfig / SpecConfig / CacheConfig)
    paged.py      KVLayout protocol: DenseLayout (per-slot ring, default)
                  and PagedLayout (page-table pools, precision-tiered pages,
                  prefix sharing with copy-on-write)
    metrics.py    tok/s, TTFT, latency, slot occupancy, plan-cache hits,
                  speculative acceptance, per-tenant SLO attainment /
                  fairness (share vs entitlement), page occupancy / tier mix

Tracing: ``ServeConfig(trace=repro.obs.TraceConfig(...))`` records typed
events (request spans, engine dispatches, controller decisions with causes)
into ``engine.tracer`` — exportable as a Chrome trace, Prometheus text, or
the merged precision timeline, and replayable through the
tests/scheduler_model.py invariant harness.  Tracing off (the default) is
the shared no-op NULL_TRACER: identical compiles and dispatches, zero
jit-visible cost (DESIGN.md section Observability).

``ServeEngine(model, params, config=ServeConfig(...))`` is the documented
construction path (the flat kwargs remain as a deprecation shim).
``AdaptConfig(slo=...)`` closes the runtime-precision loop (repro.adapt);
``spec=SpecConfig(...)`` runs self-speculative decode rounds (repro.spec);
``SchedulingConfig(tenants=, classes=)`` turns on multi-tenant priority
scheduling; ``CacheConfig(layout="paged")`` switches the KV cache to the
page-table pool.  See DESIGN.md sections Serving / Runtime adaptation /
Speculative decoding / Multi-tenant scheduling / Paged KV cache.
"""
from repro.serve.config import (  # noqa: F401
    AdaptConfig,
    CacheConfig,
    SchedulingConfig,
    ServeConfig,
)
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.metrics import ServeMetrics  # noqa: F401
from repro.serve.paged import (  # noqa: F401
    DenseLayout,
    KVLayout,
    PagedLayout,
    PagePool,
    make_layout,
)
from repro.serve.scheduler import Request, Scheduler, ragged_requests  # noqa: F401
from repro.serve.tenancy import (  # noqa: F401
    RequestClass,
    Tenant,
    class_requests,
)
