"""repro.serve — continuous-batching serving subsystem.

    scheduler.py  admission queue + slot lifecycle (WAITING/PREFILL/DECODE/DONE)
    engine.py     masked compiled step over the fixed slot array + streaming API
    metrics.py    tok/s, TTFT, latency, slot occupancy, plan-cache hits,
                  speculative acceptance / verify-steps-per-token

``ServeEngine(slo=...)`` closes the runtime-precision loop (repro.adapt);
``ServeEngine(speculate=SpecConfig(...))`` runs self-speculative decode
rounds (repro.spec).  See DESIGN.md sections Serving / Runtime adaptation /
Speculative decoding for the slot-array layout and masking invariants.
"""
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.metrics import ServeMetrics  # noqa: F401
from repro.serve.scheduler import Request, Scheduler, ragged_requests  # noqa: F401
