"""Tenant / request-class abstractions for multi-tenant serving.

A real deployment never sees one architecture with one SLO: chat traffic
wants tight tail latency, batch jobs want throughput, audio-length prompts
want neither to starve.  This module is the vocabulary the scheduler,
engine, metrics and the tenant sweep share:

  * :class:`Tenant` — who is asking: admission priority (lower = more
    urgent), a token-rate entitlement ``share`` for fairness reporting, and
    an optional per-tenant ``accuracy`` budget that seeds that tenant's own
    ``repro.adapt`` controller (one tenant's hot workload must not drag
    another tenant's mode table — DESIGN.md section Multi-tenant
    scheduling).
  * :class:`RequestClass` — what is being asked: a deadline ``slo_steps``
    measured in *engine steps* (machine-independent, the unit the EDF
    scheduler and the attainment gate both use), an optional wall-clock
    ``slo_ms`` for reporting, and the prompt/decode shape profile the
    workload generators draw from (chat: short/short, batch: long decodes,
    audio: long prompts).

Deadlines deliberately live on the class and priorities on the tenant: two
tenants can run the same "chat" class at different priorities, and one
tenant can mix classes.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One request stream's identity: priority, entitlement, error budget.

    ``priority``: admission urgency, lower is more urgent (0 = front of the
    line).  ``share``: relative decode-slot entitlement weight used by the
    fairness report (``ServeMetrics.tenant_summary``) — it does not gate
    admission, it defines what "fair" means when measuring.  ``accuracy``:
    optional per-tenant relative-error budget; with ``ServeEngine(slo=...)``
    it becomes that tenant's own SLO ``max_err``, giving the tenant a
    private mode table + hysteresis controller.
    """

    name: str
    priority: int = 1
    share: float = 1.0
    accuracy: float | None = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant needs a non-empty name")
        if self.share <= 0:
            raise ValueError(f"tenant {self.name}: share must be positive")
        if self.accuracy is not None and self.accuracy <= 0:
            raise ValueError(f"tenant {self.name}: accuracy must be positive")


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One traffic shape: deadline + prompt/decode profile.

    ``slo_steps``: complete within this many *engine steps* of submission
    (None = no deadline).  Steps, not seconds: the scheduler's EDF term and
    the CI attainment gate must not depend on host speed.  ``slo_ms`` is
    the wall-clock target reported alongside (p50/p99), never scheduled on.
    ``prompt_len``/``max_new`` are the generator profile for this class —
    the scheduler itself only reads ``slo_steps``.
    """

    name: str
    slo_steps: int | None = None
    slo_ms: float | None = None
    prompt_len: int = 8
    max_new: int = 8

    def __post_init__(self):
        if not self.name:
            raise ValueError("request class needs a non-empty name")
        if self.slo_steps is not None and self.slo_steps < 1:
            raise ValueError(f"class {self.name}: slo_steps must be >= 1")
        if self.prompt_len < 1 or self.max_new < 0:
            raise ValueError(f"class {self.name}: bad shape profile")


DEFAULT_TENANT = Tenant("default", priority=1, share=1.0)
DEFAULT_CLASS = RequestClass("default")


def _normalize(items, default, kind) -> dict:
    """dict | iterable | None -> name-keyed registry always containing
    ``default`` (single-tenant callers never have to mention tenancy)."""
    reg = {default.name: default}
    if items is None:
        return reg
    if isinstance(items, dict):
        items = items.values()
    for it in items:
        if not isinstance(it, type(default)):
            raise TypeError(f"expected {type(default).__name__} for {kind}, "
                            f"got {type(it).__name__}")
        reg[it.name] = it
    return reg


def normalize_tenants(tenants) -> dict[str, Tenant]:
    return _normalize(tenants, DEFAULT_TENANT, "tenants")


def normalize_classes(classes) -> dict[str, RequestClass]:
    return _normalize(classes, DEFAULT_CLASS, "classes")


def class_requests(rc: RequestClass, tenant: Tenant, n: int, vocab: int,
                   rng: np.random.Generator, rid_base: int = 0) -> list:
    """n ragged requests drawn from one class's shape profile: prompt
    lengths U[prompt_len/2 .. prompt_len], budgets U[max_new/2 .. max_new],
    tagged with the tenant and class names (the sweep's workload unit)."""
    from repro.serve.scheduler import Request

    return [
        Request(
            prompt=rng.integers(0, vocab, int(rng.integers(
                max(rc.prompt_len // 2, 1), rc.prompt_len + 1))).astype(np.int32),
            max_new=int(rng.integers(max(rc.max_new // 2, 1), rc.max_new + 1)),
            rid=rid_base + i,
            tenant=tenant.name,
            rclass=rc.name,
        )
        for i in range(n)
    ]
