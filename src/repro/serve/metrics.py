"""Serving metrics: throughput, TTFT, per-request latency, slot occupancy,
plan-cache hits, per-tenant fairness and SLO attainment.

``ServeMetrics`` is pure bookkeeping — the engine calls the ``on_*`` hooks
and ``summary()`` folds them into one dict.  Slot occupancy is measured over
*decode steps only* (prefill is per-request work, not slot-array work):
``occupancy = sum(active slots per step) / (decode steps * slots)`` — the
fraction of the compiled step's rows doing useful work, the number that says
whether continuous batching is actually keeping the array full.

Multi-tenant accounting (DESIGN.md section Multi-tenant scheduling): every
request carries its tenant / request-class tags plus the class's step-unit
deadline, so ``tenant_summary()`` can report per tenant

  * **SLO attainment** — fraction of this tenant's deadline-carrying
    requests that completed within ``slo_steps`` *engine steps* of
    submission (step units, not wall clock: the number the CI gate
    compares between schedulers must not depend on host speed);
  * **latency percentiles** — wall-clock p50/p99 submit-to-done and TTFT
    (reporting only, never gated);
  * **decode-slot share vs entitlement** — the fraction of (decode step x
    active slot) pairs this tenant consumed, against its configured
    ``share`` weight renormalized over tenants that actually submitted.

TTFT is recorded once, at the request's *first* token: a preempted-then-
resumed request must not get a second "first token" (resume restores state,
it does not re-prefill), so ``on_first_token`` ignores repeats.

Plan-cache numbers are deltas against the engine-construction snapshot, so
they count only the planning this engine triggered (``repro.plan``
caches globally).
"""
from __future__ import annotations

import dataclasses
import time

from repro.plan import plan_cache_stats


@dataclasses.dataclass
class RequestTimes:
    submit: float
    first_token: float | None = None
    done: float | None = None
    n_tokens: int = 0
    tenant: str = "default"
    rclass: str = "default"
    slo_steps: int | None = None  # relative deadline in engine steps
    slo_ms: float | None = None  # wall-clock target (reporting only)
    submit_step: int | None = None
    done_step: int | None = None
    preemptions: int = 0


def percentile(values: list[float], q: float) -> float | None:
    """Nearest-rank percentile (q in [0, 100]); None on empty input."""
    if not values:
        return None
    ordered = sorted(values)
    rank = max(int(round(q / 100.0 * len(ordered) + 0.5)) - 1, 0)
    return ordered[min(rank, len(ordered) - 1)]


class ServeMetrics:
    def __init__(self, slots: int, clock=time.perf_counter):
        self.slots = slots
        self.clock = clock
        self.requests: dict[int, RequestTimes] = {}
        self.tokens_out = 0
        self.prefills = 0
        self.decode_steps = 0
        self.active_slot_steps = 0  # sum over decode steps of active slots
        self.preemptions = 0  # park/requeue events (resumes = preemptions)
        # per-tenant (decode step x active slot) consumption + entitlement
        self.tenant_slot_steps: dict[str, int] = {}
        self.tenant_shares: dict[str, float] = {}  # configured entitlement
        # runtime-adaptation observability (repro.adapt): how many decode
        # steps ran under each mode label, every mode switch, every probe
        self.mode_steps: dict[str, int] = {}
        self.mode_switches = 0
        self.mode_timeline: list[tuple[int, str]] = []  # (decode_step, label)
        self.probe_errs: list[tuple[int, float]] = []  # (decode_step, err)
        # speculative decoding (repro.spec): per-round draft/accept counts.
        # spec_slot_rounds counts (round, active slot) pairs — each is one
        # expensive-mode verify execution for that slot, the numerator of
        # verify_steps_per_token.
        self.spec_rounds = 0
        self.spec_slot_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.draft_shift_timeline: list[tuple[int, int]] = []  # (round, shift)
        # paged KV cache (repro.serve.paged): peak concurrent in-flight
        # rows, per-step pool stats, page-pressure evictions, tier events
        self.peak_active = 0
        self.page_stats_last: dict | None = None
        self.page_occupancy_peak = 0.0
        self.page_sharing_peak = 0.0
        self.page_evictions = 0
        self.tier_events: list[tuple[int, dict]] = []  # (decode_step, stats)
        self._t_first_event: float | None = None
        self._t_last_event: float | None = None
        snap = plan_cache_stats()
        self._plan_snap = (snap.hits, snap.misses)

    # -- hooks (called by ServeEngine) --------------------------------------

    def _mark(self) -> float:
        t = self.clock()
        if self._t_last_event is not None and t < self._t_last_event:
            # perf_counter is monotonic, but an injected clock (tests) or a
            # platform regression must never mint negative latencies /
            # TTFTs — clamp every stamp to the last one seen
            t = self._t_last_event
        if self._t_first_event is None:
            self._t_first_event = t
        self._t_last_event = t
        return t

    def set_tenant_shares(self, shares: dict[str, float]) -> None:
        """Configured entitlement weights (Tenant.share) for the fairness
        report — set once by the engine at construction."""
        self.tenant_shares = dict(shares)

    def on_submit(self, rid: int, *, tenant: str = "default",
                  rclass: str = "default", slo_steps: int | None = None,
                  slo_ms: float | None = None,
                  step: int | None = None) -> None:
        self.requests[rid] = RequestTimes(
            submit=self._mark(), tenant=tenant, rclass=rclass,
            slo_steps=slo_steps, slo_ms=slo_ms, submit_step=step)

    def on_first_token(self, rid: int) -> None:
        """First token of a request's life.  Repeats are ignored: a
        preempted-then-resumed request already produced its first token, so
        its TTFT must keep the original timestamp."""
        if self.requests[rid].first_token is not None:
            return
        self.prefills += 1
        self.requests[rid].first_token = self._mark()

    def on_token(self, rid: int) -> None:
        self.tokens_out += 1
        self.requests[rid].n_tokens += 1

    def on_preempt(self, rid: int) -> None:
        """One park/requeue of a running request (engine preemption path)."""
        self.preemptions += 1
        self.requests[rid].preemptions += 1

    def on_decode_step(self, n_active: int, mode: str | None = None,
                       tenant_active: dict[str, int] | None = None) -> None:
        self.decode_steps += 1
        self.active_slot_steps += n_active
        self.peak_active = max(self.peak_active, n_active)
        if tenant_active:
            for name, n in tenant_active.items():
                self.tenant_slot_steps[name] = (
                    self.tenant_slot_steps.get(name, 0) + n)
        if mode is not None:
            self.mode_steps[mode] = self.mode_steps.get(mode, 0) + 1
            if not self.mode_timeline or self.mode_timeline[-1][1] != mode:
                self.mode_timeline.append((self.decode_steps, mode))
        self._mark()

    def on_mode_switch(self) -> None:
        """One applied mode-table change (repro.adapt controller decision).
        The timeline itself is recorded by ``on_decode_step`` — this only
        counts reconfigurations."""
        self.mode_switches += 1

    def on_probe(self, err: float) -> None:
        self.probe_errs.append((self.decode_steps, float(err)))

    def on_spec_round(self, n_active: int, *, drafted: int, accepted: int,
                      emitted: int) -> None:
        """One speculative round (repro.spec): ``drafted`` cheap-mode draft
        tokens proposed across the active slots, ``accepted`` of them kept
        by verify, ``emitted`` tokens actually produced (accepted prefixes
        plus correction tokens, clamped to each slot's budget)."""
        self.spec_rounds += 1
        self.spec_slot_rounds += n_active
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_emitted += emitted

    def on_draft_shift(self, round_idx: int, shift: int) -> None:
        """One applied acceptance-controller move of the draft-mode shift."""
        self.draft_shift_timeline.append((round_idx, shift))

    def on_page_stats(self, stats: dict) -> None:
        """Per-step paged-pool snapshot (occupancy, sharing, tier mix) —
        the last snapshot and the occupancy peak are kept."""
        self.page_stats_last = stats
        self.page_occupancy_peak = max(self.page_occupancy_peak,
                                       stats.get("occupancy", 0.0))
        self.page_sharing_peak = max(self.page_sharing_peak,
                                     stats.get("sharing_ratio", 0.0))

    def on_page_evict(self) -> None:
        """One page-pressure eviction: the pool could not grow an active
        row, so the scheduler's victim parked (on top of the on_preempt the
        engine's park path already records)."""
        self.page_evictions += 1

    def on_page_tier(self, step: int, stats: dict) -> None:
        """One applied tier tick (demotions/promotions + measured
        residuals, repro.adapt.pages)."""
        self.tier_events.append((step, stats))

    def on_done(self, rid: int, step: int | None = None) -> None:
        r = self.requests[rid]
        r.done = self._mark()
        r.done_step = step

    # -- derived -------------------------------------------------------------

    def ttft(self, rid: int) -> float | None:
        """Time to first token, or None when the rid is unknown or has no
        first token yet (never raises — callers poll mid-flight rids)."""
        r = self.requests.get(rid)
        if r is None or r.first_token is None:
            return None
        return r.first_token - r.submit

    def latency(self, rid: int) -> float | None:
        """Submit-to-done latency, or None when the rid is unknown or not
        done yet (never raises — callers poll mid-flight rids)."""
        r = self.requests.get(rid)
        if r is None or r.done is None:
            return None
        return r.done - r.submit

    @property
    def acceptance_rate(self) -> float | None:
        """Fraction of drafted tokens the verify chain accepted."""
        if not self.spec_drafted:
            return None
        return self.spec_accepted / self.spec_drafted

    @property
    def verify_steps_per_token(self) -> float | None:
        """Expensive-mode verify *dispatches* per emitted decode token —
        (round, active slot) pairs over tokens emitted by rounds.  This is
        the sequential-latency unit of decode (the baseline engine pays
        exactly 1.0 per token by construction; any acceptance pushes it
        below 1), NOT a FLOP count: the verify chain still computes every
        position, it just does so inside one dispatch per round.  The
        FLOP-level saving comes separately from the draft substeps running
        the cheap limb modes (DESIGN.md section Speculative decoding)."""
        if not self.spec_emitted:
            return None
        return self.spec_slot_rounds / self.spec_emitted

    @property
    def occupancy(self) -> float:
        if not self.decode_steps:
            return 0.0
        return self.active_slot_steps / (self.decode_steps * self.slots)

    @property
    def mode_occupancy(self) -> dict[str, float]:
        """Fraction of decode steps spent under each mode label — the
        serving-level view of how often the reconfigurable multiplier
        actually ran in each configuration."""
        total = sum(self.mode_steps.values())
        if not total:
            return {}
        return {m: n / total for m, n in sorted(self.mode_steps.items())}

    def tenant_summary(self) -> dict[str, dict]:
        """Per-tenant fairness / SLO view.  Tenants appear when they were
        declared with a share or submitted at least one request; a tenant
        with zero completed requests reports None percentiles and, if it
        submitted deadline-carrying requests, an attainment of 0.0 (a
        missed deadline is a miss, not a gap in the data).

        ``attainment``: over this tenant's requests whose class carries
        ``slo_steps``, the fraction completed within that many engine steps
        of submission (None when the tenant has no deadline-carrying
        requests).  ``slot_share``: measured fraction of decode (step x
        active slot) pairs; ``entitlement``: the tenant's configured share
        weight renormalized over tenants that submitted anything."""
        names = sorted(set(self.tenant_shares)
                       | {r.tenant for r in self.requests.values()})
        submitted_names = {r.tenant for r in self.requests.values()}
        ent_total = sum(self.tenant_shares.get(n, 1.0)
                        for n in submitted_names) or 1.0
        total_slot_steps = sum(self.tenant_slot_steps.values())
        out: dict[str, dict] = {}
        for name in names:
            rs = [r for r in self.requests.values() if r.tenant == name]
            lats = [r.done - r.submit for r in rs if r.done is not None]
            ttfts = [r.first_token - r.submit for r in rs
                     if r.first_token is not None]
            with_slo = [r for r in rs if r.slo_steps is not None]
            met = sum(
                1 for r in with_slo
                if r.done_step is not None and r.submit_step is not None
                and r.done_step - r.submit_step <= r.slo_steps)
            ms_targets = [r for r in rs if r.slo_ms is not None]
            ms_met = sum(1 for r in ms_targets if r.done is not None
                         and (r.done - r.submit) * 1e3 <= r.slo_ms)
            out[name] = {
                "submitted": len(rs),
                "completed": sum(1 for r in rs if r.done is not None),
                "tokens": sum(r.n_tokens for r in rs),
                "preemptions": sum(r.preemptions for r in rs),
                "classes": sorted({r.rclass for r in rs}),
                "attainment": (met / len(with_slo)) if with_slo else None,
                "attainment_ms": (ms_met / len(ms_targets)
                                  if ms_targets else None),
                "latency_p50_s": percentile(lats, 50),
                "latency_p99_s": percentile(lats, 99),
                "ttft_p50_s": percentile(ttfts, 50),
                "slot_share": (
                    self.tenant_slot_steps.get(name, 0) / total_slot_steps
                    if total_slot_steps else 0.0),
                "entitlement": (
                    self.tenant_shares.get(name, 1.0) / ent_total
                    if name in submitted_names else 0.0),
            }
        return out

    def plan_cache_delta(self) -> dict:
        snap = plan_cache_stats()
        return {
            "hits": snap.hits - self._plan_snap[0],
            "misses": snap.misses - self._plan_snap[1],
            "entries": snap.entries,
        }

    def summary(self) -> dict:
        ttfts = [self.ttft(r) for r in self.requests if self.ttft(r) is not None]
        lats = [self.latency(r) for r in self.requests if self.latency(r) is not None]
        span = (
            (self._t_last_event - self._t_first_event)
            if self._t_first_event is not None and self._t_last_event is not None
            else 0.0
        )
        return {
            "requests": len(self.requests),
            "completed": len(lats),
            "tokens_out": self.tokens_out,
            "tok_s": self.tokens_out / span if span > 0 else 0.0,
            "ttft_mean_s": sum(ttfts) / len(ttfts) if ttfts else None,
            "latency_mean_s": sum(lats) / len(lats) if lats else None,
            "decode_steps": self.decode_steps,
            "occupancy": self.occupancy,
            "preemptions": self.preemptions,
            "tenants": self.tenant_summary(),
            "mode_switches": self.mode_switches,
            "mode_occupancy": self.mode_occupancy,
            "probe_err_max": (max(e for _, e in self.probe_errs)
                              if self.probe_errs else None),
            "probe_err_mean": (sum(e for _, e in self.probe_errs)
                               / len(self.probe_errs)
                               if self.probe_errs else None),
            "spec_rounds": self.spec_rounds,
            "spec_drafted": self.spec_drafted,
            "spec_accepted": self.spec_accepted,
            "spec_rejected": self.spec_drafted - self.spec_accepted,
            "spec_emitted": self.spec_emitted,
            "acceptance_rate": self.acceptance_rate,
            "verify_steps_per_token": self.verify_steps_per_token,
            "draft_shift_moves": len(self.draft_shift_timeline),
            "peak_active": self.peak_active,
            "pages": self._pages_summary(),
            "plan_cache": self.plan_cache_delta(),
        }

    def _pages_summary(self) -> dict | None:
        if self.page_stats_last is None:
            return None
        s = dict(self.page_stats_last)
        s["occupancy_peak"] = self.page_occupancy_peak
        s["sharing_peak"] = self.page_sharing_peak
        s["page_evictions"] = self.page_evictions
        s["tier_ticks"] = len(self.tier_events)
        s["tier_demoted"] = sum(t.get("demoted", 0)
                                for _, t in self.tier_events)
        s["tier_promoted"] = sum(t.get("promoted", 0)
                                 for _, t in self.tier_events)
        s["tier_err_max"] = (max(t.get("err", 0.0)
                                 for _, t in self.tier_events)
                             if self.tier_events else None)
        return s

    def format_summary(self) -> str:
        s = self.summary()
        ttft = f"{s['ttft_mean_s']*1e3:.1f}ms" if s["ttft_mean_s"] is not None else "-"
        lat = f"{s['latency_mean_s']*1e3:.1f}ms" if s["latency_mean_s"] is not None else "-"
        pc = s["plan_cache"]
        out = (
            f"{s['tokens_out']} tokens from {s['completed']}/{s['requests']} "
            f"requests | {s['tok_s']:.1f} tok/s | ttft {ttft} | latency {lat} "
            f"| occupancy {s['occupancy']:.2f} over {s['decode_steps']} steps "
            f"| plan cache +{pc['misses']} plans / {pc['hits']} hits"
        )
        if s["preemptions"]:
            out += f" | {s['preemptions']} preemptions"
        if s["mode_occupancy"]:
            occ = " ".join(f"{m}:{f:.2f}" for m, f in s["mode_occupancy"].items())
            out += f" | modes {occ} ({s['mode_switches']} switches)"
        if s["probe_err_max"] is not None:
            out += (f" | probe err mean {s['probe_err_mean']:.2e} "
                    f"max {s['probe_err_max']:.2e}")
        if s["spec_rounds"]:
            out += (f" | spec {s['spec_rounds']} rounds, acceptance "
                    f"{s['acceptance_rate']:.2f}, verify-steps/token "
                    f"{s['verify_steps_per_token']:.2f}"
                    f" ({s['draft_shift_moves']} draft-shift moves)")
        if s["pages"] is not None:
            p = s["pages"]
            out += (f" | pages {p['pages_used']}/{p['pages_total']} "
                    f"(peak occ {p['occupancy_peak']:.2f}, "
                    f"sharing {p['sharing_ratio']:.2f}, "
                    f"{p['page_evictions']} evictions)")
            if p["tier_ticks"]:
                err = (f"{p['tier_err_max']:.2e}"
                       if p["tier_err_max"] is not None else "-")
                out += (f" | tiers {p['tier_demoted']} demoted / "
                        f"{p['tier_promoted']} promoted, err max {err}")
        return out

    def format_tenants(self) -> str:
        """One line per tenant: the fairness / attainment report."""
        rows = []
        for name, t in self.tenant_summary().items():
            att = (f"{t['attainment']:.0%}" if t["attainment"] is not None
                   else "-")
            p50 = (f"{t['latency_p50_s']*1e3:.0f}ms"
                   if t["latency_p50_s"] is not None else "-")
            p99 = (f"{t['latency_p99_s']*1e3:.0f}ms"
                   if t["latency_p99_s"] is not None else "-")
            rows.append(
                f"tenant {name}: {t['completed']}/{t['submitted']} done "
                f"({','.join(t['classes']) or '-'}) | attainment {att} "
                f"| p50 {p50} p99 {p99} | share {t['slot_share']:.2f} "
                f"(entitled {t['entitlement']:.2f}) "
                f"| {t['preemptions']} preemptions"
            )
        return "\n".join(rows)
