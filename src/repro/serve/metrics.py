"""Serving metrics: throughput, TTFT, per-request latency, slot occupancy,
plan-cache hits.

``ServeMetrics`` is pure bookkeeping — the engine calls the ``on_*`` hooks
and ``summary()`` folds them into one dict.  Slot occupancy is measured over
*decode steps only* (prefill is per-request work, not slot-array work):
``occupancy = sum(active slots per step) / (decode steps * slots)`` — the
fraction of the compiled step's rows doing useful work, the number that says
whether continuous batching is actually keeping the array full.

Plan-cache numbers are deltas against the engine-construction snapshot, so
they count only the planning this engine triggered (``repro.plan``
caches globally).
"""
from __future__ import annotations

import dataclasses
import time

from repro.plan import plan_cache_stats


@dataclasses.dataclass
class RequestTimes:
    submit: float
    first_token: float | None = None
    done: float | None = None
    n_tokens: int = 0


class ServeMetrics:
    def __init__(self, slots: int, clock=time.perf_counter):
        self.slots = slots
        self.clock = clock
        self.requests: dict[int, RequestTimes] = {}
        self.tokens_out = 0
        self.prefills = 0
        self.decode_steps = 0
        self.active_slot_steps = 0  # sum over decode steps of active slots
        # runtime-adaptation observability (repro.adapt): how many decode
        # steps ran under each mode label, every mode switch, every probe
        self.mode_steps: dict[str, int] = {}
        self.mode_switches = 0
        self.mode_timeline: list[tuple[int, str]] = []  # (decode_step, label)
        self.probe_errs: list[tuple[int, float]] = []  # (decode_step, err)
        # speculative decoding (repro.spec): per-round draft/accept counts.
        # spec_slot_rounds counts (round, active slot) pairs — each is one
        # expensive-mode verify execution for that slot, the numerator of
        # verify_steps_per_token.
        self.spec_rounds = 0
        self.spec_slot_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.draft_shift_timeline: list[tuple[int, int]] = []  # (round, shift)
        self._t_first_event: float | None = None
        self._t_last_event: float | None = None
        snap = plan_cache_stats()
        self._plan_snap = (snap.hits, snap.misses)

    # -- hooks (called by ServeEngine) --------------------------------------

    def _mark(self) -> float:
        t = self.clock()
        if self._t_first_event is None:
            self._t_first_event = t
        self._t_last_event = t
        return t

    def on_submit(self, rid: int) -> None:
        self.requests[rid] = RequestTimes(submit=self._mark())

    def on_first_token(self, rid: int) -> None:
        self.prefills += 1
        self.requests[rid].first_token = self._mark()

    def on_token(self, rid: int) -> None:
        self.tokens_out += 1
        self.requests[rid].n_tokens += 1

    def on_decode_step(self, n_active: int, mode: str | None = None) -> None:
        self.decode_steps += 1
        self.active_slot_steps += n_active
        if mode is not None:
            self.mode_steps[mode] = self.mode_steps.get(mode, 0) + 1
            if not self.mode_timeline or self.mode_timeline[-1][1] != mode:
                self.mode_timeline.append((self.decode_steps, mode))
        self._mark()

    def on_mode_switch(self) -> None:
        """One applied mode-table change (repro.adapt controller decision).
        The timeline itself is recorded by ``on_decode_step`` — this only
        counts reconfigurations."""
        self.mode_switches += 1

    def on_probe(self, err: float) -> None:
        self.probe_errs.append((self.decode_steps, float(err)))

    def on_spec_round(self, n_active: int, *, drafted: int, accepted: int,
                      emitted: int) -> None:
        """One speculative round (repro.spec): ``drafted`` cheap-mode draft
        tokens proposed across the active slots, ``accepted`` of them kept
        by verify, ``emitted`` tokens actually produced (accepted prefixes
        plus correction tokens, clamped to each slot's budget)."""
        self.spec_rounds += 1
        self.spec_slot_rounds += n_active
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_emitted += emitted

    def on_draft_shift(self, round_idx: int, shift: int) -> None:
        """One applied acceptance-controller move of the draft-mode shift."""
        self.draft_shift_timeline.append((round_idx, shift))

    def on_done(self, rid: int) -> None:
        self.requests[rid].done = self._mark()

    # -- derived -------------------------------------------------------------

    def ttft(self, rid: int) -> float | None:
        """Time to first token, or None when the rid is unknown or has no
        first token yet (never raises — callers poll mid-flight rids)."""
        r = self.requests.get(rid)
        if r is None or r.first_token is None:
            return None
        return r.first_token - r.submit

    def latency(self, rid: int) -> float | None:
        """Submit-to-done latency, or None when the rid is unknown or not
        done yet (never raises — callers poll mid-flight rids)."""
        r = self.requests.get(rid)
        if r is None or r.done is None:
            return None
        return r.done - r.submit

    @property
    def acceptance_rate(self) -> float | None:
        """Fraction of drafted tokens the verify chain accepted."""
        if not self.spec_drafted:
            return None
        return self.spec_accepted / self.spec_drafted

    @property
    def verify_steps_per_token(self) -> float | None:
        """Expensive-mode verify *dispatches* per emitted decode token —
        (round, active slot) pairs over tokens emitted by rounds.  This is
        the sequential-latency unit of decode (the baseline engine pays
        exactly 1.0 per token by construction; any acceptance pushes it
        below 1), NOT a FLOP count: the verify chain still computes every
        position, it just does so inside one dispatch per round.  The
        FLOP-level saving comes separately from the draft substeps running
        the cheap limb modes (DESIGN.md section Speculative decoding)."""
        if not self.spec_emitted:
            return None
        return self.spec_slot_rounds / self.spec_emitted

    @property
    def occupancy(self) -> float:
        if not self.decode_steps:
            return 0.0
        return self.active_slot_steps / (self.decode_steps * self.slots)

    @property
    def mode_occupancy(self) -> dict[str, float]:
        """Fraction of decode steps spent under each mode label — the
        serving-level view of how often the reconfigurable multiplier
        actually ran in each configuration."""
        total = sum(self.mode_steps.values())
        if not total:
            return {}
        return {m: n / total for m, n in sorted(self.mode_steps.items())}

    def plan_cache_delta(self) -> dict:
        snap = plan_cache_stats()
        return {
            "hits": snap.hits - self._plan_snap[0],
            "misses": snap.misses - self._plan_snap[1],
            "entries": snap.entries,
        }

    def summary(self) -> dict:
        ttfts = [self.ttft(r) for r in self.requests if self.ttft(r) is not None]
        lats = [self.latency(r) for r in self.requests if self.latency(r) is not None]
        span = (
            (self._t_last_event - self._t_first_event)
            if self._t_first_event is not None and self._t_last_event is not None
            else 0.0
        )
        return {
            "requests": len(self.requests),
            "completed": len(lats),
            "tokens_out": self.tokens_out,
            "tok_s": self.tokens_out / span if span > 0 else 0.0,
            "ttft_mean_s": sum(ttfts) / len(ttfts) if ttfts else None,
            "latency_mean_s": sum(lats) / len(lats) if lats else None,
            "decode_steps": self.decode_steps,
            "occupancy": self.occupancy,
            "mode_switches": self.mode_switches,
            "mode_occupancy": self.mode_occupancy,
            "probe_err_max": (max(e for _, e in self.probe_errs)
                              if self.probe_errs else None),
            "probe_err_mean": (sum(e for _, e in self.probe_errs)
                               / len(self.probe_errs)
                               if self.probe_errs else None),
            "spec_rounds": self.spec_rounds,
            "spec_drafted": self.spec_drafted,
            "spec_accepted": self.spec_accepted,
            "spec_rejected": self.spec_drafted - self.spec_accepted,
            "spec_emitted": self.spec_emitted,
            "acceptance_rate": self.acceptance_rate,
            "verify_steps_per_token": self.verify_steps_per_token,
            "draft_shift_moves": len(self.draft_shift_timeline),
            "plan_cache": self.plan_cache_delta(),
        }

    def format_summary(self) -> str:
        s = self.summary()
        ttft = f"{s['ttft_mean_s']*1e3:.1f}ms" if s["ttft_mean_s"] is not None else "-"
        lat = f"{s['latency_mean_s']*1e3:.1f}ms" if s["latency_mean_s"] is not None else "-"
        pc = s["plan_cache"]
        out = (
            f"{s['tokens_out']} tokens from {s['completed']}/{s['requests']} "
            f"requests | {s['tok_s']:.1f} tok/s | ttft {ttft} | latency {lat} "
            f"| occupancy {s['occupancy']:.2f} over {s['decode_steps']} steps "
            f"| plan cache +{pc['misses']} plans / {pc['hits']} hits"
        )
        if s["mode_occupancy"]:
            occ = " ".join(f"{m}:{f:.2f}" for m, f in s["mode_occupancy"].items())
            out += f" | modes {occ} ({s['mode_switches']} switches)"
        if s["probe_err_max"] is not None:
            out += (f" | probe err mean {s['probe_err_mean']:.2e} "
                    f"max {s['probe_err_max']:.2e}")
        if s["spec_rounds"]:
            out += (f" | spec {s['spec_rounds']} rounds, acceptance "
                    f"{s['acceptance_rate']:.2f}, verify-steps/token "
                    f"{s['verify_steps_per_token']:.2f}"
                    f" ({s['draft_shift_moves']} draft-shift moves)")
        return out
