"""Trace exporters: Chrome-trace/Perfetto JSON and Prometheus text.

The Chrome document uses only self-balancing phases — ``"X"`` (complete
spans with explicit ``dur``), ``"i"`` (instants), ``"C"`` (counters) and
``"M"`` (metadata) — so a truncated ring can never produce unbalanced
begin/end pairs.  Layout: pid 1 is the engine (dispatch spans and decision
instants on tid 0), pid 2 holds one thread per request (tid = rid) whose
spans are the request lifecycle reconstructed from submit/admit/preempt/
resume/done events.  Timestamps are microseconds relative to the first
event.

``validate_chrome`` is the schema + well-formedness gate CI runs on
exported traces; ``span_violations`` checks the *semantic* lifecycle
ordering on the raw event stream (the replay harness in
tests/scheduler_model.py checks the scheduler invariants proper).
"""
from __future__ import annotations

import json

from repro.obs.tracer import Event

# request-lifecycle phases, in legal transition order
_QUEUED, _RUNNING, _PREEMPTED = "queued", "running", "preempted"

#: engine events rendered as instants on the engine track; values are the
#: Chrome ``s`` scope ("t" thread-scoped, "p" process-scoped)
_INSTANT_KINDS = {
    "mode_switch": "p",
    "draft_shift": "p",
    "tier_tick": "t",
    "adapt_decision": "t",
    "preempt_plan": "t",
    "admit_defer": "t",
    "admit_refuse": "t",
    "page_evict": "t",
    "cow_fork": "t",
    "prefix_share": "t",
    "recompile": "p",
    "spec_round": "t",
}

#: engine events with a ``dur_ms`` payload rendered as complete spans
_SPAN_KINDS = ("decode_step", "prefill")


def _args(e: Event) -> dict:
    args = {"step": e.step}
    if e.cause is not None:
        args["cause"] = e.cause
    if e.slot is not None:
        args["slot"] = e.slot
    if e.data:
        args.update(e.data)
    return args


def to_chrome(events: list[Event], counters: dict | None = None,
              gauges: dict | None = None) -> dict:
    """Build a Chrome-trace document from a recorded event list."""
    out: list[dict] = [
        {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
         "args": {"name": "engine"}},
        {"ph": "M", "pid": 2, "tid": 0, "name": "process_name",
         "args": {"name": "requests"}},
    ]
    if not events:
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    t0 = min(e.ts for e in events)

    def us(ts: float) -> float:
        return (ts - t0) * 1e6

    # -- engine track: dispatch spans, instants, counters --------------------
    for e in events:
        if e.kind in _SPAN_KINDS:
            dur_us = float((e.data or {}).get("dur_ms", 0.0)) * 1e3
            out.append({
                "ph": "X", "pid": 1, "tid": 0, "name": e.kind, "cat": "engine",
                "ts": max(0.0, us(e.ts) - dur_us), "dur": dur_us,
                "args": _args(e)})
            if e.kind == "decode_step" and "n_active" in (e.data or {}):
                out.append({
                    "ph": "C", "pid": 1, "tid": 0, "name": "active_slots",
                    "ts": us(e.ts),
                    "args": {"active": e.data["n_active"]}})
        elif e.kind in _INSTANT_KINDS:
            out.append({
                "ph": "i", "pid": 1, "tid": 0, "name": e.kind, "cat": "engine",
                "ts": us(e.ts), "s": _INSTANT_KINDS[e.kind],
                "args": _args(e)})

    # -- request tracks: lifecycle spans ------------------------------------
    # open[rid] = (phase_name, start_ts); transitions close the open span
    open_: dict[int, tuple[str, float]] = {}
    named: set[int] = set()
    end_ts = max(e.ts for e in events)

    def close(rid: int, ts: float) -> None:
        phase, start = open_.pop(rid)
        out.append({
            "ph": "X", "pid": 2, "tid": rid, "name": phase, "cat": "request",
            "ts": us(start), "dur": max(0.0, us(ts) - us(start)),
            "args": {"rid": rid}})

    for e in events:
        if e.rid is None or e.kind not in (
                "submit", "admit", "resume", "preempt", "done"):
            continue
        rid = e.rid
        if rid not in named:
            named.add(rid)
            out.append({"ph": "M", "pid": 2, "tid": rid, "name": "thread_name",
                        "args": {"name": f"request {rid}"}})
        if rid in open_:
            close(rid, e.ts)
        if e.kind == "submit":
            open_[rid] = (_QUEUED, e.ts)
        elif e.kind in ("admit", "resume"):
            open_[rid] = (_RUNNING, e.ts)
        elif e.kind == "preempt":
            open_[rid] = (_PREEMPTED, e.ts)
        # "done" closes without reopening
    for rid in sorted(open_):  # requests still in flight at ring end
        close(rid, end_ts)

    # -- final registry values as a trailing counter sample ------------------
    for name, value in sorted((counters or {}).items()):
        out.append({"ph": "C", "pid": 1, "tid": 0, "name": name,
                    "ts": us(end_ts), "args": {"value": value}})
    for name, value in sorted((gauges or {}).items()):
        out.append({"ph": "C", "pid": 1, "tid": 0, "name": name,
                    "ts": us(end_ts), "args": {"value": value}})

    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome(path: str, events: list[Event], counters: dict | None = None,
                 gauges: dict | None = None) -> dict:
    doc = to_chrome(events, counters, gauges)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


_REQUIRED = {"ph", "pid", "tid", "name"}
_KNOWN_PH = {"X", "i", "C", "M"}
#: nesting tolerance in µs — adjacent spans produced from one float clock
#: can land ~1e-9 µs apart after the relative-µs conversion
_EPS_US = 1e-3


def validate_chrome(doc: dict) -> list[str]:
    """Schema + span-tree well-formedness.  Returns violation strings
    (empty = valid): every event carries the required keys, only
    self-balancing phases appear, X durations are non-negative, and on each
    (pid, tid) track the X spans form a proper tree (nested or disjoint,
    never partially overlapping)."""
    problems: list[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    tracks: dict[tuple, list[tuple[float, float, str]]] = {}
    for i, e in enumerate(evs):
        if not isinstance(e, dict):
            problems.append(f"event {i}: not an object")
            continue
        missing = _REQUIRED - e.keys()
        if missing:
            problems.append(f"event {i}: missing keys {sorted(missing)}")
            continue
        ph = e["ph"]
        if ph not in _KNOWN_PH:
            problems.append(f"event {i}: unexpected ph {ph!r}")
            continue
        if ph != "M" and "ts" not in e:
            problems.append(f"event {i}: {ph}-event without ts")
            continue
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: X-event bad dur {dur!r}")
                continue
            tracks.setdefault((e["pid"], e["tid"]), []).append(
                (float(e["ts"]), float(dur), e["name"]))
        elif ph == "i" and e.get("s") not in ("t", "p", "g"):
            problems.append(f"event {i}: instant with bad scope {e.get('s')!r}")
    for key, spans in tracks.items():
        spans.sort()
        stack: list[tuple[float, float, str]] = []
        for ts, dur, name in spans:
            while stack and ts >= stack[-1][0] + stack[-1][1] - _EPS_US:
                stack.pop()
            if stack:
                p_ts, p_dur, p_name = stack[-1]
                if ts + dur > p_ts + p_dur + _EPS_US:
                    problems.append(
                        f"track {key}: span {name!r} [{ts}, {ts + dur}] "
                        f"partially overlaps {p_name!r} "
                        f"[{p_ts}, {p_ts + p_dur}]")
            stack.append((ts, dur, name))
    return problems


#: legal predecessor states per lifecycle event; None = not yet seen
_LIFECYCLE = {
    "submit": (None,),
    "admit": (_QUEUED,),
    "resume": (_PREEMPTED,),
    "preempt": (_RUNNING,),
    "done": (_QUEUED, _RUNNING),  # zero-budget requests finish from queued
}
_NEXT_STATE = {"submit": _QUEUED, "admit": _RUNNING, "resume": _RUNNING,
               "preempt": _PREEMPTED, "done": "done"}


def span_violations(events: list[Event]) -> list[str]:
    """Per-request lifecycle-order check on the raw stream: submit before
    admit, resume only after preempt, exactly one done, nothing after it."""
    problems: list[str] = []
    state: dict[int, str | None] = {}
    for e in events:
        if e.kind not in _LIFECYCLE or e.rid is None:
            continue
        prev = state.get(e.rid)
        if prev == "done":
            problems.append(f"rid {e.rid}: {e.kind} after done (step {e.step})")
        elif prev not in _LIFECYCLE[e.kind]:
            problems.append(
                f"rid {e.rid}: {e.kind} from state {prev!r} (step {e.step})")
        state[e.rid] = _NEXT_STATE[e.kind]
    return problems


def to_prometheus(counters: dict, gauges: dict) -> str:
    """Prometheus text exposition of the registry, names prefixed
    ``repro_obs_`` and sanitized to the metric charset."""
    def clean(name: str) -> str:
        return "repro_obs_" + "".join(
            c if c.isalnum() or c == "_" else "_" for c in name)

    lines: list[str] = []
    for name, value in sorted(counters.items()):
        m = clean(name)
        lines += [f"# TYPE {m} counter", f"{m} {value:g}"]
    for name, value in sorted(gauges.items()):
        m = clean(name)
        lines += [f"# TYPE {m} gauge", f"{m} {value:g}"]
    return "\n".join(lines) + ("\n" if lines else "")
