"""Profiling hooks around the compiled serving phases.

``PhaseProfiler`` accumulates wall time and token counts per phase
(prefill, decode, spec) from timings the engine already takes, and detects
recompiles by watching jit cache-size deltas (the same
``_cache_size()``-based counters ``ServeEngine.decode_compile_count``
exposes).  It feeds the tracer's counter registry so the Prometheus
exposition and Chrome counters carry the same numbers."""
from __future__ import annotations

import dataclasses

from repro.obs.tracer import NULL_TRACER


@dataclasses.dataclass
class PhaseStats:
    calls: int = 0
    wall_s: float = 0.0
    tokens: int = 0

    @property
    def tok_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s > 0 else 0.0


class PhaseProfiler:
    """Per-phase wall/token accounting + recompile detection."""

    def __init__(self, tracer=NULL_TRACER):
        self.tracer = tracer
        self.phases: dict[str, PhaseStats] = {}
        self._cache_sizes: dict[str, int] = {}
        self.recompiles = 0

    def record(self, phase: str, dur_s: float, tokens: int = 0) -> None:
        st = self.phases.setdefault(phase, PhaseStats())
        st.calls += 1
        st.wall_s += dur_s
        st.tokens += tokens
        if self.tracer.enabled:
            self.tracer.inc(f"{phase}_calls")
            self.tracer.inc(f"{phase}_wall_ms", dur_s * 1e3)
            if tokens:
                self.tracer.inc(f"{phase}_tokens", tokens)

    def observe_cache(self, name: str, size: int | None) -> None:
        """Track a jit cache size; growth after the first sample is a
        recompile.  ``None`` (cache size unavailable on this jax) is a
        no-op."""
        if size is None:
            return
        prev = self._cache_sizes.get(name)
        self._cache_sizes[name] = size
        if prev is not None and size > prev:
            self.recompiles += size - prev
            if self.tracer.enabled:
                self.tracer.inc("recompiles", size - prev)
                self.tracer.emit("recompile", cause=name,
                                 sizes={"before": prev, "after": size})

    def snapshot(self) -> dict:
        return {
            "recompiles": self.recompiles,
            "phases": {
                name: {"calls": st.calls, "wall_s": st.wall_s,
                       "tokens": st.tokens, "tok_s": st.tok_s}
                for name, st in sorted(self.phases.items())},
        }

    def describe(self) -> str:
        parts = [
            f"{name}: {st.calls} calls {st.wall_s * 1e3:.1f}ms"
            + (f" {st.tokens} tok ({st.tok_s:.0f} tok/s)" if st.tokens else "")
            for name, st in sorted(self.phases.items())]
        parts.append(f"recompiles: {self.recompiles}")
        return " | ".join(parts)
