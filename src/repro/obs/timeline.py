"""Precision timeline: one aligned per-step view of every precision axis.

The serving stack reconfigures precision on three independent clocks —
the adapt controller shifts the mode table (``mode_switch``), the
speculation controller moves the draft shift (``draft_shift``), and the
page-tier controller moves the cold-page mantissa depth (``tier_tick``).
Each already keeps its own timeline; this module merges the trace events
into one step-indexed table with carry-forward semantics, so "what
precision was everything at when step 37 went slow?" is one row.
"""
from __future__ import annotations

from repro.obs.tracer import Event


def precision_timeline(events: list[Event]) -> list[dict]:
    """Rows ``{step, mode, sites, draft_shift, tier_keep, tier_depth}``,
    one per step at which any axis changed (values carry forward between
    rows).  ``mode``/``sites`` come from mode_switch events (decode_step
    events seed the initial mode label), draft_shift and tier_tick fill the
    other axes."""
    state = {"mode": None, "sites": None, "draft_shift": None,
             "tier_keep": None, "tier_depth": None}
    rows: list[dict] = []

    def push(step: int) -> None:
        if rows and rows[-1]["step"] == step:
            rows[-1].update({"step": step, **state})
        else:
            rows.append({"step": step, **state})

    for e in events:
        data = e.data or {}
        if e.kind == "decode_step":
            mode = data.get("mode")
            if mode is not None and state["mode"] is None:
                state["mode"] = mode
                push(e.step)
        elif e.kind == "mode_switch":
            if "mode" in data:
                state["mode"] = data["mode"]
            if "sites" in data:
                state["sites"] = data["sites"]
            push(e.step)
        elif e.kind == "draft_shift":
            state["draft_shift"] = data.get("shift")
            push(e.step)
        elif e.kind == "tier_tick":
            state["tier_keep"] = data.get("keep")
            state["tier_depth"] = data.get("depth")
            push(e.step)
    return rows


def _cell(value) -> str:
    if value is None:
        return "-"
    if isinstance(value, dict):
        return ",".join(f"{k}={v}" for k, v in sorted(value.items()))
    return str(value)


def format_timeline(rows: list[dict]) -> str:
    """Fixed-width table of the merged timeline (for --trace-out runs)."""
    if not rows:
        return "precision timeline: no reconfiguration events recorded"
    cols = ("step", "mode", "sites", "draft_shift", "tier_keep", "tier_depth")
    table = [[_cell(r.get(c)) for c in cols] for r in rows]
    widths = [max(len(c), *(len(row[i]) for row in table))
              for i, c in enumerate(cols)]
    lines = ["  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    lines += ["  ".join(v.ljust(w) for v, w in zip(row, widths))
              for row in table]
    return "\n".join(lines)
