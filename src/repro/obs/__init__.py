"""repro.obs — unified tracing, precision timelines, and profiling hooks.

Standalone by design: this package imports nothing from repro.serve /
repro.adapt / repro.spec, so every serving component can hold a tracer
without import cycles.  See DESIGN.md section "Observability"."""
from repro.obs.export import (
    span_violations,
    to_chrome,
    to_prometheus,
    validate_chrome,
    write_chrome,
)
from repro.obs.profile import PhaseProfiler, PhaseStats
from repro.obs.timeline import format_timeline, precision_timeline
from repro.obs.tracer import (
    NULL_TRACER,
    Event,
    NullTracer,
    TraceConfig,
    Tracer,
)

__all__ = [
    "NULL_TRACER",
    "Event",
    "NullTracer",
    "PhaseProfiler",
    "PhaseStats",
    "TraceConfig",
    "Tracer",
    "format_timeline",
    "precision_timeline",
    "span_violations",
    "to_chrome",
    "to_prometheus",
    "validate_chrome",
    "write_chrome",
]
