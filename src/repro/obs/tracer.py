"""Low-overhead structured tracing for the serving stack.

One :class:`Tracer` per engine records typed :class:`Event` records into a
bounded ring buffer (``collections.deque(maxlen=capacity)``) on a monotonic
clock, plus a flat counter/gauge registry.  Everything is host-side Python:
no event ever becomes a jit argument or a device value, so a traced engine
compiles and dispatches *exactly* what an untraced one does — the
zero-jit-visible-cost contract tests/test_obs.py pins (identical tokens and
identical compile counts with tracing on vs off).

When tracing is off the engine holds :data:`NULL_TRACER`, whose hooks are
no-ops and whose ``enabled`` flag lets hot paths skip even the argument
construction (``if tracer.enabled: tracer.emit(...)``).

Event taxonomy (DESIGN.md section Observability):

  lifecycle   submit, admit, resume, preempt, token, done — the per-request
              span skeleton; step stamps follow the scheduler clock and the
              stream replays through the tests/scheduler_model.py invariant
              harness (consumer mode);
  engine      decode_step, spec_round, prefill, recompile — per-dispatch
              wall time and token accounting (repro.obs.profile);
  decisions   adapt_decision, mode_switch, draft_shift, tier_tick,
              preempt_plan, admit_defer, admit_refuse, page_evict, cow_fork,
              prefix_share — every reconfiguration with its *cause*.

Exporters (Chrome trace, Prometheus text, the precision timeline) read the
ring after the run; see repro.obs.export / repro.obs.timeline.
"""
from __future__ import annotations

import collections
import dataclasses
import time


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Tracing knobs carried on ``ServeConfig.trace``.

    ``capacity``: ring-buffer size in events — old events drop first (the
    replay harness requires a lossless ring, so size it to the run).
    ``out``: Chrome-trace path ``launch/serve --trace-out`` writes at exit.
    """

    capacity: int = 1 << 16
    out: str | None = None

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")


@dataclasses.dataclass
class Event:
    """One typed trace record.  ``ts`` is the tracer's monotonic clock
    (seconds, ``time.perf_counter``); ``step`` is the scheduler clock the
    event belongs to; ``cause`` names *why* for decision events."""

    ts: float
    step: int
    kind: str
    rid: int | None = None
    slot: int | None = None
    cause: str | None = None
    data: dict | None = None


class Tracer:
    """Ring-buffered event recorder + counter/gauge registry."""

    enabled = True

    def __init__(self, config: TraceConfig | None = None,
                 clock=time.perf_counter):
        self.config = config or TraceConfig()
        self.clock = clock
        self.events: collections.deque[Event] = collections.deque(
            maxlen=self.config.capacity)
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        #: current scheduler step — the engine advances this once per
        #: ``step()`` so emit sites need not thread the clock through
        self.step = 0
        self.emitted = 0

    @property
    def dropped(self) -> int:
        """Events the ring has discarded (0 = lossless, replayable)."""
        return self.emitted - len(self.events)

    def emit(self, kind: str, *, rid: int | None = None,
             slot: int | None = None, cause: str | None = None,
             step: int | None = None, **data) -> None:
        self.emitted += 1
        self.events.append(Event(
            ts=self.clock(), step=self.step if step is None else int(step),
            kind=kind, rid=rid, slot=slot, cause=cause, data=data or None))

    def inc(self, name: str, value: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + value

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    # -- exporters (repro.obs.export / repro.obs.timeline) -------------------

    def chrome(self) -> dict:
        """The trace as a Chrome-trace/Perfetto ``traceEvents`` document."""
        from repro.obs.export import to_chrome

        return to_chrome(list(self.events), self.counters, self.gauges)

    def export_chrome(self, path: str) -> dict:
        """Write the Chrome-trace JSON to ``path``; returns the document."""
        from repro.obs.export import write_chrome

        return write_chrome(path, list(self.events), self.counters,
                            self.gauges)

    def prometheus(self) -> str:
        """Prometheus text exposition of the counter/gauge registry."""
        from repro.obs.export import to_prometheus

        return to_prometheus(self.counters, self.gauges)

    def precision_timeline(self) -> list[dict]:
        """Aligned per-step precision view (repro.obs.timeline)."""
        from repro.obs.timeline import precision_timeline

        return precision_timeline(list(self.events))

    def format_timeline(self) -> str:
        from repro.obs.timeline import format_timeline

        return format_timeline(self.precision_timeline())

    def describe(self) -> str:
        kinds: dict[str, int] = {}
        for e in self.events:
            kinds[e.kind] = kinds.get(e.kind, 0) + 1
        body = " ".join(f"{k}:{n}" for k, n in sorted(kinds.items()))
        return (f"{self.emitted} events ({self.dropped} dropped, "
                f"capacity {self.config.capacity}) | {body or '-'}")


class NullTracer:
    """The tracing-off sentinel: every hook is a no-op and ``enabled`` is
    False, so guarded emit sites cost one attribute read.  Exporters refuse
    loudly rather than returning an empty trace that looks like a run."""

    enabled = False
    events: tuple = ()
    counters: dict[str, float] = {}
    gauges: dict[str, float] = {}
    step = 0
    emitted = 0
    dropped = 0

    def emit(self, kind: str, **kw) -> None:
        pass

    def inc(self, name: str, value: float = 1.0) -> None:
        pass

    def set_gauge(self, name: str, value: float) -> None:
        pass

    def _off(self):
        raise RuntimeError(
            "tracing is off: construct the engine with "
            "ServeConfig(trace=TraceConfig(...)) to record events")

    def chrome(self) -> dict:
        self._off()

    def export_chrome(self, path: str) -> dict:
        self._off()

    def prometheus(self) -> str:
        self._off()

    def precision_timeline(self) -> list[dict]:
        self._off()

    def format_timeline(self) -> str:
        self._off()

    def describe(self) -> str:
        return "tracing off"


#: shared no-op tracer: the default for every instrumented component
NULL_TRACER = NullTracer()
