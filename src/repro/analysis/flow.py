"""Precision-flow checker: abstract interpretation over jaxprs.

The lattice value of a float variable is its *effective mantissa width* in
bits — ``float64`` 53, ``float32`` 24, ``float16`` 11, ``bfloat16`` 8.  A
``quantize_mantissa`` site lowers the value (keeps the storage dtype but
truncates mantissa content); a ``convert_element_type`` moves it between
storage widths.  Walking the traced jaxpr of a hot path, four contracts
from the paper's run-time-reconfigurable datapath are checked:

``FLOW-F64``
    No float64 value — invar, constvar, or equation output — may appear in
    a device path, except inside declared oracle sub-jaxprs.  Traces run
    under ``jax.experimental.enable_x64`` so a latent f64 cannot hide
    behind jax's silent default-config downcast.  Weak-typed scalars
    (plain Python floats awaiting promotion) are exempt.

``FLOW-WIDEN``
    Every ``convert_element_type`` that *widens* a float must be on an
    allowlisted accumulation edge (default: ``bfloat16 -> float32``, the
    limb-accumulation contract).  Anything else is a silent upcast that
    would mask the configured precision.

``FLOW-MODE``
    Mode-select arguments must reach the jaxpr as traced int32 scalars
    AND be consumed by at least one equation.  An unused mode invar means
    the Python body constant-folded the mode — the zero-recompile contract
    is broken (each mode would recompile).

``FLOW-NARROW``
    ``quantize_mantissa`` / limb-truncation sites (pjit equations whose
    name contains ``quantize_mantissa``) may only *narrow* the lattice
    value: output storage bits must not exceed the input's lattice bits.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.analysis.report import Violation

#: mantissa bits (incl. implicit leading 1) per float storage dtype
MANTISSA_BITS = {
    "float64": 53,
    "float32": 24,
    "float16": 11,
    "bfloat16": 8,
}

#: float widenings that are part of the datapath contract (limb products
#: accumulate in f32; everything else must justify itself per-path)
DEFAULT_WIDEN_ALLOW = (("bfloat16", "float32"),)


def _aval(var):
    return getattr(var, "aval", None)


def _dtype_name(aval) -> str | None:
    dt = getattr(aval, "dtype", None)
    return None if dt is None else str(dt)


def _float_bits(aval) -> int | None:
    """Storage mantissa bits if ``aval`` is a float, else None."""
    name = _dtype_name(aval)
    return None if name is None else MANTISSA_BITS.get(name)


def _is_weak(aval) -> bool:
    return bool(getattr(aval, "weak_type", False))


def _is_literal(var) -> bool:
    return hasattr(var, "val")


def analyze_flow(fn, *args, path: str,
                 mode_args: tuple[int, ...] = (),
                 widen_allow=DEFAULT_WIDEN_ALLOW,
                 oracles: tuple[str, ...] = (),
                 x64: bool = True,
                 **kwargs) -> list[Violation]:
    """Trace ``fn(*args, **kwargs)`` and run all four flow rules.

    ``mode_args`` are positional indices (into ``args``) of mode-select
    scalars; ``oracles`` are substrings of nested-jaxpr names whose bodies
    are declared f64-capable (reference oracles) and skipped; ``x64``
    traces under ``enable_x64`` so strong float64 cannot be masked.
    """
    def trace():
        return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)

    if x64:
        with jax.experimental.enable_x64():
            closed = trace()
    else:
        closed = trace()
    mode_offsets = _mode_offsets(args, mode_args)
    return flow_violations(closed, path, mode_offsets=mode_offsets,
                           widen_allow=widen_allow, oracles=oracles)


def _mode_offsets(args, mode_args: tuple[int, ...]):
    """Map positional arg indices to groups of flattened-invar offsets.

    One group per declared mode argument: a mode arg may be a single
    scalar or a pytree of per-site scalars (a ModeTable ``scalars()``
    dict).  Every leaf must be int32, but only the *argument* must be
    consumed (≥ 1 leaf read) — site scalars unused by an architecture are
    merely inert args, not constant-folded modes.
    """
    counts = [len(jax.tree_util.tree_leaves(a)) for a in args]
    starts = np.concatenate([[0], np.cumsum(counts)]).tolist()
    return tuple(
        (idx, tuple(range(starts[idx], starts[idx] + counts[idx])))
        for idx in mode_args)


def flow_violations(closed, path: str, *,
                    mode_offsets=(),  # ((arg_idx, (invar offsets...)), ...)
                    widen_allow=DEFAULT_WIDEN_ALLOW,
                    oracles: tuple[str, ...] = ()) -> list[Violation]:
    """All four flow rules over an already-traced ClosedJaxpr."""
    out: list[Violation] = []
    allow = {tuple(pair) for pair in widen_allow}
    jaxpr = closed.jaxpr
    seen_f64: set[str] = set()

    # lattice env: id(var) -> effective mantissa bits (floats only)
    env: dict[int, int] = {}

    def bits_of(var) -> int | None:
        aval = _aval(var)
        b = _float_bits(aval)
        if b is None:
            return None
        return env.get(id(var), b)

    def note_f64(aval, what: str) -> None:
        if _dtype_name(aval) == "float64" and not _is_weak(aval):
            if what not in seen_f64:
                seen_f64.add(what)
                out.append(Violation(
                    "FLOW-F64", path,
                    f"float64 on device path at {what} "
                    "(declare an oracle or narrow the source)"))

    def seed(var) -> None:
        aval = _aval(var)
        b = _float_bits(aval)
        if b is not None:
            env.setdefault(id(var), b)

    def walk(jpr, depth: int) -> None:
        for var in list(jpr.invars) + list(jpr.constvars):
            note_f64(_aval(var), f"depth{depth} invar {var}")
            seed(var)
        for eqn in jpr.eqns:
            prim = eqn.primitive.name
            if prim == "pallas_call":
                # kernel bodies run the predicated datapath; their refs
                # are not host-visible dtypes — audit outputs only
                for ov in eqn.outvars:
                    note_f64(_aval(ov), f"{prim} out {ov}")
                    seed(ov)
                continue
            name = str(eqn.params.get("name", "")) if eqn.params else ""
            if name and any(tag in name for tag in oracles):
                for ov in eqn.outvars:
                    seed(ov)
                continue  # declared f64 oracle: body exempt
            in_bits = [b for b in (bits_of(v) for v in eqn.invars)
                       if b is not None]
            if prim == "convert_element_type":
                src = _aval(eqn.invars[0])
                dst = _aval(eqn.outvars[0])
                sb, db = _float_bits(src), _float_bits(dst)
                if (sb is not None and db is not None and db > sb
                        and not _is_weak(src)
                        and (_dtype_name(src), _dtype_name(dst)) not in allow):
                    out.append(Violation(
                        "FLOW-WIDEN", path,
                        f"un-allowlisted float widening "
                        f"{_dtype_name(src)} -> {_dtype_name(dst)}"))
            if "quantize_mantissa" in name:
                for ov in eqn.outvars:
                    ob = _float_bits(_aval(ov))
                    if ob is None:
                        continue
                    src_bits = max(in_bits) if in_bits else ob
                    if ob > src_bits:
                        out.append(Violation(
                            "FLOW-NARROW", path,
                            f"quantize site '{name}' widens the lattice: "
                            f"{src_bits} -> {ob} mantissa bits"))
                    env[id(ov)] = min(ob, src_bits)
            for ov in eqn.outvars:
                note_f64(_aval(ov), f"{prim} out {ov}")
                seed(ov)
            for sub in _subjaxprs(eqn.params):
                walk(sub, depth + 1)

    walk(jaxpr, 0)

    # FLOW-MODE: each declared mode invar must be int32 and consumed
    used: set[int] = set()
    def mark_used(jpr) -> None:
        for eqn in jpr.eqns:
            for v in eqn.invars:
                if not _is_literal(v):
                    used.add(id(v))
        for v in jpr.outvars:
            if not _is_literal(v):
                used.add(id(v))
    mark_used(jaxpr)
    for arg_idx, offsets in mode_offsets:
        consumed = False
        for off in offsets:
            var = jaxpr.invars[off]
            name = _dtype_name(_aval(var))
            if name != "int32":
                out.append(Violation(
                    "FLOW-MODE", path,
                    f"mode arg {arg_idx} (invar {off}) has dtype {name}, "
                    "must be traced int32"))
            consumed = consumed or id(var) in used
        if offsets and not consumed:
            out.append(Violation(
                "FLOW-MODE", path,
                f"mode arg {arg_idx} is never consumed — the mode was "
                "constant-folded in Python, breaking the zero-recompile "
                "contract"))
    return out


def _subjaxprs(params):
    """Nested jaxprs, duck-typed (shared shape with dispatch._subjaxprs
    but kept local so flow has no import edge on dispatch)."""
    if not params:
        return
    for val in params.values():
        for item in val if isinstance(val, (tuple, list)) else (val,):
            if hasattr(item, "jaxpr") and hasattr(getattr(item, "jaxpr"), "eqns"):
                yield item.jaxpr  # ClosedJaxpr (unwrap before the eqns probe:
                #                   ClosedJaxpr forwards .eqns but not .invars)
            elif hasattr(item, "eqns"):
                yield item
