"""Dispatch & fusion auditor: count precision-dispatch structure in jaxprs.

This is the single home of the jaxpr walkers that used to live in
``kernels/tile_matmul/tile_policy.py`` (which still re-exports them): the
tile tests' two-counter ``dispatch_stats`` plus the generalized
``audit_stats`` — per-path counts of ``pallas_call``, ``lax.switch``/
``cond``, scan/while, scatter/gather, dtype converts, and the largest
gather output — checked against declarative :class:`Expect` records for
every hot path (``repro.analysis.hotpaths``).

Two rules:

``DISP-COUNT``
    A declarative count expectation failed — e.g. the runtime-bound tile
    pmm must be exactly 1 fused ``pallas_call`` with 0 switches (the
    paper's one-multiplier/many-modes contract), the static decode step
    must contain no mode switches at all.

``DISP-DENSIFY``
    A gather-class equation materialized more bytes than the declared
    per-path bound — the "paged gather rows never densify the pool"
    contract: page-table reads may gather each row's own pages (≤ B × cap
    rows), never the whole pool per row.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.analysis.report import Violation

#: equations that read memory by index — the densify rule measures these
GATHER_PRIMS = ("gather", "dynamic_slice")
#: equations that write memory by index
SCATTER_PRIMS = ("scatter", "scatter-add", "scatter-mul", "scatter-min",
                 "scatter-max", "dynamic_update_slice")


def dispatch_stats(fn, *args, **kwargs) -> dict[str, int]:
    """Trace ``fn(*args, **kwargs)`` and count precision-dispatch structure:
    ``switches`` (lax.switch/cond equations — the old N-branch runtime path)
    and ``pallas_calls`` (fused kernel dispatches).  Descends through nested
    jaxprs but NOT into kernel bodies, so the predicated passes inside the
    tile kernel do not count as switches.  Used by tests and tile_sweep to
    assert the tile path collapses N branches into one dispatch.
    """
    full = audit_stats(fn, *args, **kwargs)
    return {"switches": full["switches"], "pallas_calls": full["pallas_calls"]}


def audit_stats(fn, *args, **kwargs) -> dict[str, int]:
    """Full dispatch audit of ``fn(*args, **kwargs)``'s jaxpr.

    Returns every counter the per-path expectations can bind:
    ``switches`` / ``pallas_calls`` (as ``dispatch_stats``), ``scans`` /
    ``whiles`` (sequential control), ``gathers`` / ``scatters`` (indexed
    memory traffic), ``converts`` (``convert_element_type`` equations),
    ``dots`` (``dot_general`` — the MXU dispatch count of non-pallas
    paths), ``eqns`` (total equations, nested included), and
    ``max_gather_bytes`` (largest gather-class output — the densify
    measure).  Kernel bodies are not descended into, matching
    ``dispatch_stats``.
    """
    jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    stats = {
        "switches": 0, "pallas_calls": 0, "scans": 0, "whiles": 0,
        "gathers": 0, "scatters": 0, "converts": 0, "dots": 0, "eqns": 0,
        "max_gather_bytes": 0,
    }
    _walk(jaxpr.jaxpr, stats)
    return stats


def audit_jaxpr(jaxpr) -> dict[str, int]:
    """``audit_stats`` over an already-traced (unclosed) jaxpr."""
    stats = {
        "switches": 0, "pallas_calls": 0, "scans": 0, "whiles": 0,
        "gathers": 0, "scatters": 0, "converts": 0, "dots": 0, "eqns": 0,
        "max_gather_bytes": 0,
    }
    _walk(jaxpr, stats)
    return stats


def _subjaxprs(params):
    """Nested jaxprs in an equation's params, version-portable (duck-typed
    on .eqns / .jaxpr instead of jax.core types, which moved across jax
    releases)."""
    for val in params.values():
        for item in val if isinstance(val, (tuple, list)) else (val,):
            if hasattr(item, "eqns"):  # Jaxpr
                yield item
            elif hasattr(item, "jaxpr") and hasattr(getattr(item, "jaxpr"), "eqns"):
                yield item.jaxpr  # ClosedJaxpr


def _out_bytes(eqn) -> int:
    total = 0
    for var in eqn.outvars:
        aval = getattr(var, "aval", None)
        if aval is not None and hasattr(aval, "shape") and hasattr(aval, "dtype"):
            total += int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize
    return total


def _walk(jaxpr, stats) -> None:
    for eqn in jaxpr.eqns:
        stats["eqns"] += 1
        name = eqn.primitive.name
        if name == "pallas_call":
            stats["pallas_calls"] += 1
            continue  # kernel-internal predication is not a dispatch
        if name == "cond":
            stats["switches"] += 1
        elif name == "scan":
            stats["scans"] += 1
        elif name == "while":
            stats["whiles"] += 1
        elif name in GATHER_PRIMS:
            stats["gathers"] += 1
            stats["max_gather_bytes"] = max(
                stats["max_gather_bytes"], _out_bytes(eqn))
        elif name in SCATTER_PRIMS:
            stats["scatters"] += 1
        elif name == "convert_element_type":
            stats["converts"] += 1
        elif name == "dot_general":
            stats["dots"] += 1
        for sub in _subjaxprs(eqn.params):
            _walk(sub, stats)


@dataclasses.dataclass(frozen=True)
class Expect:
    """Declarative dispatch expectation for one audited hot path.

    ``exact`` pins a counter to a value, ``at_most``/``at_least`` bound it;
    ``densify_bytes`` caps ``max_gather_bytes`` (the pool-densify rule) —
    set it to the path's legitimate per-step gather ceiling, e.g.
    B × cap × heads × head_dim × itemsize for a paged decode step.
    """

    exact: dict[str, int] = dataclasses.field(default_factory=dict)
    at_most: dict[str, int] = dataclasses.field(default_factory=dict)
    at_least: dict[str, int] = dataclasses.field(default_factory=dict)
    densify_bytes: int | None = None

    def check(self, stats: dict[str, int], where: str) -> list[Violation]:
        out: list[Violation] = []
        for key, want in self.exact.items():
            if stats.get(key) != want:
                out.append(Violation(
                    "DISP-COUNT", where,
                    f"expected {key} == {want}, traced {stats.get(key)}"))
        for key, cap in self.at_most.items():
            if stats.get(key, 0) > cap:
                out.append(Violation(
                    "DISP-COUNT", where,
                    f"expected {key} <= {cap}, traced {stats.get(key)}"))
        for key, floor in self.at_least.items():
            if stats.get(key, 0) < floor:
                out.append(Violation(
                    "DISP-COUNT", where,
                    f"expected {key} >= {floor}, traced {stats.get(key)}"))
        if (self.densify_bytes is not None
                and stats.get("max_gather_bytes", 0) > self.densify_bytes):
            out.append(Violation(
                "DISP-DENSIFY", where,
                f"a gather materialized {stats['max_gather_bytes']} bytes "
                f"(> {self.densify_bytes}): rows must gather their own "
                "pages, never densify the pool"))
        return out


def audit(fn, args, expect: Expect, where: str, **kwargs) -> list[Violation]:
    """Trace ``fn(*args, **kwargs)`` and check ``expect`` against it."""
    return expect.check(audit_stats(fn, *args, **kwargs), where)
