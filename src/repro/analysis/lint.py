"""Trace-hygiene linter: AST rules for jax code in this repo.

Static source checks that the jaxpr-level passes cannot express — they
look at what the *Python* does around tracing, not what the trace
contains.  Each rule has a stable ID, a docstring in :data:`RULES`, and a
per-file allowlist in :data:`ALLOWLIST` (suffix-matched paths, so moves
within ``src/`` keep working).

``TH001`` host-branch-on-traced
    ``if`` / ``while`` / conditional expressions inside a jit body whose
    test reads a traced parameter's *value*.  Host branching on traced
    values either fails at trace time or — worse — constant-folds per
    value and recompiles.  Metadata access (``.shape``/``.ndim``/
    ``.dtype``/``.size``), ``is None`` checks, ``isinstance``/``len``,
    and static argnames are all fine and excluded.

``TH002`` wallclock-timing
    ``time.time()`` anywhere in ``src/``.  Duration spans must use
    ``time.perf_counter()`` (monotonic — wall clock can step backwards
    under NTP adjustment); genuine wall-clock *metadata stamps* are
    allowlisted per file.

``TH003`` host-call-in-jit
    ``np.*`` / ``numpy.*`` calls or ``float()``/``int()``/``bool()``
    coercions applied to traced parameters inside a jit body.  These
    force a host transfer (ConcretizationTypeError at best, silent
    constant-folding at worst).  Host math on static metadata
    (``np.prod(x.shape)``) is fine.

``TH004`` interpret-in-jit
    ``default_interpret()`` / ``resolve_interpret()`` called inside a jit
    body.  Backend probing must happen in the non-jit shell: inside jit
    it is resolved once at trace time for whatever backend traced first
    and baked into the cache.

``TH005`` mutable-default
    Mutable literals (``[]``/``{}``/``set()``/``list()``/``dict()``) as
    function parameter defaults or as bare dataclass field defaults.
    Config dataclasses are compared and hashed as cache keys here;
    mutable defaults alias across instances.
"""
from __future__ import annotations

import ast
import os

from repro.analysis.report import Violation

#: rule catalogue: ID -> one-line summary (full semantics in module docstring)
RULES = {
    "TH001": "host Python branch on a traced value inside a jit body",
    "TH002": "time.time() used where a monotonic clock is required",
    "TH003": "numpy/host call on a traced value inside a jit body",
    "TH004": "interpret= resolved inside a jit boundary",
    "TH005": "mutable default argument / dataclass field default",
}

#: per-rule path-suffix allowlist (the only sanctioned escapes)
ALLOWLIST: dict[str, tuple[str, ...]] = {
    # manifest stamps are *metadata* — wall-clock is the point
    "TH002": ("checkpoint/manager.py",),
}

#: attribute reads that are static metadata, not traced values
METADATA_ATTRS = frozenset(
    {"shape", "ndim", "dtype", "size", "itemsize", "sharding", "aval",
     "weak_type"})

#: host calls that never concretize (operate on metadata / types)
_SAFE_CALLS = frozenset(
    {"isinstance", "len", "getattr", "hasattr", "callable", "type", "repr",
     "str", "id"})

_COERCIONS = frozenset({"float", "int", "bool", "complex"})


def allowed(rule: str, path: str) -> bool:
    norm = path.replace(os.sep, "/")
    return any(norm.endswith(sfx) for sfx in ALLOWLIST.get(rule, ()))


def lint_source(text: str, path: str) -> list[Violation]:
    """Run every rule over one file's source."""
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as exc:
        return [Violation("TH000", f"{path}:{exc.lineno}",
                          f"file does not parse: {exc.msg}")]
    out: list[Violation] = []
    jitted = _jitted_functions(tree)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            statics = jitted.get(node.name)
            if statics is not None or _jit_decorated(node)[0]:
                if statics is None:
                    statics = _jit_decorated(node)[1]
                out.extend(_lint_jit_body(node, statics, path))
    out.extend(_lint_wallclock(tree, path))
    out.extend(_lint_mutable_defaults(tree, path))
    return [v for v in out if not allowed(v.rule, path)]


def lint_file(path: str) -> list[Violation]:
    with open(path) as f:
        return lint_source(f.read(), path)


def lint_paths(root: str) -> tuple[list[Violation], list[str]]:
    """Lint every ``.py`` under ``root``; returns (violations, files)."""
    files: list[str] = []
    out: list[Violation] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__", ".git"))
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                p = os.path.join(dirpath, fn)
                files.append(p)
                out.extend(lint_file(p))
    return out, files


# --------------------------------------------------------------------------
# jit-body discovery

def _is_jax_jit(node: ast.AST) -> bool:
    """Matches ``jax.jit`` or bare ``jit``."""
    if isinstance(node, ast.Attribute):
        return (node.attr == "jit" and isinstance(node.value, ast.Name)
                and node.value.id == "jax")
    return isinstance(node, ast.Name) and node.id == "jit"


def _static_names(call: ast.Call) -> frozenset[str]:
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums") and isinstance(
                kw.value, (ast.Tuple, ast.List, ast.Constant)):
            elts = (kw.value.elts
                    if isinstance(kw.value, (ast.Tuple, ast.List))
                    else [kw.value])
            return frozenset(
                e.value for e in elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str))
    return frozenset()


def _jit_decorated(fn: ast.FunctionDef) -> tuple[bool, frozenset[str]]:
    """(is-jitted, static argnames) from this def's decorators."""
    for dec in fn.decorator_list:
        if _is_jax_jit(dec):
            return True, frozenset()
        if isinstance(dec, ast.Call):
            if _is_jax_jit(dec.func):          # @jax.jit(donate_argnums=...)
                return True, _static_names(dec)
            func = dec.func                    # @partial(jax.jit, ...)
            if (isinstance(func, ast.Name) and func.id == "partial"
                    or isinstance(func, ast.Attribute)
                    and func.attr == "partial"):
                if dec.args and _is_jax_jit(dec.args[0]):
                    return True, _static_names(dec)
    return False, frozenset()


def _jitted_functions(tree: ast.Module) -> dict[str, frozenset[str]]:
    """Function names wrapped by ``jax.jit(...)`` anywhere in the module —
    covers ``step = jax.jit(fn)`` and ``self._step = jax.jit(self._fn)``
    (the engine idiom) — mapped to their static argnames."""
    jitted: dict[str, frozenset[str]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jax_jit(node.func) and node.args:
            target = node.args[0]
            name = None
            if isinstance(target, ast.Name):
                name = target.id
            elif isinstance(target, ast.Attribute):  # self._masked_step
                name = target.attr
            if name is not None:
                jitted[name] = _static_names(node)
    return jitted


# --------------------------------------------------------------------------
# TH001 / TH003 / TH004 — rules scoped to a jit body

def _traced_params(fn: ast.FunctionDef, statics: frozenset[str]) -> frozenset[str]:
    names = [a.arg for a in (fn.args.posonlyargs + fn.args.args
                             + fn.args.kwonlyargs)]
    return frozenset(n for n in names if n not in statics and n != "self")


def _reads_traced(node: ast.AST, traced: frozenset[str]) -> bool:
    """Does evaluating ``node`` read a traced param's *value* (not just
    its static metadata)?"""
    if isinstance(node, ast.Name):
        return node.id in traced
    if isinstance(node, ast.Attribute):
        if node.attr in METADATA_ATTRS:
            return False
        return _reads_traced(node.value, traced)
    if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Attribute):
        if node.value.attr in METADATA_ATTRS:   # x.shape[0]
            return False
    if isinstance(node, ast.Call):
        fname = node.func
        if isinstance(fname, ast.Name) and fname.id in _SAFE_CALLS:
            return False
        return (_reads_traced(fname, traced)     # x.sum() — traced receiver
                or any(_reads_traced(c, traced)
                       for c in list(node.args)
                       + [kw.value for kw in node.keywords]))
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return False                        # x is None — identity only
    return any(_reads_traced(c, traced) for c in ast.iter_child_nodes(node))


def _np_rooted(func: ast.AST) -> bool:
    node = func
    while isinstance(node, ast.Attribute):
        node = node.value
    return isinstance(node, ast.Name) and node.id in ("np", "numpy")


def _lint_jit_body(fn: ast.FunctionDef, statics: frozenset[str],
                   path: str) -> list[Violation]:
    traced = _traced_params(fn, statics)
    out: list[Violation] = []
    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.IfExp, ast.While)):
            if _reads_traced(node.test, traced):
                out.append(Violation(
                    "TH001", f"{path}:{node.lineno}",
                    f"jit body '{fn.name}' branches in host Python on a "
                    "traced value — use lax.cond/select or hoist to the "
                    "shell"))
        elif isinstance(node, ast.Call):
            args = list(node.args) + [kw.value for kw in node.keywords]
            on_traced = any(_reads_traced(a, traced) for a in args)
            if _np_rooted(node.func) and on_traced:
                out.append(Violation(
                    "TH003", f"{path}:{node.lineno}",
                    f"jit body '{fn.name}' calls numpy on a traced value "
                    "— use jnp (host numpy concretizes)"))
            elif (isinstance(node.func, ast.Name)
                    and node.func.id in _COERCIONS and on_traced):
                out.append(Violation(
                    "TH003", f"{path}:{node.lineno}",
                    f"jit body '{fn.name}' coerces a traced value with "
                    f"{node.func.id}() — host coercion concretizes"))
            fname = node.func
            called = (fname.id if isinstance(fname, ast.Name)
                      else fname.attr if isinstance(fname, ast.Attribute)
                      else "")
            if called in ("default_interpret", "resolve_interpret"):
                out.append(Violation(
                    "TH004", f"{path}:{node.lineno}",
                    f"jit body '{fn.name}' resolves interpret= inside the "
                    "jit boundary — the first-traced backend gets baked "
                    "in; resolve in the non-jit shell"))
    return out


# --------------------------------------------------------------------------
# TH002 / TH005 — module-wide rules

def _lint_wallclock(tree: ast.Module, path: str) -> list[Violation]:
    out = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "time"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "time"):
            out.append(Violation(
                "TH002", f"{path}:{node.lineno}",
                "time.time() — use time.perf_counter() for spans "
                "(wall clock can step backwards); allowlist genuine "
                "metadata stamps"))
    return out


def _mutable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("list", "dict", "set") and not node.args
            and not node.keywords)


def _lint_mutable_defaults(tree: ast.Module, path: str) -> list[Violation]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for default in node.args.defaults + [
                    d for d in node.args.kw_defaults if d is not None]:
                if _mutable_default(default):
                    out.append(Violation(
                        "TH005", f"{path}:{default.lineno}",
                        f"mutable default argument in '{node.name}' — "
                        "use None or dataclasses.field(default_factory=...)"))
        elif isinstance(node, ast.ClassDef) and _is_dataclass(node):
            for stmt in node.body:
                if (isinstance(stmt, ast.AnnAssign) and stmt.value is not None
                        and _mutable_default(stmt.value)):
                    out.append(Violation(
                        "TH005", f"{path}:{stmt.lineno}",
                        f"mutable field default on dataclass "
                        f"'{node.name}' — use field(default_factory=...)"))
    return out


def _is_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = (target.id if isinstance(target, ast.Name)
                else target.attr if isinstance(target, ast.Attribute) else "")
        if name == "dataclass":
            return True
    return False
