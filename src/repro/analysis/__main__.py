"""CLI gate: ``python -m repro.analysis`` runs all three passes and exits
nonzero on any violation.

* trace-hygiene linter over ``--src`` (default: the repo's ``src/`` tree,
  located relative to this package so the gate works from any cwd);
* precision-flow + dispatch audits over every registered hot path
  (``--quick`` restricts to the kernel/train subset — no engine builds);
* ``--report out.json`` writes the machine-readable violation report
  (the CI artifact).
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.hotpaths import all_paths, check
from repro.analysis.lint import lint_paths
from repro.analysis.report import format_report, write_json


def _default_src() -> str:
    # src/repro/analysis/__main__.py -> src/
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--src", default=None,
                    help="source tree to lint (default: the repo src/)")
    ap.add_argument("--quick", action="store_true",
                    help="kernel/train hot paths only (skip engine builds)")
    ap.add_argument("--skip-lint", action="store_true")
    ap.add_argument("--skip-paths", action="store_true")
    ap.add_argument("--report", default=None, metavar="OUT.json",
                    help="write the JSON violation report (CI artifact)")
    args = ap.parse_args(argv)

    violations, checked = [], []
    if not args.skip_lint:
        src = args.src or _default_src()
        lint_v, files = lint_paths(src)
        violations += lint_v
        checked += [f"lint:{os.path.relpath(p, src)}" for p in files]
    if not args.skip_paths:
        path_v, names = check(all_paths(quick=args.quick))
        violations += path_v
        checked += names

    print(format_report(violations, checked))
    if args.report:
        write_json(args.report, violations, checked)
        print(f"report -> {args.report}")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
