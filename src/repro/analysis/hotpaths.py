"""Hot-path registry: every compiled path the static analyses audit.

Each :class:`HotPath` pins a traced callable, its example arguments, a
declarative dispatch :class:`~repro.analysis.dispatch.Expect`, and the
flow-rule configuration (mode args, f64 tracing).  The registry is the
contract surface: counts are pinned against the registry's own smoke
configs, so a structural change to a hot path (an extra switch, a
densified gather, a dropped fusion) fails the gate until the contract is
consciously updated here.

Coverage:

* kernel paths — runtime-bound pmm on both impls (xla = 1 switch, tile =
  1 fused ``pallas_call`` / 0 switches), budget-driven ``tile_matmul_auto``,
  and the ``quantize_mantissa`` kernel; traced under x64 so FLOW-F64 is
  live.
* the train step (f64-clean even under x64; zero switches).
* the live serve engine across dense / ssm / hybrid architectures ×
  {dense, paged} cache × {plain decode, speculative round}, plus the
  modal adaptive step and the modal-verify speculative round.  Engine
  state is built under default x64-off config, so these trace with
  ``x64=False`` — the f64 rule is carried by the kernel/train paths.

``mode_args`` marks which positional arguments are mode-select scalars
(or per-site scalar dicts) for the FLOW-MODE zero-recompile check.  The
speculative round with ``modal_verify=False`` deliberately ignores its
verify table (verification runs the static baseline step for bit
identity), so only the draft table is declared; the ``modal-verify``
cell declares both.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

from repro.analysis.dispatch import Expect, audit_stats
from repro.analysis.flow import DEFAULT_WIDEN_ALLOW, analyze_flow
from repro.analysis.report import Violation

#: dense-attention, state-space, and hybrid (local-window) families
ARCHS = ("qwen1.5-0.5b", "mamba2-2.7b", "recurrentgemma-9b")

#: per-arch cap on the largest legitimate gather in one decode step /
#: spec round (bytes) — the densify guards.  A per-row pool densify is
#: ≥ 2× these (B × pool rows vs B × cap rows), so exact pins hold margin.
_DECODE_GATHER_CAP = {"qwen1.5-0.5b": 8192, "mamba2-2.7b": 4096,
                      "recurrentgemma-9b": 4096}
_SPEC_GATHER_CAP = {
    ("qwen1.5-0.5b", False): 8192, ("qwen1.5-0.5b", True): 16384,
    ("mamba2-2.7b", False): 65536, ("mamba2-2.7b", True): 65536,
    ("recurrentgemma-9b", False): 4096, ("recurrentgemma-9b", True): 4096,
}


@dataclasses.dataclass(frozen=True)
class HotPath:
    name: str
    fn: Callable
    args: tuple
    expect: Expect
    mode_args: tuple[int, ...] = ()
    x64: bool = True
    oracles: tuple[str, ...] = ()
    widen_allow: tuple = DEFAULT_WIDEN_ALLOW


def check(paths) -> tuple[list[Violation], list[str]]:
    """Run the dispatch audit + all flow rules over each path."""
    violations: list[Violation] = []
    checked: list[str] = []
    for hp in paths:
        checked.append(hp.name)
        stats = audit_stats(hp.fn, *hp.args)
        violations.extend(hp.expect.check(stats, hp.name))
        violations.extend(analyze_flow(
            hp.fn, *hp.args, path=hp.name, mode_args=hp.mode_args,
            widen_allow=hp.widen_allow, oracles=hp.oracles, x64=hp.x64))
    return violations, checked


def all_paths(quick: bool = False) -> list[HotPath]:
    """The full registry (or the fast kernel/train subset for tests)."""
    paths = kernel_paths() + train_paths()
    if not quick:
        paths += engine_paths()
    return paths


# --------------------------------------------------------------------------
# kernel + train paths (analyzer-built args: traced under x64)

def kernel_paths() -> list[HotPath]:
    import jax.numpy as jnp
    import numpy as np

    from repro.core.rmpm import mp_matmul_runtime
    from repro.kernels.quantize_mantissa.ops import quantize_mantissa_op
    from repro.kernels.tile_matmul.ops import tile_matmul_auto

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((64, 96)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((96, 48)).astype(np.float32))
    blk = (32, 32, 32)

    def pmm(impl):
        def fn(a_, b_, mode):
            return mp_matmul_runtime(a_, b_, mode, impl=impl, block=blk,
                                     allow_auto=False)
        return fn

    return [
        # the old N-branch runtime path: exactly one lax.switch, no kernels
        HotPath("pmm-runtime-xla", pmm("xla"), (a, b, jnp.int32(2)),
                Expect(exact={"switches": 1, "pallas_calls": 0},
                       at_least={"dots": 1}),
                mode_args=(2,)),
        # the paper's contract: N modes collapse into ONE fused dispatch
        HotPath("pmm-runtime-tile", pmm("tile"), (a, b, jnp.int32(2)),
                Expect(exact={"switches": 0, "pallas_calls": 1, "dots": 0}),
                mode_args=(2,)),
        HotPath("tile-matmul-auto",
                lambda a_, b_: tile_matmul_auto(a_, b_, 2.0**-10,
                                                bm=32, bn=32, bk=32),
                (a, b),
                Expect(exact={"switches": 0, "pallas_calls": 1})),
        HotPath("quantize-mantissa",
                lambda x: quantize_mantissa_op(x, keep=8), (a,),
                Expect(exact={"switches": 0, "pallas_calls": 1})),
    ]


def train_paths() -> list[HotPath]:
    import jax
    import jax.numpy as jnp

    from repro.train.step import TrainConfig, init_train_state, make_train_step

    _cfg, model, _params = _tiny("qwen1.5-0.5b")
    tcfg = TrainConfig()
    state = init_train_state(model, jax.random.key(0), tcfg)
    step = make_train_step(model, tcfg)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    return [
        HotPath("train-step", step, (state, batch),
                Expect(exact={"switches": 0, "pallas_calls": 0, "whiles": 0},
                       at_least={"dots": 1})),
    ]


# --------------------------------------------------------------------------
# live-engine matrix

@functools.lru_cache(maxsize=None)
def _tiny(arch: str):
    import dataclasses as dc

    import jax

    from repro.configs import get_smoke_config
    from repro.core.policy import NATIVE_F32
    from repro.models import build_model

    cfg = get_smoke_config(arch).with_policy(NATIVE_F32)
    cfg = dc.replace(cfg, n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(arch: str, *, paged: bool = False, spec=None, slo=None,
            accuracy=None):
    from repro.serve import CacheConfig, ServeConfig, ServeEngine
    from repro.serve.config import AdaptConfig

    _cfg, model, params = _tiny(arch)
    cache = (CacheConfig(layout="paged", page_size=4) if paged
             else CacheConfig())
    cfg = ServeConfig(
        batch_slots=2, max_len=32, accuracy=accuracy, cache=cache,
        spec=spec, adapt=AdaptConfig(slo=slo))
    return ServeEngine(model, params, config=cfg), params


def engine_paths(archs: tuple[str, ...] = ARCHS) -> list[HotPath]:
    import jax.numpy as jnp

    from repro.adapt import SLO
    from repro.spec import SpecConfig
    from repro.spec.rollout import build_spec_round

    tokens = jnp.zeros((2, 1), jnp.int32)
    active = jnp.ones((2,), bool)
    paths: list[HotPath] = []

    # static decode step: zero switches, no host loops, bounded gathers
    for arch in archs:
        for paged in (False, True):
            eng, params = _engine(arch, paged=paged)
            paths.append(HotPath(
                f"decode/{arch}/{'paged' if paged else 'dense'}",
                eng._masked_step, (params, tokens, eng.state, active),
                Expect(exact={"switches": 0, "pallas_calls": 0, "whiles": 0},
                       at_most={"scans": 4},
                       densify_bytes=_DECODE_GATHER_CAP[arch]),
                x64=False))

    # speculative round: draft table runtime-bound (≥1 switch), one
    # compiled round (k draft scans + verify + rollback = 6 scans)
    for arch in archs:
        for paged in (False, True):
            eng, params = _engine(arch, paged=paged, spec=SpecConfig(k=2))
            round_fn = build_spec_round(eng.model_decode, eng._axes, 2,
                                        modal_verify=False)
            args = (params, tokens, eng.state, active,
                    eng._spec_table.scalars_shifted(-eng.draft_shift),
                    eng._spec_table.scalars())
            paths.append(HotPath(
                f"spec/{arch}/{'paged' if paged else 'dense'}",
                round_fn, args,
                Expect(exact={"pallas_calls": 0, "whiles": 0, "scans": 6},
                       at_least={"switches": 1},
                       densify_bytes=_SPEC_GATHER_CAP[arch, paged]),
                mode_args=(4,), x64=False))

    # modal adaptive step: the ModeTable scalars must stay traced args
    eng, params = _engine("qwen1.5-0.5b", slo=SLO(max_err=0.5),
                          accuracy=2.0**-5)
    paths.append(HotPath(
        "decode-modal/qwen1.5-0.5b",
        eng._masked_step_modal,
        (params, tokens, eng.state, active, eng.mode_table.scalars()),
        Expect(exact={"pallas_calls": 0, "whiles": 0},
               at_least={"switches": 1}),
        mode_args=(4,), x64=False))

    # modal-verify speculative round: BOTH tables runtime-bound
    eng, params = _engine("qwen1.5-0.5b", slo=SLO(max_err=0.5),
                          accuracy=2.0**-5, spec=SpecConfig(k=2))
    round_fn = build_spec_round(eng.model_decode, eng._axes, 2,
                                modal_verify=True)
    paths.append(HotPath(
        "spec-modal/qwen1.5-0.5b",
        round_fn,
        (params, tokens, eng.state, active,
         eng._spec_table.scalars_shifted(-eng.draft_shift),
         eng._spec_table.scalars()),
        Expect(exact={"pallas_calls": 0, "whiles": 0, "scans": 6},
               at_least={"switches": 2}),
        mode_args=(4, 5), x64=False))
    return paths
