"""repro.analysis — static verification of the run-time-precision contracts.

Three passes over the repo, run together by ``python -m repro.analysis``:

* :mod:`repro.analysis.flow` — precision-flow checking over traced jaxprs
  (FLOW-F64 / FLOW-WIDEN / FLOW-MODE / FLOW-NARROW).
* :mod:`repro.analysis.dispatch` — dispatch & fusion audit with
  declarative per-hot-path expectations (DISP-COUNT / DISP-DENSIFY).
* :mod:`repro.analysis.lint` — trace-hygiene AST linter over ``src/``
  (TH001–TH005).

The hot paths themselves live in :mod:`repro.analysis.hotpaths`; results
are :class:`~repro.analysis.report.Violation` records.
"""
from repro.analysis.dispatch import (
    Expect,
    audit,
    audit_jaxpr,
    audit_stats,
    dispatch_stats,
)
from repro.analysis.flow import MANTISSA_BITS, analyze_flow, flow_violations
from repro.analysis.lint import (
    ALLOWLIST,
    RULES,
    lint_file,
    lint_paths,
    lint_source,
)
from repro.analysis.report import Violation, format_report, rule_ids, write_json

__all__ = [
    "ALLOWLIST",
    "Expect",
    "MANTISSA_BITS",
    "RULES",
    "Violation",
    "analyze_flow",
    "audit",
    "audit_jaxpr",
    "audit_stats",
    "dispatch_stats",
    "flow_violations",
    "format_report",
    "lint_file",
    "lint_paths",
    "lint_source",
    "rule_ids",
    "write_json",
]
