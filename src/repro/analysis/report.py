"""Violation record + report shared by the three analysis passes.

Every rule in ``repro.analysis`` (flow / dispatch / lint) reports findings
as :class:`Violation` values — a stable, JSON-serializable shape so the CLI
can aggregate passes, the CI gate can upload one artifact, and tests can
assert "this fixture fires exactly rule X and nothing else" without parsing
formatted text.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule firing at one location.

    ``rule``  — stable rule ID (``FLOW-F64``, ``DISP-COUNT``, ``TH002`` ...).
    ``where`` — the audited unit: a hot-path name for jaxpr rules, a
    ``path:line`` for source rules.
    ``message`` — human-readable detail (what was found vs what the
    contract requires).
    """

    rule: str
    where: str
    message: str

    def format(self) -> str:
        return f"{self.rule} @ {self.where}: {self.message}"


def rule_ids(violations: Iterable[Violation]) -> set[str]:
    """Distinct rule IDs in a violation list (test helper)."""
    return {v.rule for v in violations}


def format_report(violations: list[Violation], checked: list[str]) -> str:
    """One text block: every violation, then the pass/fail summary line."""
    lines = [v.format() for v in violations]
    lines.append(
        f"repro.analysis: {len(checked)} units checked, "
        f"{len(violations)} violation(s)"
        + ("" if violations else " — clean")
    )
    return "\n".join(lines)


def write_json(path: str, violations: list[Violation],
               checked: list[str]) -> None:
    """The CI artifact: machine-readable violation report."""
    doc = {
        "checked": checked,
        "violations": [dataclasses.asdict(v) for v in violations],
        "clean": not violations,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
