"""Sharding rules: DP / FSDP / TP / EP / SP over the production mesh.

Mesh axes: ('data', 'model') single-pod, ('pod', 'data', 'model') multi-pod.
  * batch            -> ('pod', 'data')   (pod is extra data parallelism)
  * TP ('heads')     -> heads / d_ff / experts / vocab on 'model'
  * SP ('sequence')  -> sequence on 'model' (archs whose head count does not
                        divide the model axis: qwen1.5-4b 20H, internvl2 14H)
  * FSDP             -> parameters additionally sharded over 'data'
                        (ZeRO-3 via GSPMD; scan-level all-gather)

``constrain`` is a mesh-aware with_sharding_constraint that becomes a no-op
outside a mesh context (CPU smoke tests) and drops axis names the current
mesh does not have (single-pod vs multi-pod reuse the same model code).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

BATCH_AXES = ("pod", "data")


def _active_mesh():
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        # jax < 0.5: the abstract-mesh accessor only exists privately and
        # returns () when no mesh context is active.  Meshless paths (CPU
        # smoke tests, single-device serving) just need the no-op branch.
        from jax._src import mesh as _mesh_lib

        get = getattr(_mesh_lib, "get_abstract_mesh", lambda: None)
    mesh = get()
    if mesh is None or not getattr(mesh, "axis_names", None) or getattr(mesh, "empty", False):
        return None
    return mesh


def _clean_spec(axes, mesh) -> P:
    names = set(mesh.axis_names)
    # axes that are Manual in the current (abstract) mesh — e.g. 'pod' inside
    # the gradient-compression shard_map — cannot appear in constraints
    try:
        manual = {
            n for n, t in zip(mesh.axis_names, mesh.axis_types)
            if "Manual" in str(t)
        }
        names -= manual
    except AttributeError:
        pass
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        elif isinstance(a, (tuple, list)):
            kept = tuple(n for n in a if n in names)
            out.append(kept if kept else None)
        else:
            out.append(a if a in names else None)
    return P(*out)


def _axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, tuple(mesh.shape[a] for a in mesh.axis_names)))


def _fit_spec(axes, shape, mesh) -> P:
    """Drop mesh axes whose product does not divide the dim (replicate
    instead) — non-divisible cases (odd vocabs, batch=1 long-context,
    GQA kv-heads < model axis) are legal configs, not errors."""
    sizes = _axis_sizes(mesh)
    spec = _clean_spec(axes, mesh)
    fitted = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            fitted.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        kept: list[str] = []
        prod = 1
        for n in names:
            if dim % (prod * sizes[n]) == 0:
                kept.append(n)
                prod *= sizes[n]
        fitted.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*fitted)


def constrain(x: jax.Array, *axes) -> jax.Array:
    """Sharding constraint that is a no-op without a mesh context."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, _fit_spec(axes, x.shape, mesh))


# ---------------------------------------------------------------------------
# Parameter shardings (by tree-path name patterns)
# ---------------------------------------------------------------------------


def _spec_for(path: str, ndim: int, cfg, stacked: bool) -> tuple:
    """Return partition axes for the trailing (non-layer-stack) dims."""
    tp = cfg.attn_shard == "heads"  # TP scheme; SP keeps weights unsharded on model
    fsdp = ("data",) if cfg.fsdp else None
    mdl = "model"

    def tail(*axes):
        return ((None,) if stacked else ()) + axes

    # --- embeddings / logits ---
    if "unembed" in path:  # (D, V)
        if path.endswith(".b"):
            return tail(mdl)
        return tail(fsdp, mdl)
    if "embed" in path:  # (V, D)
        return tail(mdl, fsdp)
    # --- MoE ---
    if "router" in path:
        return tail(fsdp, None) if ndim - stacked == 2 else tail(None)
    # Expert weights: EP (E over model) + ZeRO-3 (D or F over data).
    # Perf cell B iteration 1 tried EP-local (no data sharding): collective
    # bytes halved but resident experts hit 258 GB/device (61 layers x 24
    # experts) — refuted.  The per-microbatch regather is the honest ZeRO-3
    # cost at 1T scale; cross-pod gradient compression attacks the slower
    # link instead (EXPERIMENTS.md section Perf cell B).
    if any(s in path for s in ("moe.gate", "moe.up")):  # (E, D, F)
        return tail(mdl, fsdp, None)
    if "moe.down" in path:  # (E, F, D)
        return tail(mdl, None, fsdp)
    # --- ssm ---
    if "in_proj" in path:  # (D, d_proj) — output channels model-sharded
        return tail(fsdp, mdl if tp else None)
    if "out_proj" in path:  # (d_inner, D)
        return tail(mdl if tp else None, fsdp)
    if "conv_w" in path:  # (K, C)
        return tail(None, mdl if tp else None)
    if any(s in path for s in ("a_log", "dt_bias", "d_skip")):
        return tail(mdl if tp else None)
    # --- griffin rg-lru ---
    if any(s in path for s in ("in_x", "in_gate")):  # (D, W)
        return tail(fsdp, mdl if tp else None)
    if any(s in path for s in (".wa.", ".wx.")):  # (W, W)
        return tail(fsdp, mdl if tp else None)
    if path.endswith("lam"):
        return tail(mdl if tp else None)
    if ".out." in path or path.endswith("out.w"):  # (W, D)
        return tail(mdl if tp else None, fsdp)
    # --- attention ---
    if any(s in path for s in ("wq", "wk", "wv")):
        if path.endswith(".b"):  # bias (H*hd,)
            return tail(mdl if tp else None)
        return tail(fsdp, mdl if tp else None)
    if "wo" in path:  # (H*hd, D)
        return tail(mdl if tp else None, fsdp)
    # --- mlp ---
    if any(s in path for s in ("gate", "up")):
        if path.endswith(".b"):
            return tail(mdl if tp else None)
        return tail(fsdp, mdl if tp else None)
    if "down" in path:
        if path.endswith(".b"):
            return tail(None)
        return tail(mdl if tp else None, fsdp)
    # --- norms / scalars / everything else: replicated (fsdp on 1st if big)
    return tail(*([None] * (ndim - (1 if stacked else 0))))


def _tree_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _tree_paths(v, f"{prefix}.{k}" if prefix else str(k))
    else:
        yield prefix, tree


def param_shardings(params_shape: Any, cfg, mesh) -> Any:
    """PyTree of NamedSharding matching ``params_shape`` (ShapeDtypeStructs
    or arrays).  Layer-stacked leaves (leading dim == n_layers-ish) get a
    leading None axis."""

    def one(path, leaf):
        ndim = len(leaf.shape)
        stacked = _is_stacked(path, leaf, cfg)
        axes = _spec_for(path, ndim, cfg, stacked)
        axes = tuple(axes)[:ndim]
        axes = axes + (None,) * (ndim - len(axes))
        return jax.NamedSharding(mesh, _fit_spec(axes, leaf.shape, mesh))

    flat = dict(_tree_paths(params_shape))
    return _rebuild(params_shape, {p: one(p, leaf) for p, leaf in flat.items()})


def _is_stacked(path: str, leaf, cfg) -> bool:
    head = path.split(".", 1)[0]
    return head in ("layers", "enc_layers", "dec_layers", "super", "rem", "moe_layers")


def _rebuild(tree, flat, prefix=""):
    if isinstance(tree, dict):
        return {
            k: _rebuild(v, flat, f"{prefix}.{k}" if prefix else str(k))
            for k, v in tree.items()
        }
    return flat[prefix]


def input_shardings(batch_shape: Any, mesh) -> Any:
    """Batch inputs: leading dim over ('pod','data'), rest replicated."""

    def one(leaf):
        axes = (BATCH_AXES,) + (None,) * (len(leaf.shape) - 1)
        return jax.NamedSharding(mesh, _fit_spec(axes, leaf.shape, mesh))

    return jax.tree.map(one, batch_shape)


def replicated(mesh):
    return jax.NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Decode-state shardings (KV caches / SSM / RG-LRU states)
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def decode_state_shardings(state_shape: Any, cfg, mesh) -> Any:
    """NamedSharding pytree for a DecodeState shape tree.

    KV caches: batch over ('pod','data'), kv-heads over 'model' when the head
    count divides the axis (GQA kv < model_size replicates KV — the standard
    TP-vs-GQA trade).  SSM / RG-LRU states: channels/heads over 'model'."""
    msize = dict(zip(mesh.axis_names, mesh.shape.values() if hasattr(mesh.shape, "values") else mesh.shape))
    model_n = msize.get("model", 1)
    tp = cfg.attn_shard == "heads"
    kv_div = cfg.n_kv_heads and cfg.n_kv_heads % model_n == 0 and tp
    mdl = "model" if tp else None

    def one(path, leaf):
        name = _path_str(path).rsplit(".", 1)[-1]
        nd = len(leaf.shape)
        if name in ("k", "v", "k_scale", "v_scale"):
            axes = (None,) * (nd - 4) + (BATCH_AXES, None, "model" if kv_div else None, None)
        elif name == "conv":
            axes = (None,) * (nd - 3) + (BATCH_AXES, None, mdl)
        elif name == "ssm":
            axes = (None,) * (nd - 4) + (BATCH_AXES, mdl, None, None)
        elif name == "h":
            axes = (None,) * (nd - 2) + (BATCH_AXES, mdl)
        elif name == "enc_out":
            axes = (BATCH_AXES,) + (None,) * (nd - 1)
        else:  # pos / length / position scalars
            axes = (None,) * nd
        return jax.NamedSharding(mesh, _fit_spec(axes[:nd], leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, state_shape)
