from repro.distributed.sharding import (  # noqa: F401
    BATCH_AXES,
    constrain,
    param_shardings,
    input_shardings,
)
