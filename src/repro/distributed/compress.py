"""int8 error-feedback gradient compression for the cross-pod reduction.

The paper's thesis — spend precision only where it buys accuracy — applied to
the collective roofline term: cross-pod gradient all-reduce is the longest
link (DCN vs ICI), so gradients cross it as block-scaled int8 with an
error-feedback residual carried to the next step (1-bit-Adam-family result:
EF keeps SGD/Adam convergence).  4x fewer bytes on the 'pod' axis, measured
in EXPERIMENTS.md section Perf.

Implementation: shard_map over the pod axis; psum of the dequantized local
int8 blocks (the quantization bounds what each pod *contributes*; XLA moves
int8 + f32 scales between pods when it materializes the reduction).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array

_BLOCK = 512


def _quantize_block(x: Array) -> tuple[Array, Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_block(q: Array, scale: Array, shape, size) -> Array:
    return (q.astype(jnp.float32) * scale).reshape(-1)[:size].reshape(shape)


def compress_decompress(x: Array) -> tuple[Array, Array]:
    """Round-trip int8 quantization; returns (approx, residual)."""
    q, s = _quantize_block(x)
    approx = _dequantize_block(q, s, x.shape, x.size)
    return approx, x - approx


def ef_reduce_leaf(g: Array, r: Array) -> tuple[Array, Array]:
    """Error-feedback int8 mean-reduction of one leaf over the 'pod' axis.
    MUST run inside a shard_map that is manual over 'pod' — this is what
    keeps the f32 all-reduce OUT of the backward pass (the collective moves
    int8 + per-block scales: 4x fewer bytes on the cross-pod link)."""
    corrected = g + r
    q, s = _quantize_block(corrected)
    approx = _dequantize_block(q, s, g.shape, g.size)
    new_r = corrected - approx  # error feedback
    q_all = jax.lax.all_gather(q, "pod")
    s_all = jax.lax.all_gather(s, "pod")
    n_pods = jax.lax.psum(1, "pod")
    summed = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
    reduced = summed.reshape(g.shape) / n_pods
    return reduced, new_r


def ef_reduce_tree(grads: Any, residuals: Any) -> tuple[Any, Any]:
    pairs = jax.tree.map(ef_reduce_leaf, grads, residuals)
    red = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return red, res


def compressed_psum_pod(grads: Any, residuals: Any, mesh) -> tuple[Any, Any]:
    """Error-feedback compressed mean-reduction over the 'pod' mesh axis.

    grads/residuals: pytrees replicated-over-pod in their sharded layout.
    Returns (reduced_grads, new_residuals).
    """
    if "pod" not in mesh.axis_names:
        return grads, residuals

    def local(g, r):
        corrected = g + r
        q, s = _quantize_block(corrected)
        approx = _dequantize_block(q, s, g.shape, g.size)
        new_r = corrected - approx  # error feedback
        # The collective moves int8 + per-block f32 scales (4x fewer bytes
        # than an f32 all-reduce) — this is what the roofline parser sees.
        q_all = jax.lax.all_gather(q, "pod")  # (n_pods, blocks, BLOCK) int8
        s_all = jax.lax.all_gather(s, "pod")
        n_pods = jax.lax.psum(1, "pod")
        summed = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
        reduced = summed.reshape(-1)[: g.size].reshape(g.shape) / n_pods
        return reduced, new_r

    def fn(g_tree, r_tree):
        pairs = jax.tree.map(local, g_tree, r_tree)
        red = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
        return red, res

    spec = jax.tree.map(lambda _: P(), grads)
    return jax.shard_map(
        fn,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec),
        axis_names={"pod"},  # manual over pod only; data/model stay GSPMD
        check_vma=False,
    )(grads, residuals)
