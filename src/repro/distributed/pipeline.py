"""GPipe-style pipeline parallelism over a mesh axis (DESIGN section 4, optional).

``pipeline_apply`` runs a homogeneous layer-stack across S pipeline stages:
stage i holds the i-th slice of the stacked parameters; microbatches stream
through the classic (M + S - 1)-step schedule with boundary activations moved
by ``ppermute``.  Implemented with shard_map manual over the stage axis.
AD flows through (ppermute transposes to the reverse permutation), so
jax.grad over the pipeline works for training.  Current limitation: the
shard_map must be manual over its whole mesh (partial-manual out_specs over a
mixed pod/data mesh trips an XLA normalization issue — the b/433785288 class);
use a dedicated stage axis / sub-mesh.  Validated exact (fwd + grad) in
tests/test_distributed.py.

Bubble fraction = (S-1)/(M+S-1) — choose M >> S.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


def pipeline_apply(
    stage_fn: Callable,
    stage_params,
    x_microbatches: Array,  # (M, microbatch, ...)
    mesh,
    axis: str = "pod",
):
    """Run ``stage_fn(params_i, x)`` across the ``axis`` mesh dimension as a
    pipeline.  ``stage_params`` leaves are stacked (S, ...).  Returns the
    (M, microbatch, ...) outputs, replicated over the stage axis."""
    n_stages = mesh.shape[axis]
    m = x_microbatches.shape[0]

    def run(params, xs):
        sid = jax.lax.axis_index(axis)
        last = n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        p_local = jax.tree.map(lambda p: p[0], params)  # (1, ...) -> (...)

        outs0 = jnp.zeros_like(xs)
        inflight0 = jnp.zeros_like(xs[0])

        def step(carry, t):
            outs, inflight = carry
            # stage 0 ingests microbatch t (clamped; masked below), others
            # consume the activation handed over by the previous stage
            x_in = jnp.where(
                sid == 0, xs[jnp.clip(t, 0, m - 1)], inflight
            )
            y = stage_fn(p_local, x_in)
            # the emitting microbatch index at the LAST stage is t-(S-1)
            idx = t - last
            take = (idx >= 0) & (sid == last)
            outs = jnp.where(
                take, outs.at[jnp.clip(idx, 0, m - 1)].set(y), outs
            )
            inflight = jax.lax.ppermute(y, axis, perm)
            return (outs, inflight), None

        (outs, _), _ = jax.lax.scan(
            step, (outs0, inflight0), jnp.arange(m + n_stages - 1)
        )
        # replicate the last stage's outputs across the axis
        outs = jax.lax.psum(
            jnp.where(sid == last, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    return jax.shard_map(
        run,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        axis_names={axis},
        check_vma=False,
    )(stage_params, x_microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
