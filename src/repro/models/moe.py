"""Mixture-of-Experts layer: GShard-style capacity routing, EP-sharded.

Dispatch/combine are one-hot contractions (semantically gathers) and stay in
native precision; the expert GEMMs — the FLOP hot spot — route through the
RMPM engine ('moe_expert' op class).  Routing groups are sequence chunks of
``moe_group_size`` tokens (batch dim stays data-sharded, expert dim is
model-sharded => the dispatch einsum is collective-free and the combine
reduces over experts with one psum over the model axis, inserted by GSPMD).

Decode (S == 1) groups over the batch instead, with capacity
ceil(B * top_k / E * cf) — keeping the expert-GEMM waste at ~cf instead of
the E/top_k x a per-token capacity grouping would cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, pein

Array = jax.Array


def moe_init(key, cfg) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.moe_experts, cfg.d_model, cfg.d_ff
    std = (2.0 / (d + f)) ** 0.5
    p = {
        "router": dense_init(ks[0], d, e, scale=0.02),
        "gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * std,
        "up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * std,
        "down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * std,
    }
    if cfg.moe_shared_experts:
        from repro.models.layers import swiglu_init

        p["shared"] = swiglu_init(ks[4], d, f * cfg.moe_shared_experts)
    return p


def _route(x: Array, router_w: Array, cfg) -> tuple[Array, Array, Array]:
    """x: (..., T, D) -> top-k (weights, ids) and router probs (aux loss)."""
    logits = pein("gtd,de->gte", x, router_w, "router", cfg.policy)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.moe_top_k)
    weights = weights / (weights.sum(axis=-1, keepdims=True) + 1e-9)
    return weights, ids, probs


def _dispatch_combine(ids: Array, weights: Array, e: int, capacity: int):
    """Build (G, T, E, C) dispatch one-hot and combine weights.

    Position-in-expert via cumulative sum over the token axis (GShard):
    tokens beyond capacity are dropped (their combine weight is 0).
    """
    g, t, k = ids.shape
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.float32)  # (G, T, K, E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(g, k * t, e)  # k-major: slot
    # priority: earlier tokens (and lower k) win capacity slots
    pos = jnp.cumsum(flat, axis=1) - 1.0  # (G, K*T, E)
    pos = pos.reshape(g, k, t, e).transpose(0, 2, 1, 3)  # (G, T, K, E)
    keep = (pos < capacity) & (onehot > 0)
    # Loop over the (small) k axis so the (G,T,E,C) slot tensor is never
    # materialized with a K dimension — 8x memory for kimi-scale MoE.
    dispatch = jnp.zeros((g, t, e, capacity), jnp.bfloat16)
    combine = jnp.zeros((g, t, e, capacity), jnp.bfloat16)
    for ki in range(k):
        slot = jax.nn.one_hot(pos[:, :, ki].astype(jnp.int32), capacity, dtype=jnp.float32)
        slot = slot * keep[:, :, ki, :, None]  # (G, T, E, C)
        dispatch = dispatch + slot.astype(jnp.bfloat16)
        combine = combine + (slot * weights[:, :, ki, None, None]).astype(jnp.bfloat16)
    return dispatch, combine


def moe_apply(p: Params, x: Array, cfg) -> tuple[Array, Array]:
    """x: (B, S, D) -> (out, aux_loss)."""
    policy = cfg.policy
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    if s == 1:  # decode: group over batch
        xg = x.reshape(1, b, d)
        t = b
    else:
        gs = min(cfg.moe_group_size, s)
        assert s % gs == 0, (s, gs)
        xg = x.reshape(b * (s // gs), gs, d)
        t = gs
    capacity = max(1, int(-(-t * k // e) * cfg.moe_capacity_factor))

    weights, ids, probs = _route(xg, p["router"]["w"], cfg)
    dispatch, combine = _dispatch_combine(ids, weights, e, capacity)
    # load-balance auxiliary loss (Switch): E * <f_e * p_e>
    frac_tokens = jnp.mean(
        jax.nn.one_hot(ids[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)

    xin = jnp.einsum(  # gather: native precision (one-hot)
        "gtec,gtd->gecd", dispatch, xg.astype(jnp.bfloat16)
    ).astype(jnp.float32)
    h_gate = pein("gecd,edf->gecf", xin, p["gate"], "moe_expert", policy)
    h_up = pein("gecd,edf->gecf", xin, p["up"], "moe_expert", policy)
    h = jax.nn.silu(h_gate) * h_up
    out_e = pein("gecf,efd->gecd", h, p["down"], "moe_expert", policy)
    out = jnp.einsum(
        "gtec,gecd->gtd", combine, out_e.astype(jnp.bfloat16)
    ).astype(jnp.float32)
    out = out.reshape(b, s, d)

    if "shared" in p:
        from repro.models.layers import swiglu_apply

        out = out + swiglu_apply(p["shared"], x, policy)
    return out, aux
