"""Model zoo: every linear/contraction routes through the RMPM engine."""
from repro.models.config import ArchConfig  # noqa: F401
from repro.models.lm import LanguageModel, build_model  # noqa: F401
