"""Architecture configuration shared by every assigned model family."""
from __future__ import annotations

import dataclasses

from repro.core.policy import NATIVE_F32, PAPER_BASELINE, PrecisionPolicy


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    moe_first_dense: int = 0  # leading dense layers before MoE stack
    moe_group_size: int = 512  # dispatch group (tokens) for capacity routing

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (RG-LRU + local attention, Griffin pattern rec,rec,attn)
    hybrid_pattern: tuple[str, ...] = ()
    local_window: int = 0  # sliding-window size for local attention (0 = full)

    # enc-dec
    n_encoder_layers: int = 0

    # vlm
    n_vision_tokens: int = 0

    # execution
    attn_shard: str = "heads"  # 'heads' (TP) | 'sequence' (SP) — see sharding.py
    attn_chunk: int = 1024  # flash-attention KV chunk (memory-roofline lever)
    param_dtype: str = "float32"
    kv_cache_dtype: str = "bfloat16"  # 'bfloat16' | 'int8' (precision lever)
    remat: bool = True
    fsdp: bool = False  # additionally shard params over the data axis
    policy: PrecisionPolicy = PAPER_BASELINE

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode with O(1)/O(window) state (long_500k gate)?"""
        return self.family in ("ssm", "hybrid")

    def with_policy(self, policy: PrecisionPolicy) -> "ArchConfig":
        return dataclasses.replace(self, policy=policy)

    def for_cpu_example(self) -> "ArchConfig":
        return dataclasses.replace(self, policy=NATIVE_F32, remat=False)


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Shrink any config to a CPU-runnable smoke size, same family/topology."""
    changes: dict = dict(
        n_layers=min(cfg.n_layers, 4 if not cfg.hybrid_pattern else 6),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=256,
        vocab=512,
        head_dim=32,
        attn_chunk=64,
        remat=False,
        moe_group_size=64,
    )
    if cfg.moe_experts:
        changes.update(moe_experts=4, moe_top_k=min(cfg.moe_top_k, 2), moe_first_dense=min(cfg.moe_first_dense, 1))
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.local_window:
        changes.update(local_window=32)
    if cfg.n_encoder_layers:
        changes.update(n_encoder_layers=2)
    if cfg.n_vision_tokens:
        changes.update(n_vision_tokens=16)
    if cfg.hybrid_pattern:
        changes.update(n_layers=6)  # two (rec, rec, attn) groups + remainder 0
    return dataclasses.replace(cfg, **changes)
