"""RecurrentGemma / Griffin hybrid blocks  [arXiv:2402.19427].

Residual pattern (rec, rec, attn) repeating (1 local-attention block per 2
RG-LRU recurrent blocks).  Projections route through RMPM; the RG-LRU gate /
diagonal recurrence is elementwise (f32, technique N/A — DESIGN.md).

Train: associative scan over the sequence.  Decode: O(1) state update.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import (
    Params,
    causal_conv1d,
    dense_init,
    pein,
)

Array = jax.Array

_C = 8.0  # Griffin's fixed scaling of the recurrence gate exponent


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RGLRUState:
    conv: Array  # (B, K-1, W)
    h: Array  # (B, W) recurrent hidden state


def rglru_init(key, cfg) -> Params:
    d = cfg.d_model
    w = d  # lru width = d_model (RecurrentGemma)
    ks = jax.random.split(key, 6)
    return {
        "in_x": dense_init(ks[0], d, w),
        "in_gate": dense_init(ks[1], d, w),
        "conv_w": jax.random.normal(ks[2], (4, w), jnp.float32) * 0.2,
        "wa": dense_init(ks[3], w, w, scale=0.02),
        "wx": dense_init(ks[4], w, w, scale=0.02),
        # Lambda init so a = sigmoid(lam)^(c r) sits in [0.9, 0.999]
        "lam": jnp.log(jnp.linspace(0.9, 0.999, w) / (1 - jnp.linspace(0.9, 0.999, w))).astype(jnp.float32),
        "out": dense_init(ks[5], w, d),
    }


def _rglru_scan(x: Array, r: Array, i: Array, lam: Array, h0: Array | None):
    """h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t), associative.

    x, r, i: (B, S, W); lam: (W,).  Returns (h_seq, h_last).
    """
    log_a_base = jax.nn.log_sigmoid(lam)[None, None, :]  # (1,1,W), negative
    log_a = _C * r * log_a_base  # (B, S, W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    if h0 is not None:
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0[:, None, :], gated], axis=1)
    a_sc, h_sc = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h_sc = h_sc[:, 1:]
    return h_sc, h_sc[:, -1]


def rglru_block_apply(
    p: Params, x: Array, cfg, state: RGLRUState | None = None
) -> tuple[Array, RGLRUState | None]:
    """Griffin recurrent residual block body. x: (B, S, D)."""
    policy = cfg.policy
    gate = jax.nn.gelu(pein("bsd,dw->bsw", x, p["in_gate"]["w"], "mlp_up", policy))
    xr = pein("bsd,dw->bsw", x, p["in_x"]["w"], "mlp_up", policy)
    conv_out, conv_state = causal_conv1d(
        xr, p["conv_w"], state.conv if state is not None else None
    )
    r = jax.nn.sigmoid(pein("bsw,wv->bsv", conv_out, p["wa"]["w"], "rnn_gate", policy))
    i = jax.nn.sigmoid(pein("bsw,wv->bsv", conv_out, p["wx"]["w"], "rnn_gate", policy))
    h, h_last = _rglru_scan(
        conv_out, r, i, p["lam"], state.h if state is not None else None
    )
    out = pein("bsw,wd->bsd", h * gate, p["out"]["w"], "mlp_down", policy)
    new_state = RGLRUState(conv=conv_state, h=h_last) if state is not None else None
    return out, new_state


def rglru_state_init(cfg, batch: int) -> RGLRUState:
    w = cfg.d_model
    return RGLRUState(
        conv=jnp.zeros((batch, 3, w), jnp.float32),
        h=jnp.zeros((batch, w), jnp.float32),
    )
