"""Family-dispatching language model: dense / moe / vlm / ssm / hybrid / encdec.

Functional style: ``init(key) -> params`` pytree; ``apply(params, batch)`` for
the training forward; ``prefill``/``decode_step`` for serving.  Layers execute
under ``lax.scan`` over stacked parameters (one compiled block body) with
optional remat — essential for compile time at 512 devices.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import BATCH_AXES, constrain
from repro.models import griffin as griffin_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ArchConfig
from repro.models.layers import (
    Params,
    attention_apply,
    attention_init,
    dense_init,
    gelu_mlp_apply,
    gelu_mlp_init,
    kv_cache_init,
    pein,
    rms_norm,
    stack_tree,
    stacked,
    swiglu_apply,
    swiglu_init,
)

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecodeState:
    caches: Any  # family-specific pytree of stacked caches/states
    position: Array  # scalar int32; (B,) int32 in per-slot (serving) layout
    enc_out: Array | None = None  # encdec: encoder activations


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ArchConfig, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind in ("dense", "moe", "enc", "dec"):
        p["attn"] = attention_init(ks[0], cfg)
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if kind == "moe":
            p["moe"] = moe_lib.moe_init(ks[1], cfg)
        elif kind in ("enc", "dec"):
            p["mlp"] = gelu_mlp_init(ks[1], cfg.d_model, cfg.d_ff)
        else:
            p["mlp"] = swiglu_init(ks[1], cfg.d_model, cfg.d_ff)
        if kind == "dec":
            p["cross"] = attention_init(ks[2], cfg)
            p["norm3"] = jnp.zeros((cfg.d_model,), jnp.float32)
    elif kind == "ssm":
        p["ssm"] = ssm_lib.mamba2_init(ks[0], cfg)
    elif kind == "rec":
        p["rec"] = griffin_lib.rglru_init(ks[0], cfg)
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["mlp"] = swiglu_init(ks[1], cfg.d_model, cfg.d_ff)
    elif kind == "attn_local":
        p["attn"] = attention_init(ks[0], cfg)
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["mlp"] = swiglu_init(ks[1], cfg.d_model, cfg.d_ff)
    else:
        raise ValueError(kind)
    return p


def _constrain_act(x: Array, cfg: ArchConfig) -> Array:
    if cfg.attn_shard == "sequence":
        return constrain(x, BATCH_AXES, "model", None)
    return constrain(x, BATCH_AXES, None, None)


def _block_apply(
    p: Params,
    x: Array,
    cfg: ArchConfig,
    kind: str,
    *,
    positions: Array | None = None,
    cache=None,
    enc_out: Array | None = None,
):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    x = _constrain_act(x, cfg)
    window = cfg.local_window if kind == "attn_local" else 0
    if kind in ("dense", "moe", "enc", "dec", "attn_local"):
        h, new_attn_cache = attention_apply(
            p["attn"],
            rms_norm(x, p["norm1"], cfg.norm_eps),
            cfg,
            positions=positions,
            cache=cache["attn"] if isinstance(cache, dict) and "attn" in cache else cache,
            window=window,
            causal=(kind != "enc"),
        )
        x = x + h
        if kind == "dec":
            h, _ = attention_apply(
                p["cross"],
                rms_norm(x, p["norm3"], cfg.norm_eps),
                cfg,
                kv_override=(enc_out, enc_out),
            )
            x = x + h
        xi = rms_norm(x, p["norm2"], cfg.norm_eps)
        if kind == "moe":
            h, aux = moe_lib.moe_apply(p["moe"], xi, cfg)
        elif kind in ("enc", "dec"):
            h = gelu_mlp_apply(p["mlp"], xi, cfg.policy)
        else:
            h = swiglu_apply(p["mlp"], xi, cfg.policy)
        x = x + h
        return x, new_attn_cache, aux
    if kind == "ssm":
        h, new_state = ssm_lib.mamba2_apply(
            p["ssm"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, state=cache
        )
        return x + h, new_state, aux
    if kind == "rec":
        h, new_state = griffin_lib.rglru_block_apply(
            p["rec"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, state=cache
        )
        x = x + h
        h = swiglu_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg.policy)
        return x + h, new_state, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------


def _layer_kinds(cfg: ArchConfig) -> list[str]:
    if cfg.family in ("dense", "vlm"):
        return ["dense"] * cfg.n_layers
    if cfg.family == "moe":
        return ["dense"] * cfg.moe_first_dense + ["moe"] * (
            cfg.n_layers - cfg.moe_first_dense
        )
    if cfg.family == "ssm":
        return ["ssm"] * cfg.n_layers
    if cfg.family == "hybrid":
        pat = cfg.hybrid_pattern or ("rec", "rec", "attn_local")
        kinds = [pat[i % len(pat)] for i in range(cfg.n_layers)]
        return kinds
    if cfg.family == "encdec":
        return ["dec"] * cfg.n_layers
    raise ValueError(cfg.family)


class LanguageModel:
    """cfg-driven functional model covering all assigned families."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.kinds = _layer_kinds(cfg)
        # contiguous runs of identical layer kinds are scanned together
        self.segments: list[tuple[str, int]] = []
        for kd in self.kinds:
            if self.segments and self.segments[-1][0] == kd:
                self.segments[-1] = (kd, self.segments[-1][1] + 1)
            else:
                self.segments.append((kd, 1))
        # hybrid: scan over the repeating supergroup instead of per-kind runs
        if cfg.family == "hybrid":
            pat = cfg.hybrid_pattern or ("rec", "rec", "attn_local")
            n_super, rem = divmod(cfg.n_layers, len(pat))
            self.hybrid_pat = pat
            self.n_super = n_super
            self.hybrid_rem = [pat[i] for i in range(rem)]

    # -- init ---------------------------------------------------------------

    def init(self, key) -> Params:
        cfg = self.cfg
        k_emb, k_layers, k_out, k_enc, k_extra = jax.random.split(key, 5)
        params: Params = {
            "embed": {
                "w": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), jnp.float32)
                * 0.02
            },
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if cfg.family == "hybrid":
            params["super"] = {
                f"l{i}_{kd}": stacked(
                    jax.random.split(jax.random.fold_in(k_layers, i), self.n_super),
                    _block_init,
                    cfg,
                    kd,
                )
                for i, kd in enumerate(self.hybrid_pat)
            }
            if self.hybrid_rem:
                rem_keys = jax.random.split(k_extra, len(self.hybrid_rem))
                params["rem"] = {
                    f"l{i}_{kd}": _block_init(rem_keys[i], cfg, kd)
                    for i, kd in enumerate(self.hybrid_rem)
                }
        else:
            params["layers"] = {}
            seg_keys = jax.random.split(k_layers, len(self.segments))
            for si, (kd, n) in enumerate(self.segments):
                params["layers"][f"seg{si}_{kd}"] = stacked(
                    jax.random.split(seg_keys[si], n), _block_init, cfg, kd
                )
        if cfg.family == "encdec":
            params["enc_layers"] = stacked(
                jax.random.split(k_enc, cfg.n_encoder_layers), _block_init, cfg, "enc"
            )
        if not cfg.tie_embeddings:
            params["unembed"] = dense_init(k_out, cfg.d_model, cfg.vocab, scale=0.02)
        return params

    # -- shared helpers -------------------------------------------------------

    def _embed(self, params: Params, tokens: Array) -> Array:
        x = params["embed"]["w"][tokens]  # gather — native
        return _constrain_act(x.astype(jnp.float32), self.cfg)

    def _logits(self, params: Params, x: Array) -> Array:
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = pein("bsd,vd->bsv", x, params["embed"]["w"], "logits", cfg.policy)
        else:
            logits = pein(
                "bsd,dv->bsv", x, params["unembed"]["w"], "logits", cfg.policy
            )
        if cfg.attn_shard == "sequence":
            return constrain(logits, BATCH_AXES, "model", None)
        return constrain(logits, BATCH_AXES, None, "model")

    def _scan_segment(self, seg_params, x, kind, *, caches=None, positions=None, enc_out=None):
        """lax.scan over a stacked segment.  Returns (x, new_caches, aux)."""
        cfg = self.cfg

        def body(carry, layer):
            xc, aux = carry
            lp, lcache = layer
            xo, new_cache, a = _block_apply(
                lp, xc, cfg, kind, positions=positions, cache=lcache, enc_out=enc_out
            )
            return (xo, aux + a), new_cache

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), new_caches = jax.lax.scan(
            body_fn, (x, jnp.float32(0.0)), (seg_params, caches)
        )
        return x, new_caches, aux

    # -- training forward -----------------------------------------------------

    def apply(self, params: Params, batch: dict[str, Array]) -> tuple[Array, Array]:
        """Returns (logits, aux_loss)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            return self._apply_encdec(params, batch)
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        if cfg.family == "vlm":
            pix = batch["pixel_embeds"].astype(jnp.float32)  # (B, n_vis, D)
            x = jnp.concatenate([pix, x], axis=1)
        positions = jnp.arange(x.shape[1])
        aux_total = jnp.float32(0.0)
        if cfg.family == "hybrid":
            x, aux_total = self._hybrid_stack(params, x, positions)
        else:
            for si, (kd, _) in enumerate(self.segments):
                x, _, aux = self._scan_segment(
                    params["layers"][f"seg{si}_{kd}"], x, kd, positions=positions
                )
                aux_total = aux_total + aux
        if cfg.family == "vlm":
            x = x[:, batch["pixel_embeds"].shape[1] :]
        return self._logits(params, x), aux_total

    def _hybrid_stack(self, params, x, positions, caches=None):
        """Scan over supergroups of the repeating hybrid pattern."""
        cfg = self.cfg
        pat = self.hybrid_pat

        def body(carry, layer):
            xc, aux = carry
            lp, lcaches = layer
            new_caches = {}
            for i, kd in enumerate(pat):
                key = f"l{i}_{kd}"
                xc, nc, a = _block_apply(
                    lp[key],
                    xc,
                    cfg,
                    kd,
                    positions=positions,
                    cache=None if lcaches is None else lcaches[key],
                )
                aux = aux + a
                new_caches[key] = nc
            return (xc, aux), (None if lcaches is None else new_caches)

        body_fn = jax.checkpoint(body) if cfg.remat else body
        sup_caches = None if caches is None else caches["super"]
        (x, aux), new_sup = jax.lax.scan(
            body_fn, (x, jnp.float32(0.0)), (params["super"], sup_caches)
        )
        new_caches = {"super": new_sup, "rem": {}}
        for i, kd in enumerate(self.hybrid_rem):
            key = f"l{i}_{kd}"
            rc = None if caches is None else caches["rem"][key]
            x, nc, a = _block_apply(
                params["rem"][key], x, cfg, kd, positions=positions, cache=rc
            )
            aux = aux + a
            new_caches["rem"][key] = nc
        if caches is None:
            return x, aux
        return x, aux, new_caches

    def _apply_encdec(self, params, batch):
        cfg = self.cfg
        frames = batch["frames"].astype(jnp.float32)  # (B, S_enc, D) stub embeds
        enc = _constrain_act(frames, cfg)
        enc_pos = jnp.arange(enc.shape[1])
        enc, _, _ = self._scan_segment(
            params["enc_layers"], enc, "enc", positions=enc_pos
        )
        enc = rms_norm(enc, params["final_norm"], cfg.norm_eps)  # shared final norm
        x = self._embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1])
        x, _, aux = self._scan_segment(
            params["layers"]["seg0_dec"], x, "dec", positions=positions, enc_out=enc
        )
        return self._logits(params, x), aux

    # -- serving ----------------------------------------------------------------

    def init_decode_state(self, batch: int, max_len: int, enc_len: int = 0,
                          per_slot: bool = False) -> DecodeState:
        """``per_slot=True`` builds the continuous-batching layout: every KV
        cache carries (B,) lengths / (B, Smax) positions and ``position`` is
        (B,), so slots at different sequence depths share one compiled decode
        step (DESIGN.md section Serving)."""
        cfg = self.cfg
        hd, hkv = cfg.head_dim, cfg.n_kv_heads

        def kv(n, cap=None):
            return stack_tree(
                n, kv_cache_init(batch, cap or max_len, hkv, hd,
                                 cfg.kv_cache_dtype, per_slot=per_slot)
            )

        if cfg.family in ("dense", "vlm", "moe", "encdec"):
            caches = {
                f"seg{si}_{kd}": kv(n) for si, (kd, n) in enumerate(self.segments)
            }
        elif cfg.family == "ssm":
            caches = {
                f"seg{si}_{kd}": stack_tree(n, ssm_lib.ssm_state_init(cfg, batch))
                for si, (kd, n) in enumerate(self.segments)
            }
        elif cfg.family == "hybrid":
            sup = {}
            for i, kd in enumerate(self.hybrid_pat):
                if kd == "rec":
                    sup[f"l{i}_{kd}"] = stack_tree(
                        self.n_super, griffin_lib.rglru_state_init(cfg, batch)
                    )
                else:  # local attention: cache only the window (ring buffer)
                    wlen = min(max_len, cfg.local_window or max_len)
                    sup[f"l{i}_{kd}"] = kv(self.n_super, cap=wlen)
            rem = {
                f"l{i}_{kd}": (
                    griffin_lib.rglru_state_init(cfg, batch)
                    if kd == "rec"
                    else kv_cache_init(
                        batch,
                        min(max_len, cfg.local_window or max_len),
                        hkv,
                        hd,
                        cfg.kv_cache_dtype,
                        per_slot=per_slot,
                    )
                )
                for i, kd in enumerate(self.hybrid_rem)
            }
            caches = {"super": sup, "rem": rem}
        else:
            raise ValueError(cfg.family)
        position = jnp.zeros((batch,), jnp.int32) if per_slot else jnp.int32(0)
        return DecodeState(caches=caches, position=position, enc_out=None)

    def decode_step(
        self,
        params: Params,
        tokens: Array,
        state: DecodeState,
        pixel_embeds: Array | None = None,
    ) -> tuple[Array, DecodeState]:
        """tokens: (B, S_step) — one (or a few) new token(s) per sequence.
        ``pixel_embeds`` (VLM prefill): patch embeddings prepended to the
        prompt."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if pixel_embeds is not None:
            x = jnp.concatenate([pixel_embeds.astype(jnp.float32), x], axis=1)
        # per-slot layout: position (B,) -> positions (B, S); shared: (S,)
        pos0 = state.position
        positions = (pos0[:, None] if pos0.ndim else pos0) + jnp.arange(x.shape[1])
        new_caches = {}
        if cfg.family == "hybrid":
            x, _, new_caches = self._hybrid_stack(
                params, x, positions, caches=state.caches
            )
        else:
            enc_out = state.enc_out
            for si, (kd, _) in enumerate(self.segments):
                key = f"seg{si}_{kd}"
                x, nc, _ = self._scan_segment(
                    params["layers"][key] if "layers" in params else params[key],
                    x,
                    kd,
                    caches=state.caches[key],
                    positions=positions,
                    enc_out=enc_out,
                )
                new_caches[key] = nc
        if pixel_embeds is not None:
            x = x[:, pixel_embeds.shape[1] :]
        logits = self._logits(params, x)
        new_state = DecodeState(
            caches=new_caches,
            position=state.position + (tokens.shape[1] if pixel_embeds is None
                                       else tokens.shape[1] + pixel_embeds.shape[1]),
            enc_out=state.enc_out,
        )
        return logits, new_state

    def prefill_encoder(self, params: Params, frames: Array, state: DecodeState) -> DecodeState:
        cfg = self.cfg
        enc = _constrain_act(frames.astype(jnp.float32), cfg)
        enc, _, _ = self._scan_segment(
            params["enc_layers"], enc, "enc", positions=jnp.arange(enc.shape[1])
        )
        enc = rms_norm(enc, params["final_norm"], cfg.norm_eps)
        return dataclasses.replace(state, enc_out=enc)


def build_model(cfg: ArchConfig) -> LanguageModel:
    return LanguageModel(cfg)
