"""Mamba2 / SSD (state-space duality) block  [arXiv:2405.21060].

The SSD chunked algorithm is matmul-dominated — exactly the workload the
paper's engine targets: the intra-chunk quadratic term and the inter-chunk
state GEMMs route through RMPM ('ssd' op class).  The recurrent gate/decay
algebra itself is elementwise (not a GEMM) and runs in f32 — the technique is
N/A to the scan, as recorded in DESIGN.md section Arch-applicability.

Train: chunked dual form (quadratic intra-chunk + linear inter-chunk scan).
Decode: O(1) recurrent state update per token.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import Params, causal_conv1d, dense_init, pein

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMState:
    conv: Array  # (B, K-1, conv_channels)
    ssm: Array  # (B, H, P, N)


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads


def mamba2_init(key, cfg) -> Params:
    d, n = cfg.d_model, cfg.ssm_state
    d_inner, n_heads = _dims(cfg)
    ks = jax.random.split(key, 4)
    # in_proj -> [z (gate), x, B, C, dt]
    d_proj = 2 * d_inner + 2 * n + n_heads
    conv_ch = d_inner + 2 * n  # conv over x, B, C
    return {
        "in_proj": dense_init(ks[0], d, d_proj),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32) * 0.2,
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[2], d_inner, d),
    }


def _segsum(x: Array) -> Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (i >= j)."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(xh, dt, a, bmat, cmat, cfg, h0=None):
    """SSD dual form over chunks.

    xh: (B, S, H, P); dt: (B, S, H); a: (H,) negative decay rates;
    bmat/cmat: (B, S, N).  Returns (y, final_state (B, H, P, N)).
    """
    policy = cfg.policy
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, (s, q)
    c = s // q
    xs = xh.reshape(b, c, q, h, p)
    dts = dt.reshape(b, c, q, h)
    bs = bmat.reshape(b, c, q, n)
    cs = cmat.reshape(b, c, q, n)

    da = dts * a[None, None, None, :]  # (B, C, Q, H) negative
    da_cum = jnp.cumsum(da, axis=2)

    # --- intra-chunk (quadratic, matmul-heavy) ---
    l_mat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))  # (B, C, H, Q, Q)
    scores = pein("bcqn,bckn->bcqk", cs, bs, "ssd", policy)  # (B, C, Q, Q)
    gated = scores[:, :, None] * l_mat  # (B, C, H, Q, Q)
    xdt = xs * dts[..., None]  # (B, C, Q, H, P)
    y_intra = pein("bchqk,bckhp->bcqhp", gated, xdt, "ssd", policy)

    # --- chunk states ---
    decay_to_end = jnp.exp(da_cum[:, :, -1:, :] - da_cum)  # (B, C, Q, H)
    states = pein(
        "bcqn,bcqhp->bchpn", bs, xdt * decay_to_end[..., None], "ssd", policy
    )  # (B, C, H, P, N)

    # --- inter-chunk recurrence over C (sequential scan, tiny) ---
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])  # (B, C, H)

    def step(carry, inp):
        st, dec = inp  # (B, H, P, N), (B, H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit PREVIOUS state (state entering the chunk)

    init = (
        h0 if h0 is not None else jnp.zeros((b, h, p, n), jnp.float32)
    )
    final, prev_states = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B, C, H, P, N)

    # --- inter-chunk output ---
    decay_from_start = jnp.exp(da_cum)  # (B, C, Q, H)
    c_gated = cs[:, :, :, None, :] * decay_from_start[..., None]  # (B,C,Q,H,N)
    y_inter = pein("bcqhn,bchpn->bcqhp", c_gated, prev_states, "ssd", policy)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final


def mamba2_apply(
    p: Params, x: Array, cfg, state: SSMState | None = None
) -> tuple[Array, SSMState | None]:
    """x: (B, S, d_model).  state!=None -> decode (S small, sequential)."""
    policy = cfg.policy
    b, s, _ = x.shape
    d_inner, n_heads = _dims(cfg)
    n = cfg.ssm_state
    proj = pein("bsd,de->bse", x, p["in_proj"]["w"], "ssm_in", policy)
    z, xbc, dt_raw = jnp.split(proj, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    conv_in = xbc  # (B, S, d_inner + 2N)
    conv_out, conv_state = causal_conv1d(
        conv_in, p["conv_w"], state.conv if state is not None else None
    )
    conv_out = jax.nn.silu(conv_out)
    xh, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    xh = xh.reshape(b, s, n_heads, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"][None, None, :])  # (B, S, H)
    a = -jnp.exp(p["a_log"])  # (H,) negative

    if state is None:
        y, final = _ssd_chunked(xh, dt, a, bmat, cmat, cfg)
        new_state = None
    elif s > 1 and s % min(cfg.ssm_chunk, s) == 0:
        # multi-token prefill: the chunked DUAL form with the carried state —
        # the sequential recurrence would round-trip the (B,H,P,N) state
        # through HBM once per token (measured 5.5e14 B/device at 32k,
        # EXPERIMENTS.md section Perf cell E)
        y, final = _ssd_chunked(xh, dt, a, bmat, cmat, cfg, h0=state.ssm)
        new_state = SSMState(conv=conv_state, ssm=final)
    else:
        # recurrent: h = exp(dt*a) h + dt * B x ; y = C h   (per step)
        def step(h, inp):
            xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
            decay = jnp.exp(dtt * a[None, :])[..., None, None]
            h = h * decay + (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]
            yt = jnp.einsum("bhpn,bn->bhp", h, ct)
            return h, yt

        final, ys = jax.lax.scan(
            step,
            state.ssm,
            (
                xh.transpose(1, 0, 2, 3),
                dt.transpose(1, 0, 2),
                bmat.transpose(1, 0, 2),
                cmat.transpose(1, 0, 2),
            ),
        )
        y = ys.transpose(1, 0, 2, 3)  # (B, S, H, P)
        new_state = SSMState(conv=conv_state, ssm=final)

    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_inner)
    # gated RMSNorm (Mamba2)
    y = y * jax.nn.silu(z)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * (1.0 + p["norm_scale"])
    out = pein("bse,ed->bsd", y, p["out_proj"]["w"], "ssm_out", policy)
    return out, new_state


def ssm_state_init(cfg, batch: int) -> SSMState:
    d_inner, n_heads = _dims(cfg)
    conv_ch = d_inner + 2 * cfg.ssm_state
    return SSMState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.float32),
        ssm=jnp.zeros((batch, n_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    )
