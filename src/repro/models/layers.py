"""Shared layers.  Every contraction routes through the RMPM engine (C1):
the paper's multi-precision multiplier is the only multiplier in the system.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.adapt.runtime_policy import runtime_mode_for
from repro.core.policy import PrecisionPolicy
from repro.core.precision import F32_MODES, DoubleF32
from repro.core.rmpm import mp_einsum, mp_einsum_runtime, mp_matmul_runtime
from repro.plan import execute, plan_matmul

Array = jax.Array
Params = dict[str, Any]

# ---------------------------------------------------------------------------
# Policy-routed contractions
# ---------------------------------------------------------------------------


def pmm(x: Array, w: Array, op: str, policy: PrecisionPolicy) -> Array:
    """Policy-routed matmul: the op-class name selects the precision mode
    (the paper's application-program-driven mode-select bits), the planner
    (repro.plan) selects Strassen depth and — when ``policy.impl='auto'`` —
    the execution impl.  Planning happens at trace time on static shapes and
    is cached, so a scanned layer stack plans each distinct GEMM once.

    When the call-site is bound to a runtime mode scalar (repro.adapt's
    ``bind_modes``, installed by the adaptive serve/train steps), the plan's
    static mode becomes merely the initial condition: execution routes
    through ``mp_matmul_runtime`` with the plan's impl/tuned block
    preserved, and the scalar — a jit argument — reconfigures precision
    with zero recompiles.  Tile-eligible plans (``Plan.tile_eligible``:
    pallas-class f32 plans) take the partitioned-SIMD kernel — the scalar
    becomes a uniform per-tile mode map inside ONE fused dispatch,
    bit-identical to the pallas branch the ``lax.switch`` would have
    picked; other impls keep the N-branch switch.  Only f32-ladder plans
    are switchable; DF32/Strassen plans keep their static path.
    """
    plan = plan_matmul(
        tuple(x.shape),
        tuple(w.shape),
        mode=policy.mode_for(op),
        impl=None if policy.impl == "auto" else policy.impl,
        rounding=policy.rounding,
        max_depth=policy.max_strassen_depth,
    )
    rt_mode = runtime_mode_for(op)
    if (
        rt_mode is not None
        and plan.mode in F32_MODES
        and plan.dtype == "float32"
        and not isinstance(x, DoubleF32)
    ):
        # runtime reconfiguration wins over the plan's Strassen depth: the
        # switch branches are classical (depth applies per static mode only).
        # Mode tables hold concrete modes, so the AUTO operand probe is
        # skipped (allow_auto=False — it would re-read both operands).
        # 'native' cannot express a mode switch; xla keeps the lax.switch
        impl = "tile" if plan.tile_eligible else "xla"
        return mp_matmul_runtime(
            x, w, rt_mode, rounding=plan.rounding,
            impl=impl, block=plan.block, allow_auto=False,
        )
    return execute(plan, x, w)


def pein(eq: str, a: Array, b: Array, op: str, policy: PrecisionPolicy) -> Array:
    mode = policy.mode_for(op)
    rt_mode = runtime_mode_for(op)
    if (
        rt_mode is not None
        and mode in F32_MODES
        and not isinstance(a, DoubleF32)
        and not isinstance(b, DoubleF32)
    ):
        # bound sites always run the limb engine: a 'native' policy impl
        # (plain f32, mode-blind) cannot express a mode switch, so the xla
        # limb algebra is the runtime path even for native policies —
        # adaptation trades the native fast path for reconfigurability
        impl = policy.impl if policy.impl in ("xla", "pallas", "tile") else "xla"
        return mp_einsum_runtime(
            eq, a, b, rt_mode, rounding=policy.rounding, impl=impl
        )
    return mp_einsum(
        eq, a, b, mode, rounding=policy.rounding, impl=policy.impl
    )


def plinear(x: Array, p: Params, op: str, policy: PrecisionPolicy) -> Array:
    out = pmm(x, p["w"], op, policy)
    if "b" in p:
        out = out + p["b"].astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, bias: bool = False, scale: float | None = None) -> Params:
    std = scale if scale is not None else (2.0 / (d_in + d_out)) ** 0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * std}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def stacked(keys, init_fn, *args, **kw):
    """Initialize per-layer params stacked along a leading layer axis
    (matches the lax.scan-over-layers execution)."""
    return jax.vmap(lambda k: init_fn(k, *args, **kw))(keys)


# ---------------------------------------------------------------------------
# Norms / activations / RoPE
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * (1.0 + scale)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
        ang = ang[None, :, None, :]  # (1, S, 1, half)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# KV cache (bf16 or block-scaled int8 — the paper's precision lever applied
# to decode memory, section Perf)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: Array  # (B, Smax, Hkv, hd)  bf16 or int8
    v: Array
    k_scale: Array | None  # (B, Smax, Hkv, 1) f32 when int8
    v_scale: Array | None
    pos: Array  # (Smax,) int32 — global position stored in each slot (-1 empty)
    # per-slot layout (continuous batching): pos is (B, Smax)
    length: Array  # scalar int32 — total tokens ever appended; (B,) per-slot


def kv_cache_init(batch: int, s_max: int, n_kv: int, hd: int, dtype: str,
                  per_slot: bool = False) -> KVCache:
    # distinct k/v buffers: donated arguments must not alias
    if per_slot:
        pos = jnp.full((batch, s_max), -1, jnp.int32)
        length = jnp.zeros((batch,), jnp.int32)
    else:
        pos = jnp.full((s_max,), -1, jnp.int32)
        length = jnp.int32(0)

    def z(dt):
        # distinct buffers per call: k/v must never alias (donation)
        return jnp.zeros((batch, s_max, n_kv, hd), dt)

    if dtype == "int8":
        def s():
            return jnp.zeros((batch, s_max, n_kv, 1), jnp.float32)

        return KVCache(z(jnp.int8), z(jnp.int8), s(), s(), pos, length)
    return KVCache(z(jnp.bfloat16), z(jnp.bfloat16), None, None, pos, length)


def stack_tree(n: int, tree):
    """Stack a cache/state pytree along a new leading layer axis."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)


def _quant_rows(x: Array) -> tuple[Array, Array]:
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def kv_cache_append(cache: KVCache, k_new: Array, v_new: Array) -> KVCache:
    """Append (B, S_new, Hkv, hd) f32 at slot length % capacity (ring buffer
    for sliding-window caches; plain append while length < capacity).
    Multi-token appends must not straddle the wrap point (prefill sizes the
    cache to the prompt, so wrap only occurs in 1-token decode steps).
    """
    cap = cache.k.shape[1]
    s_new = k_new.shape[1]
    if s_new > cap:
        # prefill longer than the (sliding-window) cache: keep the tail only
        drop = s_new - cap
        k_new = k_new[:, drop:]
        v_new = v_new[:, drop:]
        new_pos = cache.length + drop + jnp.arange(cap, dtype=jnp.int32)
        length = cache.length + s_new
        cache = KVCache(cache.k, cache.v, cache.k_scale, cache.v_scale,
                        new_pos, cache.length)
        s_new = cap
        slot = jnp.int32(0)
        pos = new_pos
        total = length
    else:
        slot = jax.lax.rem(cache.length, cap)
        pos = jax.lax.dynamic_update_slice(
            cache.pos, cache.length + jnp.arange(s_new, dtype=jnp.int32), (slot,)
        )
        total = cache.length + s_new
    if cache.k_scale is not None:
        kq, ks = _quant_rows(k_new)
        vq, vs = _quant_rows(v_new)
        k = jax.lax.dynamic_update_slice(cache.k, kq, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, vq, (0, slot, 0, 0))
        kss = jax.lax.dynamic_update_slice(cache.k_scale, ks, (0, slot, 0, 0))
        vss = jax.lax.dynamic_update_slice(cache.v_scale, vs, (0, slot, 0, 0))
        return KVCache(k, v, kss, vss, pos, total)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))
    return KVCache(k, v, None, None, pos, total)


def _kv_append_row(c: KVCache, k_new: Array, v_new: Array) -> KVCache:
    """Single-row append: ``c`` leaves carry no batch dim (k: (Smax, Hkv, hd),
    pos: (Smax,), length: scalar).  Mirrors ``kv_cache_append`` including the
    ring wrap and the long-prefill tail-keep."""
    cap = c.k.shape[0]
    s_new = k_new.shape[0]
    if s_new > cap:
        drop = s_new - cap
        k_new, v_new = k_new[drop:], v_new[drop:]
        pos = c.length + drop + jnp.arange(cap, dtype=jnp.int32)
        total = c.length + s_new
        slot = jnp.int32(0)
        s_new = cap
    else:
        slot = jax.lax.rem(c.length, cap)
        pos = jax.lax.dynamic_update_slice(
            c.pos, c.length + jnp.arange(s_new, dtype=jnp.int32), (slot,)
        )
        total = c.length + s_new
    if c.k_scale is not None:
        kq, ks = _quant_rows(k_new)
        vq, vs = _quant_rows(v_new)
        return KVCache(
            jax.lax.dynamic_update_slice(c.k, kq, (slot, 0, 0)),
            jax.lax.dynamic_update_slice(c.v, vq, (slot, 0, 0)),
            jax.lax.dynamic_update_slice(c.k_scale, ks, (slot, 0, 0)),
            jax.lax.dynamic_update_slice(c.v_scale, vs, (slot, 0, 0)),
            pos, total,
        )
    return KVCache(
        jax.lax.dynamic_update_slice(c.k, k_new.astype(c.k.dtype), (slot, 0, 0)),
        jax.lax.dynamic_update_slice(c.v, v_new.astype(c.v.dtype), (slot, 0, 0)),
        None, None, pos, total,
    )


def kv_cache_append_slots(cache: KVCache, k_new: Array, v_new: Array) -> KVCache:
    """Per-slot append for continuous batching: ``cache.length`` is (B,) and
    ``cache.pos`` is (B, Smax), so every slot writes at its *own* ring offset
    — slots at different sequence lengths share one compiled step
    (DESIGN.md section Serving)."""
    return jax.vmap(_kv_append_row)(cache, k_new, v_new)


# ---------------------------------------------------------------------------
# Paged KV cache (fixed-size pages in a shared pool, per-row page tables —
# the serving-side layout behind repro.serve.paged.PagedLayout).  Virtual
# addressing preserves the dense ring semantics bit-for-bit: virtual slot v
# of row b lives at pool[page_tbl[b, v // page], v % page], so append/view
# reproduce exactly the (B, cap, Hkv, hd) arrays the dense cache would hold.
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedKVCache:
    """Page-table KV cache node (always per-slot / continuous batching).

    ``k_pool``/``v_pool`` are shared across rows: (P, page, Hkv, hd) with
    page index 0 reserved as a scratch page — unmapped table entries (-1)
    clamp to it, so appends from inactive rows (whose tables the engine has
    cleared) land in scratch instead of corrupting live pages.  ``page_tbl``
    is (B, n_pages) int32 (-1 = unmapped); ``pos``/``length`` keep the exact
    dense per-slot semantics (pos (B, cap) global positions, -1 empty).
    """

    k_pool: Array  # (P, page, Hkv, hd) bf16 or int8
    v_pool: Array
    k_scale: Array | None  # (P, page, Hkv, 1) f32 when int8
    v_scale: Array | None
    page_tbl: Array  # (B, n_pages) int32, -1 = unmapped (-> scratch page 0)
    pos: Array  # (B, cap) int32 global positions (-1 empty)
    length: Array  # (B,) int32 total tokens ever appended per row


def paged_cache_init(batch: int, cap: int, n_kv: int, hd: int, dtype: str,
                     n_pages: int, page_size: int) -> PagedKVCache:
    """Build an empty paged cache: ``n_pages`` usable pages (+1 scratch) of
    ``page_size`` tokens; ``cap`` is the per-row virtual capacity (the dense
    cache's Smax — ring wrap happens in virtual space)."""
    per_row = -(-cap // page_size)
    if n_pages < per_row:
        raise ValueError(
            f"pool of {n_pages} pages cannot hold one full row "
            f"(cap={cap}, page_size={page_size} -> {per_row} pages/row)")
    tbl = jnp.full((batch, per_row), -1, jnp.int32)
    pos = jnp.full((batch, cap), -1, jnp.int32)
    length = jnp.zeros((batch,), jnp.int32)

    def z(dt):
        return jnp.zeros((n_pages + 1, page_size, n_kv, hd), dt)

    if dtype == "int8":
        def sc():
            return jnp.zeros((n_pages + 1, page_size, n_kv, 1), jnp.float32)

        return PagedKVCache(z(jnp.int8), z(jnp.int8), sc(), sc(), tbl, pos, length)
    return PagedKVCache(z(jnp.bfloat16), z(jnp.bfloat16), None, None, tbl, pos,
                        length)


def _paged_addr(cache: PagedKVCache, vi: Array) -> tuple[Array, Array]:
    """Virtual indices (B, S) -> (pool page, in-page offset), clamping
    unmapped entries to the scratch page."""
    ps = cache.k_pool.shape[1]
    pages = jnp.take_along_axis(cache.page_tbl, vi // ps, axis=1)
    return jnp.maximum(pages, 0), vi % ps


def paged_append(cache: PagedKVCache, k_new: Array, v_new: Array) -> PagedKVCache:
    """Per-row ring append through the page table.  Mirrors
    ``kv_cache_append_slots`` exactly in virtual space (same cast, same int8
    row quantization, same pos/length updates); multi-token appends must not
    straddle the virtual wrap point, same as the dense contract."""
    cap = cache.pos.shape[1]
    b, s_new = k_new.shape[:2]
    slot = jax.lax.rem(cache.length, cap)  # (B,)
    vi = slot[:, None] + jnp.arange(s_new, dtype=jnp.int32)  # (B, S)
    rows = jnp.arange(b)[:, None]
    pos = cache.pos.at[rows, vi].set(
        cache.length[:, None] + jnp.arange(s_new, dtype=jnp.int32))
    pages, off = _paged_addr(cache, vi)
    total = cache.length + s_new
    if cache.k_scale is not None:
        kq, ks = _quant_rows(k_new)
        vq, vs = _quant_rows(v_new)
        return PagedKVCache(
            cache.k_pool.at[pages, off].set(kq),
            cache.v_pool.at[pages, off].set(vq),
            cache.k_scale.at[pages, off].set(ks),
            cache.v_scale.at[pages, off].set(vs),
            cache.page_tbl, pos, total,
        )
    return PagedKVCache(
        cache.k_pool.at[pages, off].set(k_new.astype(cache.k_pool.dtype)),
        cache.v_pool.at[pages, off].set(v_new.astype(cache.v_pool.dtype)),
        None, None, cache.page_tbl, pos, total,
    )


def paged_view(cache: PagedKVCache) -> tuple[Array, Array, Array | None, Array | None]:
    """Materialize the dense (B, cap, Hkv, hd) view the attention kernel
    reads: gather pages by table, flatten, trim to the virtual capacity.
    Unmapped entries read the scratch page — garbage there is masked by
    ``pos == -1`` in flash_attention, so the view is bit-identical to the
    dense cache wherever positions are valid."""
    cap = cache.pos.shape[1]
    npg, ps = cache.page_tbl.shape[1], cache.k_pool.shape[1]
    b = cache.page_tbl.shape[0]
    tbl = jnp.maximum(cache.page_tbl, 0)

    def view(pool):
        if pool is None:
            return None
        return pool[tbl].reshape(b, npg * ps, *pool.shape[2:])[:, :cap]

    return view(cache.k_pool), view(cache.v_pool), view(cache.k_scale), view(cache.v_scale)


def paged_scatter_rows(cache: PagedKVCache, k: Array, v: Array,
                       k_scale: Array | None, v_scale: Array | None,
                       pos: Array, length: Array) -> PagedKVCache:
    """Write every row's full (B, cap, ...) virtual content back through the
    page table — the inverse of ``paged_view``, used by the speculative
    rollback to restore rejected writes.  Rows whose table entries are
    unmapped write the scratch page (inactive rows are harmless); rows
    sharing a page write identical bits (shared prefix pages are fully
    settled before any speculative round), so duplicate scatters are
    order-independent."""
    cap = cache.pos.shape[1]
    b = cache.page_tbl.shape[0]
    vi = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32), (b, cap))
    pages, off = _paged_addr(cache, vi)

    def put(pool, vals):
        if pool is None:
            return None
        return pool.at[pages, off].set(vals.astype(pool.dtype))

    return PagedKVCache(
        put(cache.k_pool, k), put(cache.v_pool, v),
        put(cache.k_scale, k_scale), put(cache.v_scale, v_scale),
        cache.page_tbl, pos, length,
    )


def kv_append(cache: KVCache | PagedKVCache, k_new: Array, v_new: Array):
    """Layout dispatch for cache appends (the KVLayout seam): paged nodes
    scatter through their page table, dense per-slot nodes ((B,) lengths)
    ring-append per row, shared-length nodes append at one scalar offset."""
    if isinstance(cache, PagedKVCache):
        return paged_append(cache, k_new, v_new)
    if cache.length.ndim == 1:
        return kv_cache_append_slots(cache, k_new, v_new)
    return kv_cache_append(cache, k_new, v_new)


def kv_view(cache: KVCache | PagedKVCache):
    """The (k, v, k_scale, v_scale) arrays attention reads for this node."""
    if isinstance(cache, PagedKVCache):
        return paged_view(cache)
    return cache.k, cache.v, cache.k_scale, cache.v_scale


def _dequant_chunk(x: Array, scale: Array | None) -> Array:
    if scale is None:
        return x.astype(jnp.float32)
    return x.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Flash attention (chunked-KV online softmax — never materializes S x S)
# ---------------------------------------------------------------------------


def flash_attention(
    q: Array,  # (B, Sq, Hq, hd) f32
    k: Array,  # (B, Skv, Hkv, hd) f32/bf16/int8
    v: Array,
    policy: PrecisionPolicy,
    *,
    causal: bool = True,
    window: int = 0,  # sliding window (0 = unbounded)
    q_offset: Array | int = 0,  # global position of q[0] (decode); (B,) per-slot
    kv_len: Array | int | None = None,  # valid cache length
    kv_positions: Array | None = None,  # (Skv,) or (B, Skv) global positions
    k_scale: Array | None = None,
    v_scale: Array | None = None,
    chunk: int = 1024,
) -> Array:
    """Online-softmax attention, KV scanned in chunks.

    GQA: Hq = Hkv * G.  Scores and attention-value products go through the
    RMPM engine ('attn_qk' / 'attn_av' op classes).  The chunked scan keeps
    the compiled working set at O(Sq * chunk) instead of O(Sq * Skv) — the
    memory-roofline term depends directly on ``chunk``.
    """
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        def padded(x):
            return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))

        k, v = padded(k), padded(v)
        if k_scale is not None:
            k_scale, v_scale = padded(k_scale), padded(v_scale)
        if kv_positions is not None:
            kv_positions = jnp.pad(
                kv_positions,
                ((0, 0), (0, pad)) if kv_positions.ndim == 2 else (0, pad),
                constant_values=-1,
            )
    if kv_len is None:
        kv_len = skv
    if kv_positions is None:
        kv_positions = jnp.arange(n_chunks * chunk, dtype=jnp.int32)
        kv_positions = jnp.where(kv_positions < jnp.asarray(kv_len), kv_positions, -1)

    qg = q.reshape(b, sq, hkv, g, hd).astype(jnp.float32) * (hd**-0.5)
    q_offset = jnp.asarray(q_offset)
    # per-slot decode: q_offset (B,) -> q_pos (B, Sq); shared: (Sq,)
    q_pos = (q_offset[:, None] if q_offset.ndim else q_offset) + jnp.arange(sq)

    kc = k.reshape(b, n_chunks, chunk, hkv, hd)
    vc = v.reshape(b, n_chunks, chunk, hkv, hd)
    ksc = k_scale.reshape(b, n_chunks, chunk, hkv, 1) if k_scale is not None else None
    vsc = v_scale.reshape(b, n_chunks, chunk, hkv, 1) if v_scale is not None else None

    def step(carry, ci):
        m, lse, acc = carry
        kt = _dequant_chunk(
            jax.lax.dynamic_index_in_dim(kc, ci, 1, keepdims=False),
            jax.lax.dynamic_index_in_dim(ksc, ci, 1, keepdims=False) if ksc is not None else None,
        )
        vt = _dequant_chunk(
            jax.lax.dynamic_index_in_dim(vc, ci, 1, keepdims=False),
            jax.lax.dynamic_index_in_dim(vsc, ci, 1, keepdims=False) if vsc is not None else None,
        )
        s = pein("bqhgd,bkhd->bhgqk", qg, kt, "attn_qk", policy)  # (B,Hkv,G,Sq,C)
        kv_pos = jax.lax.dynamic_slice_in_dim(
            kv_positions, ci * chunk, chunk, axis=kv_positions.ndim - 1
        )
        # broadcast to (B|1, Sq|1, C) so per-slot positions mask per batch row
        kv_b = kv_pos if kv_pos.ndim == 2 else kv_pos[None, :]  # (B|1, C)
        q_b = q_pos if q_pos.ndim == 2 else q_pos[None, :]  # (B|1, Sq)
        valid = kv_b[:, None, :] >= 0
        if causal:
            valid = valid & (kv_b[:, None, :] <= q_b[:, :, None])
        if window:
            valid = valid & (kv_b[:, None, :] > q_b[:, :, None] - window)
        s = jnp.where(valid[:, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = lse * alpha + p.sum(axis=-1)
        pv = pein("bhgqk,bkhd->bhgqd", p, vt, "attn_av", policy)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    (m, lse, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(lse, 1e-30)[..., None]
    # (B,Hkv,G,Sq,hd) -> (B,Sq,Hq,hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, hd)


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + flash) — train and decode paths
# ---------------------------------------------------------------------------


def attention_init(key, cfg) -> Params:
    ks = jax.random.split(key, 4)
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    return {
        "wq": dense_init(ks[0], cfg.d_model, hq * hd, cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, hkv * hd, cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, hkv * hd, cfg.qkv_bias),
        "wo": dense_init(ks[3], hq * hd, cfg.d_model),
    }


def attention_apply(
    p: Params,
    x: Array,
    cfg,
    *,
    positions: Array | None = None,
    cache: KVCache | PagedKVCache | None = None,
    window: int = 0,
    causal: bool = True,
    kv_override: tuple[Array, Array] | None = None,  # cross-attention KV
) -> tuple[Array, KVCache | PagedKVCache | None]:
    policy = cfg.policy
    b, s, _ = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    sp = cfg.attn_shard == "sequence"
    q = plinear(x, p["wq"], "qkv", policy).reshape(b, s, hq, hd)
    if kv_override is None:
        k = plinear(x, p["wk"], "qkv", policy).reshape(b, s, hkv, hd)
        v = plinear(x, p["wv"], "qkv", policy).reshape(b, s, hkv, hd)
        if positions is None:
            positions = jnp.arange(s)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if sp:
            # sequence-parallel attention: Q stays S-sharded over 'model';
            # K/V are all-gathered (small for GQA) — without these explicit
            # constraints GSPMD replicates the whole attention computation
            # (measured 5-11x HLO-flop waste, EXPERIMENTS.md section Perf cell A)
            from repro.distributed.sharding import BATCH_AXES as _BA, constrain as _c

            q = _c(q, _BA, "model", None, None)
            k = _c(k, _BA, None, None, None)
            v = _c(v, _BA, None, None, None)
        else:
            from repro.distributed.sharding import BATCH_AXES as _BA, constrain as _c

            q = _c(q, _BA, None, "model", None)
            k = _c(k, _BA, None, "model", None)
            v = _c(v, _BA, None, "model", None)
    else:
        enc = kv_override[0]
        k = plinear(enc, p["wk"], "qkv", policy).reshape(b, enc.shape[1], hkv, hd)
        v = plinear(enc, p["wv"], "qkv", policy).reshape(b, enc.shape[1], hkv, hd)
        causal = False

    if cache is not None and kv_override is None:
        q_offset = cache.length
        cache = kv_append(cache, k, v)  # KVLayout dispatch (paged/per-slot/shared)
        if s > 1:
            # prefill: attend over the fresh full-length K/V (the window
            # cache may be smaller than the prompt; it keeps only the tail)
            fresh_pos = jnp.arange(s, dtype=jnp.int32)
            fresh_pos = (q_offset[:, None] if q_offset.ndim else jnp.asarray(q_offset)) + fresh_pos
            out = flash_attention(
                q, k, v, policy, causal=causal, window=window,
                q_offset=q_offset,
                kv_positions=fresh_pos,
                chunk=cfg.attn_chunk,
            )
        else:
            k_read, v_read, ks_read, vs_read = kv_view(cache)
            out = flash_attention(
                q,
                k_read,
                v_read,
                policy,
                causal=causal,
                window=window,
                q_offset=q_offset,
                kv_positions=cache.pos,
                k_scale=ks_read,
                v_scale=vs_read,
                chunk=cfg.attn_chunk,
            )
    else:
        out = flash_attention(
            q, k, v, policy, causal=causal, window=window, chunk=cfg.attn_chunk
        )
    out = pmm(out.reshape(b, s, hq * hd), p["wo"]["w"], "out", policy)
    return out, cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "gate": dense_init(ks[0], d_model, d_ff),
        "up": dense_init(ks[1], d_model, d_ff),
        "down": dense_init(ks[2], d_ff, d_model),
    }


def swiglu_apply(p: Params, x: Array, policy: PrecisionPolicy) -> Array:
    g = pmm(x, p["gate"]["w"], "mlp_up", policy)
    u = pmm(x, p["up"]["w"], "mlp_up", policy)
    return pmm(jax.nn.silu(g) * u, p["down"]["w"], "mlp_down", policy)


def gelu_mlp_init(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "up": dense_init(ks[0], d_model, d_ff, bias=True),
        "down": dense_init(ks[1], d_ff, d_model, bias=True),
    }


def gelu_mlp_apply(p: Params, x: Array, policy: PrecisionPolicy) -> Array:
    h = jax.nn.gelu(plinear(x, p["up"], "mlp_up", policy))
    return plinear(h, p["down"], "mlp_down", policy)


# ---------------------------------------------------------------------------
# Causal depthwise conv (SSM / RG-LRU front)
# ---------------------------------------------------------------------------


def causal_conv1d(x: Array, w: Array, state: Array | None = None):
    """x: (B, S, C); w: (K, C) depthwise.  Returns (y, new_state) where
    state carries the trailing K-1 inputs for decode."""
    ksz = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (ksz - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(ksz)
    )
    new_state = xp[:, -(ksz - 1) :, :] if ksz > 1 else None
    return y, new_state
