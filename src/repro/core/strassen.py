"""Strassen top-down block matmul (paper C4, section 3.1).

The paper's recommended *top-down* organisation — Strassen as the external
algorithm over 2x2 block partitions, the classical multiply as the internal
(leaf) algorithm — maps onto TPU as: recursive 2x2 block split at trace time,
7 block products per level (vs 8 classical), leaves dispatched to the RMPM
limb engine / MXU.  Each level scales matmul FLOPs by 7/8 in exchange for
O(n^2) extra adds and working set, i.e. it trades the compute roofline term
against the memory term.

Note: the paper's Eq. (3) contains a typo (p11 appears twice); we use the
standard Strassen combination with p22 = S1 - S2 + S3 + S6.

The alpha/beta streaming variant (paper Eq. 8-9) is an FPGA pipelining device;
XLA's scheduler provides the equivalent overlap, so the standard recursion is
kept (DESIGN.md section 2/C4).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array
LeafFn = Callable[[Array, Array], Array]


def _default_leaf(a: Array, b: Array) -> Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def _pad_to(x: Array, rows: int, cols: int) -> Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _strassen(a: Array, b: Array, depth: int, leaf_fn: LeafFn) -> Array:
    if depth == 0:
        return leaf_fn(a, b)
    m, k = a.shape
    _, n = b.shape
    mh, kh, nh = m // 2, k // 2, n // 2
    a11, a12 = a[:mh, :kh], a[:mh, kh:]
    a21, a22 = a[mh:, :kh], a[mh:, kh:]
    b11, b12 = b[:kh, :nh], b[:kh, nh:]
    b21, b22 = b[kh:, :nh], b[kh:, nh:]

    def rec(x, y):
        return _strassen(x, y, depth - 1, leaf_fn)

    # Paper Eq. (2): the seven partial products S1..S7.
    s1 = rec(a11 + a22, b11 + b22)
    s2 = rec(a21 + a22, b11)
    s3 = rec(a11, b12 - b22)
    s4 = rec(a22, b21 - b11)
    s5 = rec(a11 + a12, b22)
    s6 = rec(a21 - a11, b11 + b12)
    s7 = rec(a12 - a22, b21 + b22)
    # Paper Eq. (3) (typo-corrected).
    c11 = s1 + s4 - s5 + s7
    c12 = s3 + s5
    c21 = s2 + s4
    c22 = s1 - s2 + s3 + s6
    return jnp.concatenate(
        [jnp.concatenate([c11, c12], axis=1), jnp.concatenate([c21, c22], axis=1)],
        axis=0,
    )


def strassen_matmul(
    a: Array,
    b: Array,
    depth: int = 1,
    leaf_fn: LeafFn | None = None,
    align: int = 128,
) -> Array:
    """Strassen block matmul a (M, K) @ b (K, N) with ``depth`` recursion
    levels (7^depth leaf products).  Operands are zero-padded so every leaf is
    a multiple of ``align`` (MXU tile) — padding preserves the product.
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError("strassen_matmul is 2D; flatten leading dims first")
    if depth < 0:
        raise ValueError("depth must be >= 0")
    leaf_fn = leaf_fn or _default_leaf
    if depth == 0:
        return leaf_fn(a, b)
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    unit = align * (2**depth)
    mp_, kp, np_ = _ceil_to(m, unit), _ceil_to(k, unit), _ceil_to(n, unit)
    ap = _pad_to(a.astype(jnp.float32), mp_, kp)
    bp = _pad_to(b.astype(jnp.float32), kp, np_)
    out = _strassen(ap, bp, depth, leaf_fn)
    return out[:m, :n]


def leaf_products(depth: int) -> int:
    """Number of leaf matmuls: 7^depth (classical recursion would be 8^depth)."""
    return 7**depth


def flops_ratio(depth: int) -> float:
    """Matmul-FLOP ratio vs classical: (7/8)^depth."""
    return (7.0 / 8.0) ** depth
