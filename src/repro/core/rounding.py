"""Bit-exact mantissa truncation + rounding (paper C3, section 3.3.4).

The paper truncates operands to the selected mantissa width *before*
multiplication and rounds with a 4-bit scheme:

    G = guard  (MSB of the dropped field)
    R = round  (next bit)
    E = extra  (next bit — the paper's addition over classic G/R/T)
    T = sticky (OR of all remaining dropped bits)

    rnd = G & (R | T | E)                                   (paper Eq. 10)

and adds ``rnd`` to the LSB of the kept mantissa (round-up scheme).  We
implement it bit-exactly on the int32 view of f32 (and the int64 view of f64
when x64 is enabled), alongside round-to-nearest-even and plain truncation for
comparison (benchmarks/table9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_ROUNDINGS = ("trunc", "rne", "grte")


def _quantize_bits(xi, mant_bits: int, keep: int, rounding: str, int_dtype, uint_dtype):
    """Quantize the significand of a float viewed as an integer.

    xi: integer view; mant_bits: explicit mantissa field width of the format
    (23 for f32, 52 for f64); keep: number of explicit mantissa bits to keep.
    """
    drop = mant_bits - keep
    if drop <= 0:
        return xi
    one = jnp.asarray(1, uint_dtype)
    xu = xi.astype(uint_dtype)
    lsb_unit = one << drop  # one ULP of the kept format
    kept = xu & ~(lsb_unit - one)

    if rounding == "trunc":
        out = kept
    elif rounding == "grte":
        g = (xu >> (drop - 1)) & one
        r = (xu >> (drop - 2)) & one if drop >= 2 else jnp.zeros_like(xu)
        e = (xu >> (drop - 3)) & one if drop >= 3 else jnp.zeros_like(xu)
        if drop >= 4:
            t = (xu & ((one << (drop - 3)) - one)) != 0
            t = t.astype(uint_dtype)
        else:
            t = jnp.zeros_like(xu)
        rnd = g & (r | t | e)  # paper Eq. (10)
        out = kept + rnd * lsb_unit
    elif rounding == "rne":
        g = (xu >> (drop - 1)) & one
        rest = (xu & ((one << (drop - 1)) - one)) != 0
        lsb = (xu >> drop) & one
        round_up = (g == one) & (rest | (lsb == one))
        out = kept + round_up.astype(uint_dtype) * lsb_unit
    else:
        raise ValueError(f"rounding must be one of {_ROUNDINGS}, got {rounding!r}")
    # Rounding may carry into the exponent field; that is the correct IEEE
    # behaviour (mantissa overflow renormalizes), so plain integer add works.
    return out.astype(int_dtype)


def quantize_mantissa(x: jax.Array, keep_bits: int, rounding: str = "grte") -> jax.Array:
    """Reduce ``x`` to ``keep_bits`` explicit mantissa bits (sign/exponent
    unchanged) using the selected rounding scheme.  Pure-jnp oracle for the
    Pallas kernel in ``kernels/quantize_mantissa``.
    """
    if rounding not in _ROUNDINGS:
        raise ValueError(f"rounding must be one of {_ROUNDINGS}, got {rounding!r}")
    if keep_bits < 1:
        # keep_bits <= 0 would make drop > mant_bits: the kept-mask and the
        # rounding carry then reach into the exponent and sign fields and
        # the "quantized" value is garbage, not a coarser float
        raise ValueError(f"keep_bits must be >= 1, got {keep_bits}")
    if x.dtype == jnp.float32:
        xi = jax.lax.bitcast_convert_type(x, jnp.int32)
        qi = _quantize_bits(xi, 23, min(keep_bits, 23), rounding, jnp.int32, jnp.uint32)
        out = jax.lax.bitcast_convert_type(qi, jnp.float32)
    elif x.dtype == jnp.float64:
        xi = jax.lax.bitcast_convert_type(x, jnp.int64)
        qi = _quantize_bits(xi, 52, min(keep_bits, 52), rounding, jnp.int64, jnp.uint64)
        out = jax.lax.bitcast_convert_type(qi, jnp.float64)
    else:
        raise TypeError(f"quantize_mantissa supports f32/f64, got {x.dtype}")
    # NaN/Inf have all-ones exponents; mantissa rounding could corrupt them
    # (Inf -> NaN or NaN payload change).  Pass specials through untouched.
    return jnp.where(jnp.isfinite(x), out, x)
