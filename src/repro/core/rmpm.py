"""RMPM — the Run-time-reconfigurable Multi-Precision Matmul engine (C1+C2).

This is the paper's reconfigurable floating-point multiplier, lifted from a
single FP multiply to the TPU-native unit of work: a matmul on the MXU.

  * ``mp_matmul(a, b, mode)``         — static-mode k-limb matmul
  * ``mp_matmul_runtime(a, b, mode)`` — runtime mode scalar, one compiled
        executable, ``lax.switch`` selects the active branch (the paper's
        "unused multipliers are shut down"; no recompile <-> no re-synthesis)
  * ``mp_einsum(eq, a, b, mode)``     — same engine for arbitrary
        contractions (attention scores, attention-value, SSD blocks, ...)

Implementation paths:
  * ``impl='xla'``    — limb algebra expressed as jnp dots; XLA lowers each
        pass to an MXU matmul (this is what the dry-run/roofline measures).
  * ``impl='pallas'`` — fused limb-extraction + multi-pass matmul kernel
        (kernels/limb_matmul); TPU target, validated in interpret mode.
  * ``impl='tile'``   — partitioned-SIMD kernel (kernels/tile_matmul): a
        per-tile mode map rides along as a runtime argument, so one fused
        dispatch serves every f32-ladder mode (and mixed-mode maps) with no
        ``lax.switch`` — uniform maps are bit-identical to impl='pallas'.
  * ``impl='native'`` — plain f32 jnp.dot reference execution (numerically
        ~= M24); used for fast CPU end-to-end examples.

High modes (M32/M48) accumulate their partial products with Neumaier
compensation over K-chunks, because a plain f32 accumulator would cap the
achievable precision near 2^-24 for large K (see DESIGN.md section 2 / tests).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import limb as limb_lib
from repro.core.precision import (
    DF32_MODES,
    F32_MODES,
    MODE_LIMBS,
    DoubleF32,
    Mode,
    auto_mode,
)

Array = jax.Array


def _two_sum(a: Array, b: Array) -> tuple[Array, Array]:
    """Knuth TwoSum: s + e == a + b exactly (s = fl(a+b))."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def _neumaier_sum(parts: Sequence[Array]) -> Array:
    s = parts[0]
    comp = jnp.zeros_like(s)
    for p in parts[1:]:
        s, e = _two_sum(s, p)
        comp = comp + e
    return s + comp


# ---------------------------------------------------------------------------
# Core limb contraction
# ---------------------------------------------------------------------------


def _limb_einsum(
    eq: str,
    a,
    b,
    k: int,
    rounding: str = "rne",
    compensated: bool | None = None,
) -> Array:
    """k-limb multi-pass contraction: sum over Karatsuba terms (i+j < k) of
    einsum(a_i, b_j), bf16 x bf16 -> f32 per pass."""
    if compensated is None:
        compensated = k >= 4
    a_limbs = limb_lib.split_limbs(a, k, rounding)
    b_limbs = limb_lib.split_limbs(b, k, rounding)
    terms = limb_lib.limb_product_terms(k)
    parts = [
        jnp.einsum(eq, a_limbs[i], b_limbs[j], preferred_element_type=jnp.float32)
        for (i, j) in terms
    ]
    if compensated:
        return _neumaier_sum(parts)
    acc = parts[0]
    for p in parts[1:]:
        acc = acc + p
    return acc


def _limb_matmul_dd(a, b, k: int, rounding: str = "rne") -> Array:
    """High-precision (M32/M48) 2D matmul with double-f32 (Neumaier)
    accumulation.  a: (M, K), b: (K, N)  ->  (M, N) f32.

    bf16 x bf16 elementwise products are EXACT in f32 (16-bit significands),
    so the only error source is summation; a TwoSum cascade over the K axis
    and the Karatsuba terms keeps it near u^2 ~ 2^-48 instead of the ~2^-24
    cap of a monolithic f32 dot.  O(K) sequential — this is the
    validation-grade path; the TPU Pallas kernel carries (sum, comp) f32
    accumulator pairs in VMEM across K-tiles for the same effect per tile.
    """
    a_limbs = limb_lib.split_limbs(a, k, rounding)  # (k, M, K)
    b_limbs = limb_lib.split_limbs(b, k, rounding)  # (k, K, N)
    terms = limb_lib.limb_product_terms(k)
    m, kdim = a_limbs.shape[1], a_limbs.shape[2]
    n = b_limbs.shape[2]
    a_f = a_limbs.astype(jnp.float32)
    b_f = b_limbs.astype(jnp.float32)

    def step(carry, x):
        s, comp = carry
        for i, j in terms:
            p = a_f[i, :, x][:, None] * b_f[j, x, :][None, :]  # exact in f32
            s, e = _two_sum(s, p)
            comp = comp + e
        return (s, comp), None

    zeros = jnp.zeros((m, n), jnp.float32)
    (s, comp), _ = jax.lax.scan(step, (zeros, zeros), jnp.arange(kdim))
    # The result carries > 24 significand bits, so it is returned as a
    # DoubleF32 pair (the paper likewise outputs the full double-width word).
    hi, lo = _two_sum(s, comp)
    return DoubleF32(hi, lo)


def _check_mode_operands(mode: Mode, a, b) -> None:
    if mode in DF32_MODES:
        return  # DoubleF32 preferred but plain f32 accepted (lo = 0)
    if isinstance(a, DoubleF32) or isinstance(b, DoubleF32):
        raise ValueError(
            f"mode {mode.name} is an f32 mode; DoubleF32 operands need M32/M48"
        )


def mp_einsum(
    eq: str,
    a,
    b,
    mode: Mode = Mode.M24,
    *,
    rounding: str = "rne",
    impl: str = "xla",
    block: tuple[int, int, int] | None = None,
) -> Array:
    """Multi-precision einsum through the RMPM engine (two-operand).

    ``block`` carries the autotuner's Pallas (bm, bn, bk) tile override: it
    is honoured when ``impl='pallas'`` and ``eq`` is the plain 2D matmul
    contraction (dispatched to the fused kernel), and ignored otherwise —
    general einsum contractions run the XLA limb algebra, whose tiling XLA
    owns (same contract as ``mp_matmul``).
    """
    mode = Mode(mode)
    if impl in ("pallas", "tile") and eq == "mk,kn->mn" and mode != Mode.AUTO:
        return mp_matmul(a, b, mode, rounding=rounding, impl=impl, block=block)
    if impl == "native" or mode == Mode.AUTO:
        if mode == Mode.AUTO:
            raise ValueError("AUTO requires mp_matmul_runtime / mp_einsum_runtime")
        av = a.hi + a.lo if isinstance(a, DoubleF32) else a
        bv = b.hi + b.lo if isinstance(b, DoubleF32) else b
        return jnp.einsum(
            eq,
            av.astype(jnp.float32),
            bv.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
    _check_mode_operands(mode, a, b)
    return _limb_einsum(eq, a, b, MODE_LIMBS[mode], rounding)


def mp_matmul(
    a,
    b,
    mode: Mode = Mode.M24,
    *,
    rounding: str = "rne",
    impl: str = "xla",
    strassen_depth: int = 0,
    block: tuple[int, int, int] | None = None,
) -> Array:
    """Multi-precision matmul: a (..., K) @ b (K, N) -> (..., N) f32.

    ``strassen_depth > 0`` routes through the paper's top-down Strassen block
    recursion (C4) with this engine at the leaves.  ``block`` overrides the
    Pallas kernel's (bm, bn, bk) tile sizes — the autotuner's fourth lever
    (repro.tune); it is ignored by the non-Pallas impls, whose tiling XLA
    owns.
    """
    mode = Mode(mode)
    if strassen_depth > 0:
        from repro.core import strassen as strassen_lib  # local import (cycle)

        leaf = functools.partial(
            mp_matmul, mode=mode, rounding=rounding, impl=impl, block=block
        )
        return strassen_lib.strassen_matmul(a, b, depth=strassen_depth, leaf_fn=leaf)
    if impl == "tile":
        from repro.kernels.tile_matmul import ops as tile_ops

        bm, bn, bk = block if block is not None else tile_ops.DEFAULT_BLOCK
        return tile_ops.tile_matmul_mode(
            a, b, mode, rounding=rounding, bm=bm, bn=bn, bk=bk
        )
    if impl == "pallas":
        from repro.kernels.limb_matmul import ops as limb_ops

        if block is not None:
            bm, bn, bk = block
            return limb_ops.limb_matmul(
                a, b, MODE_LIMBS[mode], rounding=rounding, bm=bm, bn=bn, bk=bk
            )
        return limb_ops.limb_matmul(a, b, MODE_LIMBS[mode], rounding=rounding)
    shape_a = a.hi.shape if isinstance(a, DoubleF32) else a.shape
    if len(shape_a) == 2:
        if mode in DF32_MODES and impl == "xla":
            return _limb_matmul_dd(a, b, MODE_LIMBS[mode], rounding)
        return mp_einsum("mk,kn->mn", a, b, mode, rounding=rounding, impl=impl)
    # Rank-generic einsum — do NOT flatten leading dims: a (batch, seq, d)
    # reshape would merge two differently-sharded dims and GSPMD falls back
    # to replicating the matmul over 'model' (measured 16x HLO-flop waste on
    # sequence-parallel archs; EXPERIMENTS.md section Perf cell A).
    lead = "uvwxyz"[: len(shape_a) - 1]
    eq = f"{lead}k,kn->{lead}n"
    return mp_einsum(eq, a, b, mode, rounding=rounding, impl=impl)


# ---------------------------------------------------------------------------
# Runtime reconfiguration (C1's mode-select bits + C2's auto-mode)
# ---------------------------------------------------------------------------


def mp_matmul_runtime(
    a: Array,
    b: Array,
    mode: Array | int | Mode = Mode.AUTO,
    *,
    rounding: str = "rne",
    auto_tol: float = 0.0,
    impl: str = "xla",
    block: tuple[int, int, int] | None = None,
    allow_auto: bool = True,
) -> Array:
    """Runtime-reconfigurable matmul over the f32 mode set {M8, M16, M24}.

    ``mode`` may be a traced int32 scalar (the paper's mode-select bits) — the
    executable contains all three branches but only the selected one runs.
    ``Mode.AUTO`` (0) probes operands and picks the cheapest adequate mode.

    ``impl``/``block`` plumb the planner's execution choice and the
    autotuner's Pallas tile override into every branch, so an adapted
    call-site (repro.adapt) keeps its tuned blocks when the mode scalar
    changes — the tile shape is a property of the GEMM geometry, not of the
    limb count.

    ``allow_auto=False`` asserts the scalar is a concrete mode (1..3), never
    ``Mode.AUTO``: the operand-occupancy probe is skipped entirely.  The
    probe costs a full read of both operands (3 rounds of casts +
    reductions), and ``jnp.where`` evaluates it even when the scalar is
    never 0 — for memory-bound GEMMs that multiplies the step cost.  The
    adaptation loop (repro.adapt), whose mode tables only hold concrete
    modes, uses this path.
    """
    if isinstance(mode, Mode) and mode != Mode.AUTO:
        return mp_matmul(a, b, mode, rounding=rounding, impl=impl, block=block)
    mode_scalar = jnp.asarray(mode, jnp.int32)
    if allow_auto:
        selected = jnp.where(
            mode_scalar == int(Mode.AUTO),
            auto_mode(a, b, tol=auto_tol, max_mode=Mode.M24),
            mode_scalar,
        )
    else:
        selected = mode_scalar
    if impl == "tile":
        # Partitioned-SIMD path: ONE fused dispatch for every mode — the
        # traced scalar becomes a uniform tile map inside the kernel instead
        # of selecting one of N branch executables.
        from repro.kernels.tile_matmul import ops as tile_ops

        bm, bn, bk = block if block is not None else tile_ops.DEFAULT_BLOCK
        return tile_ops.tile_matmul_runtime(
            a, b, selected, rounding=rounding, bm=bm, bn=bn, bk=bk
        )
    branches = [
        functools.partial(mp_matmul, mode=m, rounding=rounding, impl=impl,
                          block=block)
        for m in F32_MODES
    ]
    return jax.lax.switch(jnp.clip(selected - 1, 0, len(branches) - 1), branches, a, b)


def mp_einsum_runtime(
    eq: str,
    a: Array,
    b: Array,
    mode: Array | int,
    *,
    rounding: str = "rne",
    impl: str = "xla",
    block: tuple[int, int, int] | None = None,
) -> Array:
    """Runtime-switchable einsum over the f32 mode set {M8, M16, M24} —
    ``mp_matmul_runtime``'s contraction-generic sibling, used by the adapted
    ``pein`` call-sites (attention scores / attention-value).

    ``impl``/``block`` are forwarded to every branch under the same contract
    as :func:`mp_einsum` (``block`` only takes effect for the pallas 2D
    matmul dispatch).  ``impl='native'`` is rejected: its branches would all
    compute the same plain f32 einsum, silently turning the mode switch into
    a no-op — callers wanting native execution should not bind the site.
    """
    if impl == "native":
        raise ValueError(
            "impl='native' ignores the mode: a runtime switch over identical "
            "branches is a no-op; use the static mp_einsum instead"
        )
    mode_scalar = jnp.asarray(mode, jnp.int32)
    if impl == "tile":
        if eq == "mk,kn->mn":
            return mp_matmul_runtime(
                a, b, mode_scalar, rounding=rounding, impl="tile", block=block,
                allow_auto=False,
            )
        # General contractions have no tile kernel; keep the switch over the
        # XLA limb algebra rather than silently changing numerics.
        impl = "xla"
    branches = [
        functools.partial(mp_einsum, eq, mode=m, rounding=rounding, impl=impl,
                          block=block)
        for m in F32_MODES
    ]
    return jax.lax.switch(
        jnp.clip(mode_scalar - 1, 0, len(branches) - 1), branches, a, b
    )


def mp_matmul_runtime_df32(
    a: DoubleF32,
    b: DoubleF32,
    mode: Array | int | Mode,
    *,
    rounding: str = "rne",
) -> Array:
    """Runtime switch over the extended-precision mode set {M32, M48}."""
    mode_scalar = jnp.asarray(mode, jnp.int32)
    branches = [
        functools.partial(mp_matmul, mode=m, rounding=rounding) for m in DF32_MODES
    ]
    idx = jnp.clip(mode_scalar - int(Mode.M32), 0, len(branches) - 1)
    return jax.lax.switch(idx, branches, a, b)


# ---------------------------------------------------------------------------
# Model-facing convenience
# ---------------------------------------------------------------------------


def mp_linear(x: Array, w: Array, b: Array | None, mode: Mode, **kw) -> Array:
    out = mp_matmul(x, w, mode, **kw)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out
