"""bf16 limb decomposition — the TPU analogue of the paper's Karatsuba split.

A float is expanded into a sum of bf16 "limbs", each carrying the next ~8
significand bits (the MXU's native quantum):

    x = x0 + x1 + ... + x_{k-1} + r_k,   x_i = bf16(x - sum_{j<i} x_j)

For f32 input, 3 limbs reconstruct exactly (24-bit significand) over the
normal range.  Modes beyond 24 bits take DoubleF32 (hi, lo) operands: the hi
word contributes the first 3 limbs, the lo word the rest — mirroring how the
paper feeds 52-bit mantissas through an 8-bit leaf multiplier.

Optionally, limbs can be extracted with the paper's G&(R|T|E) rounding (C3)
instead of the hardware round-to-nearest-even cast.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.precision import DoubleF32
from repro.core.rounding import quantize_mantissa


def _to_bf16(x: jax.Array, rounding: str) -> jax.Array:
    if rounding == "rne":
        return x.astype(jnp.bfloat16)
    # Paper-faithful rounding: quantize the f32 mantissa to bf16's 7 explicit
    # bits with the selected scheme, then the bf16 cast is exact.
    return quantize_mantissa(x, 7, rounding).astype(jnp.bfloat16)


def split_limbs(x, k: int, rounding: str = "rne") -> jax.Array:
    """Split ``x`` (f32 array or DoubleF32) into ``k`` bf16 limbs.

    Returns a (k, *x.shape) bf16 array with x ~= sum_i limbs[i].
    """
    if isinstance(x, DoubleF32):
        hi, lo = x.hi.astype(jnp.float32), x.lo.astype(jnp.float32)
    else:
        hi, lo = x.astype(jnp.float32), None
    limbs = []
    r = hi
    for i in range(k):
        if lo is not None and i == 3:
            # hi's 24 significand bits are exhausted after 3 limbs; inject lo.
            # (The residual r is ~0 here; adding first keeps any leftovers.)
            r = r + lo
            lo = None
        li = _to_bf16(r, rounding)
        limbs.append(li)
        r = r - li.astype(jnp.float32)
    if lo is not None and k < 3:
        pass  # lo never injected: k-limb mode cannot see it (by design).
    return jnp.stack(limbs)


def reconstruct(limbs: jax.Array) -> jax.Array:
    """Sum limbs back to f32 (low-order first for accuracy)."""
    acc = jnp.zeros(limbs.shape[1:], jnp.float32)
    for i in range(limbs.shape[0] - 1, -1, -1):
        acc = acc + limbs[i].astype(jnp.float32)
    return acc


def limb_product_terms(k: int) -> list[tuple[int, int]]:
    """Retained Karatsuba cross products for a k-limb multiply: all (i, j)
    with i + j < k, ordered high-order-first (smallest magnitude first) so the
    f32 accumulation loses the least (paper section 3.3.5.3 economy: terms with
    i + j >= k fall entirely below the kept mantissa and are dropped)."""
    terms = [(i, j) for i in range(k) for j in range(k) if i + j < k]
    terms.sort(key=lambda ij: -(ij[0] + ij[1]))
    return terms
