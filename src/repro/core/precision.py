"""Precision modes for the run-time-reconfigurable multi-precision matmul.

Paper mapping (Arish & Sharma 2017, Table 1):

    paper mode 1 (000) auto            -> Mode.AUTO   (operand probe, C2)
    paper mode 2 (001) 8-bit mantissa  -> Mode.M8     (1 bf16 limb,  1 pass)
    paper mode 3 (010) 16-bit          -> Mode.M16    (2 limbs,      3 passes)
    paper mode 4 (011) 23-bit (single) -> Mode.M24    (3 limbs,      6 passes)
    paper mode 5 (100) 36-bit          -> Mode.M32    (4 limbs,     10 passes)
    paper mode 6 (101) 52-bit (double) -> Mode.M48    (6 limbs,     21 passes)

The TPU MXU's native multiply quantum is the bf16 8-bit significand, so the
paper's mantissa ladder is re-quantized to limb multiples (DESIGN.md section 2).
Modes >= M32 require DoubleF32 (hi, lo) operands since TPU has no f64.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import NamedTuple

import jax
import jax.numpy as jnp


class Mode(enum.IntEnum):
    """Precision mode select (the paper's 3 mode-select bits)."""

    AUTO = 0
    M8 = 1
    M16 = 2
    M24 = 3
    M32 = 4
    M48 = 5


# Number of bf16 limbs per mode.
MODE_LIMBS: dict[Mode, int] = {
    Mode.M8: 1,
    Mode.M16: 2,
    Mode.M24: 3,
    Mode.M32: 4,
    Mode.M48: 6,
}

# Effective significand bits carried per mode (8 bits per limb; bf16 has a
# 7-bit explicit + 1 hidden significand).
MODE_BITS: dict[Mode, int] = {m: 8 * k for m, k in MODE_LIMBS.items()}

# MXU passes = number of retained Karatsuba cross products: |{(i,j): i+j<k}|.
MODE_PASSES: dict[Mode, int] = {m: k * (k + 1) // 2 for m, k in MODE_LIMBS.items()}

# Modes that operate on plain f32 operands (runtime-switchable set).
F32_MODES = (Mode.M8, Mode.M16, Mode.M24)
# Modes that require DoubleF32 operands.
DF32_MODES = (Mode.M32, Mode.M48)


class DoubleF32(NamedTuple):
    """Unevaluated hi+lo f32 pair (Dekker / double-double style).

    value == hi + lo with |lo| <= ulp(hi)/2.  This is the TPU-side stand-in
    for the paper's 52-bit-mantissa double-precision operands.
    """

    hi: jax.Array
    lo: jax.Array

    @property
    def shape(self):
        return self.hi.shape

    @property
    def dtype(self):
        return self.hi.dtype

    def value_f64(self) -> jax.Array:  # oracle-side only (requires x64)
        return self.hi.astype(jnp.float64) + self.lo.astype(jnp.float64)


def df32_from_f64(x) -> DoubleF32:
    """Split a float64 array into a DoubleF32 pair (test/oracle helper)."""
    hi = x.astype(jnp.float32)
    lo = (x - hi.astype(jnp.float64)).astype(jnp.float32)
    return DoubleF32(jnp.asarray(hi), jnp.asarray(lo))


def df32_from_f32(x: jax.Array) -> DoubleF32:
    return DoubleF32(x.astype(jnp.float32), jnp.zeros_like(x, jnp.float32))


@dataclasses.dataclass(frozen=True)
class PrecisionSpec:
    """Carried alongside operands; the software analogue of the paper's
    67-bit bus format (3 mode bits prepended to the IEEE word)."""

    mode: Mode = Mode.M24
    rounding: str = "rne"  # 'rne' | 'grte' | 'trunc'  (C3)
    auto_tol: float = 0.0  # relative tolerance for auto-mode probe

    @property
    def limbs(self) -> int:
        return MODE_LIMBS[self.mode]


def classify(x: jax.Array) -> dict[str, jax.Array]:
    """Exception signals of the paper's multiplier output port:
    zero / infinity / NaN / denormal (per-element booleans).

    Bit-level (exponent==0 / all-ones) so flush-to-zero backends cannot hide
    denormals — mirrors the paper's exponent+significand field tests.
    """
    if x.dtype != jnp.float32:
        x = x.astype(jnp.float32)
    xi = jax.lax.bitcast_convert_type(x, jnp.uint32)
    exp = (xi >> 23) & jnp.uint32(0xFF)
    mant = xi & jnp.uint32(0x7FFFFF)
    return {
        "zero": (exp == 0) & (mant == 0),
        "infinity": (exp == 0xFF) & (mant == 0),
        "nan": (exp == 0xFF) & (mant != 0),
        "denormal": (exp == 0) & (mant != 0),
    }


def mode_mismatch_error(mode_a: jax.Array, mode_b: jax.Array) -> jax.Array:
    """Paper section 3.3.1: operands carrying different mode-select bits raise the
    mode-select-error signal."""
    return jnp.asarray(mode_a) != jnp.asarray(mode_b)


# ---------------------------------------------------------------------------
# Auto-mode (C2): operand limb-occupancy probe.
# ---------------------------------------------------------------------------


def _limbs_needed(x: jax.Array, max_limbs: int, tol: float) -> jax.Array:
    """Smallest k such that the k-limb bf16 expansion reconstructs ``x`` to
    within ``tol * max|x|``.  TPU analogue of the paper's trailing-zero count
    (Fig 7): integer-valued / low-precision data needs fewer limbs."""
    r = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(r)), jnp.finfo(jnp.float32).tiny)
    # Residual magnitude decreases with each extracted limb, so the first k
    # whose residual is within tolerance is  max_limbs - (#ok levels) + 1.
    n_ok = jnp.int32(0)
    for _ in range(max_limbs):
        limb = r.astype(jnp.bfloat16).astype(jnp.float32)
        r = r - limb
        ok = jnp.max(jnp.abs(r)) <= tol * scale
        n_ok = n_ok + ok.astype(jnp.int32)
    return jnp.clip(jnp.int32(max_limbs) - n_ok + 1, 1, max_limbs)


def auto_mode(a: jax.Array, b: jax.Array, tol: float = 0.0, max_mode: Mode = Mode.M24) -> jax.Array:
    """Runtime mode selection from operand contents (paper mode 1).

    Returns an int32 scalar in {1..max_mode} suitable for ``lax.switch``
    dispatch inside a jitted computation (no recompilation — the FPGA paper's
    'no re-synthesis' property).
    """
    max_limbs = MODE_LIMBS[Mode(max_mode)]
    ka = _limbs_needed(a, max_limbs, tol)
    kb = _limbs_needed(b, max_limbs, tol)
    k = jnp.maximum(ka, kb)
    # limb count -> mode index (1,2,3 limbs -> M8,M16,M24; 4->M32; 6->M48)
    k_to_mode = jnp.array([0, 1, 2, 3, 4, 5, 5], dtype=jnp.int32)
    return k_to_mode[jnp.clip(k, 1, 6)]
