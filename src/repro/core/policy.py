"""Per-layer/per-op precision policies.

The paper's mode-select bits are set "by the application program" (section 3.3.1).
In this framework the application program is the model config: a
``PrecisionPolicy`` maps op classes (qkv / attn_qk / attn_av / out / mlp_up /
mlp_down / moe_expert / logits / embed / ssm_in / ...) to RMPM modes, either
statically (compiled per mode — used by dry-run/roofline) or as a runtime
scalar (one executable, ``lax.switch`` — used by serving).
"""
from __future__ import annotations

import dataclasses

from repro.core.precision import Mode


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    default: Mode = Mode.M24
    overrides: tuple[tuple[str, Mode], ...] = ()
    rounding: str = "rne"
    impl: str = "xla"  # 'xla' | 'pallas' | 'tile' | 'native' | 'auto' (planner picks)
    # Largest Strassen depth the planner (repro.plan) may choose for this
    # policy's matmuls.  0 keeps every contraction classical — bit-identical
    # to the pre-planner dispatch; serving/benchmark paths opt in.
    max_strassen_depth: int = 0

    def mode_for(self, op: str) -> Mode:
        for name, mode in self.overrides:
            if name == op:
                return mode
        return self.default

    def with_impl(self, impl: str) -> "PrecisionPolicy":
        return dataclasses.replace(self, impl=impl)

    def with_strassen(self, max_depth: int) -> "PrecisionPolicy":
        return dataclasses.replace(self, max_strassen_depth=max_depth)

    def describe(self) -> str:
        ov = ", ".join(f"{n}={m.name}" for n, m in self.overrides)
        out = f"default={self.default.name}" + (f" [{ov}]" if ov else "")
        if self.impl != "xla":
            out += f" impl={self.impl}"
        if self.max_strassen_depth:
            out += f" strassen<={self.max_strassen_depth}"
        return out


# The paper-faithful baseline: every multiply at single-precision fidelity
# (mode 4 / 23-bit mantissa ~ M24 = 3 limbs, 6 MXU passes).  This is what a
# "conventional" non-reconfigurable FP unit would do, and what XLA's
# HIGHEST-precision f32 matmul does on TPU.
PAPER_BASELINE = PrecisionPolicy(default=Mode.M24)

# Reduced-precision run-time mode: everything in one MXU pass (bf16), the
# paper's mode 2.  Accuracy-critical ops stay higher per the mixed policy.
FAST_M8 = PrecisionPolicy(default=Mode.M8)

# Beyond-paper mixed policy (the optimized configuration in section Perf):
# bulk GEMMs at one pass, numerically sensitive contractions at 2-3 limbs.
MIXED = PrecisionPolicy(
    default=Mode.M8,
    overrides=(
        ("attn_qk", Mode.M16),
        ("logits", Mode.M16),
        ("router", Mode.M24),
    ),
)

# Fast CPU execution path for end-to-end examples (numerically ~= M24).
NATIVE_F32 = PrecisionPolicy(default=Mode.M24, impl="native")

PRESETS: dict[str, PrecisionPolicy] = {
    "paper_baseline": PAPER_BASELINE,
    "fast_m8": FAST_M8,
    "mixed": MIXED,
    "native_f32": NATIVE_F32,
}
