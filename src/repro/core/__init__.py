"""Core: the paper's contribution as composable JAX modules.

C1 rmpm      — run-time-reconfigurable multi-precision matmul engine
C2 precision — mode ladder + auto-mode operand probe
C3 rounding  — G&(R|T|E) / RNE / truncate mantissa quantization
C4 strassen  — top-down Strassen block matmul
"""
from repro.core.precision import (  # noqa: F401
    DF32_MODES,
    F32_MODES,
    MODE_BITS,
    MODE_LIMBS,
    MODE_PASSES,
    DoubleF32,
    Mode,
    PrecisionSpec,
    auto_mode,
    classify,
    df32_from_f32,
    df32_from_f64,
    mode_mismatch_error,
)
from repro.core.policy import (  # noqa: F401
    FAST_M8,
    MIXED,
    NATIVE_F32,
    PAPER_BASELINE,
    PRESETS,
    PrecisionPolicy,
)
from repro.core.rmpm import (  # noqa: F401
    mp_einsum,
    mp_einsum_runtime,
    mp_linear,
    mp_matmul,
    mp_matmul_runtime,
    mp_matmul_runtime_df32,
)
from repro.core.rounding import quantize_mantissa  # noqa: F401
from repro.core.strassen import strassen_matmul  # noqa: F401
