"""AdamW with optional int8 block-quantized moments (distributed-optimization
trick: halves+halves optimizer HBM — what lets kimi-k2 fit 512 chips, see
EXPERIMENTS.md) and cosine/linear schedules with warmup."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array

_BLOCK = 256  # quantization block (last-dim groups)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # 'cosine' | 'linear' | 'const'
    quantize_moments: bool = False


def schedule_lr(cfg: AdamWConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    if cfg.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


# --- int8 block quantization of moment tensors -----------------------------


def _quant(x: Array) -> tuple[Array, Array]:
    """Block-quantize along the LAST dim only: (..., D) -> (..., D/B, B).

    Flattening across dims would destroy the GSPMD sharding (the partitioner
    falls back to full rematerialization of the unsharded tensor — measured
    338 GB/device at kimi scale); last-dim blocking keeps every sharded
    leading dim (experts, d_model rows) intact."""
    d = x.shape[-1] if x.ndim else 1
    block = _BLOCK if d % _BLOCK == 0 else d
    xb = x.reshape(*x.shape[:-1], d // block, block)
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q: Array, scale: Array, shape, size) -> Array:
    del size
    return (q.astype(jnp.float32) * scale).reshape(shape)


def _maybe_q(x: Array, on: bool):
    return _quant(x) if on else x


def _maybe_dq(m, shape, size, on: bool) -> Array:
    return _dequant(*m, shape, size) if on else m


def init_state(params: Any, cfg: AdamWConfig) -> dict:
    # m and v must be DISTINCT buffers (donation forbids aliased arguments)
    q = cfg.quantize_moments

    def zero_q(p):
        return _maybe_q(jnp.zeros_like(p, jnp.float32), q)

    return {
        "step": jnp.int32(0),
        "m": jax.tree.map(zero_q, params),
        "v": jax.tree.map(zero_q, params),
    }


def global_norm(tree: Any) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(params: Any, grads: Any, state: dict, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    q = cfg.quantize_moments
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0

    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(state["m"])
    leaves_v = treedef.flatten_up_to(state["v"])

    new_p, new_m, new_v = [], [], []
    for p, g, m_, v_ in zip(leaves_p, leaves_g, leaves_m, leaves_v):
        g = g.astype(jnp.float32) * clip
        m = _maybe_dq(m_, p.shape, p.size, q)
        v = _maybe_dq(v_, p.shape, p.size, q)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        upd = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(_maybe_q(m, q))
        new_v.append(_maybe_q(v, q))

    return (
        jax.tree.unflatten(treedef, new_p),
        {"step": step, "m": jax.tree.unflatten(treedef, new_m), "v": jax.tree.unflatten(treedef, new_v)},
        {"lr": lr, "grad_norm": gnorm},
    )
