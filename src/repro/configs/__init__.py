"""Registry of the assigned architectures (+ the paper's own 4x4 config)."""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig, reduce_for_smoke

_MODULES = {
    "qwen1.5-4b": "qwen1_5_4b",
    "command-r-plus-104b": "command_r_plus_104b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "internvl2-1b": "internvl2_1b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "whisper-medium": "whisper_medium",
    "mamba2-2.7b": "mamba2_2_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    return reduce_for_smoke(get_config(arch))
