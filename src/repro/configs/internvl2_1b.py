"""internvl2-1b [vlm] — InternViT frontend (stub) + InternLM2 backbone
[arXiv:2404.16821; hf].  14 heads do not divide the model axis -> SP attention.
The modality frontend is a STUB per the assignment: input_specs provides
precomputed patch embeddings (B, n_vision_tokens, d_model)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    head_dim=64,
    n_vision_tokens=256,
    attn_shard="sequence",
)
