"""The paper's own configuration: a 4x4 matrix multiplier built from 2x2
processing elements (Strassen external, RMPM multiplier internal) —
configs for examples/strassen_demo.py and benchmarks."""
from repro.core.policy import PAPER_BASELINE

PE_SIZE = 2        # processing element: 2x2 matmul
MATRIX_SIZE = 4    # top level: 4x4
STRASSEN_DEPTH = 1  # one level of 7-product recursion
POLICY = PAPER_BASELINE
