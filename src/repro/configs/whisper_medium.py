"""whisper-medium [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified].  input_specs provides precomputed frame
embeddings (B, S_frames, d_model); RoPE replaces sinusoidal/learned positions
(documented modernization, DESIGN.md)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
)
