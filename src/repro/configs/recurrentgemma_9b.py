"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, pattern
(rec, rec, attn) 1:2  [arXiv:2402.19427; unverified].  MQA kv=1; local
window 2048."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    hybrid_pattern=("rec", "rec", "attn_local"),
    local_window=2048,
    fsdp=True,
)
