"""qwen1.5-4b [dense] — QKV bias  [hf:Qwen/Qwen1.5-0.5B family; hf].

20 heads do not divide the 16-way model axis -> sequence-parallel attention.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    attn_shard="sequence",
    fsdp=True,
)
