"""kimi-k2-1t-a32b [moe] — 384 experts top-8 + 1 shared, trillion-param MoE
[arXiv:2501.kimi2; unverified, paper-table].  d_ff is per-expert width."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    moe_experts=384,
    moe_top_k=8,
    moe_shared_experts=1,
    moe_first_dense=1,
    fsdp=True,
)
