"""Autotuner CLI: measure the candidate space, persist a tuning table.

    PYTHONPATH=src python -m repro.tune --sizes 128,256,512 --iters 3
    PYTHONPATH=src python -m repro.tune --sizes 128,256 --out /tmp/t.json

The default output path is ``tuning/<backend>.json`` — the location the
planner's ``TUNE_TABLE=tuning`` directory form resolves per backend.
"""

from __future__ import annotations

import argparse

import jax

from repro.core.precision import Mode
from repro.tune.runner import DEFAULT_BLOCKS, tune


def _parse_blocks(spec: str) -> tuple[tuple[int, int, int], ...]:
    out = []
    for part in spec.split(","):
        bm, bn, bk = (int(x) for x in part.strip().split("x"))
        out.append((bm, bn, bk))
    return tuple(out)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Microbenchmark (mode x depth x impl x block) candidates "
        "and write a versioned tuning table for the matmul planner.",
    )
    ap.add_argument("--sizes", default="128,256,512", help="square sizes, comma-sep")
    ap.add_argument("--iters", type=int, default=3, help="timed iterations per cell")
    ap.add_argument("--modes", default="M8,M16,M24", help="RMPM modes to measure")
    ap.add_argument(
        "--impls",
        default="",
        help="comma-sep impl subset (default: native,xla off-TPU; "
        "xla,pallas on TPU)",
    )
    ap.add_argument("--max-depth", type=int, default=1, help="max Strassen depth")
    ap.add_argument(
        "--blocks",
        default=",".join("x".join(map(str, b)) for b in DEFAULT_BLOCKS),
        help="Pallas bm x bn x bk grid, comma-sep (e.g. 128x128x512)",
    )
    ap.add_argument("--align", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="", help="label override (default: host)")
    ap.add_argument(
        "--out",
        default="",
        help="output path (default: tuning/<backend>.json)",
    )
    args = ap.parse_args(argv)

    backend = args.backend or jax.default_backend()
    out = args.out or f"tuning/{backend}.json"
    table = tune(
        tuple(int(s) for s in args.sizes.split(",")),
        backend=backend,
        modes=tuple(Mode[m.strip()] for m in args.modes.split(",")),
        impls=tuple(s.strip() for s in args.impls.split(",")) if args.impls else None,
        max_depth=args.max_depth,
        align=args.align,
        blocks=_parse_blocks(args.blocks),
        iters=args.iters,
        seed=args.seed,
        progress=lambda line: print(line, flush=True),
    )
    table.save(out)
    bal = table.balance
    print(
        f"wrote {out}: {len(table.records)} records, fingerprint "
        f"{table.fingerprint}"
    )
    print(
        f"fitted balance: peak {bal.peak_flops:.3g} FLOP/s, "
        f"bw {bal.hbm_bw:.3g} B/s ({bal.source})"
    )


if __name__ == "__main__":
    main()
