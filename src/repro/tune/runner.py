"""On-device autotuner: microbenchmark the planner's candidate space.

For each shape in the grid the runner measures every candidate execution
point — (RMPM mode, Strassen depth, impl, and Pallas block sizes ``bm/bn/bk``
for kernels/limb_matmul) — and records median wall time, achieved FLOP/s and
max-abs error vs a float64 reference.  The result is a :class:`TuneTable`
(tune/table.py) the planner resolves against instead of trusting the
hand-entered roofline constants (DESIGN.md section Autotuner).

The candidate space mirrors the planner's own (planner._impl_candidates /
_depth_candidates): 'native'+'xla' off-TPU, 'xla'+'pallas'+'tile' (with a
block grid for both kernels) on TPU, depths gated by ``align * 2**depth``
fitting the shape.  'tile' is the partitioned-SIMD kernel run with a uniform
map — measuring it against 'pallas' lets the planner decide from data
whether the per-tile predication costs anything on a given machine.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import numpy as np

from repro.core.precision import MODE_LIMBS, Mode
from repro.tune.table import TuneRecord, TuneTable, mode_key

DEFAULT_MODES = (Mode.M8, Mode.M16, Mode.M24)

#: Pallas block-size grid (bm, bn, bk); ops.py clamps each to the shape, so
#: oversized entries degrade to the whole-dim block instead of failing.
DEFAULT_BLOCKS = ((128, 128, 128), (128, 128, 512))


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One execution point of the tuner's search space."""

    mode: Mode
    impl: str
    depth: int
    block: tuple[int, int, int] | None = None

    def label(self) -> str:
        blk = "x".join(map(str, self.block)) if self.block else "-"
        return f"{mode_key(self.mode, self.impl)}/{self.impl}/d{self.depth}/{blk}"


def depth_candidates(m: int, k: int, n: int, max_depth: int, align: int) -> list[int]:
    """Depths whose leaves keep at least one ``align`` tile per side — the
    same gate the planner applies (planner._depth_candidates)."""
    out = [0]
    for d in range(1, max_depth + 1):
        if min(m, k, n) >= align * (2**d):
            out.append(d)
    return out


def candidates(
    m: int,
    k: int,
    n: int,
    backend: str,
    *,
    modes: tuple[Mode, ...] = DEFAULT_MODES,
    impls: tuple[str, ...] | None = None,
    max_depth: int = 1,
    align: int = 128,
    blocks: tuple[tuple[int, int, int], ...] = DEFAULT_BLOCKS,
) -> list[Candidate]:
    """The measurable candidate space for one shape on one backend."""
    if impls is None:
        impls = ("xla", "pallas", "tile") if backend == "tpu" else ("native", "xla")
    out: list[Candidate] = []
    for depth in depth_candidates(m, k, n, max_depth, align):
        for impl in impls:
            if impl == "native":
                # plain f32 dot ignores the mode: measure once per depth
                out.append(Candidate(Mode.M24, "native", depth))
                continue
            for mode in modes:
                if impl == "pallas":
                    if MODE_LIMBS[mode] < 2:
                        continue  # fused extraction needs >= 2 resident limbs
                    for blk in blocks:
                        out.append(Candidate(mode, "pallas", depth, blk))
                elif impl == "tile":
                    # uniform-map tile kernel: same fused datapath, every
                    # f32 mode (a 1-limb map still beats a switch dispatch)
                    for blk in blocks:
                        out.append(Candidate(mode, "tile", depth, blk))
                else:
                    out.append(Candidate(mode, impl, depth))
    return out


def _median_wall_us(fn, a, b, iters: int, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(a, b))
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(a, b))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def measure(
    m: int,
    k: int,
    n: int,
    cand: Candidate,
    *,
    iters: int = 3,
    seed: int = 0,
) -> TuneRecord:
    """Measure one candidate on one shape: median wall, FLOP/s, f64 error."""
    from repro.core.rmpm import mp_matmul

    rng = np.random.default_rng((seed, m, k, n))
    a = np.asarray(rng.standard_normal((m, k)), np.float32)
    b = np.asarray(rng.standard_normal((k, n)), np.float32)
    aj, bj = jax.numpy.asarray(a), jax.numpy.asarray(b)
    fn = jax.jit(
        functools.partial(
            mp_matmul,
            mode=cand.mode,
            impl=cand.impl,
            strassen_depth=cand.depth,
            block=cand.block,
        )
    )
    wall_us = _median_wall_us(fn, aj, bj, iters)
    out = np.asarray(fn(aj, bj), np.float64)
    ref = a.astype(np.float64) @ b.astype(np.float64)
    max_abs = float(np.abs(out - ref).max())
    rel = max_abs / float(np.abs(ref).max())
    return TuneRecord(
        m=m,
        k=k,
        n=n,
        mode=mode_key(cand.mode, cand.impl),
        impl=cand.impl,
        depth=cand.depth,
        wall_us=wall_us,
        flops_per_s=2.0 * m * k * n / (wall_us * 1e-6),
        max_abs_err=max_abs,
        rel_err=rel,
        block=cand.block,
        iters=iters,
    )


def tune(
    sizes: tuple[int, ...],
    *,
    backend: str | None = None,
    modes: tuple[Mode, ...] = DEFAULT_MODES,
    impls: tuple[str, ...] | None = None,
    max_depth: int = 1,
    align: int = 128,
    blocks: tuple[tuple[int, int, int], ...] = DEFAULT_BLOCKS,
    iters: int = 3,
    seed: int = 0,
    progress=None,
) -> TuneTable:
    """Sweep the candidate space over square ``sizes`` -> a TuneTable."""
    if backend is None:
        backend = jax.default_backend()
    records = []
    for size in sizes:
        m = k = n = int(size)
        for cand in candidates(
            m,
            k,
            n,
            backend,
            modes=modes,
            impls=impls,
            max_depth=max_depth,
            align=align,
            blocks=blocks,
        ):
            rec = measure(m, k, n, cand, iters=iters, seed=seed)
            records.append(rec)
            if progress is not None:
                progress(
                    f"n={size} {cand.label()}: {rec.wall_us:.0f}us "
                    f"({rec.flops_per_s / 1e9:.2f} GFLOP/s, rel={rec.rel_err:.1e})"
                )
    return TuneTable(
        backend=backend,
        records=tuple(records),
        align=align,
        jax_version=jax.__version__,
        iters=iters,
    )
