"""Versioned JSON tuning tables: measured execution-point costs, persisted.

A :class:`TuneTable` holds one :class:`TuneRecord` per measured execution
point — (shape, RMPM mode, impl, Strassen depth, Pallas block sizes) — with
median wall time, achieved FLOP/s and max-abs error vs f64.  The planner
(repro.plan.planner) resolves candidate costs against it in a three-level
order: exact-shape hit, flops-scaled nearest neighbor, roofline fallback
(with the roofline constants themselves re-fit from the table's records via
``repro.plan.cost.fit_balance``).  See DESIGN.md section Autotuner.

Tables are written by ``python -m repro.tune`` to ``tuning/<backend>.json``;
the schema is versioned so a stale committed table fails loudly instead of
silently misplanning.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import math
import os

from repro.core.precision import Mode
from repro.plan.cost import MachineBalance, estimate, fit_balance

SCHEMA_VERSION = 1

#: mode key under which impl='native' records are stored — a plain f32 dot
#: ignores the RMPM mode, so one measurement covers every mode's candidate.
NATIVE_MODE_KEY = "native"

#: neighbor interpolation gives up beyond this M*K*N ratio (either way) and
#: the planner falls back to the (re-fit) roofline instead of extrapolating
#: a measurement across orders of magnitude.
NEIGHBOR_MAX_FLOP_RATIO = 4096.0


def mode_key(mode, impl: str) -> str:
    """Table lookup key for a (mode, impl) pair: native collapses the mode."""
    if impl == "native":
        return NATIVE_MODE_KEY
    return mode if isinstance(mode, str) else Mode(mode).name


@dataclasses.dataclass(frozen=True)
class TuneRecord:
    """One measured execution point."""

    m: int
    k: int
    n: int
    mode: str  # Mode name, or NATIVE_MODE_KEY for impl='native'
    impl: str  # 'native' | 'xla' | 'pallas' | 'tile'
    depth: int  # Strassen depth
    wall_us: float  # median wall time
    flops_per_s: float  # achieved useful rate: 2*m*k*n / wall
    max_abs_err: float  # vs float64 reference
    rel_err: float  # max_abs_err / max|ref|
    block: tuple[int, int, int] | None = None  # Pallas (bm, bn, bk), else None
    iters: int = 0

    @property
    def wall_s(self) -> float:
        return self.wall_us * 1e-6

    @property
    def mkn(self) -> float:
        return float(self.m) * self.k * self.n

    def key(self) -> tuple:
        return (self.m, self.k, self.n, self.mode, self.impl, self.depth)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["block"] = list(self.block) if self.block is not None else None
        return d

    @classmethod
    def from_json(cls, d: dict) -> "TuneRecord":
        d = dict(d)
        if d.get("block") is not None:
            d["block"] = tuple(int(x) for x in d["block"])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class TuneTable:
    """A backend's measured cost table (records + fitted machine balance)."""

    backend: str  # 'cpu' | 'tpu' | 'gpu' — tables never cross backends
    records: tuple[TuneRecord, ...]
    align: int = 128
    jax_version: str = ""
    iters: int = 0

    # -- persistence --------------------------------------------------------

    def to_json(self) -> dict:
        bal = self.balance
        return {
            "schema_version": SCHEMA_VERSION,
            "backend": self.backend,
            "align": self.align,
            "jax_version": self.jax_version,
            "iters": self.iters,
            "balance": {
                "peak_flops": bal.peak_flops,
                "hbm_bw": bal.hbm_bw,
                "source": bal.source,
            },
            "records": [r.to_json() for r in self.records],
        }

    @classmethod
    def from_json(cls, doc: dict) -> "TuneTable":
        version = doc.get("schema_version")
        if version != SCHEMA_VERSION:
            raise ValueError(
                f"tuning-table schema_version {version!r} != supported "
                f"{SCHEMA_VERSION}; re-run `python -m repro.tune`"
            )
        return cls(
            backend=doc["backend"],
            records=tuple(TuneRecord.from_json(r) for r in doc["records"]),
            align=int(doc.get("align", 128)),
            jax_version=doc.get("jax_version", ""),
            iters=int(doc.get("iters", 0)),
        )

    def save(self, path: str) -> None:
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "TuneTable":
        with open(path) as f:
            return cls.from_json(json.load(f))

    # -- identity -----------------------------------------------------------

    @functools.cached_property
    def fingerprint(self) -> str:
        """Content digest — part of the plan-cache key, so swapping tables
        invalidates cached plans without a manual cache clear."""
        blob = json.dumps(self.to_json(), sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]

    # -- lookup: exact hit, then nearest neighbor ---------------------------

    @functools.cached_property
    def _exact(self) -> dict[tuple, TuneRecord]:
        idx: dict[tuple, TuneRecord] = {}
        for r in self.records:
            cur = idx.get(r.key())
            if cur is None or r.wall_us < cur.wall_us:
                idx[r.key()] = r  # best block variant wins
        return idx

    @functools.cached_property
    def _by_config(self) -> dict[tuple, list[TuneRecord]]:
        groups: dict[tuple, list[TuneRecord]] = {}
        for r in self._exact.values():
            groups.setdefault((r.mode, r.impl, r.depth), []).append(r)
        return groups

    def lookup(self, m: int, k: int, n: int, mode, impl: str, depth: int):
        """Exact-shape hit for one candidate, or None.  Among block variants
        of the same point, the fastest measurement wins."""
        return self._exact.get((m, k, n, mode_key(mode, impl), impl, depth))

    def nearest(
        self,
        m: int,
        k: int,
        n: int,
        mode,
        impl: str,
        depth: int,
        max_ratio: float = NEIGHBOR_MAX_FLOP_RATIO,
    ):
        """Closest same-config record by |log MKN ratio| -> (record, ratio).

        ``ratio`` is the candidate/record flop ratio; the caller scales the
        record's wall time by it (constant achieved FLOP/s assumption).
        Returns None when no same-config record sits within ``max_ratio``.
        """
        group = self._by_config.get((mode_key(mode, impl), impl, depth))
        if not group:
            return None
        target = float(m) * k * n
        best = min(group, key=lambda r: abs(math.log(target / r.mkn)))
        ratio = target / best.mkn
        if ratio > max_ratio or ratio < 1.0 / max_ratio:
            return None
        return best, ratio

    # -- fitted machine balance --------------------------------------------

    def record_estimate(self, r: TuneRecord):
        """The roofline's view of one record (default constants)."""
        mode = Mode.M24 if r.mode == NATIVE_MODE_KEY else Mode[r.mode]
        return estimate(r.m, r.k, r.n, mode, r.impl, r.depth, align=self.align)

    @functools.cached_property
    def balance(self) -> MachineBalance:
        """Roofline constants re-fit from this table's measurements."""
        samples = [
            (self.record_estimate(r), r.wall_s) for r in self.records if r.wall_us > 0
        ]
        return fit_balance(samples, source=f"fit:{self.backend}")
