"""repro.tune — measured-cost autotuner feeding the matmul planner.

Microbenchmarks the planner's candidate space — (RMPM mode, Strassen depth,
impl, Pallas block sizes) — on the device it runs on, and persists the
measurements to a versioned JSON tuning table the planner resolves against
(exact hit -> scaled neighbor -> re-fit roofline; DESIGN.md section
Autotuner):

    table = tune((128, 256, 512), iters=3)     # or: python -m repro.tune
    table.save("tuning/cpu.json")
    plan_matmul((256, 256), (256, 256), accuracy=2**-4, tune_table=table)

Tables also load process-wide from the ``TUNE_TABLE`` env var (a table file
or a directory of ``<backend>.json`` files) or via
``repro.plan.set_tune_table``.
"""

from repro.tune.runner import (  # noqa: F401
    DEFAULT_BLOCKS,
    DEFAULT_MODES,
    Candidate,
    candidates,
    depth_candidates,
    measure,
    tune,
)
from repro.tune.table import (  # noqa: F401
    NATIVE_MODE_KEY,
    NEIGHBOR_MAX_FLOP_RATIO,
    SCHEMA_VERSION,
    TuneRecord,
    TuneTable,
    mode_key,
)
