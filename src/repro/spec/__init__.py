"""repro.spec — self-speculative decoding via run-time precision drafting.

The paper's multiplier reconfigures its precision at run time with no
re-synthesis; repro.adapt made that literal in JAX (mode-select bits are jit
scalars).  This package exploits the consequence no fixed-precision engine
gets for free: **the cheap mode of the same compiled step is a draft
model** — speculative decoding with no second set of weights, no second
executable, and no extra parameter memory.

    config.py   SpecConfig + the acceptance-driven draft-shift controller
                (repro.adapt's hysteresis controller fed the measured
                rejection rate instead of a numeric error probe)
    rollout.py  the compiled draft/verify/rollback round: k cheap-mode
                substeps propose, k+1 exact baseline substeps verify, and a
                single rollback-select restores every slot to its accepted
                prefix (KV positions/lengths arithmetically, ring rows by a
                pos-mask select, recurrent states by a per-slot gather)

``ServeEngine(speculate=SpecConfig(...))`` closes the loop.  Outputs are
bit-identical to the non-speculative greedy engine by construction: the
verify chain replays the exact baseline step, so the accepted prefix plus
the correction token *is* the baseline's token sequence.  See DESIGN.md
section Speculative decoding.
"""
from repro.spec.config import AcceptanceController, SpecConfig  # noqa: F401
from repro.spec.rollout import build_spec_round  # noqa: F401
