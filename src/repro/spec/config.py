"""Speculation config + the acceptance-driven draft-shift controller.

The draft model is the verify model's mode table shifted ``draft_shift``
rungs down the runtime-switchable f32 ladder (M24 -> M16 -> M8).  The shift
is itself a run-time knob: the measured draft rejection rate feeds the same
dual-threshold hysteresis controller repro.adapt uses for numeric error, so

  * too many rejections  -> shallower draft (shift toward the verify modes:
    each rejected round wastes draft work, so buy acceptance with precision);
  * high acceptance      -> cheaper draft (spend the headroom on fewer limb
    passes per drafted token).

Precedence vs the PR-4 SLO controller (DESIGN.md section Speculative
decoding): the SLO controller owns the *verify* table — output quality —
and never consults acceptance; this controller owns only the *relative*
draft shift, so when the SLO controller moves the verify table the draft
follows at the same distance.  Output tokens come exclusively from the
verify chain, so neither controller can change what is emitted — only what
it costs.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """``ServeEngine(speculate=SpecConfig(...))`` knobs.

    ``k``: draft depth — cheap-mode tokens proposed per round (the verify
    chain then replays ``k + 1`` positions).  ``draft_shift``: initial rungs
    below the verify table for the draft table (clamped to the ladder).
    ``adapt``: let the acceptance controller retune ``draft_shift`` at run
    time.  ``max_reject``: rejection-rate ceiling — above it the draft
    shallows; at or below ``max_reject * down_factor`` it deepens (the dead
    band between is where the controller holds).  ``every``: controller
    cadence in rounds; ``cooldown``: minimum observations between shifts.
    """

    k: int = 3
    draft_shift: int = 2
    adapt: bool = True
    max_reject: float = 0.4
    down_factor: float = 0.25
    every: int = 4
    cooldown: int = 1

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"draft depth k must be >= 1, got {self.k}")
        if self.draft_shift < 1:
            raise ValueError(
                f"draft_shift must be >= 1 (0 would draft with the verify "
                f"modes themselves), got {self.draft_shift}")
        if not (0.0 < self.max_reject < 1.0):
            raise ValueError(
                f"max_reject must be in (0, 1), got {self.max_reject}")


class AcceptanceController:
    """Rejection rate -> draft-shift moves, with hysteresis.

    Reuses :class:`repro.adapt.HysteresisController` verbatim: the
    "observed error" is the windowed draft rejection rate, an *up* decision
    (error above the SLO) shrinks the shift by one rung, a *down* decision
    grows it.  ``ladder`` is the number of rungs available below the verify
    table (the f32 ladder span), so the shift lives in ``[1, ladder]``.
    """

    def __init__(self, cfg: SpecConfig, ladder: int, shift: int | None = None):
        from repro.adapt import SLO, HysteresisController

        self.cfg = cfg
        self.ladder = max(int(ladder), 1)
        self.shift = max(1, min(cfg.draft_shift if shift is None else shift,
                                self.ladder))
        self.controller = HysteresisController(
            SLO(max_err=cfg.max_reject, down_factor=cfg.down_factor),
            cooldown=cfg.cooldown,
        )

    @property
    def shallower_moves(self) -> int:
        return self.controller.up_shifts

    @property
    def deeper_moves(self) -> int:
        return self.controller.down_shifts

    def observe(self, round_idx, reject_rate: float) -> int:
        """One windowed rejection-rate observation -> applied shift delta
        in {-1, 0, +1} rungs of draft *precision* (+1 = shallower draft)."""
        decision = self.controller.observe(
            round_idx, err=float(reject_rate),
            can_up=self.shift > 1, can_down=self.shift < self.ladder,
        )
        if decision > 0:
            self.shift -= 1  # shallower: draft one rung closer to verify
        elif decision < 0:
            self.shift += 1  # deeper: cheaper draft modes
        return decision
