"""The compiled speculative round: draft k cheap, verify k+1 exact, roll back.

One round over the serve engine's slot array (B = slots):

  1. **draft** — ``k`` single-token substeps of the same compiled decode
     body under the *draft* mode table (``bind_modes``: the mode-select
     scalars are jit arguments, so changing the draft depth costs zero
     recompiles).  The draft runs on a scratch copy of the state: its
     low-precision KV writes and recurrent updates are never kept.
  2. **verify** — ``k + 1`` substeps of the *exact baseline step* (static
     plans, or the live adaptive table when the engine adapts) from the same
     pre-round state, over the inputs ``[t0, d1..dk]``.  Greedy argmax at
     position ``i`` is precisely the token the non-speculative engine would
     have emitted after the first ``i`` inputs — so the longest prefix where
     draft and verify agree, plus verify's correction token at the first
     disagreement, *is* the baseline token sequence (bit-identical outputs).
  3. **rollback-select** — one compiled select restores every slot to its
     accepted prefix, per leaf kind:

       * KV ``length`` / ``DecodeState.position``: arithmetic
         (``len0 + 1 + n_acc``);
       * KV rows (``k``/``v``/scales/``pos``): entries the verify chain
         wrote past the accepted point are restored from the pre-round
         cache by a ``pos > len0 + n_acc`` mask — this also repairs
         sliding-window ring buffers, whose rejected writes land on top of
         still-live old-window rows;
       * recurrent states (SSM / RG-LRU / conv): gathered per slot from the
         per-substep snapshot stack at index ``n_acc`` (these are small —
         the KV cache itself is never stacked);
       * inactive rows keep their exact pre-round state (the engine's
         masking invariant).

The whole round is one function, jitted once per engine: mode tables, draft
shift and acceptance all ride in as array arguments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.adapt import bind_modes
from repro.models.layers import (
    KVCache,
    PagedKVCache,
    paged_scatter_rows,
    paged_view,
)
from repro.serve.engine import row_select as _sel  # the masked-step freeze


def _is_kv(x) -> bool:
    """Cache nodes of either layout — skipped by snapshot, rolled back by
    the pos-mask select rather than the substep stack."""
    return isinstance(x, (KVCache, PagedKVCache))


def _gather_substep(stacked, n_acc, ax: int):
    """Pick snapshot ``n_acc[b]`` per slot from a (k+1, ...)-stacked leaf
    whose unstacked batch axis is ``ax``."""
    shape = [1] * stacked.ndim
    shape[ax + 1] = n_acc.shape[0]
    idx = n_acc.reshape(shape).astype(jnp.int32)
    return jnp.take_along_axis(stacked, idx, axis=0)[0]


def snapshot(state):
    """The rollback payload of one verify substep: every leaf except the KV
    caches (those roll back via length arithmetic + the pos-mask select, so
    stacking them across substeps would be k+1 copies of decode memory)."""
    return jax.tree.map(lambda n: None if _is_kv(n) else n, state,
                        is_leaf=_is_kv)


def _roll_kv(axn: KVCache, c0: KVCache, cf: KVCache, n_acc, active) -> KVCache:
    """Roll one KV cache node back to its accepted prefix.

    ``axn`` carries the per-leaf batch axes (layer-stacked caches put batch
    at axis 1, un-stacked hybrid remainders at axis 0); ``c0``/``cf`` are
    the pre-round and post-verify nodes.  Entries with a stored position
    past the last accepted token were written by rejected substeps: their
    rows (and ring slots — for sliding windows they overwrote live old
    rows) are restored from ``c0``.
    """
    shape = [1] * c0.length.ndim
    shape[axn.length] = n_acc.shape[0]
    keep_last = c0.length + n_acc.reshape(shape)  # position of last kept token
    mask = cf.pos > keep_last[..., None]  # (..., B, Smax): rejected writes

    def mix(fresh, old):
        m = mask.reshape(mask.shape + (1,) * (fresh.ndim - mask.ndim))
        return jnp.where(m, old, fresh)

    rolled = KVCache(
        k=mix(cf.k, c0.k),
        v=mix(cf.v, c0.v),
        k_scale=None if cf.k_scale is None else mix(cf.k_scale, c0.k_scale),
        v_scale=None if cf.v_scale is None else mix(cf.v_scale, c0.v_scale),
        pos=jnp.where(mask, c0.pos, cf.pos),
        length=keep_last + 1,
    )
    return jax.tree.map(lambda ax, new, old: _sel(ax, new, old, active),
                        axn, rolled, c0)


def _roll_paged_one(c0: PagedKVCache, cf: PagedKVCache, keep_last, mask):
    """Roll one un-stacked paged node: mix the pre-round and post-verify
    *virtual views* under the same pos mask the dense rollback uses, then
    scatter every row's mixed content back through the (unchanged) page
    table.  Shared prefix pages receive identical duplicate writes (their
    content is settled before the round and the mask never flips it), and
    unmapped rows write scratch — so the scatter is order-independent."""
    k0, v0, ks0, vs0 = paged_view(c0)
    kf, vf, ksf, vsf = paged_view(cf)

    def mix(fresh, old):
        if fresh is None:
            return None
        m = mask.reshape(mask.shape + (1,) * (fresh.ndim - mask.ndim))
        return jnp.where(m, old, fresh)

    return paged_scatter_rows(
        cf, mix(kf, k0), mix(vf, v0), mix(ksf, ks0), mix(vsf, vs0),
        pos=jnp.where(mask, c0.pos, cf.pos), length=keep_last + 1)


def _roll_paged(axn: PagedKVCache, c0: PagedKVCache, cf: PagedKVCache,
                n_acc, active) -> PagedKVCache:
    """Paged twin of :func:`_roll_kv`.  The verify chain appended through
    the page table (prepare_step pre-allocated and COW-forked pages for all
    k+1 writes), so rejected entries live in private pages: restoring them
    is a per-row virtual mix + scatter.  Per-row leaves (pos/length) then
    freeze inactive rows via the usual select; pool leaves are SHARED —
    inactive rows' cleared tables already routed their writes to scratch."""
    shape = [1] * c0.length.ndim
    shape[axn.length] = n_acc.shape[0]
    keep_last = c0.length + n_acc.reshape(shape)
    mask = cf.pos > keep_last[..., None]
    if c0.length.ndim == 2:  # layer-stacked group
        rolled = jax.vmap(_roll_paged_one)(c0, cf, keep_last, mask)
    else:
        rolled = _roll_paged_one(c0, cf, keep_last, mask)
    return jax.tree.map(lambda ax, new, old: _sel(ax, new, old, active),
                        axn, rolled, c0)


def rollback(axes, state0, state_fin, snaps, n_acc, active):
    """One compiled rollback-select over the whole DecodeState pytree."""

    def roll(axn, s0n, finn, snapn):
        if isinstance(axn, PagedKVCache):
            return _roll_paged(axn, s0n, finn, n_acc, active)
        if isinstance(axn, KVCache):
            return _roll_kv(axn, s0n, finn, n_acc, active)
        return _sel(axn, _gather_substep(snapn, n_acc, axn), s0n, active)

    return jax.tree.map(roll, axes, state0, state_fin, snaps, is_leaf=_is_kv)


def build_spec_round(model, axes, k: int, modal_verify: bool):
    """Build the pure round function for ``model`` (jit it once).

    ``axes``: the engine's per-leaf batch-axis pytree (``_batch_axes``).
    ``modal_verify``: bind the verify substeps to the engine's live mode
    table (the adaptive engines' baseline is the modal step); when False the
    verify substeps run the static-plan path — the exact executable the
    PR-2 baseline engine steps with.

    Returned signature::

        round_fn(params, tokens, state, active, draft_modes, verify_modes)
            -> (drafts (k, B), greedy (k+1, B), n_acc (B,), new_state)
    """
    if k < 1:
        raise ValueError(f"draft depth k must be >= 1, got {k}")

    def round_fn(params, tokens, state, active, draft_modes, verify_modes):
        # -- draft: k cheap-mode substeps on a scratch state ----------------
        def draft_body(carry, _):
            tok, st = carry
            with bind_modes(draft_modes):
                logits, st2 = model.decode_step(params, tok, st)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return (nxt[:, None], st2), nxt

        (_, _), drafts = jax.lax.scan(
            draft_body, (tokens, state), None, length=k)  # drafts: (k, B)

        # -- verify: k+1 exact baseline substeps from the pre-round state ---
        inputs = jnp.concatenate([tokens, drafts.T], axis=1)  # (B, k+1)

        def verify_body(st, tok_col):
            if modal_verify:
                with bind_modes(verify_modes):
                    logits, st2 = model.decode_step(params, tok_col[:, None], st)
            else:
                logits, st2 = model.decode_step(params, tok_col[:, None], st)
            g = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return st2, (g, snapshot(st2))

        state_fin, (greedy, snaps) = jax.lax.scan(
            verify_body, state, inputs.T)  # greedy: (k+1, B)

        # -- accept the longest agreeing prefix, roll back the rest ---------
        match = (drafts == greedy[:-1]).astype(jnp.int32)  # (k, B)
        n_acc = jnp.sum(jnp.cumprod(match, axis=0), axis=0)  # (B,) in [0, k]
        new_state = rollback(axes, state, state_fin, snaps, n_acc, active)
        return drafts, greedy, n_acc, new_state

    return round_fn


#: windowed draft/verify agreement below this rate is a rejection storm —
#: the draft table is too cheap for the current token distribution and the
#: acceptance controller should be pulling the shift shallower
REJECT_STORM_RATE = 0.25


def trace_round(tracer, *, k: int, n_active: int, agreed: int, emitted: int,
                dur_ms: float | None = None) -> None:
    """Emit one speculative round's trace record (repro.obs).

    ``agreed`` is raw draft/verify agreement (what the acceptance controller
    sees), ``emitted`` the tokens that actually left the engine after budget
    clamping.  A round whose acceptance rate drops below
    :data:`REJECT_STORM_RATE` is stamped ``cause="reject_storm"`` so draft
    collapses are findable in the trace without replaying the counters."""
    if not tracer.enabled:
        return
    drafted = k * n_active
    rate = agreed / drafted if drafted else None
    tracer.emit(
        "spec_round",
        cause=("reject_storm" if rate is not None and rate < REJECT_STORM_RATE
               else None),
        dur_ms=dur_ms, n_active=n_active, drafted=drafted, agreed=agreed,
        emitted=emitted, accept_rate=rate)
    tracer.inc("spec_rounds")
    tracer.inc("spec_drafted", drafted)
    tracer.inc("spec_agreed", agreed)
    if rate is not None and rate < REJECT_STORM_RATE:
        tracer.inc("spec_reject_storms")
