"""Fault-tolerant checkpointing: async save thread, atomic commit, keep-K GC,
SIGTERM emergency save, elastic resume (restore reshards to the mesh in
context — a restart may bring up a different device count).

Format: one .npz per host (single-process here; the path layout already
carries a process index for multi-host) + manifest.json with the step,
pytree structure and config fingerprint.  No TensorStore dependency.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/[{i}]"))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: dict[str, Any]) -> Any:
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("[") for k in node):
            return tuple(fix(node[f"[{i}]"]) for i in range(len(node)))
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._emergency_state = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, block: bool = False) -> None:
        # Snapshot to host memory synchronously (donated buffers may die).
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
        if self.async_save and not block:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat)

    def _write(self, step: int, flat: dict[str, np.ndarray]) -> None:
        tmp = os.path.join(self.directory, f".tmp_step_{step:08d}")
        final = os.path.join(self.directory, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard_{jax.process_index():05d}.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "n_arrays": len(flat),
            "bytes": int(sum(v.nbytes for v in flat.values())),
            "process_count": jax.process_count(),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.directory)):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.directory, name, "manifest.json")
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, shardings: Any = None) -> tuple[int, Any]:
        """Elastic restore: if ``shardings`` (matching pytree of NamedSharding)
        is given, arrays are placed with jax.device_put onto the *current*
        mesh — the saved mesh shape is irrelevant (resharding on load)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with np.load(os.path.join(path, f"shard_{jax.process_index():05d}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten(flat)
        if shardings is not None:
            state = jax.tree.map(jax.device_put, state, shardings)
        return step, state

    # -- fault tolerance hooks --------------------------------------------------

    def install_sigterm_handler(self, get_state) -> None:
        """On SIGTERM (preemption), write an emergency checkpoint before exit."""

        def handler(signum, frame):
            step, state = get_state()
            self.save(step, state, block=True)
            raise SystemExit(128 + signum)

        signal.signal(signal.SIGTERM, handler)
