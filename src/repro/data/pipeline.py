"""Data pipeline: synthetic LM stream + packed binary token shards + prefetch.

* ``SyntheticLM``  — deterministic pseudo-text (Zipfian ngram chain) from a
  seed; restart-safe skip-ahead (``state = step index``), so a resumed run
  sees exactly the missed batches.
* ``PackedReader`` — the on-disk format: uint32 tokens in fixed-length
  records, memory-mapped, sharded by (process, data-parallel rank).
* ``Prefetcher``   — background-thread double buffering so host data prep
  overlaps device compute (straggler mitigation lever #1).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class SyntheticLM:
    """Deterministic, learnable synthetic language: a seeded sparse bigram
    chain with Zipfian unigrams — cross-entropy decreases during training,
    so examples/train_lm.py shows real learning without a corpus."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        self.seed = seed
        rng = np.random.default_rng(seed)
        self._succ = rng.integers(0, vocab, size=(vocab, 4), dtype=np.int32)
        self._step = 0

    @property
    def state(self) -> int:
        return self._step

    def skip_to(self, step: int) -> None:
        self._step = step

    def next_batch(self) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed + 1) * 1_000_003 + self._step)
        self._step += 1
        b, s = self.batch, self.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, b)
        branch = rng.integers(0, 4, (b, s))
        noise = rng.random((b, s)) < 0.1
        rand = rng.integers(0, self.vocab, (b, s))
        for t in range(s):
            nxt = self._succ[toks[:, t], branch[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class PackedReader:
    """Reads fixed-length uint32 token records from a binary shard file,
    partitioned across data-parallel ranks; deterministic epoch shuffling."""

    HEADER = 16  # magic(4) version(4) seq_len(4) n_records(4)
    MAGIC = 0x52505244  # 'RPRD'

    @staticmethod
    def write(path: str, tokens: np.ndarray) -> None:
        """tokens: (n_records, seq_len+1) uint32."""
        n, s = tokens.shape
        with open(path, "wb") as f:
            np.array([PackedReader.MAGIC, 1, s, n], np.uint32).tofile(f)
            tokens.astype(np.uint32).tofile(f)

    def __init__(self, path: str, batch: int, rank: int = 0, world: int = 1, seed: int = 0):
        header = np.fromfile(path, np.uint32, 4)
        assert header[0] == self.MAGIC, f"bad magic in {path}"
        self.seq_plus = int(header[2])
        self.n_records = int(header[3])
        self._data = np.memmap(
            path, np.uint32, "r", offset=self.HEADER, shape=(self.n_records, self.seq_plus)
        )
        self.batch, self.rank, self.world, self.seed = batch, rank, world, seed
        self._step = 0

    @property
    def state(self) -> int:
        return self._step

    def skip_to(self, step: int) -> None:
        self._step = step

    def next_batch(self) -> dict[str, np.ndarray]:
        per_epoch = self.n_records // (self.batch * self.world)
        epoch, it = divmod(self._step, max(per_epoch, 1))
        order = np.random.default_rng(self.seed + epoch).permutation(self.n_records)
        base = (it * self.world + self.rank) * self.batch
        idx = order[base : base + self.batch]
        if len(idx) < self.batch:  # wrap small files
            idx = np.resize(idx, self.batch)
        recs = np.asarray(self._data[idx], np.int32)
        self._step += 1
        return {"tokens": recs[:, :-1], "labels": recs[:, 1:]}


class Prefetcher:
    def __init__(self, source, depth: int = 2):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            batch = self.source.next_batch()
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
