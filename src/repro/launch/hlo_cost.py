"""Scan-correct cost extraction from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (XLA cannot assume
trip counts), which under-counts scan-over-layers / gradient-accumulation
programs by orders of magnitude.  This parser walks the HLO call graph and
multiplies each while body by its ``known_trip_count`` backend_config, giving
per-device totals for:

  * flops            — dot/convolution ops (2 * result_elems * contracted)
  * hbm_bytes        — operand + result bytes of dot / fusion / copy /
                       collective ops (a one-pass-over-operands HBM model;
                       VMEM-resident reuse inside a fusion is not charged)
  * collective_bytes — result bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute
                       (per-kind breakdown included)

Shapes in post-partitioning HLO are per-device, so all totals are per-device.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:body|to_apply|calls|branch_computations|called_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _result_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    n_collectives: int = 0

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.by_collective.items():
            self.by_collective[k] += v
        self.n_collectives += other.n_collectives
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(
            self.flops * m,
            self.hbm_bytes * m,
            self.collective_bytes * m,
            defaultdict(float, {k: v * m for k, v in self.by_collective.items()}),
            int(self.n_collectives * m),
        )


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    entry_name = None
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and not stripped.startswith("}"):
            # computation header iff the text before the first '(' has no '='
            # (op lines are '%x = type op(...)'; param lists may contain
            # '=' only inside sharding annotations AFTER the '(')
            head = stripped.split("(", 1)[0]
            if "=" not in head:
                m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", stripped)
                if m:
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry_name = cur
                    continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def parse_hlo_cost(hlo: str, entry_hint: str | None = None) -> Cost:
    comps = _split_computations(hlo)
    # entry: the ENTRY block, else a 'main*' computation, else the first
    entry = entry_hint
    if entry is None and "__entry__" in comps:
        entry = "__entry__"
    if entry is None:
        for name in comps:
            if name.startswith("main"):
                entry = name
                break
    if entry is None:
        entry = next(iter(comps))

    memo: dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # cycle guard
        total = Cost()
        lines = comps.get(name, [])
        # per-computation symbol table for operand shapes
        table: dict[str, str] = {}
        parsed = []
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            vname, vtype, op = dm.group(1), dm.group(2), dm.group(3)
            table[vname] = vtype
            parsed.append((vname, vtype, op, line))
        for vname, vtype, op, line in parsed:
            if op in ("dot", "dot_general"):
                # flops = 2 * result_elems * contracted_size
                lhs_m = _OPERAND_RE.findall(line.split("(", 1)[1])
                lhs_shape = table.get(lhs_m[0], "") if lhs_m else ""
                cdims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                contracted = 1
                if cdims_m and lhs_shape:
                    ldims = _result_dims(lhs_shape)
                    for ci in cdims_m.group(1).split(","):
                        if ci != "" and int(ci) < len(ldims):
                            contracted *= ldims[int(ci)]
                res_elems = _shape_elems(vtype)
                total.flops += 2.0 * res_elems * contracted
                total.hbm_bytes += _shape_bytes(vtype) + sum(
                    _shape_bytes(table.get(o, "")) for o in lhs_m[:2]
                )
            elif op == "convolution":
                res_elems = _shape_elems(vtype)
                total.flops += 2.0 * res_elems * 8  # small; conv is rare here
                total.hbm_bytes += _shape_bytes(vtype)
            # 'copy' is excluded: XLA:CPU materializes while-carry aliasing
            # copies that the TPU backend elides (donated/aliased buffers);
            # charging them inflated the HBM proxy ~2x.
            elif op in ("fusion", "transpose", "reshape", "reduce",
                        "concatenate", "select-and-scatter", "sort"):
                # one pass over operands + result (real HBM traffic)
                ops_m = _OPERAND_RE.findall(line.split("(", 1)[1])
                total.hbm_bytes += _shape_bytes(vtype) + sum(
                    _shape_bytes(table.get(o, "")) for o in ops_m[:8]
                )
            elif op in ("broadcast", "iota", "pad"):
                total.hbm_bytes += _shape_bytes(vtype)  # write-only
            elif op in ("slice", "dynamic-slice", "gather"):
                total.hbm_bytes += 2 * _shape_bytes(vtype)  # read+write the slice
            elif op in ("dynamic-update-slice", "scatter"):
                # traffic ~ the update operand, not the full target buffer
                ops_m = _OPERAND_RE.findall(line.split("(", 1)[1])
                upd = _shape_bytes(table.get(ops_m[1], "")) if len(ops_m) > 1 else 0
                total.hbm_bytes += 2 * upd
            elif op in _COLLECTIVES:
                nbytes = _shape_bytes(vtype)
                total.collective_bytes += nbytes
                total.by_collective[op] += nbytes
                total.n_collectives += 1
                total.hbm_bytes += 2 * nbytes
            if op == "while":
                body_m = re.search(r"body=%?([\w.\-]+)", line)
                trip_m = _TRIP_RE.search(line)
                trips = int(trip_m.group(1)) if trip_m else 1
                if body_m:
                    total += comp_cost(body_m.group(1)).scaled(trips)
                cond_m = _COND_RE.search(line)
                if cond_m:
                    total += comp_cost(cond_m.group(1)).scaled(trips)
            elif op in ("call", "custom-call", "conditional", "async-start", "fusion"):
                for grp in _CALLED_RE.findall(line):
                    for cname in re.split(r",\s*%?", grp):
                        if cname in comps:
                            sub = comp_cost(cname)
                            if op == "fusion":
                                # operand/result bytes already charged at the
                                # call site; only dots matter inside fusions
                                sub = dataclasses.replace(
                                    sub, hbm_bytes=0.0,
                                    by_collective=defaultdict(float, sub.by_collective),
                                )
                            total += sub
        memo[name] = total
        return total

    return comp_cost(entry)


# --------------------------------------------------------------------------
# Roofline terms (TPU v5e constants from the assignment)
# --------------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def roofline_terms(cost: Cost) -> dict:
    """Seconds per term, per chip (cost is already per-device)."""
    t_compute = cost.flops / PEAK_FLOPS
    t_memory = cost.hbm_bytes / HBM_BW
    t_collective = cost.collective_bytes / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "flops_per_device": cost.flops,
        "hbm_bytes_per_device": cost.hbm_bytes,
        "collective_bytes_per_device": cost.collective_bytes,
        "collective_breakdown": dict(cost.by_collective),
    }
