"""The assigned (architecture x input-shape) cells and their step builders.

Shapes (LM family, per assignment):
    train_4k      seq=4096    global_batch=256   (training step)
    prefill_32k   seq=32768   global_batch=32    (inference prefill)
    decode_32k    seq=32768   global_batch=128   (one token, 32k KV cache)
    long_500k     seq=524288  global_batch=1     (long-context decode —
                  sub-quadratic archs only: ssm / hybrid; full-attention
                  archs are N/A by definition, see DESIGN.md)

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins — no
device allocation anywhere in the dry-run path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (
    decode_state_shardings,
    input_shardings,
    param_shardings,
    replicated,
)
from repro.models import build_model
from repro.models.config import ArchConfig
from repro.optim import adamw
from repro.train.step import TrainConfig, make_train_step


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# gradient-accumulation steps per arch for train_4k (activation-memory lever)
ACCUM = {
    "kimi-k2-1t-a32b": 16,
    "command-r-plus-104b": 8,
    "phi3.5-moe-42b-a6.6b": 8,
    "recurrentgemma-9b": 4,
    "qwen1.5-4b": 4,
    "phi3-mini-3.8b": 4,
    "mamba2-2.7b": 2,
    "whisper-medium": 2,
    "qwen1.5-0.5b": 2,
    "internvl2-1b": 2,
}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: no sub-quadratic path (DESIGN.md)"
    return True, ""


def _arch_tweaks(cfg: ArchConfig, shape: ShapeSpec) -> ArchConfig:
    """Per-cell execution knobs (documented levers, not architecture changes)."""
    changes: dict = {}
    if cfg.name == "kimi-k2-1t-a32b":
        # int8 moments: 1T-param AdamW does not fit 512 chips otherwise
        changes["moe_group_size"] = 512
    if shape.kind != "train" and shape.seq >= 32768:
        changes["attn_chunk"] = 2048
    return dataclasses.replace(cfg, **changes) if changes else cfg


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStructs for the *data* inputs of the step."""
    b, s = shape.batch, shape.seq
    i32 = jnp.int32
    f32 = jnp.float32
    if shape.kind == "train":
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        if cfg.family == "vlm":
            st = s - cfg.n_vision_tokens
            return {
                "tokens": jax.ShapeDtypeStruct((b, st), i32),
                "pixel_embeds": jax.ShapeDtypeStruct((b, cfg.n_vision_tokens, cfg.d_model), f32),
                "labels": jax.ShapeDtypeStruct((b, st), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
            }
        if cfg.family == "vlm":
            return {
                "tokens": jax.ShapeDtypeStruct((b, s - cfg.n_vision_tokens), i32),
                "pixel_embeds": jax.ShapeDtypeStruct((b, cfg.n_vision_tokens, cfg.d_model), f32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a seq-length cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}


def _opt_shape(params_shape, ocfg):
    return jax.eval_shape(lambda p: adamw.init_state(p, ocfg), params_shape)


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, *, grad_compression: bool = False) -> dict:
    """Returns dict(fn, args=(shapes...), in_shardings, donate) ready to
    jit/lower — the (architecture x shape x mesh) dry-run unit."""
    cfg = _arch_tweaks(cfg, shape)
    model = build_model(cfg)
    b, s = shape.batch, shape.seq
    data = input_specs(cfg, shape)

    if shape.kind == "train":
        quant_moments = cfg.name == "kimi-k2-1t-a32b"
        tcfg = TrainConfig(
            optimizer=adamw.AdamWConfig(quantize_moments=quant_moments),
            accum_steps=ACCUM.get(cfg.name, 1),
            grad_compression=grad_compression,
        )
        step = make_train_step(model, tcfg, mesh)
        params_shape = jax.eval_shape(model.init, jax.random.key(0))
        state_shape = {
            "params": params_shape,
            "opt": _opt_shape(params_shape, tcfg.optimizer),
        }
        if grad_compression:
            state_shape["residual"] = params_shape
        p_shard = param_shardings(params_shape, cfg, mesh)
        if quant_moments:
            # quantized moments block along the LAST dim: (..., D/B, B).
            # Inherit the param's leading-dim sharding (experts stay EP-
            # sharded); the two trailing block dims replicate.
            from repro.distributed.sharding import _fit_spec

            def _qm(param_leaf, sharding):
                spec = tuple(sharding.spec)
                lead = spec[: max(len(param_leaf.shape) - 1, 0)]
                q_spec = jax.sharding.PartitionSpec(*(lead + (None, None)))
                return (
                    jax.NamedSharding(mesh, q_spec),
                    jax.NamedSharding(mesh, q_spec),
                )

            m_shard = jax.tree.map(_qm, params_shape, p_shard)
        else:
            m_shard = p_shard
        state_shard = {
            "params": p_shard,
            "opt": {"step": replicated(mesh), "m": m_shard, "v": m_shard},
        }
        if grad_compression:
            state_shard["residual"] = p_shard
        return {
            "fn": step,
            "args": (state_shape, data),
            "in_shardings": (state_shard, input_shardings(data, mesh)),
            "out_shardings": (state_shard, None),
            "donate": (0,),
            "model": model,
            "cfg": cfg,
            "tcfg": tcfg,
        }

    # serving cells
    params_shape = jax.eval_shape(model.init, jax.random.key(0))
    p_shard = param_shardings(params_shape, cfg, mesh)
    cache_len = s if shape.kind == "prefill" else s
    enc_len = s if cfg.family == "encdec" else 0
    state_shape = jax.eval_shape(
        functools.partial(model.init_decode_state, b, cache_len)
    )
    if cfg.family == "encdec":
        state_shape = dataclasses.replace(
            state_shape,
            enc_out=jax.ShapeDtypeStruct((b, enc_len, cfg.d_model), jnp.float32),
        )
    s_shard = decode_state_shardings(state_shape, cfg, mesh)

    if shape.kind == "prefill":
        if cfg.family == "encdec":

            def fn(params, frames, tokens, state):
                state = model.prefill_encoder(params, frames, state)
                return model.decode_step(params, tokens, state)

            args = (params_shape, data["frames"], data["tokens"], state_shape)
            insh = (p_shard, input_shardings(data["frames"], mesh),
                    input_shardings(data["tokens"], mesh), s_shard)
            donate = (3,)
        elif cfg.family == "vlm":

            def fn(params, tokens, pixel_embeds, state):
                return model.decode_step(params, tokens, state, pixel_embeds=pixel_embeds)

            args = (params_shape, data["tokens"], data["pixel_embeds"], state_shape)
            insh = (p_shard, input_shardings(data["tokens"], mesh),
                    input_shardings(data["pixel_embeds"], mesh), s_shard)
            donate = (3,)
        else:

            def fn(params, tokens, state):
                return model.decode_step(params, tokens, state)

            args = (params_shape, data["tokens"], state_shape)
            insh = (p_shard, input_shardings(data["tokens"], mesh), s_shard)
            donate = (2,)
    else:  # decode: cache pre-filled to seq length

        def fn(params, tokens, state):
            return model.decode_step(params, tokens, state)

        args = (params_shape, data["tokens"], state_shape)
        insh = (p_shard, input_shardings(data["tokens"], mesh), s_shard)
        donate = (2,)

    return {
        "fn": fn,
        "args": args,
        "in_shardings": insh,
        "out_shardings": (None, s_shard),
        "donate": donate,
        "model": model,
        "cfg": cfg,
    }


def count_params(params_shape, cfg: ArchConfig) -> dict:
    """Total and active (MoE) parameter counts from shapes (no allocation)."""
    total = 0
    active = 0
    embed = 0

    def visit(path, leaf):
        nonlocal total, active, embed
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        is_embed = "embed" in path or "unembed" in path
        if is_embed:
            embed += n
        if cfg.moe_experts and any(s in path for s in ("moe.gate", "moe.up", "moe.down")):
            active += n * cfg.moe_top_k // cfg.moe_experts
        else:
            active += n

    from repro.distributed.sharding import _tree_paths

    for p, leaf in _tree_paths(params_shape):
        visit(p, leaf)
    return {"total": total, "active": active, "embed": embed}


def model_flops(cfg: ArchConfig, shape: ShapeSpec, params_shape) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference), N = active non-embed."""
    counts = count_params(params_shape, cfg)
    n = counts["active"] - counts["embed"]
    # unembed/logits matmul is real compute: add vocab head explicitly
    n_head = cfg.vocab * cfg.d_model
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * (n + n_head) * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * (n + n_head) * tokens
    tokens = shape.batch  # one step
    return 2.0 * (n + n_head) * tokens
