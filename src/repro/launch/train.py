"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --steps 50 --policy native_f32 --ckpt-dir /tmp/repro_ckpt

``--smoke`` shrinks the config to CPU scale (the full configs are for real
meshes; this container has one device).  On a real cluster the same driver
runs the full config: the mesh comes from ``--mesh data,model`` sizes and
jax.distributed initialization happens outside (standard JAX multi-host).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.policy import PRESETS
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed.sharding import param_shardings, replicated
from repro.models import build_model
from repro.optim import adamw
from repro.train.loop import LoopConfig, resume_or_init, train_loop
from repro.train.step import TrainConfig, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced CPU-scale config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--policy", default="native_f32", choices=tuple(PRESETS))
    ap.add_argument(
        "--accuracy", type=float, default=0.0,
        help="relative-error budget for bulk GEMMs; when set, the matmul "
             "planner (repro.plan) derives the precision policy from the "
             "cost model instead of --policy",
    )
    ap.add_argument(
        "--tune-table", default="",
        help="measured-cost tuning table (file or directory, repro.tune) "
             "the planner resolves against; empty = TUNE_TABLE env var, "
             "then pure roofline",
    )
    ap.add_argument("--mesh", default="", help="e.g. '4,2' for (data=4, model=2)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument(
        "--adapt", action="store_true",
        help="grad-norm-drift precision schedule (repro.adapt): the train "
             "step compiles once with runtime mode scalars; the schedule "
             "relaxes precision down the RMPM ladder while the grad norm is "
             "stable and shifts it back up on drift spikes",
    )
    ap.add_argument("--slo-err", type=float, default=0.5,
                    help="adapt: max tolerated relative grad-norm drift")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="adapt: per-step latency target in ms (0 = none)")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.with_policy(PRESETS[args.policy])
    if args.accuracy > 0:
        from repro.plan import plan_model_policy

        planned, plans = plan_model_policy(
            cfg, tokens=args.batch * args.seq, accuracy=args.accuracy,
            tune_table=args.tune_table or None,
        )
        cfg = cfg.with_policy(planned)
        print(f"planned policy ({args.accuracy:.1e} budget): {planned.describe()}")
        for op, p in plans.items():
            print(f"  {op}: {p.describe()}")
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("use examples/ for multimodal drivers on CPU")
    model = build_model(cfg)
    tcfg = TrainConfig(
        optimizer=adamw.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                                    total_steps=args.steps),
        accum_steps=args.accum,
    )

    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        axes = ("data", "model")[: len(shape)]
        mesh = jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(shape))

    step_fn = make_train_step(model, tcfg, mesh)
    schedule = None
    if args.adapt:
        from repro.adapt import (
            SLO,
            ModeTable,
            TrainPrecisionSchedule,
            bind_modes,
        )

        table = ModeTable.from_policy(cfg.policy)
        schedule = TrainPrecisionSchedule(
            table, SLO(max_err=args.slo_err, target_ms=args.slo_ms or None))
        inner_step = step_fn

        def step_fn(state, batch, modes):  # noqa: F811 — modal wrapper
            with bind_modes(modes):
                return inner_step(state, batch)

        print(f"adaptive precision schedule: start {table.describe()}")
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch, seed=0)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    if mesh is not None:
        params_shape = jax.eval_shape(model.init, jax.random.key(0))
        p_shard = param_shardings(params_shape, cfg, mesh)
        state_shard = {
            "params": p_shard,
            "opt": {"step": replicated(mesh), "m": p_shard, "v": p_shard},
        }
        shardings = ((state_shard, None, None) if schedule is not None
                     else (state_shard, None))
        with jax.set_mesh(mesh):
            step = jax.jit(step_fn, in_shardings=shardings, donate_argnums=0)
            start, state = resume_or_init(
                mgr, lambda: init_train_state(model, jax.random.key(0), tcfg), state_shard
            )
    else:
        step = jax.jit(step_fn, donate_argnums=0)
        start, state = resume_or_init(
            mgr, lambda: init_train_state(model, jax.random.key(0), tcfg)
        )
    if start:
        data.skip_to(start)
        print(f"resumed at step {start}")

    n = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"{args.arch}: {n/1e6:.1f}M params | policy {cfg.policy.describe()} | mesh {args.mesh or 'single'}")

    pf = Prefetcher(data)
    try:
        ctx = jax.set_mesh(mesh) if mesh is not None else _null()
        with ctx:
            state, hist = train_loop(
                step, state, pf,
                LoopConfig(total_steps=args.steps, checkpoint_every=args.checkpoint_every),
                ckpt_manager=mgr, start_step=start, adapt=schedule,
                on_metrics=lambda r: print(
                    f"step {r['step']:5d} loss {r['loss']:.4f} gnorm {r['grad_norm']:.2f} "
                    f"dt {r['dt']*1e3:.0f}ms"
                    + (f" mode {r['mode']}" if "mode" in r else "")
                    + (" STRAGGLER" if r["straggler"] else "")
                ),
            )
    finally:
        pf.close()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    print(f"loss {first:.4f} -> {last:.4f}")
    if schedule is not None:
        modes = [h.get("mode") for h in hist if "mode" in h]
        timeline = [modes[0]] if modes else []
        for m in modes[1:]:
            if m != timeline[-1]:
                timeline.append(m)
        print(f"precision schedule: {' -> '.join(timeline)} "
              f"({schedule.table.switches} switches)")


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
