"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init;
smoke tests and benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a (data, model) mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
