"""Serving launcher: open-loop continuous batching on any assigned arch.

Requests arrive by a Poisson process (``--arrival-rate`` req/s of wall
clock; 0 = everything at t=0), with ragged prompt lengths and per-request
decode budgets, and stream through ``repro.serve.ServeEngine``.  Pass
``--accuracy`` to let the matmul planner pick the RMPM precision mode per
phase — prefill and decode GEMMs are planned separately, so a budget near a
mode boundary flips the mode bits *between phases of the same workload*
(the paper's run-time reconfiguration, end to end).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 8 --slots 4 --arrival-rate 2 --accuracy 1e-3 [--kv-int8]

Pass ``--adapt`` (with ``--slo-err``, optionally ``--slo-ms``) to close the
loop at run time: the decode phase's planned modes become a mutable mode
table that repro.adapt's probe + hysteresis controller retunes against the
SLO between steps — one compiled step, the mode scalars select the live
``lax.switch`` branches (zero recompiles).

Pass ``--speculate`` (with ``--draft-k``, ``--draft-shift``) for
self-speculative decoding (repro.spec): the cheap mode of the same step
drafts, the exact baseline step verifies — outputs stay token-identical
while expensive-mode steps per token drop below 1.

Pass ``--multi-tenant`` for a canned two-tenant mix (an ``interactive``
tenant with priority 0 and deadline-carrying chat requests vs a ``bulk``
tenant flooding the slots with long batch decodes) under the priority+EDF
scheduler with preemption, and a per-tenant fairness/SLO report at the end
(``--scheduler-policy fifo`` shows the same traffic without priorities).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.policy import PRESETS
from repro.models import build_model
from repro.plan import plan_cache_stats
from repro.serve import Request, ServeEngine, ragged_requests


def run_open_loop(eng: ServeEngine, reqs: list[Request], rate: float,
                  rng: np.random.Generator) -> dict[int, list[int]]:
    """Submit each request at its Poisson arrival time (wall clock), stepping
    the engine in between — requests join slots mid-flight as capacity
    frees."""
    if rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=len(reqs)))
    else:
        arrivals = np.zeros(len(reqs))
    t0 = time.perf_counter()
    pending = list(zip(arrivals, reqs))
    outputs: dict[int, list[int]] = {}
    while pending or eng.scheduler.has_work():
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            eng.submit(pending.pop(0)[1])
        if eng.scheduler.has_work():
            eng.step()
        elif pending:
            time.sleep(min(pending[0][0] - now, 0.05))
    for rid, toks in eng.drain().items():
        outputs[rid] = toks
    return outputs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=0,
                    help="slot-array width (0 = one per request)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12,
                    help="max prompt length; actual lengths are ragged")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrival rate in req/s (0 = all at t=0)")
    ap.add_argument("--policy", default="native_f32", choices=tuple(PRESETS))
    ap.add_argument("--accuracy", type=float, default=None,
                    help="plan per-phase precision for this relative-error "
                         "budget instead of using the --policy preset modes")
    ap.add_argument("--tune-table", default="",
                    help="measured-cost tuning table (file or directory, "
                         "repro.tune) for the per-phase planner; empty = "
                         "TUNE_TABLE env var, then pure roofline")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--adapt", action="store_true",
                    help="closed-loop runtime precision adaptation of the "
                         "decode phase (repro.adapt)")
    ap.add_argument("--slo-err", type=float, default=0.05,
                    help="SLO: max observed relative error (probe logit "
                         "residual vs the max-mode reference)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="SLO: decode-step latency target in ms (0 = none); "
                         "overshooting applies downward mode pressure "
                         "within the error SLO")
    ap.add_argument("--adapt-every", type=int, default=4,
                    help="probe cadence in decode steps")
    ap.add_argument("--speculate", action="store_true",
                    help="self-speculative decoding (repro.spec): draft "
                         "--draft-k tokens per slot under a cheap mode "
                         "table, verify with the exact baseline step — "
                         "bit-identical outputs, <1 expensive-mode step per "
                         "token")
    ap.add_argument("--draft-k", type=int, default=3,
                    help="draft depth per speculative round")
    ap.add_argument("--draft-shift", type=int, default=2,
                    help="initial rungs below the verify modes for the "
                         "draft table (the acceptance controller retunes "
                         "it at run time)")
    ap.add_argument("--multi-tenant", action="store_true",
                    help="canned interactive-vs-bulk tenant mix under the "
                         "priority scheduler, with a per-tenant SLO report")
    ap.add_argument("--scheduler-policy", default="priority",
                    choices=("priority", "fifo"),
                    help="scheduler for --multi-tenant (default: priority)")
    ap.add_argument("--paged", action="store_true",
                    help="page-table KV cache (repro.serve.paged): admission "
                         "gated on free pages, eviction under page pressure, "
                         "prefix sharing — bit-identical tokens at full "
                         "precision")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (--paged)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="page-pool budget for the largest cache group "
                         "(0 = memory-equivalent to the dense layout)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable read-only prompt-prefix page sharing")
    ap.add_argument("--tier-levels", default="",
                    help="comma-separated keep-bits ladder for precision-"
                         "tiered pages, e.g. '5,3' (empty = tiers off; "
                         "requires --paged and a bf16 KV cache)")
    ap.add_argument("--tier-cold-after", type=int, default=32,
                    help="tokens behind the decode head before a page is "
                         "demotion-eligible")
    ap.add_argument("--tier-every", type=int, default=8,
                    help="decode steps between tier ticks")
    ap.add_argument("--tier-budget", type=float, default=0.0,
                    help="closed-loop residual budget for the tier "
                         "controller (0 = open loop at full depth)")
    ap.add_argument("--trace", action="store_true",
                    help="structured tracing (repro.obs): per-request spans "
                         "+ engine/decision events, printed as a precision "
                         "timeline and profile at exit")
    ap.add_argument("--trace-out", default="",
                    help="write the trace as Chrome-trace/Perfetto JSON to "
                         "this path (implies --trace)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.with_policy(PRESETS[args.policy])
    if args.kv_int8:
        import dataclasses

        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("use examples/ for multimodal drivers on CPU")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    rng = np.random.default_rng(args.seed)
    tenants = None
    classes = None
    if args.multi_tenant:
        from repro.serve import RequestClass, Tenant, class_requests

        tenants = [Tenant("interactive", priority=0, share=2.0),
                   Tenant("bulk", priority=2, share=1.0)]
        classes = [RequestClass("chat", slo_steps=10, prompt_len=6,
                                max_new=max(args.max_new // 2, 2)),
                   RequestClass("batch", prompt_len=args.prompt_len,
                                max_new=args.max_new)]
        n_bulk = max(args.requests // 2, 1)
        reqs = class_requests(classes[1], tenants[1], n_bulk, cfg.vocab, rng)
        reqs += class_requests(classes[0], tenants[0],
                               max(args.requests - n_bulk, 1), cfg.vocab,
                               rng, rid_base=100)
    else:
        reqs = ragged_requests(args.requests, cfg.vocab, args.prompt_len,
                               args.max_new, rng)
    # the grouped config path (ServeConfig.from_flags) — the documented way
    # to construct an engine; all launcher flags route through it
    from repro.serve import ServeConfig

    eng = ServeEngine(
        model, params,
        config=ServeConfig.from_flags(args, tenants=tenants, classes=classes))
    t0 = time.perf_counter()
    outs = run_open_loop(eng, reqs, args.arrival_rate, rng)
    dt = time.perf_counter() - t0
    for rid in sorted(outs):
        print(f"req {rid}: {outs[rid]}")
    # one coherent engine report: plans / adaptation / speculation / tenancy
    # / cache (+ trace and profile when tracing) from the consolidated
    # ServeEngine.describe() surface
    print(eng.format_describe())
    if args.adapt:
        print(f"compiled decode-step variants: {eng.decode_compile_count}")
    if args.speculate:
        print(f"compiled spec-round variants: {eng.spec_compile_count}")
    stats = plan_cache_stats()
    print(f"plan cache: {stats.entries} entries, "
          f"{stats.hits} hits / {stats.misses} misses (process-wide)")
    total = sum(len(v) for v in outs.values())
    print(f"{total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s incl compile; "
          f"kv={cfg.kv_cache_dtype}; slots={eng.config.batch_slots})")
    print(eng.metrics.format_summary())
    if eng.tracer.enabled:
        print(f"precision timeline:\n{eng.tracer.format_timeline()}")
        if args.trace_out:
            doc = eng.tracer.export_chrome(args.trace_out)
            print(f"trace: {len(doc['traceEvents'])} Chrome events "
                  f"-> {args.trace_out}")


if __name__ == "__main__":
    main()
