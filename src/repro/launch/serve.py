"""Serving launcher: batched greedy decode on any assigned architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
        --requests 6 --max-new 16 [--kv-int8]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.policy import PRESETS
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--policy", default="native_f32", choices=tuple(PRESETS))
    ap.add_argument("--kv-int8", action="store_true")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.with_policy(PRESETS[args.policy])
    if args.kv_int8:
        import dataclasses

        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("use examples/ for multimodal drivers on CPU")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                max_new=args.max_new, rid=i)
        for i in range(args.requests)
    ]
    eng = ServeEngine(model, params, batch_slots=max(args.requests, 1),
                      max_len=args.prompt_len + args.max_new + 8)
    t0 = time.perf_counter()
    outs = eng.generate_batch(reqs)
    dt = time.perf_counter() - t0
    total_toks = sum(len(v) for v in outs.values())
    for rid, toks in outs.items():
        print(f"req {rid}: {toks}")
    print(f"{total_toks} tokens in {dt:.2f}s "
          f"({total_toks/dt:.1f} tok/s incl compile; kv={cfg.kv_cache_dtype})")


if __name__ == "__main__":
    main()
